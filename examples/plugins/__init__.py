"""Plugin estimator kinds: the DESIGN.md §19 extension surface, proven.

Importing this package registers two estimator kinds that live entirely
outside ``src/repro`` -- no core module knows their names:

  "theta_kmv"  a KMV/theta bottom-K distinct-value sketch with retained
               multiplicities (docs/PLUGINS.md walks through it line by
               line).  Sample-window semantics, no join support, no
               exact-replay oracle (it estimates distinct values and
               duplicate pairs, not the pairwise-similarity g -- the
               accuracy auditor skips it with ``reason="no_exact_oracle"``).
  "ipf"        a Pagh-Sivertsen-style inner-product filter estimator:
               per-subset partitioned CountSketch rows per level, served
               through the SAME Eq. 4/7 inversions as the paper's sketch.
               Linear window semantics, join-capable, audited by the
               shared pairwise exact oracle.

Point ``REPRO_PLUGINS=examples.plugins`` at this module (or import it)
and both kinds serve through ``EstimationService``, the planner, the
distributed wire format, and the coordinator without a single edit under
``src/repro/{service,distributed,obs}``.
"""
from . import inner_product, theta_sketch  # noqa: F401  (registration)

from .inner_product import IPFConfig, IPFEstimator, IPFState
from .theta_sketch import ThetaConfig, ThetaEstimator, ThetaState

__all__ = [
    "IPFConfig", "IPFEstimator", "IPFState",
    "ThetaConfig", "ThetaEstimator", "ThetaState",
]
