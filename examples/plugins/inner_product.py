"""A Pagh-Sivertsen-style inner-product filter estimator as a PLUGIN kind.

The second DESIGN.md §19 plugin: a *linear*, *join-capable* estimator
kind ("ipf") registered entirely from outside ``src/repro`` -- it rides
the delta-ring window, the MODE_MERGE wire path, the fused join planner,
and the accuracy auditor purely through its :class:`EstimatorSpec`.

The sketch follows the inner-product filtering idea of Pagh et al. /
Pagh-Sivertsen (PAPERS.md): for every threshold level k it maintains one
CountSketch row of width W, partitioned into C(d, k) disjoint regions --
one per size-k attribute subset.  A record hashes each of its C(d, k)
subset projections into that subset's own region with a +/-1 sign.  Two
records colliding *on the same subset's value* add coherently; everything
else cancels in expectation.  The second moment of row k therefore has

    E[y_k] = n * C(d, k) + sum_{j >= k} C(j, k) * x_j

(each record self-collides on all C(d, k) of its subsets; a pair agreeing
on exactly j attributes agrees on C(j, k) size-k subsets) -- which is
EXACTLY the paper's Eq. 4 moment system at sampling ratio r = 1.  The
estimator therefore reuses the public inversions ``sjpc.f2_to_pair_count``
(self-join) and ``sjpc.inner_to_join_count`` (Eq. 7 two-stream join)
verbatim: a genuinely different sketch served through the same algebra.

Because the regions are disjoint, a single record never collides with
itself across subsets: at n <= 1 the moments are exact and the estimate
degenerates to the truth, as the conformance matrix demands.  States are
plain counter arrays, so merge/subtract are leaf-wise +/- (``linear=True``:
delta-ring windows, arithmetic wire deltas, bit-exact expiry).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sjpc
from repro.estimators import (EstimateTable, Estimator,
                              pairwise_exact_oracle, register, scan_rounds,
                              stack_states)


@dataclasses.dataclass(frozen=True)
class IPFConfig:
    """Static sketch shape: one (num_levels, row_width) counter plane.
    Frozen + hashable on purpose: the instance's config doubles as the
    planner's fusion-signature key (see ``_fusion_key``)."""
    d: int
    s: int
    row_width: int
    seed: int

    @property
    def num_levels(self) -> int:
        return self.d - self.s + 1


class IPFState(NamedTuple):
    """One stream's sketch: the counter plane plus the record count.
    The counter leaf is named ``counters`` like SJPC's -- linear states
    are pure arithmetic, and keeping the conventional name lets generic
    linear-algebra checks (tests, harness oracles) apply unchanged."""
    counters: jnp.ndarray   # (L, W) int32
    n: jnp.ndarray          # ()  int32


def _fmix(h: jnp.ndarray) -> jnp.ndarray:
    h ^= h >> 16
    h = h * jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    h = h * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 16)


class IPFEstimator(Estimator):
    kind = "ipf"
    linear = True
    supports_join = True

    def __init__(self, cfg: IPFConfig):
        self.cfg = cfg
        W = cfg.row_width
        # host-side per-level constants: subset index arrays, region
        # strides, per-subset hash salts (all closed over by the jit)
        self._subsets, self._strides, self._salts = [], [], []
        for k in self.thresholds:
            subs = np.array(list(itertools.combinations(range(cfg.d), k)),
                            dtype=np.int32).reshape(-1, k)
            stride = W // subs.shape[0]
            if stride < 1:
                raise ValueError(
                    f"ipf row_width {W} cannot partition into "
                    f"C({cfg.d},{k}) = {subs.shape[0]} subset regions")
            base_salt = (cfg.seed * 2654435761 ^ (k << 16)) & 0xFFFFFFFF
            salts = (np.uint32(base_salt)
                     ^ (np.arange(subs.shape[0]).astype(np.uint64)
                        * 0x85EBCA6B & 0xFFFFFFFF).astype(np.uint32))
            self._subsets.append(subs)
            self._strides.append(stride)
            self._salts.append(salts)
        self._rounds_fn = jax.jit(
            functools.partial(scan_rounds, self._ingest_one))

    # -- static config -------------------------------------------------
    @property
    def d(self) -> int:
        return self.cfg.d

    @property
    def s(self) -> int:
        return self.cfg.s

    @property
    def seed(self) -> int:
        return self.cfg.seed

    # -- state algebra -------------------------------------------------
    def init(self, sid: int = 0) -> IPFState:
        del sid                                    # linear: no provenance
        return IPFState(
            counters=jnp.zeros((self.num_levels, self.cfg.row_width),
                               jnp.int32),
            n=jnp.zeros((), jnp.int32))

    def _ingest_one(self, state: IPFState, values, mask, key) -> IPFState:
        del key                                    # hash-based, PRNG-free
        counters = state.counters
        madd = mask.astype(jnp.int32)              # (B,)
        for li, (subs, stride, salts) in enumerate(
                zip(self._subsets, self._strides, self._salts)):
            sub = values[:, subs].astype(jnp.uint32)     # (B, C, k)
            h = jnp.broadcast_to(jnp.asarray(salts)[None, :], sub.shape[:2])
            for t in range(sub.shape[-1]):
                h = (h ^ sub[..., t]) * jnp.uint32(0x9E3779B1)
            h = _fmix(h)
            sign = (1 - 2 * (h >> 31).astype(jnp.int32))       # (B, C)
            base = jnp.arange(subs.shape[0], dtype=jnp.int32) * stride
            bucket = base[None, :] + (h % jnp.uint32(stride)).astype(jnp.int32)
            contrib = sign * madd[:, None]
            counters = counters.at[li, bucket.reshape(-1)].add(
                contrib.reshape(-1))
        return IPFState(counters=counters,
                        n=state.n + jnp.sum(madd))

    def ingest_rounds(self, states, values, row_mask, keys):
        return self._rounds_fn(states, jnp.asarray(values),
                               jnp.asarray(row_mask), keys)

    def merge(self, a: IPFState, b: IPFState) -> IPFState:
        return IPFState(counters=a.counters + b.counters, n=a.n + b.n)

    def subtract(self, a: IPFState, b: IPFState) -> IPFState:
        # exact counter arithmetic, deliberately unclamped: the window's
        # delta-ring expiry relies on subtract being merge's true inverse
        return IPFState(counters=a.counters - b.counters, n=a.n - b.n)

    def memory_bytes(self) -> int:
        return self.num_levels * self.cfg.row_width * 4

    # -- estimation ----------------------------------------------------
    def _host(self, states):
        counters = np.asarray(jax.device_get(states.counters),
                              dtype=np.float64)            # (N, L, W)
        n = np.asarray(jax.device_get(states.n), dtype=np.float64)
        return counters, n

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        del use_pallas, interpret                  # host-numpy estimator
        counters, n = self._host(states)
        y = (counters ** 2).sum(axis=2)            # (N, L) second moments
        N, L = y.shape
        x = np.zeros((N, L))
        for i in range(N):
            x[i] = sjpc.f2_to_pair_count(self.d, self.s, n[i], 1.0, y[i],
                                         clamp=clamp)
        g = np.cumsum(x[:, ::-1], axis=1)[:, ::-1] + n[:, None]
        zeros = np.zeros_like(x)
        return EstimateTable(x=x, g=g, y=y, n=n, stderr=zeros,
                             stderr_offline=zeros, stderr_kind="none")

    def estimate_ref(self, state, *, clamp: bool = True) -> EstimateTable:
        return self.estimate_batch(stack_states([state]), clamp=clamp)

    def estimate_join_batch(self, states_a, states_b, *,
                            clamp: bool = True,
                            use_pallas: bool | None = None,
                            interpret: bool | None = None) -> EstimateTable:
        del use_pallas, interpret
        ca, n_a = self._host(states_a)
        cb, n_b = self._host(states_b)
        y = (ca * cb).sum(axis=2)                  # (N, L) inner products
        N, L = y.shape
        x = np.zeros((N, L))
        for i in range(N):
            x[i] = sjpc.inner_to_join_count(self.d, self.s, 1.0, y[i],
                                            clamp=clamp)
        g = np.cumsum(x[:, ::-1], axis=1)[:, ::-1]  # join g: pairs only
        zeros = np.zeros_like(x)
        return EstimateTable(x=x, g=g, y=y, n=np.stack([n_a, n_b], axis=1),
                             stderr=zeros, stderr_offline=zeros,
                             stderr_kind="none")

    def estimate_join_ref(self, state_a, state_b, *,
                          clamp: bool = True) -> EstimateTable:
        return self.estimate_join_batch(stack_states([state_a]),
                                        stack_states([state_b]),
                                        clamp=clamp)


def _fusion_key(est: IPFEstimator):
    """Planner fusion signature: same frozen config -> same jit shape ->
    fusable cohort (the spec's ``fusion`` hook; DESIGN.md §19)."""
    return est.cfg


def _factory(cfg, *, params=None, estimator_cfg=None, opts=None):
    """Equal-space factory: spread the group's counter budget
    (L * depth * width int32 cells) across L partitioned rows of
    W = depth * width cells -- memory_bytes == cfg.counters_bytes."""
    del params
    opts = opts or {}
    row_width = int(opts.get("row_width", cfg.width * cfg.depth))
    ipf_cfg = estimator_cfg or IPFConfig(
        d=cfg.d, s=cfg.s, row_width=row_width, seed=cfg.seed ^ 0x1BF0)
    return IPFEstimator(ipf_cfg)


register("ipf", _factory, state_cls=IPFState,
         linear=True, join_capable=True, stderr_kind="none",
         fusion=_fusion_key, exact_oracle=pairwise_exact_oracle)
