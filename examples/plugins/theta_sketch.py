"""A KMV/theta bottom-K distinct-value sketch as a PLUGIN estimator kind.

This module is the docs/PLUGINS.md cookbook example: a complete estimator
kind ("theta_kmv") registered from outside ``src/repro`` through the one
declarative :class:`repro.estimators.EstimatorSpec` surface.  Nothing in
the service, wire, planner, or observability layers names it -- they all
read the spec.

The sketch is the classic KMV ("k minimum values") / theta tuple sketch:
hash every record to a uniform 32-bit key and keep the K smallest distinct
(key, provenance-tag) entries, each with the multiplicity of records that
produced it.  With ``theta`` = (K-th smallest retained key + 1) / 2^32,
every distinct value survives independently with probability ``theta``,
so retained counts scale by ``1/theta``:

* distinct values  D-hat = (retained_distinct - 1) / theta  (full sketch)
* duplicate pairs  P-hat = sum_v c_v * (c_v - 1) / theta    (ordered)

A duplicate pair agrees on ALL d attributes, so it is k-similar at every
threshold: the estimator reports ``x`` = 0 except at level d (the
duplicate pairs) and the constant column ``g_k = n + P-hat`` -- a lawful,
weakly non-increasing g table, just a deliberately coarse one.  That is
the point of the example: the conformance matrix, the wire format, and
the service accept it because it honors the *protocol*, not because it
matches the paper's estimator.

Window semantics are the sample-window algebra of reservoir.py: states
are NOT linear (a bottom-K union is not counter addition), merge is the
exact identity bottomK(A union B) = bottomK(bottomK(A) union bottomK(B)),
and subtract drops entries by provenance tag (exact for the epoch states
the window hands it).  No exact-replay oracle is registered -- the
accuracy auditor skips this kind with ``reason="no_exact_oracle"``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.estimators import (EstimateTable, Estimator, register,
                              scan_rounds, stack_states)

_EMPTY_KEY = jnp.uint32(0xFFFFFFFF)   # slot sentinel; validity is tag >= 0
_ENTRY_BYTES = 12                      # key u32 + count i32 + tag i32


@dataclasses.dataclass(frozen=True)
class ThetaConfig:
    """Static plugin configuration, derived from the group's SJPCConfig
    by the factory (equal-space: capacity = counters_bytes // 12)."""
    d: int
    s: int
    capacity: int
    seed: int


class ThetaState(NamedTuple):
    """One stream's sketch: K slots of (key, count, tag) entries.

    ``tag`` is the provenance sid (-1 = empty slot) -- the same
    tag-algebra reservoir.py uses, so the window's epoch expiry
    (subtract-by-tag) is exact.  ``keys`` of empty slots hold the
    0xFFFFFFFF sentinel so a plain sort pushes them to the tail.
    """
    keys: jnp.ndarray     # (K,) uint32
    counts: jnp.ndarray   # (K,) int32 records retained behind each key
    tags: jnp.ndarray     # (K,) int32 provenance sid, -1 = empty
    n: jnp.ndarray        # ()  int32 records represented
    sid: jnp.ndarray      # ()  int32 this state's provenance tag


def _hash_rows(values: jnp.ndarray, seed: int) -> jnp.ndarray:
    """(B, d) uint32 records -> (B,) uniform 32-bit keys (fold-multiply
    mix per attribute + a murmur3-style finalizer)."""
    h = jnp.full(values.shape[0], jnp.uint32(seed ^ 0x0D15C0DE))
    for c in range(values.shape[-1]):
        h = (h ^ values[..., c].astype(jnp.uint32)) * jnp.uint32(0x9E3779B1)
    h ^= h >> 16
    h = h * jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    h = h * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 16)


def _combine(keys, counts, tags, capacity: int):
    """bottomK of a pooled entry list: lexsort by (key, tag) with empties
    last, coalesce equal (key, tag) runs, keep the first ``capacity``.

    The two-pass stable argsort is a lexicographic sort (secondary key
    first); empty slots sort via a +inf tag surrogate so a *valid* entry
    whose key happens to equal the sentinel still lands ahead of them.
    """
    valid = tags >= 0
    tag_key = jnp.where(valid, tags, jnp.int32(0x7FFFFFFF))
    order = jnp.argsort(tag_key, stable=True)
    keys, counts, tag_key = keys[order], counts[order], tag_key[order]
    order = jnp.argsort(keys, stable=True)
    keys, counts, tag_key = keys[order], counts[order], tag_key[order]

    m = keys.shape[0]
    first = jnp.concatenate([
        jnp.ones((1,), bool),
        (keys[1:] != keys[:-1]) | (tag_key[1:] != tag_key[:-1])])
    gid = jnp.cumsum(first.astype(jnp.int32)) - 1
    out_counts = jax.ops.segment_sum(counts, gid, num_segments=m)
    out_keys = jnp.full((m,), _EMPTY_KEY).at[gid].set(keys)
    out_tags = jnp.full((m,), -1, jnp.int32).at[gid].set(
        jnp.where(tag_key == jnp.int32(0x7FFFFFFF), -1, tag_key))
    out_counts = jnp.where(out_tags >= 0, out_counts, 0)
    return out_keys[:capacity], out_counts[:capacity], out_tags[:capacity]


class ThetaEstimator(Estimator):
    kind = "theta_kmv"
    linear = False
    supports_join = False

    def __init__(self, cfg: ThetaConfig):
        self.cfg = cfg
        self._rounds_fn = jax.jit(
            functools.partial(scan_rounds, self._ingest_one))

    # -- static config -------------------------------------------------
    @property
    def d(self) -> int:
        return self.cfg.d

    @property
    def s(self) -> int:
        return self.cfg.s

    @property
    def seed(self) -> int:
        return self.cfg.seed

    # -- state algebra -------------------------------------------------
    def init(self, sid: int = 0) -> ThetaState:
        K = self.cfg.capacity
        return ThetaState(
            keys=jnp.full((K,), _EMPTY_KEY),
            counts=jnp.zeros((K,), jnp.int32),
            tags=jnp.full((K,), -1, jnp.int32),
            n=jnp.zeros((), jnp.int32),
            sid=jnp.asarray(sid, jnp.int32))

    def _ingest_one(self, state: ThetaState, values, mask, key) -> ThetaState:
        del key                                   # hash-based, PRNG-free
        live = mask > 0
        row_keys = jnp.where(live, _hash_rows(values, self.cfg.seed),
                             _EMPTY_KEY)
        row_tags = jnp.where(live, state.sid, jnp.int32(-1))
        keys, counts, tags = _combine(
            jnp.concatenate([state.keys, row_keys]),
            jnp.concatenate([state.counts, live.astype(jnp.int32)]),
            jnp.concatenate([state.tags, row_tags]),
            self.cfg.capacity)
        return ThetaState(keys=keys, counts=counts, tags=tags,
                          n=state.n + jnp.sum(mask).astype(jnp.int32),
                          sid=state.sid)

    def ingest_rounds(self, states, values, row_mask, keys):
        return self._rounds_fn(states, jnp.asarray(values),
                               jnp.asarray(row_mask), keys)

    def merge(self, a: ThetaState, b: ThetaState, *,
              backing: int = 0) -> ThetaState:
        """Exact union: bottomK over the pooled entries.  ``backing`` is
        accepted for window-refill call compatibility; a KMV sketch keeps
        its K smallest keys regardless, so there is nothing to expand."""
        del backing
        keys, counts, tags = _combine(
            jnp.concatenate([a.keys, b.keys]),
            jnp.concatenate([a.counts, b.counts]),
            jnp.concatenate([a.tags, b.tags]),
            self.cfg.capacity)
        return ThetaState(keys=keys, counts=counts, tags=tags,
                          n=a.n + b.n, sid=jnp.maximum(a.sid, b.sid))

    def subtract(self, a: ThetaState, b: ThetaState) -> ThetaState:
        drop = a.tags == b.sid
        keys, counts, tags = _combine(
            jnp.where(drop, _EMPTY_KEY, a.keys),
            jnp.where(drop, 0, a.counts),
            jnp.where(drop, -1, a.tags),
            self.cfg.capacity)
        return ThetaState(keys=keys, counts=counts, tags=tags,
                          n=jnp.maximum(a.n - b.n, 0), sid=a.sid)

    def memory_bytes(self) -> int:
        return self.cfg.capacity * _ENTRY_BYTES

    # -- estimation ----------------------------------------------------
    def _row(self, keys: np.ndarray, counts: np.ndarray, tags: np.ndarray,
             n: float) -> tuple[float, float]:
        """One sketch -> (distinct-hat, ordered-duplicate-pairs-hat)."""
        valid = tags >= 0
        m = int(valid.sum())
        if m == 0 or n <= 0:
            return 0.0, 0.0
        ks = keys[valid].astype(np.uint64)
        cs = counts[valid].astype(np.float64)
        uniq, inv = np.unique(ks, return_inverse=True)
        per_key = np.zeros(uniq.shape[0])
        np.add.at(per_key, inv, cs)
        if m < self.cfg.capacity:
            theta, distinct = 1.0, float(uniq.size)       # exact regime
        else:
            theta = (float(ks.max()) + 1.0) / 4294967296.0
            distinct = max(float(uniq.size) - 1.0, 1.0) / theta
        dup = float((per_key * (per_key - 1.0)).sum()) / theta
        return distinct, dup

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        del use_pallas, interpret                 # host-numpy estimator
        keys = np.asarray(jax.device_get(states.keys))
        counts = np.asarray(jax.device_get(states.counts))
        tags = np.asarray(jax.device_get(states.tags))
        n = np.asarray(jax.device_get(states.n)).astype(np.float64)
        N, L = n.shape[0], self.num_levels
        x = np.zeros((N, L))
        y = np.zeros((N, L))
        for i in range(N):
            distinct, dup = self._row(keys[i], counts[i], tags[i], n[i])
            x[i, L - 1] = dup                     # duplicates match at d
            y[i, :] = distinct                    # diagnostic: D-hat
        if clamp:
            x = np.maximum(x, 0.0)
        g = np.cumsum(x[:, ::-1], axis=1)[:, ::-1] + n[:, None]
        zeros = np.zeros_like(x)
        return EstimateTable(x=x, g=g, y=y, n=n, stderr=zeros,
                             stderr_offline=zeros, stderr_kind="none")

    def estimate_ref(self, state, *, clamp: bool = True) -> EstimateTable:
        return self.estimate_batch(stack_states([state]), clamp=clamp)


def _factory(cfg, *, params=None, estimator_cfg=None, opts=None):
    """Equal-space factory: the sketch budget comes from the group's
    SJPCConfig (DESIGN.md §13), 12 bytes per retained entry."""
    del params
    opts = opts or {}
    budget = int(cfg.counters_bytes)
    capacity = int(opts.get("capacity", max(budget // _ENTRY_BYTES, 8)))
    theta_cfg = estimator_cfg or ThetaConfig(
        d=cfg.d, s=cfg.s, capacity=capacity, seed=cfg.seed ^ 0x7E7A)
    return ThetaEstimator(theta_cfg)


register("theta_kmv", _factory, state_cls=ThetaState,
         linear=False, join_capable=False, stderr_kind="none")
