"""Similarity JOIN size estimation (paper §6) as train<->eval contamination
detection: sketch both corpora with shared hash params; the sketch inner
products at each lattice level invert (Eq. 7) into the cross-corpus
near-duplicate count.

    PYTHONPATH=src python examples/join_contamination.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import exact
from repro.data.synthetic import zipf_tokens
from repro.data.recordize import np_records_from_tokens
from repro.sketchstream.monitor import (SketchMonitorConfig, init_monitor,
                                        monitor_update_local, MonitorState,
                                        contamination_estimate)

D, SEQ = 6, 96
N_TRAIN, N_EVAL, N_SHARED = 4096, 512, 64

rng = np.random.default_rng(3)
train_toks = zipf_tokens(rng, N_TRAIN, SEQ, 50_000, dup_fraction=0.0)
eval_toks = zipf_tokens(rng, N_EVAL, SEQ, 50_000, dup_fraction=0.0)
eval_toks[:N_SHARED] = train_toks[:N_SHARED]       # planted contamination

cfg = SketchMonitorConfig(d=D, s=D, ratio=1.0, width=4096, depth=3, shards=1)
params, st_a = init_monitor(cfg)
_, st_b = init_monitor(cfg)

step = jnp.zeros((), jnp.int32)
ca, na = st_a.counters[0], st_a.n[0]
for i in range(0, N_TRAIN, 512):                   # stream in batches
    ca, na = monitor_update_local(cfg, params, ca, na,
                                  jnp.asarray(train_toks[i:i + 512]), step + i)
cb, nb = monitor_update_local(cfg, params, st_b.counters[0], st_b.n[0],
                              jnp.asarray(eval_toks), step)

est = contamination_estimate(cfg, MonitorState(ca[None], na[None], step),
                             MonitorState(cb[None], nb[None], step))

ra = np_records_from_tokens(train_toks, D)
rb = np_records_from_tokens(eval_toks, D)
true_join = exact.exact_join_g(ra, rb, D)

print(f"planted contaminated sequences: {N_SHARED}")
print(f"exact {D}-similar join size:    {true_join:.0f}")
print(f"SJPC join estimate:             {est['join'][D]:.0f}")
print(f"relative error:                 "
      f"{abs(est['join'][D] - true_join) / true_join:.3f}")
print("\nper-level join estimates:", {D - i: f"{v:.0f}" for i, v in
                                      enumerate(reversed(est['per_level_pairs']))})
