"""Runnable examples for the repro service (and, under ``plugins/``,
estimator kinds registered entirely from outside ``src/repro`` --
the DESIGN.md §19 extension surface)."""
