"""Answer every tenant's full all-thresholds table from one compiled call.

    PYTHONPATH=src python examples/batched_queries.py

64 tenant streams share one hash group.  After ingest, a single snapshot
answers 64 streams x every threshold -- the fused batched query engine
(DESIGN.md §12) stacks all windows into one (N, levels, t, w) tensor and
runs ONE compiled dispatch (moments, depth medians, the Eq. 4 inversion,
suffix-sum g_k table, all streams at once).  The per-stream numpy oracle
(`use_fused_query=False`, the PR 2 path) answers the identical query set
for comparison, and a standing-query poll loop shows the steady-state cost
with the version-keyed cache: unchanged windows are pure lookups, and one
flush invalidates exactly the streams whose windows changed.
"""
import time

import numpy as np

from repro.core import sjpc
from repro.service import EstimationService, QueryEngine, ServiceConfig

D, S, TENANTS, RECORDS = 6, 4, 64, 2048

svc = EstimationService(ServiceConfig(batch_rows=512, window_epochs=4))
svc.create_group("tenants", sjpc.SJPCConfig(d=D, s=S, ratio=0.5,
                                            width=2048, depth=3))
rng = np.random.default_rng(0)
names = [f"tenant-{i:02d}" for i in range(TENANTS)]
for nm in names:
    svc.create_stream(nm, "tenants")
    svc.ingest(nm, rng.integers(0, 2000, size=(RECORDS, D), dtype=np.uint32))
svc.flush()

# -- one batched snapshot vs the per-stream reference oracle ---------------
svc.engine.snapshot().all_thresholds(names[0])   # compile the batched call
for tag, engine in (("fused batched", svc.engine),
                    ("per-stream oracle",
                     QueryEngine(svc.registry, use_fused_query=False))):
    engine._cache.clear()                        # time compute, not caching
    snap = engine.snapshot()
    t0 = time.perf_counter()
    tables = {nm: snap.all_thresholds(nm) for nm in names}
    dt = 1e3 * (time.perf_counter() - t0)
    cells = sum(len(t) for t in tables.values())
    print(f"{tag:>18}: {cells} (stream, threshold) cells in {dt:7.2f} ms")

fused = svc.engine.snapshot().all_thresholds(names[0])
oracle = QueryEngine(svc.registry, use_fused_query=False) \
    .snapshot().all_thresholds(names[0])
print(f"\n{names[0]} all-thresholds (fused vs oracle):")
for k in fused:
    print(f"  g_{k} = {fused[k].estimate:>12.1f} +/- {fused[k].stderr:>10.1f}"
          f"   (oracle {oracle[k].estimate:>12.1f})")

# -- steady-state polling: the version-keyed cache ------------------------
snapshots = 200
t0 = time.perf_counter()
for _ in range(snapshots):
    snap = svc.engine.snapshot(names[:16])
    for nm in names[:16]:
        snap.all_thresholds(nm)
dt = time.perf_counter() - t0
print(f"\nsteady-state polling (16 streams x all thresholds, window "
      f"unchanged): {snapshots / dt:7.0f} snapshots/s "
      f"({1e3 * dt / snapshots:.2f} ms each)")

svc.ingest(names[0], rng.integers(0, 2000, size=(256, D), dtype=np.uint32))
svc.flush()                      # bumps tenant-00's window version
r = svc.engine.snapshot([names[0]]).self_join(names[0])
print(f"after one more flush, {names[0]} g_{S} = {r.estimate:.1f} "
      f"(cache refreshed by window version, never stale)")
