"""Observability tour: metrics, spans, and live accuracy telemetry.

    PYTHONPATH=src python examples/observability.py

Runs a two-tenant estimation service with every DESIGN.md §15 signal
turned on -- span tracing to a JSON-lines file, audit_rate=1 sampled
exact replay -- drives a few ingest/poll/epoch cycles, then prints the
Prometheus text exposition and a trace excerpt (dispatch vs
device-inclusive time per span).
"""
import json
import os
import tempfile

import numpy as np

from repro.core.sjpc import SJPCConfig
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

trace_path = os.path.join(tempfile.mkdtemp(prefix="repro-obs-"),
                          "trace.jsonl")
svc = EstimationService(ServiceConfig(
    batch_rows=256, window_epochs=4,
    audit_rate=1.0,                  # audit every polled query (demo rate;
                                     # production samples, e.g. 0.01)
    trace_sink=trace_path))
svc.create_group("g", SJPCConfig(d=6, s=4, width=1024, depth=3))
svc.create_stream("tenant-a", "g")
svc.create_stream("tenant-b", "g")
svc.register_continuous(ContinuousQuery("a-self", "self_join", ("tenant-a",)))
svc.register_continuous(ContinuousQuery("a-join-b", "join",
                                        ("tenant-a", "tenant-b")))

rng = np.random.default_rng(0)
for epoch in range(3):
    for _ in range(2):
        svc.ingest("tenant-a",
                   rng.integers(0, 40, size=(300, 6), dtype=np.uint32))
        svc.ingest("tenant-b",
                   rng.integers(0, 40, size=(200, 6), dtype=np.uint32))
        out = svc.poll()             # flush + batched queries + audit
    svc.advance_epoch()

r = out["a-self"]
lo, hi = r.ci(1.96)
print(f"tenant-a self-join g_{r.s}: {r.estimate:.0f}  "
      f"(95% CI [{lo:.0f}, {hi:.0f}], n={r.n[0]:.0f})")

print("\n================ Prometheus exposition (excerpt) ================")
report = svc.metrics_report()        # refreshes derived gauges first
keep = ("ingest_", "query_cache", "service_", "accuracy_", "window_",
        "kernel_dispatch")
for line in report.splitlines():
    if line.startswith(keep) or (line.startswith("# TYPE")
                                 and line.split()[2].startswith(keep)):
        print(line)

svc.obs.tracer.close()
print(f"\n================ trace excerpt ({trace_path}) ================")
print(f"{'span':<28} {'dispatch ms':>12} {'total ms':>10}   (device gap)")
with open(trace_path) as f:
    events = [json.loads(line) for line in f]
for ev in events[-8:]:
    gap = ev["total_ms"] - ev["dispatch_ms"]
    print(f"{'  ' * ev['depth'] + ev['name']:<28} "
          f"{ev['dispatch_ms']:>12.3f} {ev['total_ms']:>10.3f}   "
          f"(+{gap:.3f})")
print(f"\n{len(events)} span events; audits run: "
      f"{svc.obs.metrics.counter_total('accuracy_audits_total'):.0f}, "
      f"CI covered: "
      f"{svc.obs.metrics.counter_total('accuracy_ci_covered_total'):.0f}")
