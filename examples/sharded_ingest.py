"""Fused, device-sharded ingest: the Step-1 hot path end to end.

    PYTHONPATH=src python examples/sharded_ingest.py

Three things happen here:

1. **Fused == reference.**  A batch is folded into the sketch through the
   fused path (one fingerprint->sketch launch for every lattice level) and
   through the per-level reference path with the same key; the counters are
   compared bit for bit -- the conformance contract the service relies on
   when it switches paths.
2. **Sharded ingest with deferred merges.**  A stream of micro-batches is
   split across a ``ShardedIngest`` executor (shard_map over the device
   mesh when the host exposes >1 device, an equivalent vmap otherwise).
   No cross-shard communication happens per micro-batch; ``merged()`` pays
   the single deferred reduction at query time.
3. **Estimates are path-independent.**  The merged sharded sketch and a
   plain unsharded sketch of the same records estimate the same g_s up to
   the sampling draw (identical at ratio=1.0, where no per-record sampling
   randomness exists).

``repro.platform.force_host_device_count`` (below, before jax initializes)
forces 4 XLA host devices on a CPU-only host so the executor picks the
real shard_map path -- the same idiom benchmarks/run.py uses via
``repro.platform.subprocess_env``.
"""
import numpy as np

from repro import platform as plat

plat.force_host_device_count(4)      # must precede the first jax dispatch

import jax

from repro.core import exact, sjpc

print(f"backend: {plat.bootstrap('auto')}, {jax.device_count()} device(s)")

D, S, WIDTH, DEPTH = 6, 4, 4096, 3
MICRO, N_MICRO, SHARDS = 1000, 6, 2

cfg = sjpc.SJPCConfig(d=D, s=S, ratio=1.0, width=WIDTH, depth=DEPTH, seed=42)
params, state0 = sjpc.init(cfg)
rng = np.random.default_rng(0)

# --- 1. fused path == reference path, bit for bit ------------------------
batch = rng.integers(0, 8, size=(MICRO, D)).astype(np.uint32)
key = jax.random.PRNGKey(7)
ref = sjpc.update(cfg, params, state0, batch, key=key)
fused = sjpc.update_fused(cfg, params, state0, batch, key=key)
assert (np.asarray(ref.counters) == np.asarray(fused.counters)).all()
print(f"fused ingest == per-level reference: bit-exact "
      f"({ref.counters.size} counters)")

# --- 2. sharded executor, merge deferred across micro-batches ------------
sh = sjpc.ShardedIngest(cfg, params, num_shards=SHARDS)
mode = "shard_map" if sh.mapped else "vmap"
history = []
for _ in range(N_MICRO):
    mb = rng.integers(0, 8, size=(MICRO, D)).astype(np.uint32)
    history.append(mb)
    sh.ingest(mb)                      # shard-local deltas, no reduction
merged = sh.merged()                   # THE one cross-shard reduction
print(f"{N_MICRO} micro-batches across {SHARDS} shards ({mode} over "
      f"{jax.device_count()} device(s)); merges paid: {sh.merges}")

# --- 3. the estimate is the same sketch it always was --------------------
all_records = np.concatenate(history)
plain = sjpc.update(cfg, params, state0, all_records)
assert (np.asarray(merged.counters) == np.asarray(plain.counters)).all()

est = sjpc.estimate(cfg, merged)
g_true = exact.exact_g(all_records, S)
print(f"g_{S} estimate {est.g_s:,.0f} vs exact {g_true:,.0f} "
      f"(rel err {abs(est.g_s - g_true) / g_true:.3%}, "
      f"n={est.n:.0f} records, {cfg.counters_bytes / 1024:.0f} KiB sketch)")
