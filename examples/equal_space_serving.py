"""Three estimators, one service, equal space: the paper's comparison live.

    PYTHONPATH=src python examples/equal_space_serving.py

Creates one hash group and registers a stream per estimator kind --
SJPC ("the paper"), streaming reservoir sampling, and streaming LSH-SS --
at byte budgets derived from the group's SJPCConfig (equal space by
construction, the Fig. 8 rule).  One planted-cluster stream is replayed
through all three; `poll()` answers every standing query from one
snapshot, so the competitors are served side by side, continuously, not
compared in a one-shot script.
"""
import numpy as np

from repro.core import exact
from repro.core.sjpc import SJPCConfig
from repro.data.synthetic import planted_cluster_records
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

KINDS = ("sjpc", "reservoir", "lsh_ss")


def main():
    cfg = SJPCConfig(d=6, s=4, ratio=1.0, width=2048, depth=3, seed=23)
    rng = np.random.default_rng(41)
    vals = planted_cluster_records(8192, cfg.d, rng,
                                   [(4, 192, 3), (5, 128, 2), (6, 64, 1)])
    x = exact.exact_pair_counts(vals)
    g_true = {s: float(x[s:].sum() + len(vals)) for s in range(4, 7)}

    svc = EstimationService(ServiceConfig(batch_rows=2048,
                                          window_epochs=None))
    svc.create_group("g", cfg)
    for kind in KINDS:
        svc.create_stream(kind, "g", estimator=kind)
        svc.ingest(kind, vals)
        svc.register_continuous(
            ContinuousQuery(f"q/{kind}", "all_thresholds", (kind,)))

    results = svc.poll()                    # ONE snapshot serves all kinds
    print(f"{len(vals)} records, SJPC budget {cfg.counters_bytes} bytes\n")
    print(f"{'estimator':>10} {'mem B':>8} " +
          " ".join(f"{'s=' + str(s):>18}" for s in g_true))
    print(f"{'(exact)':>10} {'':>8} " +
          " ".join(f"{g_true[s]:>18.0f}" for s in g_true))
    for kind in KINDS:
        mem = svc.registry.stream(kind).estimator.memory_bytes()
        row = results[f"q/{kind}"]
        cells = []
        for s in g_true:
            r = row[s]
            err = abs(r.estimate - g_true[s]) / g_true[s]
            cells.append(f"{r.estimate:>8.0f}±{r.stderr:<6.0f}({err:>4.0%})")
        kinds_bar = next(iter(row.values())).stderr_kind
        print(f"{kind:>10} {mem:>8} " + " ".join(cells)
              + f"   [{kinds_bar}]")
    print("\nper-stream estimator metadata:",
          {nm: row["estimator"] for nm, row in
           svc.describe()["groups"]["g"]["streams"].items()})


if __name__ == "__main__":
    main()
