"""The query planner: cross-group fusion, plan caching, and admission.

    PYTHONPATH=src python examples/planner_admission.py

Four hash groups share one derived config, each serving four tenant
streams with standing self-join queries.  The planner (DESIGN.md §16,
on by default) fuses all four group cohorts into ONE estimate_batch
launch per poll, caches the fusion plan across polls, and -- when a
tenant is given a query budget -- throttles that tenant to its last
fresh result, honestly marked ``stale=True``, instead of dropping it.
"""
import numpy as np

from repro.core import sjpc
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

GROUPS, PER_GROUP, D = 4, 4, 6
cfg = sjpc.SJPCConfig(d=D, s=4, ratio=0.5, width=1024, depth=3)

svc = EstimationService(ServiceConfig(batch_rows=512, window_epochs=None))
rng = np.random.default_rng(0)
names = []
for g in range(GROUPS):
    svc.create_group(f"region-{g}", cfg)        # distinct hash params...
    for t in range(PER_GROUP):
        nm = f"region-{g}/tenant-{t}"
        svc.create_stream(nm, f"region-{g}")    # ...same derived geometry
        svc.ingest(nm, rng.integers(0, 2000, size=(2048, D),
                                    dtype=np.uint32))
        names.append(nm)
svc.flush()

# standing queries: tenant-0 of region-0 is latency-critical (priority 0)
for i, nm in enumerate(names):
    svc.register_continuous(ContinuousQuery(
        f"q/{nm}", "self_join", (nm,), priority=0 if i == 0 else 1))

# -- cross-group fusion + the plan cache ----------------------------------
for _ in range(3):
    out = svc.poll()
met = svc.obs.metrics
launches = met.counter_total("planner_fused_launches_total")
cohorts = met.counter_total("planner_fused_cohorts_total")
built = met.counter_total("planner_plans_built_total")
reused = met.counter_total("planner_plan_reuse_total")
print(f"{GROUPS} groups x {PER_GROUP} streams, {len(names)} standing "
      f"queries:")
print(f"  fused launches: {launches:.0f} (covering {cohorts:.0f} group "
      f"cohorts -- one device call answered every group)")
print(f"  plans built: {built:.0f}, reused: {reused:.0f} "
      f"(topology unchanged -> no replanning)")
print(f"  {names[0]} g_4 = {out['q/' + names[0]].estimate:.1f} "
      f"+/- {out['q/' + names[0]].stderr:.1f}")

# -- admission control: budget one tenant to 1 query per 2 polls ----------
noisy = names[-1]
svc.set_tenant_budget(noisy, 0.5, burst=1.0)
print(f"\nbudgeting {noisy} to 0.5 queries/poll (burst 1):")
for i in range(4):
    svc.ingest(noisy, rng.integers(0, 2000, size=(256, D), dtype=np.uint32))
    svc.flush()                              # the window really does change
    r = svc.poll()[f"q/{noisy}"]
    print(f"  poll {i}: g_4 = {r.estimate:>10.1f}  "
          f"{'STALE (over budget, last fresh answer)' if r.stale else 'fresh'}")
rej = met.counter_total("admission_rejections_total")
print(f"admission_rejections_total = {rej:.0f}; every other tenant "
      f"stayed fresh")
