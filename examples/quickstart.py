"""Quickstart: one-pass similarity self-join size estimation on a stream.

    PYTHONPATH=src python examples/quickstart.py

Streams 20k 6-column records (with planted near-duplicates) through SJPC in
batches, then queries g_s for every threshold and compares to the exact
answer computed offline.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import platform as plat
from repro.core import sjpc, exact
from repro.data.synthetic import shingle_records

# pick the fastest available backend (tpu > gpu > cpu); the kernel registry
# dispatches each op to its best impl for this backend automatically
print(f"backend: {plat.bootstrap('auto')}")

D, S_MIN, N = 6, 3, 20_000

records = shingle_records(N, d=D, seed=1, group=6,
                          dup_profile=((3, 0.15), (4, 0.08), (5, 0.05), (6, 0.03)))

cfg = sjpc.SJPCConfig(d=D, s=S_MIN, ratio=0.5, width=1024, depth=3)
params, state = sjpc.init(cfg)
print(f"sketch memory: {cfg.counters_bytes / 1024:.0f} KiB "
      f"({cfg.num_levels} levels x {cfg.depth} x {cfg.width} int32)")

update = jax.jit(lambda st, batch, key: sjpc.update(cfg, params, st, batch, key))
key = jax.random.PRNGKey(0)
BATCH = 2_000
for i in range(0, N, BATCH):                      # one pass, limited memory
    state = update(state, jnp.asarray(records[i:i + BATCH]),
                   jax.random.fold_in(key, i))

est = sjpc.estimate(cfg, state)
print(f"\n{'s':>2} {'estimate g_s':>14} {'exact g_s':>14} {'rel err':>8}")
for s in range(S_MIN, D + 1):
    g_est = est.x[s - S_MIN:].sum() + est.n
    g_true = exact.exact_g(records, s)
    print(f"{s:>2} {g_est:>14.0f} {g_true:>14.0f} "
          f"{abs(g_est - g_true) / g_true:>8.3f}")
