"""Multi-host distributed service: sharded workers, one coordinator.

    PYTHONPATH=src python examples/distributed_scaleout.py

A 2-worker cluster (in-process handles here, so the example runs fast;
pass --subprocess for real child processes) serves 8 tenants hashed
across the workers by ``crc32(name) % 2`` (DESIGN.md §18).  Each cycle
the coordinator routes ingest to the owning worker, pulls every worker's
epoch-aligned sketch deltas over the wire format, merges them into its
query replica through the ordinary merge algebra, and closes the epoch
everywhere.  Any query is answered from the replica -- workers are never
on the query path -- and the replica state is *bit-identical* to a
single-process run over the same records (pinned tenant uids reproduce
the ingest PRNG grid exactly).

Then one worker "dies": its tenants keep serving from the last merged
window, honestly marked ``stale=True``, while the surviving shard stays
fresh.
"""
import sys

import numpy as np

from repro.distributed import harness, shard_of

SUBPROCESS = "--subprocess" in sys.argv

spec = harness.make_spec(8, kinds=("sjpc", "reservoir"), width=512,
                         window_epochs=4, batch_rows=128)
cycles = 3
batches = harness.make_batches(spec, cycles=cycles, rows_per_cycle=256)

run = harness.run_cluster(spec, batches, n_workers=2, cycles=cycles,
                          local=not SUBPROCESS, keep_open=True)
coord = run.coordinator

# -- replica == single-process oracle -------------------------------------
oracle = harness.run_oracle(spec, batches, cycles=cycles)
agree = harness.compare_to_oracle(coord, oracle, spec)
names = [s["name"] for s in spec.streams]
print(f"2 workers, {len(names)} tenants, {run.records} records in "
      f"{cycles} epochs ({run.rec_per_s:,.0f} rec/s aggregate)")
print(f"  replica vs oracle: linear counters bit-exact={agree['linear_exact']}, "
      f"worst estimate gap {agree['worst_rel_err']:.2e}")
print(f"  merge p50/p95: {1e3 * run.merge_p50_s:.1f}/"
      f"{1e3 * run.merge_p95_s:.1f} ms per worker sync")

nm = names[0]
res = coord.self_join(nm)
print(f"  {nm} (worker {shard_of(nm, 2)}): g_s ~= {res.estimate:.0f} "
      f"+/- {res.stderr:.0f}, stale={res.stale}")

# -- idle cycle: the zero-byte heartbeat ----------------------------------
stats = coord.sync()                       # nothing ingested since last sync
print(f"idle sync: {stats['heartbeats']}/{stats['workers']} workers sent "
      f"the zero-byte heartbeat ({stats['deltas']} deltas to merge)")

# -- losing a worker ------------------------------------------------------
if SUBPROCESS:
    coord.workers[0].kill()
else:
    coord.workers[0].fail()
for n in names:                            # routed records to a dead shard
    coord.ingest(n, np.asarray(batches[n][0]))   # are counted and dropped
coord.sync()
dead = sorted(coord.stale_tenants)
live = [n for n in names if n not in coord.stale_tenants]
print(f"worker 0 lost: {len(dead)} tenants now serve their last-merged "
      f"window stale=True, {len(live)} stay fresh")
print(f"  {dead[0]}: stale={coord.self_join(dead[0]).stale}   "
      f"{live[0]}: stale={coord.self_join(live[0]).stale}")

coord.close()
