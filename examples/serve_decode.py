"""Batched serving example: prefill a batch of prompts, greedy-decode with a
KV cache, and monitor the REQUEST stream for near-duplicate prompts with
SJPC (duplicate-prompt density = cache-hit opportunity, the serving-side
analogue of the paper's dedup-worthiness signal).

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.models.config import compute_dims
from repro.launch.serve import greedy_generate
from repro.sketchstream.monitor import (SketchMonitorConfig, init_monitor,
                                        monitor_update_local, MonitorState,
                                        monitor_estimate)

B, PROMPT, GEN = 8, 24, 8

cfg = configs.reduced("qwen2-7b")
dims = compute_dims(cfg, tp=1)
params = M.strip_p(M.init_params(jax.random.PRNGKey(0), cfg, dims))

rng = np.random.default_rng(5)
prompts = rng.integers(0, cfg.vocab_size, size=(B, PROMPT), dtype=np.int32)
prompts[3] = prompts[0]            # duplicate requests
prompts[5] = prompts[0]

out = greedy_generate(params, cfg, dims, jnp.asarray(prompts), GEN)
print(f"served {B} requests, prompt={PROMPT} tokens, generated {GEN} each")
for i in range(B):
    print(f"  req {i}: ...{prompts[i, -4:].tolist()} -> "
          f"{np.asarray(out[i]).tolist()}")

# --- request-stream dedup monitor ---
mcfg = SketchMonitorConfig(d=4, s=4, ratio=1.0, width=1024, depth=3, shards=1)
mparams, mstate = init_monitor(mcfg)
c, n = monitor_update_local(mcfg, mparams, mstate.counters[0], mstate.n[0],
                            jnp.asarray(prompts), jnp.zeros((), jnp.int32))
est = monitor_estimate(mcfg, MonitorState(c[None], n[None], mstate.step))
dup_pairs = (est["g"][4] - B) / 2
print(f"\nSJPC request monitor: ~{dup_pairs:.1f} duplicate prompt pairs "
      f"(true: 3)")
