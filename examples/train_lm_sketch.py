"""End-to-end driver: train an LM with the SJPC stream monitor riding the
data pipeline, under the fault-tolerant runtime (checkpoint/restart +
failure injection + straggler detection).

    PYTHONPATH=src python examples/train_lm_sketch.py                # smoke (CPU)
    PYTHONPATH=src python examples/train_lm_sketch.py --preset 100m --steps 300

The monitor logs continuous g_s estimates (near-duplicate density of the
training stream) next to the loss -- the paper's "is a dedup run worth it?"
signal, live during training.
"""
import argparse
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models.config import ArchConfig, compute_dims
from repro.launch.train import make_train_step, make_train_state
from repro.optim import make_adamw, warmup_cosine
from repro.runtime import DriverConfig, TrainDriver, SimulatedFailure
from repro.sketchstream.monitor import SketchMonitorConfig
from repro.data.loader import token_batches

PRESETS = {
    # ~100M params: the end-to-end target scale
    "100m": ArchConfig(name="lm-100m", family="dense", num_layers=8,
                       d_model=512, num_heads=8, num_kv_heads=4, d_ff=2048,
                       vocab_size=32768, head_dim=64, rope_theta=10_000.0),
    # CPU smoke default
    "smoke": ArchConfig(name="lm-smoke", family="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=512, head_dim=16, rope_theta=10_000.0),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    dims = compute_dims(cfg, tp=1)
    mcfg = SketchMonitorConfig(d=6, s=3, ratio=0.5, width=1024, depth=3,
                               shards=1)
    optimizer = make_adamw(warmup_cosine(3e-4, 20, max(args.steps, 100)),
                           weight_decay=0.1)
    state, mparams, axes = make_train_state(
        jax.random.PRNGKey(0), cfg, dims, optimizer, monitor_cfg=mcfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"model: {cfg.name} ({n_params / 1e6:.1f}M params)")

    step_fn = jax.jit(make_train_step(
        cfg, dims, optimizer, None, monitor_cfg=mcfg, monitor_params=mparams,
        remat="none", ssm_chunk=32, compute_dtype=jnp.float32))

    gen = token_batches(args.batch, args.seq, cfg.vocab_size, seed=7,
                        dup_fraction=0.2)
    batches = {}

    def make_batch(step):          # deterministic in step (replay-safe)
        while len(batches) <= step:
            batches[len(batches)] = next(gen)
        b = batches[step]
        return {k: jnp.asarray(v) for k, v in b.items()}

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
    driver = TrainDriver(step_fn, state, make_batch,
                         DriverConfig(ckpt_dir=ckpt_dir, ckpt_every=10,
                                      log_every=5, sketch_log_every=10),
                         monitor_cfg=mcfg)
    if args.inject_failure is not None:
        driver.inject_failure_at = {
            args.inject_failure: SimulatedFailure("injected node failure")}

    driver.run(args.steps)

    print("\nstep   loss     gnorm")
    for m in driver.metrics_log:
        print(f"{m['step']:>4} {m['loss']:8.4f} {m.get('grad_norm', 0):8.3f}")
    print("\nSJPC stream monitor (g_s estimates over the token stream):")
    for row in driver.sketch_log:
        gs = {k: f"{v:.0f}" for k, v in row.items() if k != "step"}
        print(f"  step {row['step']:>4}: {gs}")
    if driver.events:
        print("\nruntime events:")
        for e in driver.events:
            print(f"  {e}")


if __name__ == "__main__":
    main()
