"""Serve windowed similarity estimates to multiple tenants.

    PYTHONPATH=src python examples/serve_estimates.py

Three tenant streams share one hash group (so any pair supports the §6
join estimator).  Each "tick" the tenants ingest a batch of records --
buffered host-side, then flushed in ONE batched device dispatch for all
tenants -- and the epoch rotates, expiring data older than WINDOW epochs
by counter subtraction.  Standing (continuous) queries are polled each
tick from a single shared snapshot, with analytical error bars, and the
windowed self-join estimate is compared against the exact count over the
same live window.
"""
import numpy as np

from repro.core import exact, sjpc
from repro.data.synthetic import shingle_records
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

D, S, WINDOW, TICKS, BATCH = 6, 4, 4, 10, 800

svc = EstimationService(ServiceConfig(batch_rows=256, window_epochs=WINDOW))
group = svc.create_group("tenants", sjpc.SJPCConfig(d=D, s=S, ratio=1.0,
                                                    width=4096, depth=3))
for t in ("alpha", "beta", "gamma"):
    svc.create_stream(t, "tenants")

svc.register_continuous(ContinuousQuery("alpha/self", "self_join", ("alpha",)))
svc.register_continuous(ContinuousQuery("alpha|beta", "join", ("alpha", "beta")))

mem = svc.registry.stream("alpha").window.memory_bytes()
print(f"{D=} {S=} window={WINDOW} epochs; per-tenant window memory "
      f"{mem / 1024:.0f} KiB\n")

# beta replays a slice of alpha's records each tick -> a planted join signal
history = {t: [] for t in ("alpha", "beta", "gamma")}
for tick in range(TICKS):
    a = shingle_records(BATCH, d=D, seed=100 + tick, group=6,
                        dup_profile=((4, 0.10), (5, 0.05), (6, 0.02)))
    b = np.concatenate([a[:BATCH // 8],
                        shingle_records(BATCH - BATCH // 8, d=D,
                                        seed=500 + tick, group=6)])
    g = shingle_records(BATCH, d=D, seed=900 + tick, group=6)
    for name, recs in (("alpha", a), ("beta", b), ("gamma", g)):
        svc.ingest(name, recs)
        history[name].append(recs)
        # mirror the live window: after advance_epoch the open epoch is
        # empty, so the window holds the last WINDOW-1 closed epochs
        history[name] = history[name][-(WINDOW - 1):]
    svc.advance_epoch()

    results = svc.poll()
    r = results["alpha/self"]
    true_g = exact.exact_g(np.concatenate(history["alpha"]), S)
    j = results["alpha|beta"]
    print(f"tick {tick}: alpha g_{S} = {r.estimate:>9.0f} +/- {r.stderr:>8.0f}"
          f"  (exact {true_g:>9.0f})   alpha|beta join = {j.estimate:>7.0f}")

print("\nall-thresholds snapshot for alpha:")
for k, r in svc.snapshot().all_thresholds("alpha").items():
    print(f"  s={k}: {r.estimate:>10.0f} +/- {r.stderr:.0f}")

d = svc.describe()
ing = d["groups"]["tenants"]["ingest"]
print(f"\ningest: {ing['submitted_records']} records in {ing['rounds']} "
      f"batched dispatches ({ing['padded_rows']} padded rows); "
      f"flush time {d['flush_s']:.2f}s")
