"""Collate dry-run JSONs into the EXPERIMENTS.md §Dry-run / §Roofline tables.

    PYTHONPATH=src python -m benchmarks.collate [--dir benchmarks/out/dryrun]

Prints markdown; `--write` patches EXPERIMENTS.md between the AUTO markers.
"""
from __future__ import annotations

import argparse
import json
import os

HERE = os.path.dirname(__file__)


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}"


def load(d):
    rows = []
    if not os.path.isdir(d):
        return rows
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json") and "__" in fn:
            with open(os.path.join(d, fn)) as f:
                rows.append((fn, json.load(f)))
    return rows


def dryrun_table(rows):
    out = ["| arch | shape | mesh | kind | opt | lower s | compile s | args GB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for fn, r in rows:
        mesh = "2pod(2x16x16)" if r["chips"] == 512 else "1pod(16x16)"
        mem = (r.get("memory") or {}).get("argument_bytes")
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['kind']} | "
            f"{r.get('optimizer', '-')} | {r.get('lower_s', '-')} | "
            f"{r.get('compile_s', '-')} | {_fmt_bytes(mem)} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | mesh | flops/dev | compute ms | memory ms | "
           "collective ms | dominant | useful |",
           "|---|---|---|---|---|---|---|---|---|"]
    for fn, r in rows:
        rl = r.get("roofline")
        if not rl:
            continue
        mesh = "2pod" if r["chips"] == 512 else "1pod"
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {rl['flops']:.2e} | "
            f"{1e3 * rl['compute_s']:.2f} | {1e3 * rl['memory_s']:.2f} | "
            f"{1e3 * rl['collective_s']:.2f} | {rl['dominant']} | "
            f"{rl['useful_ratio']:.2f} |")
    return "\n".join(out)


def variants_table(rows):
    """Tagged variant JSONs vs their baselines (the §Perf evidence)."""
    base = {}
    tagged = []
    for fn, r in rows:
        parts = fn[:-5].split("__")
        key = tuple(parts[:3])
        if len(parts) == 3:
            base[key] = r
        else:
            tagged.append((key, parts[3], r))
    out = ["| cell | variant | flops/dev | mem ms (Δ) | coll ms (Δ) | dominant |",
           "|---|---|---|---|---|---|"]
    for key, tag, r in sorted(tagged):
        rl = r.get("roofline")
        b = base.get(key, {}).get("roofline")
        if not rl:
            continue

        def delta(field):
            cur = 1e3 * rl[field]
            if not b or not b.get(field):
                return f"{cur:.1f}"
            d = 100.0 * (cur - 1e3 * b[field]) / max(1e3 * b[field], 1e-9)
            return f"{cur:.1f} ({d:+.0f}%)"

        out.append(f"| {key[0]}/{key[1]}/{key[2]} | {tag} | {rl['flops']:.2e} | "
                   f"{delta('memory_s')} | {delta('collective_s')} | "
                   f"{rl['dominant']} |")
    return "\n".join(out)


def service_table(res):
    """The `service` suite: fused/reference ingest throughput, shard
    scaling, and query latency.

    Tolerant by construction: every row key is optional (service-only runs,
    pre-fused results files, and partial reruns all collate), and rows are
    emitted in a FIXED key order so two reports diff cleanly."""
    svc = res.get("service")
    if not isinstance(svc, dict) or not svc:
        return ""
    out = ["#### Service — batched multi-tenant ingest / query latency\n"]
    if svc.get("resolved_impls"):
        out.append(f"- backend {svc.get('backend', '?')}; kernel impls: "
                   + ", ".join(f"{op}={name}" for op, name
                               in sorted(svc["resolved_impls"].items()))
                   + "\n")
    out += ["| row | tenants | shards | records | records/sec |",
            "|---|---|---|---|---|"]
    # stable order: ingest rows sorted (fused?, tenants, key), then executor
    # rows sorted by shard count -- NOT dict insertion order
    ingest = sorted(
        ((key, row) for key, row in svc.items()
         if key.startswith("ingest_") and isinstance(row, dict)),
        key=lambda kv: (bool(kv[1].get("fused", True)),
                        int(kv[1].get("tenants", 0)), kv[0]))
    executor = sorted(
        ((key, row) for key, row in svc.items()
         if key.startswith("executor_") and isinstance(row, dict)),
        key=lambda kv: int(kv[1].get("shards", 0)))
    for key, row in ingest + executor:
        rps = row.get("records_per_sec")
        out.append(
            f"| {key} | {row.get('tenants', '-')} "
            f"| {row.get('shards', '-')} | {row.get('records', '-')} "
            f"| {float(rps):.0f} |" if rps is not None else
            f"| {key} | - | - | - | - |")
    speedup = svc.get("speedup_fused_vs_ref_1t")
    if speedup is not None:
        out.append(f"\nfused vs reference ingest (1 tenant): "
                   f"{float(speedup):.2f}x")
    q = svc.get("query")
    if isinstance(q, dict) and q:
        line = (
            f"\nsnapshot poll over {q.get('continuous_queries', '?')} "
            f"standing queries: "
            f"p50 {float(q.get('poll_p50_ms', 0)):.1f} ms, "
            f"p95 {float(q.get('poll_p95_ms', 0)):.1f} ms")
        if q.get("poll_p99_ms") is not None:
            line += f", p99 {float(q['poll_p99_ms']):.1f} ms"
        line += f" ({float(q.get('per_query_p50_ms', 0)):.2f} ms/query)"
        out.append(line)
        obs_bits = []
        if q.get("cache_hit_rate") is not None:
            obs_bits.append("steady-state cache hit rate "
                            f"{float(q['cache_hit_rate']):.2f}")
        if q.get("queue_depth_peak") is not None:
            obs_bits.append("ingest queue-depth peak "
                            f"{float(q['queue_depth_peak']):.0f} rows")
        if q.get("trace_events"):
            obs_bits.append(f"{int(q['trace_events'])} trace events "
                            "(benchmarks/out/trace.jsonl)")
        if obs_bits:
            out.append("observability: " + ", ".join(obs_bits))
    snap = sorted(((key, row) for key, row in svc.items()
                   if key.startswith("snapshot_") and isinstance(row, dict)),
                  key=lambda kv: (int(kv[1].get("streams", 0)), kv[0]))
    if snap:
        out.append("\n| snapshot row (all thresholds) | streams | cells "
                   "| p50 ms | p95 ms | p99 ms |")
        out.append("|---|---|---|---|---|---|")
        for key, row in snap:
            p99 = row.get("p99_ms")
            out.append(
                f"| {key} | {row.get('streams', '-')} "
                f"| {row.get('cells', '-')} "
                f"| {float(row.get('p50_ms', 0)):.2f} "
                f"| {float(row.get('p95_ms', 0)):.2f} "
                + (f"| {float(p99):.2f} |" if p99 is not None else "| - |"))
    for key, label in (
            ("speedup_fused_query_16s",
             "fused batched query (steady state) vs per-stream reference"),
            ("speedup_fused_query_cold_16s",
             "fused batched query (cold cache) vs per-stream reference")):
        sp = svc.get(key)
        if sp is not None:
            out.append(f"\n{label} at 16 streams: {float(sp):.1f}x")
    return "\n".join(out)


def planner_table(res):
    """The `planner` suite: poll latency vs standing-query count, planner
    on (cross-group fusion + plan cache) vs off (per-group prefetch).
    Tolerant of missing rows; fixed (queries, planner) order so two
    reports diff cleanly."""
    pl = res.get("planner")
    if not isinstance(pl, dict) or not pl:
        return ""
    rows = sorted(
        ((key, row) for key, row in pl.items()
         if key.startswith("poll_") and isinstance(row, dict)),
        key=lambda kv: (int(kv[1].get("queries", 0)),
                        not kv[1].get("planner", False)))
    out = ["#### Planner — poll latency vs standing-query count\n",
           "| row | planner | queries | streams | p50 ms | p95 ms |",
           "|---|---|---|---|---|---|"]
    for key, row in rows:
        out.append(
            f"| {key} | {'on' if row.get('planner') else 'off'} "
            f"| {row.get('queries', '-')} | {row.get('streams', '-')} "
            f"| {float(row.get('p50_ms', 0)):.2f} "
            f"| {float(row.get('p95_ms', 0)):.2f} |")
    ratio = pl.get("p95_ratio_1000q_vs_10q")
    if ratio is not None:
        out.append(f"\np95(1000 queries) / p95(10 queries), planner on: "
                   f"{float(ratio):.2f}x (CI guard <= 3x)")
    return "\n".join(out)


def equal_space_table(res):
    """The `equal_space` suite: every served estimator kind at derived
    (equal-space) budgets on the seeded planted-cluster stream -- the
    paper's Fig. 8 as a living benchmark.  Tolerant of missing rows and
    rendered in sorted kind order so reruns diff cleanly."""
    eq = res.get("equal_space")
    if not isinstance(eq, dict) or not eq:
        return ""
    wl = eq.get("workload", {}) if isinstance(eq.get("workload"), dict) else {}
    thresholds = sorted(int(s) for s in wl.get("g_true", {}))
    out = ["#### Equal-space accuracy — served estimators, one hash group\n"]
    if wl:
        out.append(f"workload: {wl.get('records', '?')} records, "
                   f"d={wl.get('d', '?')}, SJPC budget "
                   f"{wl.get('sjpc_bytes', '?')} bytes\n")
    hdr = ("| estimator | memory B | ingest rec/s | query p50 ms | stderr "
           "| CI95 covers |")
    sep = "|---|---|---|---|---|---|"
    for s in thresholds:
        hdr += f" rel err s={s} | ±σ s={s} |"
        sep += "---|---|"
    out += [hdr, sep]
    for kind in sorted(k for k in eq if k != "workload"):
        row = eq[kind]
        if not isinstance(row, dict):
            continue
        rps = row.get("ingest_records_per_sec")
        q50 = row.get("query_p50_ms")
        line = (f"| {kind} | {row.get('memory_bytes', '-')} "
                f"| {float(rps):.0f} |" if rps is not None
                else f"| {kind} | {row.get('memory_bytes', '-')} | - |")
        line += f" {float(q50):.1f} |" if q50 is not None else " - |"
        line += f" {row.get('stderr_kind', '-')} |"
        cov = row.get("ci95_covers", {})
        line += (f" {sum(map(bool, cov.values()))}/{len(cov)} |" if cov
                 else " - |")
        errs = row.get("rel_err", {})
        sigs = row.get("stderr_rel", {})
        for s in thresholds:
            e = errs.get(str(s))
            line += f" {float(e):.3f} |" if e is not None else " - |"
            sg = sigs.get(str(s))
            line += f" {float(sg):.3f} |" if sg is not None else " - |"
        out.append(line)
    return "\n".join(out)


def distributed_table(res):
    """The `distributed` suite: aggregate ingest scale-out at 1/2/4
    workers with merge latency and replica freshness.  Tolerant by
    construction -- any subset of worker counts renders (a partial or
    interrupted run still collates), missing fields print as `-`, and
    rows sort by worker count so reruns diff cleanly."""
    dist = res.get("distributed")
    if not isinstance(dist, dict) or not dist:
        return ""
    rows = sorted(
        ((key, row) for key, row in dist.items()
         if key.startswith("workers_") and isinstance(row, dict)),
        key=lambda kv: int(kv[1].get("workers", 0)))
    if not rows:
        return ""
    out = ["#### Distributed — multi-worker ingest scale-out\n",
           "| workers | records | rec/s | speedup | merge p50 ms "
           "| merge p95 ms | freshness p95 ms |",
           "|---|---|---|---|---|---|---|"]

    def _ms(row, key):
        v = row.get(key)
        return f"{1e3 * float(v):.2f}" if v is not None else "-"

    for key, row in rows:
        rps = row.get("rec_per_s")
        sp = row.get("speedup_vs_1w")
        out.append(
            f"| {row.get('workers', '-')} | {row.get('records', '-')} "
            + (f"| {float(rps):,.0f} " if rps is not None else "| - ")
            + (f"| {float(sp):.2f}x " if sp is not None else "| - ")
            + f"| {_ms(row, 'merge_p50_s')} | {_ms(row, 'merge_p95_s')} "
            f"| {_ms(row, 'freshness_p95_s')} |")
    budgets = [row for _, row in rows if row.get("merge_budget_s") is not None]
    if budgets:
        ok = all(row.get("merge_within_budget", False) for row in budgets)
        out.append(f"\nmerge p95 within the "
                   f"{float(budgets[0]['merge_budget_s']):.1f}s per-epoch "
                   f"budget at every worker count: {'yes' if ok else 'NO'}")
    return "\n".join(out)


def paper_tables(results_path):
    """Markdown for whatever suites are present in results.json.

    Any subset of suites collates (service-only runs, kernel-only runs, a
    stale file from an older revision); each block renders its rows in
    sorted key order so reruns produce diffable reports."""
    if not os.path.exists(results_path):
        return "(run `python -m benchmarks.run` first)"
    try:
        with open(results_path) as f:
            res = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"(unreadable results.json: {e})"
    out = []
    if isinstance(res.get("table3"), dict):
        out.append("#### Table 3 analogue — accumulative pair counts (exact)\n")
        for ds, row in sorted(res["table3"].items()):
            out.append(f"- **{ds}**: " + ", ".join(
                f"s≥{s}: {float(v):.0f}" for s, v in sorted(row.items())))
    for name, title in [("fig4_6", "Figs 4–6 — offline error (mean±std)"),
                        ("fig8", "Fig 8 — online error at equal space"),
                        ("fig9a", "Fig 9a — error vs sampling ratio"),
                        ("fig9b", "Fig 9b — error vs dimensionality"),
                        ("fig9c", "Fig 9c — error vs dataset size"),
                        ("fig10", "Fig 10 — running time scaling")]:
        if not isinstance(res.get(name), dict):
            continue
        out.append(f"\n#### {title}\n")
        for k, v in sorted(res[name].items()):
            out.append(f"- {k}: " + json.dumps(v, sort_keys=True))
    if isinstance(res.get("kernels"), dict):
        out.append("\n#### Kernel micro-bench (interpret-mode conformance)\n")
        kr = res["kernels"]
        resolved = kr.get("resolved_impls")
        if resolved:
            out.append("- registry auto-dispatch on this backend: "
                       + ", ".join(f"{op}={name}"
                                   for op, name in sorted(resolved.items())))
        bench_rows = [(k, v) for k, v in sorted(kr.items())
                      if isinstance(v, dict) and "match" in v]
        if bench_rows:
            out.append("")
            out.append("| case | backend | impl | match | ref_s "
                       "| pallas_interp_s |")
            out.append("|---|---|---|---|---|---|")
            for k, v in bench_rows:
                out.append(f"| {k} | {v.get('backend', '?')} "
                           f"| {v.get('impl', '?')} | {v['match']} "
                           f"| {v['ref_s']:.3f} "
                           f"| {v['pallas_interp_s']:.3f} |")
        for k, v in sorted(kr.items()):
            if k != "resolved_impls" and not (isinstance(v, dict)
                                              and "match" in v):
                out.append(f"- {k}: " + json.dumps(v, sort_keys=True))
    svc = service_table(res)
    if svc:
        out.append("\n" + svc)
    pl = planner_table(res)
    if pl:
        out.append("\n" + pl)
    eq = equal_space_table(res)
    if eq:
        out.append("\n" + eq)
    dist = distributed_table(res)
    if dist:
        out.append("\n" + dist)
    return "\n".join(out)


def _splice(text, start, end, md):
    if start in text:
        pre, rest = text.split(start, 1)
        _, post = rest.split(end, 1)
        return pre + start + "\n" + md + "\n" + end + post
    return text + f"\n{start}\n{md}\n{end}\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(HERE, "out", "dryrun"))
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    base = [r for r in rows if len(r[0][:-5].split("__")) == 3]
    md = ("### Dry-run cells (auto-generated)\n\n" + dryrun_table(base)
          + "\n\n### Roofline terms (auto-generated)\n\n" + roofline_table(base))
    vmd = "### Variant measurements (auto-generated)\n\n" + variants_table(rows)
    pmd = paper_tables(os.path.join(HERE, "out", "results.json"))
    print(md + "\n\n" + vmd + "\n\n" + pmd)
    if args.write:
        path = os.path.join(os.path.dirname(HERE), "EXPERIMENTS.md")
        text = open(path).read()
        text = _splice(text, "<!-- AUTO-DRYRUN-START -->",
                       "<!-- AUTO-DRYRUN-END -->", md)
        text = _splice(text, "<!-- AUTO-VARIANTS-START -->",
                       "<!-- AUTO-VARIANTS-END -->", vmd)
        text = _splice(text, "<!-- AUTO-PAPER-START -->",
                       "<!-- AUTO-PAPER-END -->", pmd)
        open(path, "w").write(text)
        print(f"\n[written to {path}]")


if __name__ == "__main__":
    main()
