"""Benchmark runner: `PYTHONPATH=src python -m benchmarks.run [names...]`.

Default (no args) runs the paper benchmarks + the kernel micro-bench and
collates any dry-run roofline JSONs under benchmarks/out/dryrun into the
roofline summary table.  Individual benchmarks: table3 fig4_6 fig8 fig9a
fig9b fig9c fig10 kernels service equal_space distributed roofline.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

HERE = os.path.dirname(__file__)
OUT_DIR = os.path.join(HERE, "out")


def bench_kernels():
    """Pallas kernel (interpret mode) vs jnp reference: correctness + the
    structural numbers the kernel claims (VMEM tile residency)."""
    import jax
    import jax.numpy as jnp
    from repro.core import sketch as sk
    from repro.core.hashing import P31
    from repro.kernels.ops import sketch_update, sketch_moments
    from repro.kernels.registry import kernel_registry

    reg = kernel_registry()
    rng = np.random.default_rng(0)
    # which registry impl auto dispatch resolves to per op on this backend
    # (what the timed use_pallas=None/True/False rows actually ran)
    out = {"resolved_impls": reg.resolution()}
    for n, t, w in [(4096, 3, 1024), (16384, 3, 4096)]:
        params = sk.make_sketch_params(rng, t)
        k1 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        k2 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        weights = jnp.ones((n,), jnp.int32)
        empty = sk.empty_counters(t, w)
        t0 = time.time()
        ref = sketch_update(empty, k1, k2, params, weights, use_pallas=False)
        ref.block_until_ready()
        t_ref = time.time() - t0
        t0 = time.time()
        pal = sketch_update(empty, k1, k2, params, weights, use_pallas=True,
                            interpret=True)
        pal.block_until_ready()
        t_pal = time.time() - t0
        match = bool(jnp.array_equal(ref, pal))
        out[f"n{n}_t{t}_w{w}"] = {"match": match, "ref_s": t_ref,
                                  "pallas_interp_s": t_pal,
                                  "backend": jax.default_backend(),
                                  "impl": reg.resolve("sketch_update").name}
        print(f"sketch_update n={n} t={t} w={w}: match={match} "
              f"(ref {t_ref:.2f}s, pallas-interpret {t_pal:.2f}s)")
        assert match
    return out


def bench_service():
    """Estimation-service numbers: fused vs reference ingest, tenant
    scaling, shard scaling, and snapshot query latency (p50/p95).

    Rows:
      ingest_ref_1t          reference (per-level, unfused) update running
                             inside the SAME scan'd pipeline -- the
                             conformance oracle.  speedup_fused_vs_ref_1t
                             therefore isolates the fused-update win only;
                             the full delta vs the PR 1 per-round-dispatch
                             pipeline is the cross-commit records/sec
                             comparison of this row's history.
      ingest_fused_{S}t      fused path (one scan'd dispatch per flush,
                             fused fingerprint->sketch update), 1/4/16
                             tenants
      executor_{K}sh         core ShardedIngest executor at 1/2/4 shards
                             (shard_map over the device mesh when the host
                             exposes enough devices; deferred merge)
      snapshot_*_{N}s        query-side rows (see _query_rows): whole-group
                             snapshot x all thresholds at 1/16/64 streams,
                             fused batched engine (steady-state and
                             cold-cache) vs the PR 2 per-stream numpy path
    """
    import jax
    from repro.core import sjpc
    from repro.core.sjpc import SJPCConfig
    from repro.service import ContinuousQuery, EstimationService, ServiceConfig

    from repro.kernels.registry import kernel_registry

    cfg = SJPCConfig(d=6, s=4, ratio=0.5, width=1024, depth=3, seed=11)
    rng = np.random.default_rng(0)
    out = {"backend": jax.default_backend(),
           "resolved_impls": kernel_registry().resolution()}
    records_per_tenant = 4096

    def run_pipeline(tenants, *, use_fused, tag, trace_sink=None):
        svc = EstimationService(ServiceConfig(batch_rows=512, window_epochs=4,
                                              use_fused=use_fused,
                                              trace_sink=trace_sink))
        svc.create_group("g", cfg)
        names = [f"t{i}" for i in range(tenants)]
        for nm in names:
            svc.create_stream(nm, "g")
        batches = {nm: rng.integers(0, 1000, size=(records_per_tenant, cfg.d),
                                    dtype=np.uint32) for nm in names}

        def _block():
            # flush() enqueues async dispatches; time the compute, not the
            # enqueue (as bench_kernels does)
            jax.block_until_ready([svc.registry.stream(nm).window.total.counters
                                   for nm in names])

        # warmup: compile the (R, S, batch_rows) executable at the SAME
        # round count the measured flushes use (the scan'd dispatch is
        # shape-specialized on R)
        for nm in names:
            svc.ingest(nm, batches[nm])
        svc.flush()
        _block()
        cycles = 3
        t0 = time.time()
        for _ in range(cycles):
            for nm in names:
                svc.ingest(nm, batches[nm])
            svc.flush()
        _block()
        dt = time.time() - t0
        total = records_per_tenant * tenants * cycles
        out[tag] = {
            "tenants": tenants, "fused": use_fused, "records": total,
            "seconds": dt, "records_per_sec": total / dt,
            "rounds": svc.describe()["groups"]["g"]["ingest"]["rounds"],
        }
        print(f"{tag:>18}: {total / dt:>10.0f} records/s "
              f"({total} records, {dt:.2f}s)")
        return svc, names

    run_pipeline(1, use_fused=False, tag="ingest_ref_1t")
    trace_path = os.path.join(OUT_DIR, "trace.jsonl")
    if os.path.exists(trace_path):
        os.remove(trace_path)        # the tracer sink appends
    for tenants in (1, 4, 16):
        svc, names = run_pipeline(
            tenants, use_fused=True, tag=f"ingest_fused_{tenants}t",
            trace_sink=trace_path if tenants == 4 else None)
        if tenants == 4:
            for nm in names:
                svc.register_continuous(
                    ContinuousQuery(f"q/{nm}", "self_join", (nm,)))
            svc.register_continuous(
                ContinuousQuery("q/join", "join", (names[0], names[1])))
            svc.poll()                       # warmup
            met = svc.obs.metrics
            hits0 = met.counter_total("query_cache_hits_total")
            miss0 = met.counter_total("query_cache_misses_total")
            lats = []
            for _ in range(30):
                t0 = time.time()
                # poll results are host floats (the service blocks on the
                # committed windows and the batch tables), so this wall
                # time is device-inclusive
                svc.poll()
                lats.append(time.time() - t0)
            lats.sort()
            hits = met.counter_total("query_cache_hits_total") - hits0
            misses = met.counter_total("query_cache_misses_total") - miss0
            out["query"] = {
                "continuous_queries": tenants + 1,
                "poll_p50_ms": 1e3 * lats[len(lats) // 2],
                "poll_p95_ms": 1e3 * lats[int(len(lats) * 0.95)],
                "poll_p99_ms": 1e3 * lats[min(int(len(lats) * 0.99),
                                              len(lats) - 1)],
                "per_query_p50_ms": 1e3 * lats[len(lats) // 2] / (tenants + 1),
                # steady-state serving: unchanged windows should be pure
                # version-keyed cache hits
                "cache_hit_rate": hits / max(hits + misses, 1.0),
                "queue_depth_peak": float(
                    met.gauge("ingest_pending_rows_peak", group="g") or 0.0),
            }
            svc.obs.tracer.close()
            out["query"]["trace_events"] = sum(
                1 for _ in open(trace_path)) if os.path.exists(
                    trace_path) else 0
            print(f"poll ({tenants + 1} standing queries): "
                  f"p50 {out['query']['poll_p50_ms']:.1f}ms "
                  f"p95 {out['query']['poll_p95_ms']:.1f}ms "
                  f"p99 {out['query']['poll_p99_ms']:.1f}ms "
                  f"cache-hit {out['query']['cache_hit_rate']:.2f} "
                  f"queue-peak {out['query']['queue_depth_peak']:.0f}")

    out["speedup_fused_vs_ref_1t"] = (
        out["ingest_fused_1t"]["records_per_sec"]
        / out["ingest_ref_1t"]["records_per_sec"])
    print(f"fused vs reference (1 tenant): "
          f"{out['speedup_fused_vs_ref_1t']:.2f}x")

    # --- core sharded executor: 1/2/4 shards, deferred merge -------------
    # shard_map needs >1 device; rather than force a multi-device host
    # platform on THIS process (which would split the XLA:CPU thread pool
    # and slow every other row), the executor rows run in a subprocess
    # with --xla_force_host_platform_device_count=4 when the current
    # backend is single-device CPU.
    if jax.device_count() >= 4:
        out.update(_executor_rows())
    else:
        import subprocess
        from repro.platform import subprocess_env
        env = subprocess_env(4)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(os.path.dirname(HERE), "src"),
                        env.get("PYTHONPATH")) if p)
        proc = subprocess.run(
            [sys.executable, "-c",
             "import json, sys, os; sys.path.insert(0, os.environ['_BENCH_DIR']);"
             "from run import _executor_rows;"
             "print('EXECUTOR_JSON ' + json.dumps(_executor_rows()))"],
            env={**env, "_BENCH_DIR": HERE}, capture_output=True, text=True)
        rows = {}
        for line in proc.stdout.splitlines():
            if line.startswith("EXECUTOR_JSON "):
                rows = json.loads(line[len("EXECUTOR_JSON "):])
            else:
                print(line)
        if not rows:
            print(f"executor subprocess failed:\n{proc.stderr[-2000:]}")
        out.update(rows)

    out.update(_query_rows())
    return out


def _query_rows():
    """Snapshot query latency: every stream x every threshold of one hash
    group, p50/p95 over repeated snapshots, at 1/16/64 streams.

    Three engines answer the identical query set:

      snapshot_fused_{N}s       the service default -- fused batched engine
                                with the version-keyed cache shared across
                                snapshots.  Steady-state serving (standing
                                queries polling between flushes, the
                                continuous-query regime): repeated snapshots
                                of an unchanged window are cache lookups.
      snapshot_fused_cold_{N}s  same engine, cache dropped every iteration:
                                isolates the one-compiled-call batch compute
                                (stack + device put + jit'd moments/
                                inversion + host assembly).
      snapshot_ref_{N}s         the PR 2 semantics: per-stream int64 numpy
                                F2 + float64 Python inversion, recomputed
                                every snapshot (PR 2 memoized per Snapshot
                                object only, so its steady state IS the
                                recompute) -- reproduced by a fresh
                                reference engine per iteration.

    ``speedup_fused_query_16s`` (the acceptance row) is steady-state fused
    vs the PR 2 path; ``speedup_fused_query_cold_16s`` is the compute-only
    ratio with no cache amortization.
    """
    from repro.core.sjpc import SJPCConfig
    from repro.service import EstimationService, QueryEngine, ServiceConfig

    cfg = SJPCConfig(d=6, s=4, ratio=0.5, width=2048, depth=3, seed=11)
    svc = EstimationService(ServiceConfig(batch_rows=512, window_epochs=4))
    svc.create_group("q", cfg)
    rng = np.random.default_rng(0)
    names = [f"q{i}" for i in range(64)]
    for nm in names:
        svc.create_stream(nm, "q")
        svc.ingest(nm, rng.integers(0, 1000, size=(2048, cfg.d),
                                    dtype=np.uint32))
    svc.flush()

    def measure(make_snapshot, sub, iters=15):
        for _ in range(2):                       # compile + warm caches
            snap = make_snapshot(sub)
            for nm in sub:
                snap.all_thresholds(nm)
        lats = []
        for _ in range(iters):
            t0 = time.time()
            snap = make_snapshot(sub)
            for nm in sub:
                snap.all_thresholds(nm)
            lats.append(time.time() - t0)
        lats.sort()
        return (1e3 * lats[len(lats) // 2],
                1e3 * lats[int(len(lats) * 0.95)],
                1e3 * lats[min(int(len(lats) * 0.99), len(lats) - 1)])

    def cold_snapshot(sub):
        svc.engine._cache.clear()
        return svc.engine.snapshot(sub)

    out = {}
    thresholds = cfg.num_levels
    for n in (1, 16, 64):
        sub = names[:n]
        rows = {
            f"snapshot_fused_{n}s": lambda s: svc.engine.snapshot(s),
            f"snapshot_fused_cold_{n}s": cold_snapshot,
            f"snapshot_ref_{n}s": lambda s: QueryEngine(
                svc.registry, use_fused_query=False).snapshot(s),
        }
        for tag, mk in rows.items():
            p50, p95, p99 = measure(mk, sub)
            out[tag] = {"streams": n, "thresholds": thresholds,
                        "cells": n * thresholds, "p50_ms": p50,
                        "p95_ms": p95, "p99_ms": p99}
            print(f"{tag:>24}: p50 {p50:7.2f}ms p95 {p95:7.2f}ms "
                  f"p99 {p99:7.2f}ms "
                  f"({n} streams x {thresholds} thresholds)")
    for kind in ("", "cold_"):
        sp = (out["snapshot_ref_16s"]["p50_ms"]
              / out[f"snapshot_fused_{kind}16s"]["p50_ms"])
        out[f"speedup_fused_query_{kind}16s"] = sp
        print(f"fused{' (cold)' if kind else ''} vs per-stream reference "
              f"(16 streams x all thresholds): {sp:.1f}x")
    return out


def _executor_rows():
    """ShardedIngest throughput at 1/2/4 shards (run where >= 4 devices
    exist; on CPU the service bench spawns this in a forced-multi-device
    subprocess)."""
    import jax
    from repro.core import sjpc
    from repro.core.sjpc import SJPCConfig

    cfg = SJPCConfig(d=6, s=4, ratio=0.5, width=1024, depth=3, seed=11)
    rng = np.random.default_rng(0)
    params, _ = sjpc.init(cfg)
    micro, n_micro = 2048, 24
    batches = [rng.integers(0, 1000, size=(micro, cfg.d), dtype=np.uint32)
               for _ in range(n_micro)]
    out = {}
    for shards in (1, 2, 4):
        sh = sjpc.ShardedIngest(cfg, params, num_shards=shards)
        sh.ingest(batches[0])                # warmup/compile
        jax.block_until_ready(sh.deltas.counters)
        sh.reset()                           # keep the compiled step fn
        t0 = time.time()
        for b in batches:
            sh.ingest(b)
        merged = sh.merged()
        jax.block_until_ready(merged.counters)
        dt = time.time() - t0
        total = micro * n_micro
        out[f"executor_{shards}sh"] = {
            "shards": shards, "mapped": sh.mapped,
            "records": total, "seconds": dt, "records_per_sec": total / dt,
            "micro_batches": n_micro, "merges": sh.merges,
        }
        print(f"executor {shards} shard(s) "
              f"({'shard_map' if sh.mapped else 'vmap'}): "
              f"{total / dt:>10.0f} records/s ({n_micro} micro-batches, "
              f"1 merge)")
    return out


def bench_planner():
    """Planner suite (DESIGN.md §16): poll latency at 10/100/1000 standing
    queries over 8 hash groups x 8 streams (same derived config, so the
    planner fuses all touched group cohorts into ONE estimate_batch
    launch), planner on vs off, with one group's windows churned between
    polls so a poll is never a pure cache walk.

    The CI acceptance guard reads ``p95_ratio_1000q_vs_10q`` from
    results.json and requires <= 3x: serving cost must scale with device
    launches (bounded by fusion + the plan cache), not with query count.
    """
    from repro.core.sjpc import SJPCConfig
    from repro.service import ContinuousQuery, EstimationService, ServiceConfig

    cfg = SJPCConfig(d=6, s=4, ratio=0.5, width=512, depth=2, seed=7)
    rng = np.random.default_rng(0)
    groups, per_group = 8, 8
    churn = rng.integers(0, 1000, size=(256, cfg.d), dtype=np.uint32)
    out = {}
    for n_queries in (10, 100, 1000):
        for use_planner in (True, False):
            svc = EstimationService(ServiceConfig(
                batch_rows=256, window_epochs=None,
                use_planner=use_planner))
            names = []
            for g in range(groups):
                svc.create_group(f"g{g}", cfg)
                for s in range(per_group):
                    nm = f"g{g}/s{s}"
                    svc.create_stream(nm, f"g{g}")
                    names.append(nm)
            for i in range(n_queries):
                svc.register_continuous(ContinuousQuery(
                    f"q{i}", "self_join", (names[i % len(names)],)))
            for nm in names:
                svc.ingest(nm, churn)
            svc.flush()
            # warmup: compile + build the plan, then one churned poll so
            # the steady-state launch shape (just g0's cohort) is compiled
            # before timing starts
            svc.poll()
            svc.ingest(names[0], churn)
            svc.flush()
            svc.poll()
            lats = []
            for _ in range(15):
                # touch g0 (covered by every query count) so each measured
                # poll recomputes that cohort -- steady-state serving with
                # live ingest, not a pure cache walk
                svc.ingest(names[0], churn)
                svc.flush()
                t0 = time.time()
                svc.poll()
                lats.append(time.time() - t0)
            lats.sort()
            tag = f"poll_{'on' if use_planner else 'off'}_{n_queries}q"
            out[tag] = {
                "queries": n_queries, "planner": use_planner,
                "streams": len(names), "groups": groups,
                "p50_ms": 1e3 * lats[len(lats) // 2],
                "p95_ms": 1e3 * lats[int(len(lats) * 0.95)],
            }
            print(f"{tag:>16}: p50 {out[tag]['p50_ms']:7.2f}ms "
                  f"p95 {out[tag]['p95_ms']:7.2f}ms")
    out["p95_ratio_1000q_vs_10q"] = (out["poll_on_1000q"]["p95_ms"]
                                     / out["poll_on_10q"]["p95_ms"])
    print(f"p95(1000q)/p95(10q), planner on: "
          f"{out['p95_ratio_1000q_vs_10q']:.2f}x (guard <= 3.0)")
    return out


def bench_equal_space():
    """The paper's Fig. 8 as a living benchmark (DESIGN.md §13.5): replay
    one seeded planted-cluster stream through ALL served estimator kinds
    at derived (equal-space) budgets, in one hash group, and report

      * per-threshold relative error vs the exact count,
      * ingest throughput (records/s, per-kind cohort dispatch),
      * query latency (whole all-thresholds table, p50 over snapshots).

    The accuracy ordering (SJPC < reservoir at the mid band) is the
    test_paper_accuracy.py service-path contract; this row records the
    margins and the throughput cost of each estimator."""
    import jax
    from repro import estimators as E
    from repro.core import exact
    from repro.core.sjpc import SJPCConfig
    from repro.data.synthetic import planted_cluster_records
    from repro.service import EstimationService, ServiceConfig

    cfg = SJPCConfig(d=6, s=4, ratio=1.0, width=2048, depth=3, seed=17)
    n_records = 16384
    rng = np.random.default_rng(29)
    vals = planted_cluster_records(n_records, cfg.d, rng,
                                   [(4, 256, 3), (5, 192, 2), (6, 96, 1)])
    x_exact = exact.exact_pair_counts(vals)
    g_true = {s: float(x_exact[s:].sum() + n_records)
              for s in range(cfg.s, cfg.d + 1)}

    kinds = E.available()
    from repro.kernels.registry import kernel_registry
    out = {"workload": {"records": n_records, "d": cfg.d,
                        "g_true": {str(s): g for s, g in g_true.items()},
                        "sjpc_bytes": cfg.counters_bytes},
           "resolved_impls": kernel_registry().resolution()}

    # side-by-side accuracy: one service, every kind in one hash group
    svc = EstimationService(ServiceConfig(batch_rows=2048,
                                          window_epochs=None))
    svc.create_group("g", cfg)
    for kind in kinds:
        svc.create_stream(kind, "g", estimator=kind)
        svc.ingest(kind, vals)
    snap = svc.snapshot()
    for kind in kinds:
        row = snap.all_thresholds(kind)
        out[kind] = {
            "memory_bytes": svc.registry.stream(kind).estimator.memory_bytes(),
            "rel_err": {str(s): abs(r.estimate - g_true[s])
                        / max(g_true[s], 1.0)
                        for s, r in row.items()},
            # the served error bars (DESIGN.md §14): relative 1-sigma and
            # whether the 95% interval covers the exact answer
            "stderr_kind": next(iter(row.values())).stderr_kind,
            "stderr_rel": {str(s): r.stderr / max(g_true[s], 1.0)
                           for s, r in row.items()},
            "ci95_covers": {str(s): bool(abs(r.estimate - g_true[s])
                                         <= 1.96 * r.stderr)
                            for s, r in row.items()},
        }

    # per-kind ingest throughput (isolated service -> clean cohort timing)
    for kind in kinds:
        s1 = EstimationService(ServiceConfig(batch_rows=2048,
                                             window_epochs=None))
        s1.create_group("g", cfg)
        s1.create_stream("t", "g", estimator=kind)
        s1.ingest("t", vals)
        s1.flush()                                   # warmup + compile
        jax.block_until_ready(
            jax.tree_util.tree_leaves(s1.registry.stream("t").window.total))
        cycles = 2
        t0 = time.time()
        for _ in range(cycles):
            s1.ingest("t", vals)
            s1.flush()
        jax.block_until_ready(
            jax.tree_util.tree_leaves(s1.registry.stream("t").window.total))
        dt = time.time() - t0
        out[kind]["ingest_records_per_sec"] = n_records * cycles / dt

        # query latency: the full all-thresholds table, p50 over snapshots
        engine = s1.engine
        for _ in range(2):
            engine._cache.clear()
            engine.snapshot(["t"]).all_thresholds("t")
        lats = []
        for _ in range(9):
            engine._cache.clear()                    # cold: compute, not cache
            t0 = time.time()
            engine.snapshot(["t"]).all_thresholds("t")
            lats.append(time.time() - t0)
        lats.sort()
        out[kind]["query_p50_ms"] = 1e3 * lats[len(lats) // 2]
        print(f"{kind:>10}: mem {out[kind]['memory_bytes']:>7}B  "
              f"ingest {out[kind]['ingest_records_per_sec']:>9.0f} rec/s  "
              f"query p50 {out[kind]['query_p50_ms']:6.1f}ms  relerr "
              + " ".join(f"s={s}:{out[kind]['rel_err'][str(s)]:.3f}"
                         for s in range(cfg.s, cfg.d + 1)))
    return out


def bench_distributed():
    """Multi-worker ingest scale-out (DESIGN.md §18.5): the same workload
    through 1/2/4 subprocess-worker clusters; rows carry aggregate ingest
    rec/s, speedup vs the 1-worker baseline, merge p50/p95 latency, and
    replica query-freshness lag.  Worker environments are pinned
    identically (one forced host device, capped threads) so the ratios
    measure tenant sharding, not thread-count drift.  The merge-latency
    trace of the 2-worker smoke run lands next to results.json for
    artifact upload."""
    from repro.distributed import harness
    smoke = harness.run_smoke(os.path.join(OUT_DIR, "distributed_smoke.json"))
    out = harness.run_scaleout((1, 2, 4))
    out["smoke"] = {k: smoke[k] for k in
                    ("linear_exact", "worst_rel_err", "records")}
    return out


def bench_roofline():
    """Collate dry-run JSONs into the roofline summary table."""
    d = os.path.join(OUT_DIR, "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        print("no dry-run artifacts under benchmarks/out/dryrun -- run "
              "PYTHONPATH=src python -m repro.launch.dryrun --arch all --out "
              "benchmarks/out/dryrun first")
        return {}
    rows = []
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            rep = json.load(f)
        r = rep.get("roofline", {})
        rows.append({
            "cell": f"{rep['arch']}/{rep['shape']}/{'2pod' if rep['chips'] == 512 else '1pod'}",
            "dominant": r.get("dominant"),
            "compute_ms": round(1e3 * r.get("compute_s", 0), 2),
            "memory_ms": round(1e3 * r.get("memory_s", 0), 2),
            "collective_ms": round(1e3 * r.get("collective_s", 0), 2),
            "useful_ratio": round(r.get("useful_ratio", 0), 3),
        })
    hdr = (f"{'cell':50s} {'dom':10s} {'comp_ms':>9s} {'mem_ms':>9s} "
           f"{'coll_ms':>9s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['cell']:50s} {str(r['dominant']):10s} "
              f"{r['compute_ms']:9.2f} {r['memory_ms']:9.2f} "
              f"{r['collective_ms']:9.2f} {r['useful_ratio']:7.3f}")
    return rows


def main(argv):
    os.makedirs(OUT_DIR, exist_ok=True)
    # REPRO_PLUGINS=examples.plugins adds plugin estimator kinds: suites
    # that enumerate estimators.available() (equal_space) pick them up
    # automatically, so plugin rows land in the collated report
    from repro import estimators
    estimators.load_plugins()
    from benchmarks import paper_benchmarks as PB
    names = argv or (list(PB.ALL)
                     + ["kernels", "service", "planner", "equal_space",
                        "distributed", "roofline"])
    results_path = os.path.join(OUT_DIR, "results.json")
    # merge into prior results so a partial run (e.g. `run service`) never
    # drops the other suites' rows from the collated report
    results = {}
    if os.path.exists(results_path):
        try:
            with open(results_path) as f:
                results = json.load(f)
        except (OSError, json.JSONDecodeError):
            results = {}
    for name in names:
        print(f"\n=== {name} ===")
        t0 = time.time()
        if name == "kernels":
            results[name] = bench_kernels()
        elif name == "service":
            results[name] = bench_service()
        elif name == "planner":
            results[name] = bench_planner()
        elif name == "equal_space":
            results[name] = bench_equal_space()
        elif name == "distributed":
            results[name] = bench_distributed()
        elif name == "roofline":
            results[name] = bench_roofline()
        else:
            results[name] = PB.ALL[name]()
        print(f"[{name}: {time.time() - t0:.1f}s]")
    with open(results_path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\nresults -> {results_path}")


if __name__ == "__main__":
    main(sys.argv[1:])
