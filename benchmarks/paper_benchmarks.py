"""Paper-table/figure reproductions on seeded synthetic matched datasets.

One benchmark per table/figure of the paper (sizes scaled to single-core CPU;
the estimator statistics -- relative error vs threshold / ratio / dims /
size -- are what the paper's claims are about, and those are
size-independent per Thm 1/2):

  table3   -- accumulative s-similar pair counts on DBLP-like data
  fig4_6   -- offline relative error: SJPC vs LSH-SS vs random sampling
  fig8     -- online (sketched) error vs random sampling at EQUAL SPACE
  fig9a    -- error vs sampling ratio r
  fig9b    -- error vs dimensionality d
  fig9c    -- error vs dataset size (constant space)
  fig10    -- running time scaling vs n (SJPC linear; sampling quadratic)
"""
from __future__ import annotations

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import exact, sjpc, baselines
from repro.data.synthetic import dblp_like, shingle_records, skewed

OUT = {}


def _run_sjpc(records, d, s, *, ratio=0.5, width=1024, depth=3, seed=0,
              batch=2048, update_fn=None):
    cfg = sjpc.SJPCConfig(d=d, s=s, ratio=ratio, width=width, depth=depth,
                          seed=seed)
    params, state = sjpc.init(cfg)
    upd = jax.jit(lambda st, vals, key: sjpc.update(cfg, params, st, vals, key,
                                                    update_fn=update_fn))
    key = jax.random.PRNGKey(seed)
    for i in range(0, len(records), batch):
        chunk = records[i:i + batch]
        if len(chunk) < batch:      # pad + mask via separate trace (tail only)
            st = sjpc.update(cfg, params, state, jnp.asarray(chunk),
                             jax.random.fold_in(key, i))
            state = st
        else:
            state = upd(state, jnp.asarray(chunk), jax.random.fold_in(key, i))
    return sjpc.estimate(cfg, state)


def _rel_err(est, true):
    return abs(est - true) / max(true, 1.0)


def table3(n=8000, trials=1):
    """Accumulative s-similar pair counts (exact) on DBLP5/6-like data."""
    rows = {}
    for name, d in [("DBLP5-like", 5), ("DBLP6-like", 6)]:
        recs = dblp_like(n, d=d, seed=42)
        x = exact.exact_pair_counts(recs)
        rows[name] = {s: float(x[s:].sum()) for s in range(1, d + 1)}
    OUT["table3"] = rows
    print(json.dumps(rows, indent=1))
    return rows


def fig4_6(n=6000, trials=8):
    """Offline relative error vs threshold: SJPC(r=.5) / LSH-SS / sampling."""
    d = 6
    recs = dblp_like(n, d=d, seed=7, dup_fraction=0.15)
    out = {}
    for s in range(2, d + 1):
        g_true = exact.exact_g(recs, s)
        errs = {"sjpc_offline": [], "lsh_ss": [], "sampling": []}
        for t in range(trials):
            rng = np.random.default_rng(100 + t)
            # SJPC offline = exact level sizes on the SAMPLED sub-value
            # streams (no sketch): emulate by wide sketch (negligible error)
            est = _run_sjpc(recs, d, s, ratio=0.5, width=1 << 14, depth=3,
                            seed=t)
            errs["sjpc_offline"].append(_rel_err(est.g_s, g_true))
            errs["lsh_ss"].append(_rel_err(
                baselines.lsh_ss_g(recs, s, rng, m_h=n // 4, m_l=n // 4), g_true))
            errs["sampling"].append(_rel_err(
                baselines.random_sampling_g(recs, s, int(np.sqrt(n) * 4), rng),
                g_true))
        out[s] = {k: {"mean": float(np.mean(v)), "std": float(np.std(v))}
                  for k, v in errs.items()}
        print(f"s={s}: " + "  ".join(
            f"{k}={np.mean(v):.3f}+-{np.std(v):.3f}" for k, v in errs.items()))
    OUT["fig4_6"] = out
    return out


def fig8(n=20000, trials=8):
    """Online error at equal space: SJPC sketches vs random sampling.

    Space: (d-s+1)*t*w counters * 4B = 4 sketches * 3 * 1024 * 4B = 48KB
    -> sampling gets 48KB / (6 cols * 4B) = 2048 records.
    """
    d, s_min = 6, 3
    recs = shingle_records(n, d=d, seed=11,
                           dup_profile=((3, 0.05), (4, 0.03), (5, 0.02), (6, 0.01)))
    space = (d - s_min + 1) * 3 * 1024 * 4
    # DBLPtitles records are 6 x 64-bit fingerprints = 48 B (paper §7.3)
    sample_sz = baselines.sample_size_for_bytes(space, d * 8)
    out = {}
    for s in range(s_min, d + 1):
        g_true = exact.exact_g(recs, s)
        e_sjpc, e_samp = [], []
        for t in range(trials):
            rng = np.random.default_rng(200 + t)
            est = _run_sjpc(recs, d, s, ratio=0.5, width=1024, depth=3, seed=t)
            e_sjpc.append(_rel_err(est.g_s, g_true))
            e_samp.append(_rel_err(
                baselines.random_sampling_g(recs, s, sample_sz, rng), g_true))
        out[s] = {"sjpc": {"mean": float(np.mean(e_sjpc)), "std": float(np.std(e_sjpc))},
                  "sampling": {"mean": float(np.mean(e_samp)), "std": float(np.std(e_samp))},
                  "g_true": g_true}
        print(f"s={s}: sjpc={np.mean(e_sjpc):.3f}+-{np.std(e_sjpc):.3f} "
              f"sampling={np.mean(e_samp):.3f}+-{np.std(e_samp):.3f}")
    OUT["fig8"] = out
    return out


def fig9a(n=10000, trials=6):
    """Error std vs sampling ratio r."""
    d, s = 6, 4
    recs = shingle_records(n, d=d, seed=13,
                           dup_profile=((4, 0.04), (5, 0.02), (6, 0.01)))
    g_true = exact.exact_g(recs, s)
    out = {}
    for r in (0.25, 0.5, 0.75, 1.0):
        errs = [ _rel_err(_run_sjpc(recs, d, s, ratio=r, seed=t).g_s, g_true)
                 for t in range(trials)]
        out[r] = {"mean": float(np.mean(errs)), "std": float(np.std(errs))}
        print(f"r={r}: {np.mean(errs):.3f}+-{np.std(errs):.3f}")
    OUT["fig9a"] = out
    return out


def fig9b(n=6000, trials=6):
    """Error vs dimensionality d (same space)."""
    s_gap = 2   # s = d - 2
    out = {}
    for d in (4, 5, 6, 7, 8):
        s = d - s_gap
        recs = shingle_records(n, d=d, seed=17,
                               dup_profile=((s, 0.04), (d, 0.01)))
        g_true = exact.exact_g(recs, s)
        errs = [_rel_err(_run_sjpc(recs, d, s, seed=t).g_s, g_true)
                for t in range(trials)]
        out[d] = {"mean": float(np.mean(errs)), "std": float(np.std(errs))}
        print(f"d={d} s={s}: {np.mean(errs):.3f}+-{np.std(errs):.3f}")
    OUT["fig9b"] = out
    return out


def fig9c(trials=4):
    """Error vs dataset size at constant space.

    Paper §7.4 construction: start from a base set and duplicate each
    record X in {1,2,4,8} times -- n grows linearly, g_s grows ~X^2, and
    the relative error DROPS with n (Thm 2: space need not grow when g_s
    grows with n^2)."""
    d, s = 6, 4
    base = shingle_records(8000, d=d, seed=19,
                           dup_profile=((4, 0.04), (6, 0.01)))
    out = {}
    for x in (1, 2, 4, 8):
        recs = np.repeat(base, x, axis=0)
        g_true = exact.exact_g(recs, s)
        errs = [_rel_err(_run_sjpc(recs, d, s, seed=t).g_s, g_true)
                for t in range(trials)]
        n = len(recs)
        out[n] = {"mean": float(np.mean(errs)), "std": float(np.std(errs)),
                  "g": g_true}
        print(f"n={n} (x{x}): {np.mean(errs):.3f}+-{np.std(errs):.3f} "
              f"(g={g_true:.0f})")
    OUT["fig9c"] = out
    return out


def fig10(trials=1):
    """Running time vs n: SJPC linear, sampling at error-matched size ~n^0.95
    quadratic in sample; plus relative error at those settings.

    The jitted batch update is warmed up once per size so compile time
    (a fixed ~10 s CPU cost) doesn't mask the linear scaling."""
    d, s = 5, 4
    out = {}
    for n in (4000, 8000, 16000, 32000):
        recs = skewed(n, d=d, frac_unique=0.2, group=16, seed=23)
        _run_sjpc(recs[:2048], d, s, ratio=1.0, width=1024, depth=3, seed=0)
        t0 = time.time()
        est = _run_sjpc(recs, d, s, ratio=1.0, width=1024, depth=3, seed=0)
        t_sjpc = time.time() - t0
        g_true = exact.exact_g(recs, s)
        e_sjpc = _rel_err(est.g_s, g_true)
        rng = np.random.default_rng(0)
        R = int(n ** 0.95)
        t0 = time.time()
        g_samp = baselines.random_sampling_g(recs, s, R, rng)
        t_samp = time.time() - t0
        out[n] = {"sjpc_s": t_sjpc, "sampling_s": t_samp,
                  "sjpc_err": e_sjpc, "sampling_err": _rel_err(g_samp, g_true)}
        print(f"n={n}: sjpc {t_sjpc:.2f}s err={e_sjpc:.3f} | "
              f"sampling(R=n^.95) {t_samp:.2f}s err={out[n]['sampling_err']:.3f}")
    OUT["fig10"] = out
    return out


def fig8_scaled(n=100_000, trials=3):
    """Fig 8 at paper-like scale: n=100k, sampling gets 48 KB = 1000
    records (1%); the sparse-pair regime where Lemma 1 bites sampling."""
    d, s_min = 6, 4
    recs = shingle_records(n, d=d, seed=29, group=4,
                           dup_profile=((4, 0.01), (5, 0.006), (6, 0.004)))
    space = (d - s_min + 1) * 3 * 1024 * 4     # 36 KB
    sample_sz = baselines.sample_size_for_bytes(space, d * 8)
    out = {}
    for s in range(s_min, d + 1):
        g_true = exact.exact_g(recs, s)
        e_sjpc, e_samp = [], []
        for t in range(trials):
            rng = np.random.default_rng(300 + t)
            est = _run_sjpc(recs, d, s, ratio=0.5, width=1024, depth=3, seed=t)
            e_sjpc.append(_rel_err(est.g_s, g_true))
            e_samp.append(_rel_err(
                baselines.random_sampling_g(recs, s, sample_sz, rng), g_true))
        out[s] = {"sjpc": float(np.mean(e_sjpc)),
                  "sampling": float(np.mean(e_samp)), "g_true": g_true}
        print(f"s={s}: sjpc={np.mean(e_sjpc):.3f} sampling={np.mean(e_samp):.3f} "
              f"(g={g_true:.0f}, sample={sample_sz})")
    OUT["fig8_scaled"] = out
    return out


ALL = {"table3": table3, "fig4_6": fig4_6, "fig8": fig8,
       "fig8_scaled": fig8_scaled, "fig9a": fig9a,
       "fig9b": fig9b, "fig9c": fig9c, "fig10": fig10}
