"""Query planner + admission control (DESIGN.md §16) and the PR 7 bug
batch: planner-on results equal planner-off results for every estimator
kind (self + join), plans cache and invalidate on topology changes,
throttled tenants get stale=True copies of their last fresh results, and
the three query/ingest-path regressions stay fixed -- join prefetch
buckets by estimator instance, cache eviction is LRU (hot standing-query
entries survive), and a stream's replay coordinate is independent of its
cohort-mates' backlogs."""
import numpy as np
import jax
import pytest

from repro import estimators as est_mod
from repro.estimators import base as est_base
from repro.core.sjpc import SJPCConfig
from repro.estimators.sjpc_backend import SJPCEstimator
from repro.obs import MetricsRegistry, Observability, Tracer
from repro.service import (ContinuousQuery, EstimationService, PlannerConfig,
                           QueryEngine, ServiceConfig)

KINDS = ["sjpc", "reservoir", "lsh_ss"]


def _cfg(**kw):
    base = dict(d=6, s=4, ratio=0.5, width=256, depth=2)
    base.update(kw)
    return SJPCConfig(**base)


def _obs():
    """A private metrics registry per test (the default bundle is
    process-global, so counters would accumulate across tests)."""
    m = MetricsRegistry()
    return Observability(metrics=m, tracer=Tracer(registry=m))


def _records(rng, n, d=6, card=6):
    return rng.integers(0, card, size=(n, d)).astype(np.uint32)


def _result_close(a, b, tol=1e-6):
    if isinstance(a, dict):
        assert a.keys() == b.keys()
        for k in a:
            _result_close(a[k], b[k], tol)
        return
    assert a.kind == b.kind and a.streams == b.streams and a.s == b.s
    assert a.estimate == pytest.approx(b.estimate, abs=tol, rel=tol)
    assert a.stderr == pytest.approx(b.stderr, abs=tol, rel=tol)
    assert a.stderr_offline == pytest.approx(b.stderr_offline,
                                             abs=tol, rel=tol)
    np.testing.assert_allclose(np.asarray(a.per_level),
                               np.asarray(b.per_level), atol=tol, rtol=tol)
    assert a.stderr_kind == b.stderr_kind


def _populate(svc, *, groups=2, rng_seed=0):
    """Identical topology + data for twin services: ``groups`` hash groups
    with one stream per estimator kind plus a second sjpc stream (the
    join partner), standing queries over all of it."""
    rng = np.random.default_rng(rng_seed)
    cfg = _cfg()
    for g in range(groups):
        gid = f"g{g}"
        svc.create_group(gid, cfg)
        for kind in KINDS:
            svc.create_stream(f"{gid}-{kind}", gid, estimator=kind)
        svc.create_stream(f"{gid}-sjpc2", gid, estimator="sjpc")
        for name in [f"{gid}-{k}" for k in KINDS] + [f"{gid}-sjpc2"]:
            svc.ingest(name, _records(rng, 300))
        for kind in KINDS:
            svc.register_continuous(ContinuousQuery(
                f"q-{gid}-{kind}", "self_join", (f"{gid}-{kind}",)))
        svc.register_continuous(ContinuousQuery(
            f"qa-{gid}", "all_thresholds", (f"{gid}-sjpc2",)))
        svc.register_continuous(ContinuousQuery(
            f"qj-{gid}", "join", (f"{gid}-sjpc", f"{gid}-sjpc2")))
    return rng


class TestPlannerConformance:
    """Planner-on == planner-off within 1e-6 for every served estimate,
    across all estimator kinds, self + all-thresholds + join, over polls
    that interleave fresh ingest (the acceptance criterion)."""

    @pytest.mark.parametrize("fused", [True, False])
    def test_on_equals_off_all_kinds(self, fused):
        on = EstimationService(ServiceConfig(
            batch_rows=64, window_epochs=4, use_planner=True,
            use_fused_query=fused), obs=_obs())
        off = EstimationService(ServiceConfig(
            batch_rows=64, window_epochs=4, use_planner=False,
            use_fused_query=fused), obs=_obs())
        rng_on = _populate(on)
        rng_off = _populate(off)
        for _ in range(2):
            out_on, out_off = on.poll(), off.poll()
            assert out_on.keys() == out_off.keys()
            for name in out_on:
                _result_close(out_on[name], out_off[name])
            for svc, rng in ((on, rng_on), (off, rng_off)):
                for g in range(2):
                    svc.ingest(f"g{g}-sjpc", _records(rng, 100))

    def test_cross_group_fusion_one_launch(self):
        """N same-config groups' sjpc cohorts must share ONE
        estimate_batch launch (the tentpole's point), with correct
        per-group results."""
        obs = _obs()
        svc = EstimationService(ServiceConfig(batch_rows=64,
                                              window_epochs=4), obs=obs)
        rng = np.random.default_rng(3)
        cfg = _cfg()
        for g in range(4):
            svc.create_group(f"g{g}", cfg)
            svc.create_stream(f"s{g}", f"g{g}")
            svc.ingest(f"s{g}", _records(rng, 200))
            svc.register_continuous(
                ContinuousQuery(f"q{g}", "self_join", (f"s{g}",)))
        out = svc.poll()
        launches = obs.metrics.series("planner_fused_launches_total")
        cohorts = obs.metrics.series("planner_fused_cohorts_total")
        assert launches[(("op", "self"),)] == 1.0
        assert cohorts[(("op", "self"),)] == 4.0
        # unstacked per-group entries match per-group single-service math
        for g in range(4):
            solo = QueryEngine(svc.registry, obs=_obs()) \
                .snapshot([f"s{g}"]).self_join(f"s{g}")
            _result_close(out[f"q{g}"], solo)

    def test_plan_cached_and_invalidated_by_create_stream(self):
        obs = _obs()
        svc = EstimationService(ServiceConfig(batch_rows=64,
                                              window_epochs=4), obs=obs)
        rng = np.random.default_rng(4)
        svc.create_group("g", _cfg())
        svc.create_stream("s0", "g")
        svc.ingest("s0", _records(rng, 200))
        svc.register_continuous(ContinuousQuery("q0", "self_join", ("s0",)))
        svc.poll()
        svc.poll()
        built = obs.metrics.series("planner_plans_built_total")
        reuse = obs.metrics.series("planner_plan_reuse_total")
        assert built[()] == 1.0 and reuse[()] == 1.0
        # a mid-life create_stream changes cohort membership: the plan must
        # rebuild, and the new stream's results must match a planner-off twin
        svc.create_stream("s1", "g")
        svc.ingest("s1", _records(rng, 150))
        svc.register_continuous(ContinuousQuery("q1", "self_join", ("s1",)))
        out = svc.poll()
        assert obs.metrics.series("planner_plans_built_total")[()] == 2.0
        twin = EstimationService(ServiceConfig(
            batch_rows=64, window_epochs=4, use_planner=False), obs=_obs())
        rng = np.random.default_rng(4)
        twin.create_group("g", _cfg())
        twin.create_stream("s0", "g")
        twin.ingest("s0", _records(rng, 200))
        twin.register_continuous(ContinuousQuery("q0", "self_join", ("s0",)))
        twin.poll()
        twin.poll()
        twin.create_stream("s1", "g")
        twin.ingest("s1", _records(rng, 150))
        twin.register_continuous(ContinuousQuery("q1", "self_join", ("s1",)))
        tout = twin.poll()
        for name in out:
            _result_close(out[name], tout[name])


class TestAdmissionControl:
    def _service(self):
        obs = _obs()
        svc = EstimationService(ServiceConfig(batch_rows=64,
                                              window_epochs=4), obs=obs)
        rng = np.random.default_rng(5)
        svc.create_group("g", _cfg())
        for s in ("a", "b"):
            svc.create_stream(s, "g")
            svc.ingest(s, _records(rng, 200))
        return svc, obs, rng

    def test_throttled_tenant_served_stale_last_fresh(self):
        svc, obs, rng = self._service()
        svc.register_continuous(ContinuousQuery("qa", "self_join", ("a",)))
        svc.register_continuous(ContinuousQuery("qb", "self_join", ("b",)))
        first = svc.poll()
        assert not first["qa"].stale and not first["qb"].stale
        svc.set_tenant_budget("a", 0)
        svc.ingest("a", _records(rng, 300))
        svc.ingest("b", _records(rng, 300))
        second = svc.poll()
        # throttled: stale flag set, values frozen at the last fresh serve
        assert second["qa"].stale
        assert second["qa"].estimate == first["qa"].estimate
        assert second["qa"].stderr == first["qa"].stderr
        # the snapshot itself advanced: the funded tenant sees new data
        assert not second["qb"].stale
        assert second["qb"].estimate != first["qb"].estimate
        rej = obs.metrics.series("admission_rejections_total")
        assert rej[(("tenant", "a"),)] == 1.0
        # budget refill restores service with fresh (non-stale) values
        svc.set_tenant_budget("a", 10)
        third = svc.poll()
        assert not third["qa"].stale
        assert third["qa"].estimate != first["qa"].estimate

    def test_priority_orders_throttling_within_tenant(self):
        svc, obs, _ = self._service()
        svc.register_continuous(ContinuousQuery(
            "low", "self_join", ("a",), priority=2, tenant="t"))
        svc.register_continuous(ContinuousQuery(
            "high", "self_join", ("b",), priority=0, tenant="t"))
        first = svc.poll()         # both fresh: never-served is admitted
        assert not first["low"].stale and not first["high"].stale
        svc.set_tenant_budget("t", 1)
        second = svc.poll()
        assert not second["high"].stale      # the critical class is served
        assert second["low"].stale           # the budget ran out below it

    def test_all_thresholds_stale_marks_every_cell(self):
        svc, obs, rng = self._service()
        svc.register_continuous(ContinuousQuery(
            "qt", "all_thresholds", ("a",)))
        first = svc.poll()
        svc.set_tenant_budget("a", 0)
        svc.ingest("a", _records(rng, 300))
        second = svc.poll()
        assert all(r.stale for r in second["qt"].values())
        for k, r in second["qt"].items():
            assert r.estimate == first["qt"][k].estimate


# -- satellite bugfix regressions -------------------------------------


class _ScaledJoinEstimator(SJPCEstimator):
    """A join-capable kind whose estimator_cfg changes the numbers: the
    sharpest probe that mixed-instance join pairs must not share one
    batched launch (the launcher's estimator would silently answer for
    every pair)."""
    kind = "sjpc_scaled"

    def __init__(self, cfg, params=None, *, scale=1.0, **kw):
        super().__init__(cfg, params, **kw)
        self.scale = float(scale)

    def estimate_join_batch(self, states_a, states_b, **kw):
        t = super().estimate_join_batch(states_a, states_b, **kw)
        return t._replace(g=np.asarray(t.g) * self.scale)

    def estimate_join_ref(self, state_a, state_b, **kw):
        t = super().estimate_join_ref(state_a, state_b, **kw)
        return t._replace(g=np.asarray(t.g) * self.scale)


@pytest.fixture
def scaled_kind():
    """Register the probe kind for one test and UNREGISTER on teardown:
    suite-mates enumerate ``estimators.available()`` (e.g. the served
    stderr and equal-space contracts) and must never see it."""
    try:
        est_mod.register(
            "sjpc_scaled",
            lambda sjpc_cfg, *, params=None, estimator_cfg=None, opts=None:
            _ScaledJoinEstimator(sjpc_cfg, params,
                                 **{**(dict(opts) if opts else {}),
                                    **(dict(estimator_cfg)
                                       if estimator_cfg else {})}))
    except ValueError:
        pass                         # already registered in this process
    yield "sjpc_scaled"
    est_base._REGISTRY.pop("sjpc_scaled", None)


class TestJoinPrefetchCohorts:
    """Regression (ISSUE 7 satellite 1): join pairs must bucket by
    estimator instance + state shapes like the self path, not by group
    alone -- a group mixing estimator_cfg-overridden streams used to
    stack every pair into the first pair's estimator."""

    @pytest.mark.parametrize("use_planner", [True, False])
    def test_mixed_instance_pairs_answer_with_their_own_estimator(
            self, use_planner, scaled_kind):
        svc = EstimationService(ServiceConfig(
            batch_rows=64, window_epochs=4, use_planner=use_planner),
            obs=_obs())
        svc.create_group("g", _cfg(ratio=1.0, width=512))
        rng = np.random.default_rng(6)
        for name in ("a1", "b1"):
            svc.create_stream(name, "g", estimator="sjpc_scaled")
        for name in ("a2", "b2"):
            svc.create_stream(name, "g", estimator="sjpc_scaled",
                              estimator_cfg={"scale": 100.0})
        for name in ("a1", "b1", "a2", "b2"):
            svc.ingest(name, _records(rng, 200, card=4))
        svc.register_continuous(
            ContinuousQuery("j1", "join", ("a1", "b1")))
        svc.register_continuous(
            ContinuousQuery("j2", "join", ("a2", "b2")))
        out = svc.poll()
        # the oracle: each pair alone, through a fresh engine (single-pair
        # launches always use the pair's own estimator)
        for qname, pair in (("j1", ("a1", "b1")), ("j2", ("a2", "b2"))):
            solo = QueryEngine(svc.registry, obs=_obs()) \
                .snapshot().join(*pair)
            assert solo.estimate > 0
            assert out[qname].estimate == pytest.approx(solo.estimate,
                                                        rel=1e-9)


class TestLRUCacheEviction:
    """Regression (ISSUE 7 satellite 2): cache overflow must evict
    least-recently-used entries, not clear the table -- hot standing
    queries survive an eviction cycle, and the evictions counter counts
    entries actually dropped."""

    def test_hot_entry_survives_churn(self, monkeypatch):
        import repro.service.query as qmod
        monkeypatch.setattr(qmod, "_CACHE_MAX_ENTRIES", 4)
        obs = _obs()
        svc = EstimationService(ServiceConfig(batch_rows=32,
                                              window_epochs=4), obs=obs)
        rng = np.random.default_rng(7)
        svc.create_group("hot", _cfg())
        svc.create_stream("hot-s", "hot")
        svc.ingest("hot-s", _records(rng, 100))
        svc.create_group("churn", _cfg())
        svc.create_stream("churn-s", "churn")
        iters = 10
        for _ in range(iters):
            svc.ingest("churn-s", _records(rng, 64))
            svc.flush()                  # bumps churn-s's window version:
            snap = svc.engine.snapshot()  # a brand-new cache entry per loop
            snap.self_join("hot-s")
            snap.self_join("churn-s")
        misses = obs.metrics.series("query_cache_misses_total")
        hot_key = (("group", "hot"), ("kind", "sjpc"), ("op", "self"))
        # the hot entry was computed exactly once; every later snapshot
        # found it despite 10 churn entries flowing through a 4-entry cache
        assert misses[hot_key] == 1.0
        assert len(svc.engine._cache) <= 4 + 1
        # evictions counter counts entries: the cache exceeds the bound
        # from the 5th churn key on, shedding exactly one stale key per
        # snapshot thereafter
        evicted = sum(obs.metrics.series(
            "query_cache_evictions_total").values())
        assert evicted == float(iters - 4)


class TestReplayCoordinateIndependence:
    """Regression (ISSUE 7 satellite 3): a stream's committed window state
    -- and its ``flushes`` replay coordinate -- must be bit-identical
    whether or not a busier cohort-mate shared its flushes (the ingest.py
    offline-replay contract)."""

    def _run(self, kind: str, with_busy: bool):
        svc = EstimationService(ServiceConfig(batch_rows=32,
                                              window_epochs=4), obs=_obs())
        svc.create_group("g", _cfg())
        svc.create_stream("solo", "g", estimator=kind)   # uid 0 either way
        if with_busy:
            svc.create_stream("busy", "g", estimator=kind)
        rng = np.random.default_rng(8)      # solo's records: shared draw
        busy_rng = np.random.default_rng(99)
        for _ in range(2):
            svc.ingest("solo", _records(rng, 40))     # 2 rounds of 32
            if with_busy:
                svc.ingest("busy", _records(busy_rng, 300))  # 10 rounds
            svc.flush()
        return svc.registry.stream("solo")

    @pytest.mark.parametrize("kind", ["sjpc", "reservoir"])
    def test_state_independent_of_cohort_backlog(self, kind):
        alone = self._run(kind, with_busy=False)
        crowded = self._run(kind, with_busy=True)
        # replay coordinate: only the rounds that carried solo's rows
        assert alone.flushes == crowded.flushes == 4
        la = jax.tree_util.tree_leaves(alone.window.window_state())
        lc = jax.tree_util.tree_leaves(crowded.window.window_state())
        assert len(la) == len(lc)
        for x, y in zip(la, lc):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestLaunchCoalescing:
    """ISSUE 9 satellite: ``PlannerConfig.coalesce_window`` lets identical
    fusion-signature launches from back-to-back sub-second polls reuse the
    in-flight result -- the new version key aliases the last launch's
    cache entry, so no device work runs -- while polls outside the window
    (and the default window of 0) recompute as before."""

    def _svc(self, window):
        svc = EstimationService(
            ServiceConfig(batch_rows=64, window_epochs=4,
                          planner=PlannerConfig(coalesce_window=window)),
            obs=_obs())
        svc.create_group("g", _cfg())
        svc.create_stream("a", "g")
        svc.create_stream("b", "g")
        svc.register_continuous(ContinuousQuery("qa", "self_join", ("a",)))
        svc.register_continuous(ContinuousQuery("qj", "join", ("a", "b")))
        clock = [0.0]
        svc.planner._now = lambda: clock[0]
        return svc, clock

    def _ingest_poll(self, svc, rng):
        for nm in ("a", "b"):
            svc.ingest(nm, _records(rng, 64))
        return svc.poll()

    def test_within_window_reuses_launch(self):
        svc, clock = self._svc(0.5)
        rng = np.random.default_rng(0)
        m = svc.obs.metrics
        r1 = self._ingest_poll(svc, rng)           # t=0: fresh launches
        clock[0] = 0.2
        r2 = self._ingest_poll(svc, rng)           # in-window: coalesced
        assert m.counter("planner_coalesced_launches_total", op="self") == 1.0
        assert m.counter("planner_coalesced_launches_total", op="join") == 1.0
        # served the in-flight result, fresh (not the stale channel)
        assert r2["qa"].estimate == r1["qa"].estimate
        assert r2["qj"].estimate == r1["qj"].estimate
        assert not r2["qa"].stale and not r2["qj"].stale
        clock[0] = 1.0                             # window measured from the
        r3 = self._ingest_poll(svc, rng)           # LAUNCH, not the alias
        assert m.counter_total("planner_coalesced_launches_total") == 2.0
        assert r3["qa"].estimate != r1["qa"].estimate

    def test_zero_window_always_recomputes(self):
        svc, clock = self._svc(0.0)
        rng = np.random.default_rng(1)
        r1 = self._ingest_poll(svc, rng)
        r2 = self._ingest_poll(svc, rng)           # same instant: still fresh
        assert svc.obs.metrics.counter_total(
            "planner_coalesced_launches_total") == 0.0
        assert r1["qa"].estimate != r2["qa"].estimate

    def test_unchanged_versions_hit_cache_not_coalescing(self):
        """A poll with no new data is a plain version-keyed cache hit; the
        coalescing counter must not claim it."""
        svc, clock = self._svc(10.0)
        rng = np.random.default_rng(2)
        r1 = self._ingest_poll(svc, rng)
        clock[0] = 0.1
        r2 = svc.poll()                            # no ingest between polls
        assert svc.obs.metrics.counter_total(
            "planner_coalesced_launches_total") == 0.0
        assert r2["qa"].estimate == r1["qa"].estimate
