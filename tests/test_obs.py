"""repro.obs: metrics registry, tracer spans, service instrumentation,
version-keyed cache accounting, accuracy telemetry, and the disabled-mode
overhead contract (DESIGN.md §15).

Service-level tests inject a private Observability bundle per test, so
they never race the process-global registry (which the kernel dispatch
counters and any default-config service write into).
"""
from __future__ import annotations

import io
import json
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.sjpc import SJPCConfig
from repro.obs import (Histogram, MetricsRegistry, Observability, Tracer,
                       default_registry, set_default_registry)
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

CFG = SJPCConfig(d=6, s=4, width=256, depth=2, seed=3)


def _bundle(**tracer_kw) -> Observability:
    reg = MetricsRegistry()
    return Observability(metrics=reg,
                         tracer=Tracer(registry=reg, **tracer_kw))


def _service(cfg: ServiceConfig = None, **bundle_kw):
    obs = _bundle(**bundle_kw)
    svc = EstimationService(cfg or ServiceConfig(batch_rows=64,
                                                 window_epochs=4), obs=obs)
    svc.create_group("g", CFG)
    return svc, obs


def _records(n, rng=None, lo=0, hi=50):
    rng = rng or np.random.default_rng(0)
    return rng.integers(lo, hi, size=(n, CFG.d), dtype=np.uint32)


# ---------------------------------------------------------------------------
# metrics core
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counters_labels_and_totals(self):
        m = MetricsRegistry()
        m.inc("req_total", group="a")
        m.inc("req_total", 2.0, group="a")
        m.inc("req_total", group="b")
        assert m.counter("req_total", group="a") == 3.0
        assert m.counter("req_total", group="b") == 1.0
        assert m.counter("req_total", group="zzz") == 0.0
        assert m.counter_total("req_total") == 4.0

    def test_gauge_set_and_high_water(self):
        m = MetricsRegistry()
        m.set("depth", 7, g="x")
        m.set("depth", 3, g="x")
        assert m.gauge("depth", g="x") == 3.0
        m.set_max("peak", 7, g="x")
        m.set_max("peak", 3, g="x")
        assert m.gauge("peak", g="x") == 7.0
        assert m.gauge("peak", g="missing") is None

    def test_label_order_is_canonical(self):
        m = MetricsRegistry()
        m.inc("c", a="1", b="2")
        m.inc("c", b="2", a="1")
        assert m.counter("c", b="2", a="1") == 2.0

    def test_histogram_quantiles(self):
        m = MetricsRegistry()
        for v in (8e-4, 4e-3, 4e-2):
            m.observe("lat", v)
        h = m.histogram("lat")
        assert h.count == 3 and h.total == pytest.approx(8e-4 + 4e-3 + 4e-2)
        # bucket-resolved: the upper bound of the holding bucket
        assert m.quantile("lat", 0.50) == 5e-3
        assert m.quantile("lat", 0.99) == 5e-2
        assert m.quantile("lat", 0.50, missing="y") == 0.0

    def test_histogram_overflow_mass(self):
        h = Histogram(bounds=(1.0, 2.0))
        h.observe(0.5)
        h.observe(99.0)
        assert h.overflow == 1
        assert h.quantile(0.99) == 2.0     # reported at the last finite bound

    def test_disabled_registry_is_inert(self):
        m = MetricsRegistry(enabled=False)
        m.inc("c")
        m.set("g", 1.0)
        m.set_max("p", 1.0)
        m.observe("h", 0.1)
        assert m.collect() == {}
        assert m.to_prometheus() == ""

    def test_prometheus_text_format(self):
        m = MetricsRegistry()
        m.inc("reqs_total", 3, group="g", kind="sjpc")
        m.set("depth", 2.0)
        m.observe("lat_seconds", 4e-3)
        text = m.to_prometheus()
        assert "# TYPE reqs_total counter" in text
        assert 'reqs_total{group="g",kind="sjpc"} 3' in text
        assert "# TYPE depth gauge" in text and "depth 2" in text
        assert "# TYPE lat_seconds histogram" in text
        assert 'lat_seconds_bucket{le="0.005"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 1' in text
        assert "lat_seconds_count 1" in text

    def test_collect_flattens_histograms(self):
        m = MetricsRegistry()
        m.observe("lat", 4e-3, op="x")
        snap = m.collect()
        row = snap["lat"]['{op="x"}']
        assert row["count"] == 1 and row["p50"] == 5e-3

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=1, max_value=60),
           st.integers(min_value=0, max_value=2**32 - 1))
    def test_histogram_quantile_monotone(self, n, seed):
        # quantiles are monotone in q and bound the empirical quantile
        # from above by at most one bucket (the read-out contract)
        rng = np.random.default_rng(seed)
        h = Histogram()
        vals = 10.0 ** rng.uniform(-4.5, 0.5, size=n)
        for v in vals:
            h.observe(float(v))
        qprobs = (0.1, 0.5, 0.9, 0.99)
        qs = [h.quantile(q) for q in qprobs]
        assert qs == sorted(qs)
        # bound from above: the returned bucket bound covers at least
        # ceil(q*n) observations, so it dominates that order statistic
        svals = np.sort(vals)
        for q, got in zip(qprobs, qs):
            assert got >= svals[int(np.ceil(q * n)) - 1]


# ---------------------------------------------------------------------------
# tracer spans
# ---------------------------------------------------------------------------

class TestTracer:
    def test_nesting_paths_and_depth(self):
        tr = Tracer()
        with tr.span("outer"):
            with tr.span("inner", rows=3):
                pass
        ev = list(tr.events)
        assert [e["name"] for e in ev] == ["inner", "outer"]  # close order
        assert ev[0]["path"] == "outer/inner" and ev[0]["depth"] == 1
        assert ev[0]["rows"] == 3
        assert ev[1]["path"] == "outer" and ev[1]["depth"] == 0

    def test_device_time_covers_registered_outputs(self):
        tr = Tracer()
        with tr.span("jit") as sp:
            y = jax.jit(lambda x: (x @ x).sum())(jnp.ones((64, 64)))
            sp.sync(y)
        ev = tr.events[-1]
        assert ev["total_ms"] >= ev["dispatch_ms"]
        assert sp.total_s >= sp.dispatch_s
        assert float(y) == pytest.approx(64.0 * 64 * 64)

    def test_jsonl_sink(self):
        buf = io.StringIO()
        tr = Tracer(sink=buf)
        with tr.span("a", k="v"):
            pass
        lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
        assert len(lines) == 1
        assert lines[0]["name"] == "a" and lines[0]["k"] == "v"
        assert {"ts", "dispatch_ms", "total_ms", "depth"} <= set(lines[0])

    def test_span_histogram_lands_in_given_registry(self):
        reg = MetricsRegistry()
        tr = Tracer()
        with tr.span("s", histogram="s_seconds", labels={"g": "x"},
                     registry=reg):
            pass
        h = reg.histogram("s_seconds", g="x")
        assert h is not None and h.count == 1

    def test_disabled_tracer_is_inert(self):
        tr = Tracer(enabled=False)
        span = tr.span("x", histogram="h")
        with span as sp:
            sp.sync(jnp.ones(3))
            sp.set(a=1)
        assert not tr.events
        assert span.total_s == 0.0

    def test_exception_pops_stack_without_emitting(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert not tr.events
        with tr.span("after"):
            pass
        assert tr.events[-1]["path"] == "after"   # stack not corrupted


# ---------------------------------------------------------------------------
# service instrumentation
# ---------------------------------------------------------------------------

class TestServiceInstrumentation:
    def test_queue_depth_gauge_tracks_submit_and_flush(self):
        svc, obs = _service()
        svc.create_stream("t", "g")
        svc.ingest("t", _records(40))
        svc.ingest("t", _records(25))
        m = obs.metrics
        assert m.gauge("ingest_pending_rows", group="g") == 65.0
        svc.flush()
        assert m.gauge("ingest_pending_rows", group="g") == 0.0
        assert m.gauge("ingest_pending_rows_peak", group="g") == 65.0
        assert m.counter("ingest_submitted_records_total", group="g") == 65.0

    def test_flush_s_is_device_inclusive_and_histogram_matches(self):
        svc, obs = _service()
        svc.create_stream("t", "g")
        svc.ingest("t", _records(200))
        svc.flush()
        # the PR 1 bug reported near-zero here (it timed the async enqueue);
        # a compile + 200-record sketch dispatch cannot run in < 50us
        assert svc.stats["flush_s"] > 5e-5
        h = obs.metrics.histogram("service_flush_seconds", group="g")
        assert h is not None and h.count == 1
        assert h.total == pytest.approx(svc.stats["flush_s"], rel=0.5)
        hc = obs.metrics.histogram("ingest_flush_seconds",
                                   group="g", kind="sjpc")
        assert hc is not None and hc.count == 1

    def test_window_rotation_metrics(self):
        svc, obs = _service(ServiceConfig(batch_rows=64, window_epochs=2))
        svc.create_stream("t", "g")
        for _ in range(3):
            svc.ingest("t", _records(10))
            svc.advance_epoch()
        m = obs.metrics
        assert m.counter("window_rotations_total", stream="t") == 3.0
        # window_epochs=2: the ring is full from the 2nd rotation on, so
        # rotations 2 and 3 each expire an epoch
        assert m.counter("window_expirations_total", stream="t") == 2.0
        assert m.gauge("window_live_epochs", stream="t") == 2.0
        assert m.gauge("window_version", stream="t") == \
            svc.registry.stream("t").window.version

    def test_estimator_memory_gauge(self):
        svc, obs = _service()
        svc.create_stream("t", "g")
        assert obs.metrics.gauge("estimator_memory_bytes",
                                 stream="t", kind="sjpc") == \
            svc.registry.stream("t").window.memory_bytes()

    def test_disabled_observe_keeps_service_working(self):
        svc = EstimationService(ServiceConfig(batch_rows=64, observe=False))
        svc.create_group("g", CFG)
        svc.create_stream("t", "g")
        svc.ingest("t", _records(100))
        svc.flush()
        # honest flush timing survives obs-off (the block is unconditional)
        assert svc.stats["flush_s"] > 5e-5
        assert svc.obs.metrics.collect() == {}
        assert svc.metrics_report() == ""
        assert svc.snapshot().self_join("t").estimate >= 0.0

    def test_metrics_report_has_derived_gauges(self):
        svc, obs = _service()
        svc.create_stream("t", "g")
        svc.ingest("t", _records(64))
        svc.register_continuous(ContinuousQuery("q", "self_join", ("t",)))
        svc.poll()
        svc.poll()
        text = svc.metrics_report()
        assert 'query_cache_hit_ratio{group="g",kind="sjpc",op="self"}' \
            in text
        assert 'estimator_memory_bytes{kind="sjpc",stream="t"}' in text
        assert "service_poll_seconds_count 2" in text


# ---------------------------------------------------------------------------
# version-keyed query-cache accounting (satellite: cache telemetry)
# ---------------------------------------------------------------------------

def _hits_misses(m, **labels):
    return (m.counter("query_cache_hits_total", **labels),
            m.counter("query_cache_misses_total", **labels))


class TestQueryCacheAccounting:
    def test_steady_state_polls_are_pure_hits(self):
        svc, obs = _service()
        svc.create_stream("t", "g")
        svc.ingest("t", _records(64))
        svc.register_continuous(ContinuousQuery("q", "self_join", ("t",)))
        svc.poll()
        h0, m0 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        assert m0 >= 1.0                  # first poll computed the batch
        for _ in range(3):
            svc.poll()                    # no-op flushes: version unchanged
        h1, m1 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        assert m1 == m0                   # zero recomputes
        assert h1 > h0

    def test_ingest_commit_invalidates(self):
        svc, obs = _service()
        svc.create_stream("t", "g")
        svc.ingest("t", _records(64))
        svc.register_continuous(ContinuousQuery("q", "self_join", ("t",)))
        svc.poll()
        _, m0 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        svc.ingest("t", _records(32))
        svc.poll()                        # version bumped -> recompute
        _, m1 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        assert m1 == m0 + 1.0

    def test_expiring_rotation_invalidates_non_expiring_does_not(self):
        svc, obs = _service(ServiceConfig(batch_rows=64, window_epochs=3))
        svc.create_stream("t", "g")
        svc.ingest("t", _records(64))
        svc.register_continuous(ContinuousQuery("q", "self_join", ("t",)))
        svc.poll()
        _, m0 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        svc.advance_epoch()               # live 1 -> 2: nothing expires
        svc.advance_epoch()               # live 2 -> 3: nothing expires
        svc.poll()
        _, m1 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        assert m1 == m0                   # version untouched, still cached
        svc.advance_epoch()               # ring full: epoch 0's data expires
        svc.poll()
        _, m2 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        assert m2 == m0 + 1.0

    def test_idle_tenant_cohort_rides_cache(self):
        # PR 5 ride-along: an idle tenant keeps its window version, so its
        # cohort's cache entry survives other-cohort commits -- hits, not
        # misses
        svc, obs = _service()
        svc.create_stream("busy", "g")
        svc.create_stream("idle", "g", estimator="reservoir")
        svc.ingest("busy", _records(64))
        svc.ingest("idle", _records(64))
        svc.register_continuous(ContinuousQuery("qb", "self_join", ("busy",)))
        svc.register_continuous(ContinuousQuery("qi", "self_join", ("idle",)))
        svc.poll()
        _, mi0 = _hits_misses(obs.metrics, group="g", kind="reservoir",
                              op="self")
        _, mb0 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        svc.ingest("busy", _records(32))  # only the sjpc cohort changes
        svc.poll()
        hi1, mi1 = _hits_misses(obs.metrics, group="g", kind="reservoir",
                                op="self")
        _, mb1 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="self")
        assert mb1 == mb0 + 1.0           # busy cohort recomputed
        assert mi1 == mi0                 # idle cohort: pure cache hit
        assert hi1 >= 1.0

    def test_join_cache_accounting(self):
        svc, obs = _service()
        svc.create_stream("a", "g")
        svc.create_stream("b", "g")
        svc.ingest("a", _records(64))
        svc.ingest("b", _records(64))
        snap = svc.snapshot()
        snap.join("a", "b")
        h0, m0 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="join")
        assert (h0, m0) == (0.0, 1.0)
        snap.join("a", "b")               # same snapshot: cached
        svc.snapshot().join("a", "b")     # new snapshot, same versions
        h1, m1 = _hits_misses(obs.metrics, group="g", kind="sjpc", op="join")
        assert (h1, m1) == (2.0, 1.0)


# ---------------------------------------------------------------------------
# accuracy telemetry
# ---------------------------------------------------------------------------

class TestAccuracyTelemetry:
    def _svc(self, **cfg_kw):
        obs = _bundle()
        svc = EstimationService(
            ServiceConfig(batch_rows=64, audit_rate=1.0, **cfg_kw), obs=obs)
        svc.create_group("g", CFG)
        return svc, obs

    def test_audits_measure_rel_err_and_coverage(self):
        svc, obs = self._svc(window_epochs=4)
        svc.create_stream("a", "g")
        svc.create_stream("b", "g")
        rng = np.random.default_rng(7)
        svc.register_continuous(ContinuousQuery("qs", "self_join", ("a",)))
        svc.register_continuous(ContinuousQuery("qa", "all_thresholds",
                                                ("a",)))
        svc.register_continuous(ContinuousQuery("qj", "join", ("a", "b")))
        svc.ingest("a", _records(120, rng, hi=8))
        svc.ingest("b", _records(80, rng, hi=8))
        svc.poll()
        m = obs.metrics
        # qs: 1 result; qa: d-s+1 = 3 results; qj: 1 result
        assert m.counter("accuracy_audits_total", kind="sjpc") == 5.0
        assert m.counter_total("accuracy_audit_skipped_total") == 0.0
        covered = m.counter("accuracy_ci_covered_total", kind="sjpc")
        assert 0.0 <= covered <= 5.0
        h = m.histogram("accuracy_rel_err", kind="sjpc", s="4")
        assert h is not None and h.count >= 2

    def test_mirror_rotates_with_window(self):
        svc, obs = self._svc(window_epochs=2)
        svc.create_stream("a", "g")
        rng = np.random.default_rng(3)
        svc.register_continuous(ContinuousQuery("q", "self_join", ("a",)))
        for _ in range(4):               # 2 rotations past the window edge
            svc.ingest("a", _records(30, rng, hi=8))
            svc.poll()
            svc.advance_epoch()
        m = obs.metrics
        # every poll audited against exactly the live window: a mirror
        # that failed to expire with the ring would skip as a mismatch
        assert m.counter("accuracy_audit_skipped_total",
                         reason="mirror_mismatch") == 0.0
        assert m.counter("accuracy_audits_total", kind="sjpc") == 4.0

    def test_state_delta_streams_skip_honestly(self):
        svc, obs = self._svc(window_epochs=4)
        svc.create_stream("a", "g")
        # build a foreign delta with the group's own params: a sibling
        # stream's flushed window total is exactly such a state
        svc.create_stream("src", "g")
        svc.ingest("src", _records(16, hi=8))
        svc.flush()
        svc.ingest_state_delta(
            "a", svc.registry.stream("src").window.total)
        svc.register_continuous(ContinuousQuery("q", "self_join", ("a",)))
        svc.poll()
        m = obs.metrics
        assert m.counter("accuracy_audit_skipped_total",
                         reason="state_delta_stream") >= 1.0
        assert m.counter_total("accuracy_audits_total") == 0.0

    def test_oversize_window_skips(self):
        svc, obs = self._svc(window_epochs=4, audit_max_records=32)
        svc.create_stream("a", "g")
        svc.ingest("a", _records(64))
        svc.register_continuous(ContinuousQuery("q", "self_join", ("a",)))
        svc.poll()
        assert obs.metrics.counter("accuracy_audit_skipped_total",
                                   reason="window_too_large") == 1.0

    def test_rate_zero_never_audits(self):
        obs = _bundle()
        svc = EstimationService(ServiceConfig(batch_rows=64), obs=obs)
        svc.create_group("g", CFG)
        svc.create_stream("a", "g")
        svc.ingest("a", _records(32))
        svc.register_continuous(ContinuousQuery("q", "self_join", ("a",)))
        svc.poll()
        assert obs.metrics.counter_total("accuracy_audits_total") == 0.0
        assert svc.obs.auditor is None


# ---------------------------------------------------------------------------
# module-level instrumentation (kernels, estimators)
# ---------------------------------------------------------------------------

class TestGlobalInstrumentation:
    def test_kernel_dispatch_counters(self):
        from repro.core import sketch as sk
        from repro.core.hashing import P31
        from repro.kernels.ops import sketch_moments, sketch_update
        fresh = MetricsRegistry()
        prev = set_default_registry(fresh)
        try:
            rng = np.random.default_rng(0)
            params = sk.make_sketch_params(rng, 2)
            keys = jnp.asarray(rng.integers(0, int(P31), size=32,
                                            dtype=np.uint32))
            c = sketch_update(sk.empty_counters(2, 64), keys, keys, params,
                              None, use_pallas=False)
            sketch_moments(c, use_pallas=False)
            assert fresh.counter("kernel_dispatch_total",
                                 kernel="sketch_update", path="jnp",
                                 impl="jnp_ref") == 1.0
            assert fresh.counter("kernel_dispatch_total",
                                 kernel="sketch_moments", path="jnp",
                                 impl="jnp_ref") == 1.0
        finally:
            set_default_registry(prev)

    def test_bootstrap_replicate_counter(self):
        from repro.estimators import uncertainty as U
        fresh = MetricsRegistry()
        prev = set_default_registry(fresh)
        try:
            rng = np.random.default_rng(1)
            items = jnp.asarray(rng.integers(0, 4, (1, 16, 4), np.uint32))
            valid = jnp.ones((1, 16), jnp.int32)
            keys = jax.random.split(jax.random.PRNGKey(0), 1)
            U.bootstrap_pair_stderr(items, valid, np.array([100.0]),
                                    keys=keys, s=2, replicates=8,
                                    pair_fn=lambda it, va: U.jnp.zeros(
                                        it.shape[:2] + (it.shape[-1] + 1,),
                                        U.jnp.int32))
            assert fresh.counter("bootstrap_replicates_total",
                                 method="bootstrap") == 8.0
        finally:
            set_default_registry(prev)


# ---------------------------------------------------------------------------
# disabled-mode overhead contract (satellite: CI guard)
# ---------------------------------------------------------------------------

class TestOverheadGuard:
    def test_enabled_within_5pct_of_disabled(self):
        """Ingest throughput with metrics+spans enabled must stay within
        5% of the disabled bundle on a seeded workload -- the DESIGN.md
        §15 near-zero-overhead contract.  Measured back-to-back with
        retries: CI machines are noisy, and the contract is about the
        instrumentation cost, not scheduler jitter."""
        recs = _records(256, np.random.default_rng(5))
        cycles = 6

        def throughput(observe: bool) -> float:
            svc = EstimationService(
                ServiceConfig(batch_rows=128, window_epochs=None,
                              observe=observe),
                obs=None if observe else Observability.disabled())
            svc.create_group("g", CFG)
            svc.create_stream("t", "g")
            svc.ingest("t", recs)
            svc.flush()                  # compile at the measured shape
            t0 = time.perf_counter()
            for _ in range(cycles):
                svc.ingest("t", recs)
                svc.flush()
            return cycles * recs.shape[0] / (time.perf_counter() - t0)

        throughput(True)                 # shared jit warmup for both modes
        ratios = []
        for _ in range(4):               # retries absorb CI noise
            off = throughput(False)
            on = throughput(True)
            ratios.append(on / off)
            if ratios[-1] >= 0.95:
                return
        raise AssertionError(
            f"metrics-enabled ingest slower than the 5% overhead budget "
            f"in all attempts: on/off ratios {[f'{r:.3f}' for r in ratios]}")
