"""Checkpoint format (atomic commit, elastic chunking) + fault-tolerant
driver (failure injection -> restore -> identical trajectory)."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.runtime import DriverConfig, TrainDriver, SimulatedFailure


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32)),
                   "step": jnp.asarray(3, jnp.int32)},
        "tuple": (jnp.ones((5, 2)), jnp.zeros((3,))),
    }


class TestCheckpoint:
    def test_round_trip(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 10, t, chunks=4)
        restored, man = restore_checkpoint(str(tmp_path), t)
        assert man.step == 10
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_elastic_rechunk(self, tmp_path):
        """Written with 8 chunks, restored fine (chunk count is a storage
        detail, not a topology contract)."""
        t = _tree(1)
        save_checkpoint(str(tmp_path), 5, t, chunks=8)
        restored, _ = restore_checkpoint(str(tmp_path), t)
        np.testing.assert_array_equal(np.asarray(t["w"]),
                                      np.asarray(restored["w"]))

    def test_atomic_no_partial_reads(self, tmp_path):
        t = _tree(2)
        save_checkpoint(str(tmp_path), 1, t)
        # simulate a crashed writer: stale tmp dir must be ignored + cleaned
        stale = tmp_path / "step_00000002.tmp-dead"
        stale.mkdir()
        (stale / "garbage.npy").write_bytes(b"xx")
        assert latest_step(str(tmp_path)) == 1
        save_checkpoint(str(tmp_path), 3, t)
        assert latest_step(str(tmp_path)) == 3
        assert not any(".tmp-" in d for d in os.listdir(tmp_path))

    def test_keep_gc(self, tmp_path):
        t = _tree(3)
        for s in range(6):
            save_checkpoint(str(tmp_path), s, t, keep=2)
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(steps) == 2
        assert latest_step(str(tmp_path)) == 5


# ---------------------------------------------------------------------------
# Driver: tiny quadratic "training" with injected failures
# ---------------------------------------------------------------------------

class _QuadState:
    pass


def _make_driver(tmp_path, ckpt_every=5):
    from typing import NamedTuple

    class S(NamedTuple):
        params: jax.Array
        opt: jax.Array
        monitor: type(None)
        step: jax.Array

    target = jnp.asarray(np.random.default_rng(0).normal(size=(16,)).astype(np.float32))

    @jax.jit
    def step_fn(state, batch):
        g = 2 * (state.params - target) + 0.01 * batch
        params = state.params - 0.1 * g
        loss = jnp.mean((state.params - target) ** 2)
        return S(params, state.opt, None, state.step + 1), {"loss": loss}

    def make_batch(step):
        return jnp.asarray(np.random.default_rng(1000 + step)
                           .normal(size=(16,)).astype(np.float32))

    init = S(jnp.zeros((16,)), jnp.zeros(()), None, jnp.zeros((), jnp.int32))
    cfg = DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                       log_every=1)
    return TrainDriver(step_fn, init, make_batch, cfg), target


class TestDriver:
    def test_runs_and_checkpoints(self, tmp_path):
        driver, _ = _make_driver(tmp_path)
        driver.run(12)
        assert driver.step == 12
        assert latest_step(str(tmp_path)) == 12
        assert any(e["kind"] == "checkpoint" for e in driver.events)

    def test_failure_recovery_identical_trajectory(self, tmp_path):
        """A mid-run crash + restore must reproduce the uninterrupted run
        exactly (deterministic data replay from the restored step)."""
        d_ref, _ = _make_driver(tmp_path / "ref", ckpt_every=5)
        d_ref.run(20)
        ref_final = np.asarray(jax.device_get(d_ref.state.params))

        d_fail, _ = _make_driver(tmp_path / "fail", ckpt_every=5)
        d_fail.inject_failure_at = {
            7: SimulatedFailure("node died"),
            13: SimulatedFailure("node died again"),
        }
        d_fail.run(20)
        assert d_fail.restarts == 2
        assert d_fail.step == 20
        np.testing.assert_allclose(
            np.asarray(jax.device_get(d_fail.state.params)), ref_final,
            rtol=1e-6)

    def test_too_many_failures_raises(self, tmp_path):
        driver, _ = _make_driver(tmp_path)
        driver.cfg.max_restarts = 1
        driver.inject_failure_at = {3: SimulatedFailure("a"),
                                    4: SimulatedFailure("b")}
        # the same step re-fails after restore -> exceeds max_restarts
        with pytest.raises(SimulatedFailure):
            driver.run(10)

    def test_straggler_detection(self, tmp_path):
        import time
        driver, _ = _make_driver(tmp_path)

        def slow_hook(step):
            if step in (8, 9, 10):
                time.sleep(0.25)

        driver.run(14, slow_step_hook=slow_hook)
        kinds = [e["kind"] for e in driver.events]
        assert "straggler" in kinds
