"""Unit + property tests for the uint32 Mersenne-31 field arithmetic."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hashing as H

P = int(H.P31)


def _np_u32(xs):
    return np.asarray(xs, dtype=np.uint32)


class TestFieldOps:
    def test_mulmod_matches_uint64_oracle_random(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, P, size=100_000, dtype=np.uint32)
        b = rng.integers(0, P, size=100_000, dtype=np.uint32)
        got = np.asarray(H.mulmod_p31(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, H.np_mulmod_p31(a, b))

    def test_mulmod_adversarial_boundaries(self):
        edge = _np_u32([0, 1, 2, 3, P - 1, P - 2, P // 2, P // 2 + 1,
                        (1 << 16) - 1, 1 << 16, (1 << 16) + 1,
                        (1 << 30) - 1, 1 << 30, (1 << 30) + 1])
        a, b = np.meshgrid(edge, edge)
        a, b = a.ravel(), b.ravel()
        got = np.asarray(H.mulmod_p31(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, H.np_mulmod_p31(a, b))

    def test_reduce_full_uint32_range(self):
        rng = np.random.default_rng(1)
        x = np.concatenate([
            rng.integers(0, 2**32, size=100_000, dtype=np.uint32),
            _np_u32([0, P - 1, P, P + 1, 2**32 - 1, 2**31, 2**31 - 1]),
        ])
        got = np.asarray(H.reduce_p31(jnp.asarray(x)))
        np.testing.assert_array_equal(got, (x.astype(np.uint64) % P).astype(np.uint32))

    @given(st.integers(0, P - 1), st.integers(0, P - 1))
    @settings(max_examples=300, deadline=None)
    def test_mulmod_property(self, a, b):
        got = int(H.mulmod_p31(jnp.uint32(a), jnp.uint32(b)))
        assert got == (a * b) % P

    @given(st.integers(0, P - 1), st.integers(0, P - 1))
    @settings(max_examples=200, deadline=None)
    def test_addmod_property(self, a, b):
        got = int(H.addmod_p31(jnp.uint32(a), jnp.uint32(b)))
        assert got == (a + b) % P


class TestCWHash:
    def test_matches_oracle(self):
        rng = np.random.default_rng(2)
        coeffs = H.random_field_elements(rng, (5, 4))
        keys = rng.integers(0, P, size=2_000, dtype=np.uint32)
        got = np.asarray(H.cw_hash(jnp.asarray(keys)[:, None], jnp.asarray(coeffs)[None]))
        np.testing.assert_array_equal(got, H.np_cw_hash(keys[:, None], coeffs[None]))

    def test_pairwise_independence_statistics(self):
        """Chi-square-ish sanity: buckets near uniform, signs near zero-mean."""
        rng = np.random.default_rng(3)
        coeffs = H.random_field_elements(rng, (4,))
        keys = np.arange(1, 200_001, dtype=np.uint32)   # worst case: sequential keys
        h = np.asarray(H.cw_hash(jnp.asarray(keys), jnp.asarray(coeffs)))
        w = 256
        counts = np.bincount(np.asarray(H.hash_bucket(jnp.asarray(h), w)), minlength=w)
        expected = len(keys) / w
        chi2 = ((counts - expected) ** 2 / expected).sum()
        # chi2 d.o.f. 255: mean 255, std ~22.6; allow 6 sigma
        assert chi2 < 255 + 6 * 23, chi2
        signs = np.asarray(H.hash_sign(jnp.asarray(h)))
        assert abs(signs.mean()) < 0.01

    def test_four_wise_sign_products(self):
        """E[s(a)s(b)s(c)s(d)] ~ 0 for distinct keys -- the moment the AGMS
        variance proof needs from 4-universality."""
        rng = np.random.default_rng(4)
        prods = []
        keys = rng.choice(P, size=4, replace=False).astype(np.uint32)
        for trial in range(4000):
            coeffs = H.random_field_elements(rng, (4,))
            s = np.asarray(H.hash_sign(H.cw_hash(jnp.asarray(keys), jnp.asarray(coeffs))))
            prods.append(np.prod(s))
        m = np.mean(prods)
        assert abs(m) < 5 / np.sqrt(len(prods)), m   # 5 sigma

    def test_pair_hash_distinct_components(self):
        rng = np.random.default_rng(5)
        coeffs = jnp.asarray(H.random_field_elements(rng, (2, 4)))
        x = jnp.asarray(rng.integers(0, P, size=100, dtype=np.uint32))
        y = jnp.asarray(rng.integers(0, P, size=100, dtype=np.uint32))
        h_xy = np.asarray(H.cw_hash_pair(x, y, coeffs))
        h_yx = np.asarray(H.cw_hash_pair(y, x, coeffs))
        assert (h_xy != h_yx).any()   # order matters (components independent)

    def test_canonical_range(self):
        rng = np.random.default_rng(6)
        coeffs = jnp.asarray(H.random_field_elements(rng, (4,)))
        x = jnp.asarray(rng.integers(0, 2**32, size=10_000, dtype=np.uint32))
        h = np.asarray(H.cw_hash(H.reduce_p31(x), coeffs))
        assert (h < P).all()
