"""Estimator-protocol conformance (DESIGN.md §13) over ALL registered kinds.

One parametrized fixture drives every estimator -- SJPC, the streaming
reservoir, and streaming LSH-SS -- through the same contracts:

  * estimate_batch == estimate_ref (<= 1e-6 relative), the batched-path
    vs scalar-oracle identity;
  * merge/subtract algebra: n adds and recovers; merge is commutative in
    the estimates; linear kinds recover state bit-exactly, tagged-sample
    kinds recover provenance exactly;
  * batch permutation invariance: stream order in a stacked cohort cannot
    change any stream's row;
  * degenerate streams n in {0, 1}: finite, g == n at every threshold
    (no pairs exist, so every estimator must report exactly the
    self-pairs).

Plus the reservoir-specific statistical contract: the vectorized
streaming Algorithm R is distributionally equivalent to offline uniform
sampling -- retention is uniform over arrival order, and the estimated
g_s is unbiased against both the exact count and the offline sampler's
mean.  Everything is seeded; failures mean the estimator changed, not
bad luck.
"""
import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro import estimators as E
from repro.core import baselines, exact
from repro.core.sjpc import SJPCConfig

CFG = SJPCConfig(d=5, s=3, ratio=1.0, width=128, depth=2, seed=31)
KINDS = E.available()
# one shared instance per kind: protocol engines are stateless between
# calls, and sharing keeps each kind's ingest jit cache warm across tests
ESTS = {kind: E.make(kind, CFG) for kind in KINDS}


@pytest.fixture(params=KINDS)
def estimator(request):
    return request.param, ESTS[request.param]


def ingest(est, state, vals, *, key_seed=0):
    """One protocol-path ingest round for a single stream."""
    vals = np.ascontiguousarray(np.asarray(vals, np.uint32))
    B = vals.shape[0]
    states = E.stack_states([state])
    keys = jax.random.fold_in(
        jax.random.PRNGKey(est.ingest_seed), key_seed)[None, None]
    new = est.ingest_rounds(states, vals[None, None],
                            np.ones((1, 1, B), np.int32), keys)
    return E.index_state(new, 0)


def _dups(rng, n=300, d=5):
    vals = rng.integers(0, 40, size=(n, d)).astype(np.uint32)
    for i in range(n // 10):
        vals[n - 1 - i] = vals[i]                 # exact duplicates
    return vals


class TestBatchVsRef:
    def test_estimate_batch_matches_scalar_ref(self, estimator):
        kind, est = estimator
        rng = np.random.default_rng(11)
        st = ingest(est, est.init(sid=0), _dups(rng))
        batch = est.estimate_batch(E.stack_states([st]))
        ref = est.estimate_ref(st)
        for field in ("x", "g", "n"):
            a, b = getattr(batch, field), getattr(ref, field)
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                       err_msg=f"{kind}.{field}")
        np.testing.assert_allclose(batch.stderr, ref.stderr, rtol=1e-6,
                                   atol=1e-6)

    def test_batch_permutation_invariance(self, estimator):
        kind, est = estimator
        rng = np.random.default_rng(12)
        a = ingest(est, est.init(sid=1), _dups(rng), key_seed=1)
        b = ingest(est, est.init(sid=2),
                   rng.integers(0, 9, size=(200, CFG.d)).astype(np.uint32),
                   key_seed=2)
        ab = est.estimate_batch(E.stack_states([a, b]))
        ba = est.estimate_batch(E.stack_states([b, a]))
        np.testing.assert_allclose(ab.g, ba.g[::-1], rtol=1e-9,
                                   err_msg=kind)
        np.testing.assert_allclose(ab.x, ba.x[::-1], rtol=1e-9)


class TestMergeSubtractAlgebra:
    def _two_epochs(self, est):
        rng = np.random.default_rng(13)
        a = ingest(est, est.init(sid=1), _dups(rng), key_seed=1)
        b = ingest(est, est.init(sid=2),
                   rng.integers(0, 9, size=(160, CFG.d)).astype(np.uint32),
                   key_seed=2)
        return a, b

    def test_merge_adds_n_and_is_commutative(self, estimator):
        kind, est = estimator
        a, b = self._two_epochs(est)
        m1, m2 = est.merge(a, b), est.merge(b, a)
        assert float(m1.n) == float(m2.n) == float(a.n) + float(b.n)
        g1 = est.estimate_ref(m1).g
        g2 = est.estimate_ref(m2).g
        np.testing.assert_allclose(g1, g2, rtol=1e-9, err_msg=kind)

    def test_subtract_inverts_merge(self, estimator):
        """Linear kinds recover the counters bit-exactly; tagged-sample
        kinds recover provenance exactly (no surviving slot carries the
        subtracted epoch's tag) and always recover n."""
        kind, est = estimator
        a, b = self._two_epochs(est)
        back = est.subtract(est.merge(a, b), b)
        assert float(back.n) == pytest.approx(float(a.n))
        if est.linear:
            np.testing.assert_array_equal(np.asarray(back.counters),
                                          np.asarray(a.counters))
        else:
            for field in back._fields:
                if field.endswith("tags"):
                    tags = np.asarray(getattr(back, field))
                    assert not np.any(tags == int(b.sid)), (kind, field)

    def test_merge_estimate_consistent_with_union(self, estimator):
        """estimate(merge(a, b)) tracks the union stream: exact for linear
        kinds (sketch linearity), within sampling error for sample kinds
        (the merged sample still estimates the union's n and pair mass)."""
        kind, est = estimator
        a, b = self._two_epochs(est)
        m = est.estimate_ref(est.merge(a, b))
        assert float(m.n[0]) == float(a.n) + float(b.n)
        assert np.all(np.isfinite(m.g)) and np.all(m.g >= 0)
        # g >= n at the lowest threshold (self-pairs are always counted)
        assert m.g[0, 0] >= float(m.n[0]) - 1e-6


class TestDegenerateStreams:
    @pytest.mark.parametrize("n", [0, 1])
    def test_no_pairs_means_g_equals_n(self, estimator, n):
        kind, est = estimator
        st = est.init(sid=0)
        if n:
            st = ingest(est, st, np.ones((1, CFG.d), np.uint32))
        for table in (est.estimate_batch(E.stack_states([st])),
                      est.estimate_ref(st)):
            assert float(table.n[0]) == float(n)
            assert np.all(np.isfinite(table.g))
            np.testing.assert_allclose(table.g[0], float(n), atol=1e-6,
                                       err_msg=f"{kind} n={n}")
            assert np.all(table.stderr >= 0)


class TestServedSideBySide:
    def test_all_kinds_in_one_group_fused_matches_ref(self):
        """The acceptance shape: one hash group serving every estimator
        kind at derived (equal-space) budgets; the fused snapshot path and
        the per-stream reference oracle agree for all of them, and poll()
        returns every stream's standing query from one snapshot."""
        from repro.service import (ContinuousQuery, EstimationService,
                                   QueryEngine, ServiceConfig)
        svc = EstimationService(ServiceConfig(batch_rows=128,
                                              window_epochs=None))
        svc.create_group("g", CFG)
        rng = np.random.default_rng(21)
        vals = _dups(rng, n=600)
        for kind in KINDS:
            svc.create_stream(f"t/{kind}", "g", estimator=kind)
            svc.ingest(f"t/{kind}", vals)
            svc.register_continuous(
                ContinuousQuery(f"q/{kind}", "self_join", (f"t/{kind}",)))
        res = svc.poll()
        assert set(res) == {f"q/{kind}" for kind in KINDS}
        ref = QueryEngine(svc.registry, use_fused_query=False).snapshot()
        for kind in KINDS:
            nm = f"t/{kind}"
            fused = svc.engine.snapshot([nm]).self_join(nm)
            oracle = ref.self_join(nm)
            assert fused.estimate == pytest.approx(oracle.estimate,
                                                   rel=1e-6), kind
            assert fused.n == oracle.n
            mem = svc.registry.stream(nm).estimator.memory_bytes()
            assert 0 < mem <= CFG.counters_bytes  # equal-space by derivation

    def test_join_requires_join_capable_kind(self):
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig(window_epochs=None))
        svc.create_group("g", CFG)
        svc.create_stream("a", "g", estimator="sjpc")
        svc.create_stream("b", "g", estimator="reservoir")
        with pytest.raises(ValueError, match="join-capable"):
            svc.snapshot().join("a", "b")


class TestAlgebraProperties:
    """Hypothesis properties over the protocol algebra, every kind (run
    with real shrinking in the CI property-hypothesis job; the tier-1
    lane drives them through the conftest stub)."""

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.sampled_from([1, 17, 64]))
    def test_merge_n_adds_subtract_recovers_every_kind(self, seed, batch):
        rng = np.random.default_rng(seed)
        va = rng.integers(0, 7, size=(batch, CFG.d)).astype(np.uint32)
        vb = rng.integers(0, 7, size=(batch, CFG.d)).astype(np.uint32)
        for kind, est in ESTS.items():
            a = ingest(est, est.init(sid=1), va, key_seed=seed % 101)
            b = ingest(est, est.init(sid=2), vb, key_seed=seed % 103)
            m = est.merge(a, b)
            assert float(m.n) == 2 * batch, kind
            assert float(est.subtract(m, b).n) == batch, kind
            g = est.estimate_ref(m).g
            assert np.all(np.isfinite(g)) and np.all(g >= 0), kind

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_batch_matches_ref_on_drawn_streams(self, seed):
        rng = np.random.default_rng(seed)
        vals = rng.integers(0, 5, size=(120, CFG.d)).astype(np.uint32)
        for kind, est in ESTS.items():
            st_ = ingest(est, est.init(sid=0), vals, key_seed=seed % 107)
            batch = est.estimate_batch(E.stack_states([st_]))
            ref = est.estimate_ref(st_)
            np.testing.assert_allclose(batch.g, ref.g, rtol=1e-6, atol=1e-6,
                                       err_msg=kind)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_g_non_increasing_in_threshold(self, seed):
        """g(s) counts pairs >= s-similar: with non-negative per-level
        estimates (all kinds construct x >= 0), the suffix-sum table must
        be non-increasing in s."""
        rng = np.random.default_rng(seed)
        vals = _dups(rng, n=200)
        for kind, est in ESTS.items():
            st_ = ingest(est, est.init(sid=0), vals, key_seed=seed % 109)
            g = est.estimate_ref(st_).g[0]
            assert np.all(g[:-1] >= g[1:] - 1e-9), (kind, g)


class TestWindowedSamples:
    def test_windowed_reservoir_tracks_live_epochs_proportionally(self):
        """Sliding-window sample estimators: total = merge-fold of live
        epoch slots.  Two live epochs of duplicate-heavy (all-identical)
        records must BOTH survive the fold roughly proportionally --
        the regression this pins: a content-only merge priority collapsed
        duplicate groups all-or-nothing under top_k."""
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig(batch_rows=64,
                                              window_epochs=3))
        svc.create_group("g", CFG)
        svc.create_stream(
            "w", "g", estimator="reservoir",
            estimator_cfg=E.ReservoirConfig(d=CFG.d, s=CFG.s, capacity=64,
                                            seed=3))
        for epoch_val in (111, 222):
            svc.ingest("w", np.full((500, CFG.d), epoch_val, np.uint32))
            svc.advance_epoch()
        win = svc.registry.stream("w").window
        assert win.n_live() == 1000.0          # both epochs live
        items = np.asarray(win.total.items)
        tags = np.asarray(win.total.tags)
        kept = items[tags >= 0, 0]
        counts = {v: int((kept == v).sum()) for v in (111, 222)}
        assert kept.shape[0] == 64
        # equal-weight epochs: each must keep a substantive share
        assert min(counts.values()) >= 10, counts
        r = svc.snapshot().self_join("w")
        assert np.isfinite(r.estimate) and r.estimate >= 0
        # one more rotation expires epoch 111: n drops to the live window
        svc.ingest("w", np.full((500, CFG.d), 333, np.uint32))
        svc.advance_epoch()
        win_n = svc.registry.stream("w").window.n_live()
        assert win_n == 1000.0                 # epochs {222, 333} + open
        tags = np.asarray(svc.registry.stream("w").window.total.tags)
        items = np.asarray(svc.registry.stream("w").window.total.items)
        assert not np.any(items[tags >= 0, 0] == 111)


class TestReservoirStatistics:
    """The streaming reservoir is distributionally equivalent to offline
    uniform sampling (the satellite's seeded statistical contract)."""

    def test_retention_uniform_over_arrival_order(self):
        """Record i's content encodes its arrival index; over T trials the
        retention counts of early/late arrival quintiles must match the
        uniform expectation R/n within a generous (but seeded) band."""
        cfg = E.ReservoirConfig(d=4, s=2, capacity=16, seed=5)
        est = E.ReservoirEstimator(cfg)
        n, T = 200, 200
        vals = np.repeat(np.arange(n, dtype=np.uint32)[:, None], 4, axis=1)
        counts = np.zeros(n)
        for t in range(T):
            st = ingest(est, est.init(sid=0), vals, key_seed=t)
            kept = np.asarray(st.items)[np.asarray(st.tags) >= 0, 0]
            assert kept.shape[0] == cfg.capacity     # stream >> capacity
            counts[kept] += 1
        assert counts.sum() == T * cfg.capacity
        quintiles = counts.reshape(5, n // 5).sum(axis=1)
        expect = T * cfg.capacity / 5                # 640
        sd = np.sqrt(T * (n // 5) * (cfg.capacity / n)
                     * (1 - cfg.capacity / n))       # ~24.3
        assert np.all(np.abs(quintiles - expect) < 6 * sd), quintiles

    def test_g_unbiased_vs_exact_and_offline_sampler(self):
        """Mean g over trials within CI of the exact count, and
        indistinguishable (by CI overlap) from offline uniform sampling at
        the same sample size."""
        d, n, R, T = 4, 400, 48, 60
        rng = np.random.default_rng(17)
        vals = rng.integers(0, 12, size=(n, d)).astype(np.uint32)
        for i in range(30):
            vals[n - 1 - i] = vals[i]
        s = 3
        g_true = exact.exact_g(vals, s)
        cfg = E.ReservoirConfig(d=d, s=s, capacity=R, seed=9)
        est = E.ReservoirEstimator(cfg)
        stream_g, offline_g = [], []
        for t in range(T):
            st = ingest(est, est.init(sid=0), vals, key_seed=t)
            stream_g.append(float(est.estimate_ref(st).g[0, 0]))
            offline_g.append(baselines.random_sampling_g(
                vals, s, R, np.random.default_rng(5000 + t)))
        stream_g, offline_g = np.array(stream_g), np.array(offline_g)
        se_s = stream_g.std(ddof=1) / np.sqrt(T)
        se_o = offline_g.std(ddof=1) / np.sqrt(T)
        assert abs(stream_g.mean() - g_true) < 4 * se_s, \
            (stream_g.mean(), g_true, se_s)
        assert abs(stream_g.mean() - offline_g.mean()) \
            < 4 * np.hypot(se_s, se_o)


class TestLSHSSStatistics:
    """The streaming LSH-SS audit (the equal_space 60-90%% error
    diagnosis): the stratified pair-reservoir scaling is unbiased -- the
    error was candidate starvation, not a bucket-weight bug.  The online
    pair generator must (a) produce candidates even in a single-round
    ingest (within-round pairing; previously zero candidates -> g
    collapsed to n) and (b) estimate g without bias on uniform data over
    seeded shuffled-arrival trials."""

    CFG_SMALL = E.LSHSSConfig(d=4, s=3, num_hash_cols=1, num_buckets=64,
                              record_capacity=64, pair_capacity=64, seed=7)

    def _ingest_rounds(self, est, vals, batch, key_seed):
        vals = np.ascontiguousarray(np.asarray(vals, np.uint32))
        n, d = vals.shape
        rounds = -(-n // batch)
        pad = rounds * batch - n
        v = np.concatenate([vals, np.zeros((pad, d), np.uint32)])
        mask = np.concatenate([np.ones(n, np.int32),
                               np.zeros(pad, np.int32)])
        base = jax.random.fold_in(
            jax.random.PRNGKey(est.ingest_seed), key_seed)
        keys = np.stack([np.asarray(jax.random.fold_in(base, r))
                         for r in range(rounds)])[:, None]
        new = est.ingest_rounds(
            E.stack_states([est.init(sid=0)]),
            v.reshape(rounds, 1, batch, d), mask.reshape(rounds, 1, batch),
            keys)
        return E.index_state(new, 0)

    def test_single_round_ingest_generates_pairs(self):
        est = E.LSHSSEstimator(self.CFG_SMALL)
        rng = np.random.default_rng(1)
        vals = rng.integers(0, 5, size=(400, 4)).astype(np.uint32)
        st = self._ingest_rounds(est, vals, 400, key_seed=0)
        assert int(st.same_seen) + int(st.cross_seen) > 100
        g = float(est.estimate_ref(st).g[0, 0])
        assert g > float(st.n)          # similar mass is visible, not just n

    def test_g_unbiased_on_uniform_data(self):
        """Seeded multi-trial unbiasedness pin: mean estimate within CI of
        the exact count when arrival order is exchangeable (per-trial
        shuffles).  This is the contract the pre-fix pairing violated on
        arrival-clustered workloads (within-round pairs were never
        candidates)."""
        est = E.LSHSSEstimator(self.CFG_SMALL)
        rng = np.random.default_rng(2)
        n, s, T = 500, 3, 40
        vals = rng.integers(0, 5, size=(n, 4)).astype(np.uint32)
        g_true = exact.exact_g(vals, s)
        ests = []
        for t in range(T):
            order = np.random.default_rng(500 + t).permutation(n)
            st = self._ingest_rounds(est, vals[order], 50, key_seed=t)
            ests.append(float(est.estimate_ref(st).g[0, s - est.s]))
        ests = np.array(ests)
        se = ests.std(ddof=1) / np.sqrt(T)
        assert abs(ests.mean() - g_true) < 4 * se, (ests.mean(), g_true, se)
