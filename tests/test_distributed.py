"""Multi-host distributed service tests (DESIGN.md §18).

The in-process tests drive the REAL protocol -- encoded opcode frames
through ``worker.handle_request`` via ``LocalWorker`` handles -- so the
full wire surface is exercised without subprocess startup.  The one
subprocess test (slow lane) runs the same smoke workload through actual
child processes.

Covered contracts:
  * coordinator == single-process oracle: bit-exact linear replica
    counters, every estimate within 1e-6 (uid pinning + epoch alignment);
  * uid pinning at the registry level: a shard registering only its
    tenants at pinned global uids sketches bit-identically;
  * idle-worker fast path: zero-byte heartbeat, no replica version bump,
    no coordinator merge work;
  * lost worker: its tenants serve the last-merged window ``stale=True``,
    other tenants are unaffected;
  * window.export_delta: per-open-epoch increments, None when idle,
    baseline re-armed on rotation (expiry never re-ships as data).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.sjpc import SJPCConfig
from repro.distributed import harness, shard_of, wire
from repro.distributed.transport import OP_EXPORT
from repro.obs import Observability
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

CFG = SJPCConfig(d=5, s=3, ratio=0.5, width=128, depth=2, seed=9)


def _mini_spec(**kw):
    kw.setdefault("kinds", ("sjpc", "reservoir"))
    return harness.make_spec(4, d=CFG.d, s=CFG.s, width=CFG.width,
                             depth=CFG.depth, seed=CFG.seed,
                             window_epochs=3, batch_rows=64, **kw)


def _run_pair(spec, cycles=3, rows=96, seed=5):
    batches = harness.make_batches(spec, cycles=cycles, rows_per_cycle=rows,
                                   seed=seed)
    run = harness.run_cluster(spec, batches, n_workers=2, cycles=cycles,
                              local=True, keep_open=True)
    oracle = harness.run_oracle(spec, batches, cycles=cycles)
    return run, oracle


class TestClusterVsOracle:
    def test_local_two_worker_cluster_matches_oracle(self):
        spec = _mini_spec()
        run, oracle = _run_pair(spec)
        try:
            agree = harness.compare_to_oracle(run.coordinator, oracle, spec)
            assert agree["linear_exact"], (
                "linear replica counters diverged from the single-process run")
            assert agree["worst_rel_err"] <= 1e-6
        finally:
            run.coordinator.close()

    def test_every_cycle_merged_deltas(self):
        spec = _mini_spec()
        run, _ = _run_pair(spec, cycles=2)
        try:
            assert all(t["deltas"] > 0 for t in run.sync_trace)
            m = run.coordinator.obs.metrics
            assert m.counter_total("coordinator_merges_total") > 0
            h = m._hists.get("coordinator_merge_seconds", {})
            assert sum(x.count for x in h.values()) == sum(
                1 for t in run.sync_trace for _ in range(2) if t["deltas"])
        finally:
            run.coordinator.close()


class TestUidPinning:
    def test_pinned_shard_matches_dense_registration(self):
        """A service registering ONLY stream b at its global uid sketches
        b bit-identically to a service registering a then b densely --
        the worker-shard == oracle precondition."""
        rng = np.random.default_rng(3)
        recs = rng.integers(0, 60, size=(128, CFG.d), dtype=np.uint32)

        def build(streams):
            svc = EstimationService(
                ServiceConfig(batch_rows=64, window_epochs=3,
                              platform="cpu"),
                obs=Observability.disabled())
            svc.create_group("g", CFG)
            for name, uid in streams:
                svc.create_stream(name, "g", uid=uid)
            return svc

        dense = build([("a", None), ("b", None)])     # b lands at uid 1
        shard = build([("b", 1)])                     # pinned straight there
        for svc in (dense, shard):
            svc.ingest("b", recs)
            svc.flush()
        tb_dense = dense.registry.stream("b").window.total
        tb_shard = shard.registry.stream("b").window.total
        assert np.array_equal(np.asarray(tb_dense.counters),
                              np.asarray(tb_shard.counters))
        assert np.array_equal(np.asarray(tb_dense.n), np.asarray(tb_shard.n))

    def test_duplicate_pinned_uid_rejected(self):
        svc = EstimationService(
            ServiceConfig(batch_rows=64, platform="cpu"),
            obs=Observability.disabled())
        svc.create_group("g", CFG)
        svc.create_stream("a", "g", uid=3)
        with pytest.raises(ValueError, match="uid"):
            svc.create_stream("b", "g", uid=3)
        svc.create_stream("c", "g")          # dense counter skipped past 3
        assert svc.registry.stream("c").uid == 4


class TestIdleHeartbeat:
    def test_idle_sync_is_zero_byte_no_version_bump_no_merge(self):
        spec = _mini_spec()
        run, _ = _run_pair(spec, cycles=2)
        coord = run.coordinator
        try:
            m = coord.obs.metrics
            merges_before = m.counter_total("coordinator_merges_total")
            versions = {s["name"]: coord.replicas[0].registry.stream(
                s["name"]).window.version for s in spec.streams}
            # the raw payload really is zero bytes (not an empty bundle)
            for _, h in coord._alive():
                h.send(OP_EXPORT)
                payload = h.recv()
                assert payload == b""
                assert wire.decode_bundle(payload) is wire.HEARTBEAT
            stats = coord.sync()             # the full idle cycle
            assert stats["deltas"] == 0
            assert stats["heartbeats"] == coord.n_workers
            assert m.counter_total("coordinator_heartbeats_total") >= 2
            assert m.counter_total("coordinator_merges_total") == merges_before
            for s in spec.streams:           # replicas untouched: no bump
                assert coord.replicas[0].registry.stream(
                    s["name"]).window.version == versions[s["name"]]
            # workers counted their heartbeats (direct probe + sync)
            for _, h in coord._alive():
                wm = h.runtime.service.obs.metrics
                assert wm.counter_total("worker_heartbeats_total") >= 2
        finally:
            coord.close()


class TestWorkerFailure:
    def test_lost_worker_serves_stale_from_last_merge(self):
        spec = _mini_spec(kinds=("sjpc",))
        batches = harness.make_batches(spec, cycles=2, rows_per_cycle=96)
        run = harness.run_cluster(spec, batches, n_workers=2, cycles=2,
                                  local=True, keep_open=True)
        coord = run.coordinator
        try:
            names = [s["name"] for s in spec.streams]
            dead_w = 0
            dead = [n for n in names if shard_of(n, 2) == dead_w]
            live = [n for n in names if shard_of(n, 2) != dead_w]
            assert dead and live             # salted names split both ways
            before = {n: coord.self_join(n).estimate for n in names}
            coord.workers[dead_w].fail()
            more = np.random.default_rng(7).integers(
                0, 60, size=(64, CFG.d), dtype=np.uint32)
            for n in names:
                coord.ingest(n, more)        # dead shard's records dropped
            coord.sync()
            assert coord._dead == {dead_w}
            assert set(coord.stale_tenants) == set(dead)
            for n in dead:                   # last-merged data, stale flag
                res = coord.self_join(n)
                assert res.stale
                assert res.estimate == before[n]
            for n in live:                   # fresh shard unaffected
                assert not coord.self_join(n).stale
            m = coord.obs.metrics
            assert m.counter("coordinator_worker_failures_total",
                             worker=str(dead_w)) == 1.0
            assert m.counter_total("coordinator_lost_ingest_records_total") \
                == 64.0 * len(dead)
            # the poll path folds the same staleness into standing queries
            coord.register_continuous(ContinuousQuery(
                name="qd", kind="self_join", streams=(dead[0],)))
            coord.register_continuous(ContinuousQuery(
                name="ql", kind="self_join", streams=(live[0],)))
            out = coord.poll()
            assert out["qd"].stale and not out["ql"].stale
        finally:
            coord.close()


class TestExportDelta:
    def _window(self, **kw):
        svc = EstimationService(
            ServiceConfig(batch_rows=64, platform="cpu", **kw),
            obs=Observability.disabled())
        svc.create_group("g", CFG)
        return svc

    def test_linear_exports_are_per_epoch_increments(self):
        svc = self._window(window_epochs=3)
        svc.create_stream("t", "g")
        rng = np.random.default_rng(0)
        w = svc.registry.stream("t").window
        svc.ingest("t", rng.integers(0, 60, size=(64, CFG.d), dtype=np.uint32))
        svc.flush()
        mode, d1 = w.export_delta()
        assert mode == "merge"
        assert w.export_delta() is None                  # idle: nothing new
        svc.ingest("t", rng.integers(0, 60, size=(64, CFG.d), dtype=np.uint32))
        svc.flush()
        mode, d2 = w.export_delta()
        # increments compose: d1 + d2 == the open epoch's accumulated state
        total = w.ingest_base()
        assert np.array_equal(np.asarray(d1.counters) + np.asarray(d2.counters),
                              np.asarray(total.counters))
        assert float(np.asarray(d1.n) + np.asarray(d2.n)) == float(
            np.asarray(total.n))
        # step is worker-local PRNG history: never shipped
        assert int(np.asarray(d1.step)) == 0 and int(np.asarray(d2.step)) == 0

    def test_rotation_rearms_baseline_expiry_not_reshipped(self):
        svc = self._window(window_epochs=2)
        svc.create_stream("t", "g")
        rng = np.random.default_rng(1)
        w = svc.registry.stream("t").window
        for _ in range(3):                   # long enough to expire an epoch
            svc.ingest("t", rng.integers(0, 60, size=(64, CFG.d),
                                         dtype=np.uint32))
            svc.flush()
            assert w.export_delta() is not None
            svc.advance_epoch()
            # rotation (incl. the expiry subtraction's version bump) must
            # not read as new data on the wire
            assert w.export_delta() is None

    def test_unbounded_linear_window_stays_incremental(self):
        svc = self._window(window_epochs=None)
        svc.create_stream("t", "g", window_epochs=None)
        rng = np.random.default_rng(2)
        w = svc.registry.stream("t").window
        svc.ingest("t", rng.integers(0, 60, size=(64, CFG.d), dtype=np.uint32))
        svc.flush()
        _, d1 = w.export_delta()
        svc.advance_epoch()                  # no ring: nothing to re-arm
        assert w.export_delta() is None
        svc.ingest("t", rng.integers(0, 60, size=(64, CFG.d), dtype=np.uint32))
        svc.flush()
        _, d2 = w.export_delta()
        assert np.array_equal(np.asarray(d1.counters) + np.asarray(d2.counters),
                              np.asarray(w.total.counters))

    def test_sample_kind_exports_open_slot_replace(self):
        svc = self._window(window_epochs=3)
        svc.create_stream("r", "g", estimator="reservoir")
        rng = np.random.default_rng(3)
        w = svc.registry.stream("r").window
        svc.ingest("r", rng.integers(0, 60, size=(64, CFG.d), dtype=np.uint32))
        svc.flush()
        mode, state = w.export_delta()
        assert mode == "replace"
        open_slot = w.ingest_base()
        for la, lb in zip(state, open_slot):
            assert np.array_equal(np.asarray(la), np.asarray(lb))
        assert w.export_delta() is None


class TestMetricsAggregation:
    def test_worker_metrics_absorbed_with_worker_label(self):
        spec = _mini_spec(kinds=("sjpc",))
        run, _ = _run_pair(spec, cycles=2)
        coord = run.coordinator
        try:
            per_worker = coord.aggregate_metrics()
            assert set(per_worker) == {0, 1}
            m = coord.obs.metrics
            for w, rep in per_worker.items():
                assert rep["worker"] == w
                assert m.gauge("worker_stats:ingested_records",
                               worker=str(w)) > 0
            report = coord.metrics_report()
            assert 'worker="0"' in report and 'worker="1"' in report
            assert "coordinator_merge_seconds" in report
            # re-absorbing overwrites (gauge semantics), never double-counts
            v = m.gauge("worker_stats:ingested_records", worker="0")
            coord.aggregate_metrics()
            assert m.gauge("worker_stats:ingested_records", worker="0") == v
        finally:
            coord.close()


@pytest.mark.slow
class TestSubprocess:
    def test_subprocess_smoke_matches_oracle(self, tmp_path):
        report = harness.run_smoke(str(tmp_path / "smoke.json"))
        assert report["linear_exact"]
        assert report["worst_rel_err"] <= 1e-6
        assert (tmp_path / "smoke.json").exists()
