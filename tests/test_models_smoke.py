"""Per-arch smoke tests: reduced config, one forward/train step + decode on
CPU, asserting output shapes and no NaNs (full configs live in the dry-run)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.models.config import compute_dims

B, S = 2, 32


def _setup(name):
    cfg = configs.reduced(name)
    dims = compute_dims(cfg, tp=1)
    params = M.strip_p(M.init_params(jax.random.PRNGKey(0), cfg, dims))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    enc = (jax.random.normal(jax.random.PRNGKey(2), (B, 16, cfg.d_model))
           if cfg.is_encdec else None)
    return cfg, dims, params, tokens, enc


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_forward_and_grad(name):
    cfg, dims, params, tokens, enc = _setup(name)

    def loss_fn(p):
        lg, aux = M.forward(p, cfg, dims, tokens, enc_feats=enc, ssm_chunk=8,
                            compute_dtype=jnp.float32)
        assert lg.shape == (B, S, dims.vocab)
        return M.lm_loss(lg, tokens, cfg.vocab_size), lg

    (loss, lg), grads = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(lg)).all(), name
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_decode_step_shapes(name):
    cfg, dims, params, tokens, enc = _setup(name)
    cache = M.init_cache(cfg, dims, B, 64, src_len=16 if cfg.is_encdec else 0,
                         dtype=jnp.float32)
    lg, cache = jax.jit(lambda p, t, c: M.decode_step(p, cfg, dims, t, c,
                                                      compute_dtype=jnp.float32)
                        )(params, tokens[:, :1], cache)
    assert lg.shape == (B, 1, dims.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    assert int(cache.lens[0]) == 1


@pytest.mark.parametrize("name", ["qwen2.5-3b", "mamba2-370m",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-large-v2"])
def test_prefill_decode_consistency(name):
    """Greedy decode after prefill == teacher-forced forward argmax.

    The strongest correctness check we have for the KV-cache / SSM-state
    decode paths: step-by-step decode must reproduce the full forward.
    """
    cfg, dims, params, tokens, enc = _setup(name)
    lg_full, _ = M.forward(params, cfg, dims, tokens, enc_feats=enc,
                           ssm_chunk=8, compute_dtype=jnp.float32)
    # decode positions 1..S-1 one at a time from a cold cache
    cache = M.init_cache(cfg, dims, B, S, src_len=16 if cfg.is_encdec else 0,
                         dtype=jnp.float32)
    if cfg.is_encdec:
        # cross memories must be filled: use prefill of first token instead
        lg_p, pcache = M.prefill(params, cfg, dims, tokens[:, :1],
                                 enc_feats=enc, ssm_chunk=8,
                                 compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg_p[:, -1]),
                                   np.asarray(lg_full[:, 0]),
                                   rtol=2e-4, atol=2e-4)
        return
    step = jax.jit(lambda p, t, c: M.decode_step(p, cfg, dims, t, c,
                                                 compute_dtype=jnp.float32))
    lgs = []
    for i in range(S):
        lg_i, cache = step(params, tokens[:, i:i + 1], cache)
        lgs.append(np.asarray(lg_i[:, 0]))
    lg_dec = np.stack(lgs, axis=1)
    np.testing.assert_allclose(lg_dec, np.asarray(lg_full),
                               rtol=5e-3, atol=5e-3)


def test_prefill_matches_forward_last_position():
    cfg, dims, params, tokens, enc = _setup("qwen2-7b")
    lg_full, _ = M.forward(params, cfg, dims, tokens, ssm_chunk=8,
                           compute_dtype=jnp.float32)
    lg_pre, cache = M.prefill(params, cfg, dims, tokens, ssm_chunk=8,
                              compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_pre[:, -1]),
                               np.asarray(lg_full[:, -1]), rtol=2e-4, atol=2e-4)
    assert int(cache.lens[0]) == S


def test_param_counts_match_config_estimate():
    """init_params sizes ~= ArchConfig.param_count (exact at tp=1 without
    padding)."""
    for name in ["internlm2-20b", "mamba2-370m", "dbrx-132b"]:
        cfg = configs.reduced(name)
        dims = compute_dims(cfg, tp=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg, dims)
        n_actual = M.param_count_tree(params)
        n_est = cfg.param_count()
        assert abs(n_actual - n_est) / n_est < 0.05, (name, n_actual, n_est)


def test_full_configs_param_counts():
    """Published parameter-count sanity for the FULL configs (no alloc)."""
    expect = {
        "jamba-1.5-large-398b": (340e9, 480e9),
        "dbrx-132b": (115e9, 150e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "internlm2-20b": (17e9, 23e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen2-7b": (6.5e9, 8.5e9),
        "qwen2.5-3b": (2.5e9, 3.8e9),
        "chameleon-34b": (30e9, 38e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }
    for name, (lo, hi) in expect.items():
        n = configs.get(name).param_count()
        assert lo < n < hi, (name, f"{n:.3e}")
