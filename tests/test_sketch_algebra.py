"""Property tests for the sketch algebra the sharded ingest path relies on:
merge is commutative/associative, subtract inverts merge, and updates are
invariant under record permutation and micro-batch splitting.  These are the
exact identities that make "split the batch across shards, defer the merge"
a refactoring of the single-device update rather than an approximation.

Uses the hypothesis stand-in from tests/conftest.py (upgraded automatically
to real hypothesis when installed)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import sjpc
from repro.core import sketch as sk
from repro.core.hashing import P31
from repro.core.sjpc import SJPCConfig, SJPCState


def _rand_state(rng, levels, t, w):
    return SJPCState(
        counters=jnp.asarray(rng.integers(-50, 50, size=(levels, t, w))
                             .astype(np.int32)),
        n=jnp.asarray(float(rng.integers(0, 100)), jnp.float32),
        step=jnp.asarray(int(rng.integers(0, 10)), jnp.int32))


def _eq(a: SJPCState, b: SJPCState, *, check_step=True):
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
    assert float(a.n) == float(b.n)
    if check_step:
        assert int(a.step) == int(b.step)


class TestMergeAlgebra:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_merge_commutative(self, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand_state(rng, 2, 3, 64), _rand_state(rng, 2, 3, 64)
        _eq(sjpc.merge(a, b), sjpc.merge(b, a))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_merge_associative(self, seed):
        rng = np.random.default_rng(seed)
        a, b, c = (_rand_state(rng, 2, 3, 64) for _ in range(3))
        _eq(sjpc.merge(sjpc.merge(a, b), c), sjpc.merge(a, sjpc.merge(b, c)))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_subtract_inverts_merge(self, seed):
        """Counters and n recover exactly; step intentionally does NOT
        (subtract keeps the minuend's step -- PRNG history is consumed, see
        sjpc.subtract's docstring) so it is asserted to the documented sum."""
        rng = np.random.default_rng(seed)
        a, b = _rand_state(rng, 2, 3, 64), _rand_state(rng, 2, 3, 64)
        back = sjpc.subtract(sjpc.merge(a, b), b)
        _eq(back, a, check_step=False)
        assert int(back.step) == int(a.step) + int(b.step)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.integers(min_value=1, max_value=5))
    def test_merge_tree_shape_irrelevant(self, seed, k):
        """Any merge tree over k shards gives the same counters as the
        left fold (what the deferred shard-axis sum computes)."""
        rng = np.random.default_rng(seed)
        states = [_rand_state(rng, 2, 2, 32) for _ in range(k + 1)]
        left = states[0]
        for s in states[1:]:
            left = sjpc.merge(left, s)
        # balanced-ish tree
        work = list(states)
        while len(work) > 1:
            work = [sjpc.merge(work[i], work[i + 1]) if i + 1 < len(work)
                    else work[i] for i in range(0, len(work), 2)]
        _eq(left, work[0])


class TestUpdateInvariance:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.sampled_from([1, 8, 24]))
    def test_permutation_invariance_ratio_one(self, seed, batch):
        """ratio=1 (no per-record sampling): reordering records cannot
        change the counters -- insertion is a commutative fold."""
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=128, depth=2, seed=21)
        params, s0 = sjpc.init(cfg)
        vals = rng.integers(0, 6, size=(batch, cfg.d)).astype(np.uint32)
        perm = rng.permutation(batch)
        _eq(sjpc.update(cfg, params, s0, vals),
            sjpc.update(cfg, params, s0, vals[perm]))

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_key_weight_pairs_permute_at_sketch_level(self, seed):
        """For ratio<1 permutation invariance holds at the sketch layer:
        permuting (key, weight) pairs together leaves counters unchanged
        (this is why shard *assignment* of records does not matter once the
        per-record weights are fixed)."""
        rng = np.random.default_rng(seed)
        t, w, n = 3, 128, 200
        params = sk.make_sketch_params(rng, t)
        k1 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        k2 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        wt = jnp.asarray(rng.integers(0, 2, size=n).astype(np.int32))
        c0 = sk.empty_counters(t, w)
        perm = rng.permutation(n)
        a = sk.sketch_update(c0, k1, k2, params, wt)
        b = sk.sketch_update(c0, k1[perm], k2[perm], params, wt[perm])
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.sampled_from([1, 8, 17]),
           st.sampled_from([1, 8, 17]))
    def test_micro_batch_split_equals_merge(self, seed, b1, b2):
        """Sequential updates from a base state == merging independently
        sketched micro-batches (same per-batch keys): linearity, the exact
        identity the deferred-merge executor depends on."""
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=128, depth=2, seed=22)
        params, s0 = sjpc.init(cfg)
        va = rng.integers(0, 6, size=(b1, cfg.d)).astype(np.uint32)
        vb = rng.integers(0, 6, size=(b2, cfg.d)).astype(np.uint32)
        ka, kb = jax.random.PRNGKey(seed % 997), jax.random.PRNGKey(seed % 991)
        sequential = sjpc.update(cfg, params,
                                 sjpc.update(cfg, params, s0, va, key=ka),
                                 vb, key=kb)
        merged = sjpc.merge(sjpc.update(cfg, params, s0, va, key=ka),
                            sjpc.update(cfg, params, sjpc.init(cfg)[1], vb,
                                        key=kb))
        _eq(sequential, merged)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.sampled_from([1.0, 0.5, 0.25]),
           st.integers(min_value=1, max_value=3))
    def test_update_fused_is_update(self, seed, ratio, depth):
        """The fused path is the reference update, bit for bit, across
        drawn ratios and depths (the conformance property)."""
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=5, s=3, ratio=float(ratio), width=128,
                         depth=depth, seed=23)
        params, s0 = sjpc.init(cfg)
        vals = rng.integers(0, 6, size=(24, cfg.d)).astype(np.uint32)
        key = jax.random.PRNGKey(seed % 1009)
        _eq(sjpc.update(cfg, params, s0, vals, key=key),
            sjpc.update_fused(cfg, params, s0, vals, key=key,
                              use_pallas=False))


class TestQueryPathProperties:
    """Properties of the estimation (query) side: threshold monotonicity,
    clamp non-negativity, and merge/estimate consistency -- on both the
    per-stream reference path and the batched fused path."""

    def _sketch(self, rng, cfg, batches, seed0=0):
        params, st = sjpc.init(cfg)
        for b in range(batches):
            vals = rng.integers(0, 5, size=(20, cfg.d)).astype(np.uint32)
            st = sjpc.update(cfg, params, st, vals,
                             key=jax.random.PRNGKey(seed0 + b))
        return params, st

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.sampled_from([1, 3]))
    def test_g_non_increasing_in_s(self, seed, depth):
        """g(s) counts pairs >= s-similar, so (with clamped X >= 0) it must
        be non-increasing in s -- on the batched path's whole g table."""
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=5, s=2, ratio=0.5, width=128, depth=depth, seed=61)
        _, state = self._sketch(rng, cfg, 3, seed0=seed % 1013)
        be = sjpc.estimate_batch(cfg, state.counters[None],
                                 np.array([float(state.n)], np.float32))
        g = be.g[0]
        assert np.all(g[:-1] >= g[1:]), g
        # and the reference per-threshold suffix sums agree with monotonicity
        ref = sjpc.estimate(cfg, state)
        ref_g = np.array([float(ref.x[i:].sum()) + ref.n
                          for i in range(cfg.num_levels)])
        assert np.all(ref_g[:-1] >= ref_g[1:])

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_clamp_never_negative(self, seed):
        """Clamped inversion output is non-negative for ARBITRARY (even
        adversarially negative) level F2 inputs, on both inversions and on
        the batched path fed random counter states."""
        rng = np.random.default_rng(seed)
        d, s = 5, 2
        y = rng.uniform(-1e6, 1e6, size=d - s + 1)
        assert (sjpc.f2_to_pair_count(d, s, n=rng.uniform(0, 1e3), r=0.5,
                                      y=y, clamp=True) >= 0).all()
        assert (sjpc.inner_to_join_count(d, s, 0.5, y, clamp=True) >= 0).all()
        counters = rng.integers(-30, 30, size=(2, d - s + 1, 2, 64)) \
            .astype(np.int32)
        cfg = SJPCConfig(d=d, s=s, ratio=0.5, width=64, depth=2, seed=62)
        be = sjpc.estimate_batch(cfg, jnp.asarray(counters),
                                 np.array([7.0, 0.0], np.float32))
        assert (be.x >= 0).all() and (be.stderr >= 0).all()
        assert (be.g >= 0).all()

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_estimate_of_merge_is_estimate_of_union(self, seed):
        """estimate(merge(a, b)) == estimate of the sequentially-updated
        union stream (same per-batch keys) -- sketch linearity carried all
        the way through the estimator, reference AND batched paths."""
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=128, depth=3, seed=63)
        params, s0 = sjpc.init(cfg)
        va = rng.integers(0, 5, size=(18, cfg.d)).astype(np.uint32)
        vb = rng.integers(0, 5, size=(12, cfg.d)).astype(np.uint32)
        ka, kb = jax.random.PRNGKey(seed % 887), jax.random.PRNGKey(seed % 883)
        a = sjpc.update(cfg, params, s0, va, key=ka)
        b = sjpc.update(cfg, params, s0, vb, key=kb)
        union = sjpc.update(cfg, params, a, vb, key=kb)
        em = sjpc.estimate(cfg, sjpc.merge(a, b))
        eu = sjpc.estimate(cfg, union)
        np.testing.assert_array_equal(em.y, eu.y)
        np.testing.assert_array_equal(em.x, eu.x)
        assert em.g_s == eu.g_s and em.n == eu.n
        bm = sjpc.estimate_batch(cfg, sjpc.merge(a, b).counters[None],
                                 np.array([float(em.n)], np.float32))
        bu = sjpc.estimate_batch(cfg, union.counters[None],
                                 np.array([float(eu.n)], np.float32))
        np.testing.assert_array_equal(bm.g, bu.g)


class TestWindowAlgebra:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_expiry_is_subtraction_inverse(self, seed):
        """Ingest epoch A, ingest epoch B, subtract A == ingest only B
        (counters + n): the window-expiry identity."""
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=128, depth=2, seed=24)
        params, s0 = sjpc.init(cfg)
        va = rng.integers(0, 6, size=(16, cfg.d)).astype(np.uint32)
        vb = rng.integers(0, 6, size=(16, cfg.d)).astype(np.uint32)
        ka, kb = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
        ea = sjpc.update(cfg, params, s0, va, key=ka)
        eab = sjpc.update(cfg, params, ea, vb, key=kb)
        only_b = sjpc.update(cfg, params, sjpc.init(cfg)[1], vb, key=kb)
        _eq(sjpc.subtract(eab, ea), only_b, check_step=False)
