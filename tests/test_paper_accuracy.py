"""Paper-accuracy regression: the headline equal-space comparison (Figs 4-8).

The paper's central claim is that SJPC beats equal-space competitors --
uniform random record sampling (the one-pass competitor of Fig. 8) and
LSH-SS [Lee et al., arXiv:1104.3212] -- by a wide margin on data with
quadratic duplicate-cluster structure (g_s >> n, the DBLP regime).  This
suite pins that result so a refactor of the estimator, the fused query
engine, or the hash pipeline cannot silently destroy it:

* a seeded Fig. 4-style workload: few LARGE near-duplicate clusters planted
  in uniform noise (sampling's worst case: cluster-membership counts in a
  small sample fluctuate quadratically into the pair estimate; the sketch
  sees every record);
* the space budget rule of Fig. 8: random sampling gets exactly the
  sketch's counter bytes worth of records (`baselines.sample_size_for_bytes`);
* assertion: SJPC median relative error < random sampling's for every
  threshold in the mid band, plus finiteness/non-negativity of every
  estimator (including LSH-SS, slow lane).

Everything is seeded -- failures mean the estimator changed, not bad luck.
The fast lane runs 5 trials; `-m slow` adds trials and the LSH-SS column.
"""
import numpy as np
import jax
import pytest

from repro.core import baselines, exact, sjpc
from repro.core.sjpc import SJPCConfig
from repro.data import synthetic

D = 6
N = 32768
S_SKETCH = 4               # sketch threshold (levels 4..6)
MID_BAND = (4, 5)          # thresholds the win is asserted on
WIDTH, DEPTH, RATIO = 2048, 3, 1.0
BASE_SEED = 900


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(17)
    vals = synthetic.planted_cluster_records(
        N, D, rng, [(4, 384, 3), (5, 256, 2), (6, 128, 1)])
    x_exact = exact.exact_pair_counts(vals)
    g_true = {s: float(x_exact[s:].sum() + N) for s in MID_BAND}
    assert all(g > 3 * N for g in g_true.values())      # the g_s >> n regime
    return vals, g_true


def _sjpc_g_table(vals, trial):
    """One fresh-hash-draw SJPC run -> g at every threshold (fused path)."""
    cfg = SJPCConfig(d=D, s=S_SKETCH, ratio=RATIO, width=WIDTH, depth=DEPTH,
                     seed=BASE_SEED + trial)
    params, st = sjpc.init(cfg)
    st = sjpc.update_fused(cfg, params, st, vals,
                           key=jax.random.PRNGKey(40 + trial),
                           use_pallas=False)
    be = sjpc.estimate_batch(cfg, st.counters[None],
                             np.array([float(st.n)], np.float32))
    return {s: float(be.g[0, s - S_SKETCH]) for s in MID_BAND}


def _equal_space_sample() -> int:
    cfg = SJPCConfig(d=D, s=S_SKETCH, ratio=RATIO, width=WIDTH, depth=DEPTH)
    return baselines.sample_size_for_bytes(cfg.counters_bytes, D * 4)


def _run_comparison(vals, g_true, trials):
    sample = _equal_space_sample()
    assert sample < N // 8          # the budget must be genuinely sublinear
    errs = {"sjpc": {s: [] for s in MID_BAND},
            "rs": {s: [] for s in MID_BAND}}
    ests = []
    for t in range(trials):
        g_sj = _sjpc_g_table(vals, t)
        rng = np.random.default_rng(1000 + t)
        for s in MID_BAND:
            g_rs = baselines.random_sampling_g(vals, s, sample, rng)
            ests += [g_sj[s], g_rs]
            errs["sjpc"][s].append(abs(g_sj[s] - g_true[s]) / g_true[s])
            errs["rs"][s].append(abs(g_rs - g_true[s]) / g_true[s])
    return errs, ests


def test_sjpc_beats_equal_space_random_sampling(workload):
    """The Fig. 4/8 headline: SJPC median relative error < random sampling
    at equal space, for every mid-band threshold."""
    vals, g_true = workload
    errs, ests = _run_comparison(vals, g_true, trials=5)
    for s in MID_BAND:
        sj = float(np.median(errs["sjpc"][s]))
        rs = float(np.median(errs["rs"][s]))
        assert sj < rs, (
            f"s={s}: SJPC median rel err {sj:.4f} no longer beats "
            f"equal-space random sampling {rs:.4f} "
            f"(sjpc={np.round(errs['sjpc'][s], 3)}, "
            f"rs={np.round(errs['rs'][s], 3)})")
        # and the estimator itself stays in a usable accuracy band
        assert sj < 0.15, f"s={s}: SJPC median rel err {sj:.4f} regressed"
    assert all(np.isfinite(e) and e >= 0 for e in ests)


def test_estimates_finite_and_nonnegative_small(workload):
    """Cheap guard on every estimator's output domain (clamped SJPC can
    never go negative; the baselines return >= n by construction)."""
    vals, _ = workload
    sub = vals[:2048]
    g_sj = _sjpc_g_table(sub, 0)
    for s in MID_BAND:
        assert np.isfinite(g_sj[s]) and g_sj[s] >= 0
    rng = np.random.default_rng(3)
    for s in MID_BAND:
        g_rs = baselines.random_sampling_g(sub, s, 256, rng)
        g_lsh = baselines.lsh_ss_g(sub, s, rng, m_h=128, m_l=128)
        assert np.isfinite(g_rs) and g_rs >= sub.shape[0]
        assert np.isfinite(g_lsh) and g_lsh >= sub.shape[0]


def test_served_sjpc_beats_served_reservoir_equal_space(workload):
    """The headline comparison THROUGH THE SERVICE PATH (DESIGN.md §13):
    SJPC and the streaming reservoir estimator served side-by-side in one
    hash group at derived equal-space budgets, on the same replayed
    stream; SJPC's median relative error must beat the served reservoir at
    every mid-band threshold.  This is the offline Fig. 4/8 contract
    promoted to a continuously-served workload."""
    from repro.service import EstimationService, ServiceConfig
    vals, g_true = workload
    errs = {"sjpc": {s: [] for s in MID_BAND},
            "res": {s: [] for s in MID_BAND}}
    for t in range(5):
        cfg = SJPCConfig(d=D, s=S_SKETCH, ratio=RATIO, width=WIDTH,
                         depth=DEPTH, seed=BASE_SEED + 50 + t)
        svc = EstimationService(ServiceConfig(batch_rows=2048,
                                              window_epochs=None))
        svc.create_group("g", cfg)
        svc.create_stream("sjpc", "g")
        svc.create_stream("res", "g", estimator="reservoir")
        res_est = svc.registry.stream("res").estimator
        # equal space by construction, and genuinely sublinear
        assert res_est.memory_bytes() <= cfg.counters_bytes
        assert res_est.cfg.capacity < N // 8
        for nm in ("sjpc", "res"):
            svc.ingest(nm, vals)
        snap = svc.snapshot()
        for nm in ("sjpc", "res"):
            for s in MID_BAND:
                g = snap.self_join(nm, s).estimate
                assert np.isfinite(g) and g >= 0
                errs[nm][s].append(abs(g - g_true[s]) / g_true[s])
    for s in MID_BAND:
        sj = float(np.median(errs["sjpc"][s]))
        rs = float(np.median(errs["res"][s]))
        assert sj < rs, (
            f"s={s}: served SJPC median rel err {sj:.4f} no longer beats "
            f"the served equal-space reservoir {rs:.4f} "
            f"(sjpc={np.round(errs['sjpc'][s], 3)}, "
            f"res={np.round(errs['res'][s], 3)})")
        assert sj < 0.15, f"s={s}: served SJPC rel err {sj:.4f} regressed"


@pytest.mark.slow
def test_sjpc_beats_random_sampling_more_trials_and_lsh_finite(workload):
    """Slow lane: more hash draws for a tighter median, plus the (multi-pass)
    LSH-SS column of the offline comparison -- asserted finite/non-negative
    and reported against the same workload."""
    vals, g_true = workload
    errs, _ = _run_comparison(vals, g_true, trials=9)
    for s in MID_BAND:
        assert float(np.median(errs["sjpc"][s])) \
            < float(np.median(errs["rs"][s]))
    for t in range(3):
        rng = np.random.default_rng(4000 + t)
        for s in MID_BAND:
            g_lsh = baselines.lsh_ss_g(vals, s, rng, m_h=1024, m_l=1024)
            assert np.isfinite(g_lsh) and g_lsh >= N
