"""Optimizer tests: AdamW trajectory, Q8Adam-vs-AdamW closeness, quantizer
round-trip properties, gradient compression error feedback."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import make_adamw, global_norm
from repro.optim.q8adam import make_q8adam, quantize, dequantize
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.compression import compress_int8, decompress_int8


def _quadratic_problem(dim=64, seed=0):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=(dim, dim)).astype(np.float32))
    params = {"w": jnp.zeros((dim, dim), jnp.float32),
              "b": jnp.zeros((dim,), jnp.float32)}

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)
    return params, loss_fn


def _run(optimizer, params, loss_fn, steps):
    state = optimizer.init(params)
    losses = []
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    upd = jax.jit(optimizer.update)
    for _ in range(steps):
        loss, g = grad_fn(params)
        params, state, _ = upd(g, state, params)
        losses.append(float(loss))
    return params, losses


def test_adamw_converges_quadratic():
    params, loss_fn = _quadratic_problem()
    _, losses = _run(make_adamw(constant(0.05), weight_decay=0.0), params,
                     loss_fn, 200)
    assert losses[-1] < 0.01 * losses[0], losses[-1]


def test_q8adam_tracks_adamw():
    params, loss_fn = _quadratic_problem()
    _, l32 = _run(make_adamw(constant(0.05), weight_decay=0.0), params, loss_fn, 150)
    _, l8 = _run(make_q8adam(constant(0.05), weight_decay=0.0), params, loss_fn, 150)
    # int8 moments shouldn't derail the trajectory
    assert l8[-1] < 0.05 * l8[0]
    assert abs(l8[-1] - l32[-1]) < 0.1 * (l32[0] - l32[-1])


class TestQuantizer:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 2000))
    @settings(max_examples=30, deadline=None)
    def test_round_trip_error_bound(self, seed, n):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32) * 10)
        qt = quantize(x)
        back = dequantize(qt, x.shape)
        # per-block abs-max scaling: error <= scale/2 <= max|block|/254
        err = np.abs(np.asarray(back - x))
        blocks = np.abs(np.asarray(x))
        assert err.max() <= blocks.max() / 127 + 1e-6

    def test_stochastic_rounding_unbiased(self):
        x = jnp.full((4096,), 0.3 * 0.011, jnp.float32)  # mid-bucket value
        x = x.at[0].set(1.4)                             # sets the scale
        samples = []
        for i in range(400):
            qt = quantize(x, key=jax.random.PRNGKey(i))
            samples.append(float(dequantize(qt, x.shape)[1]))
        # std of the mean ~ 0.011*sqrt(0.21)/20 ~ 2.5e-4; allow 4 sigma
        assert abs(np.mean(samples) - 0.0033) < 1e-3

    def test_zero_is_exact(self):
        qt = quantize(jnp.zeros((1000,), jnp.float32))
        assert float(jnp.abs(dequantize(qt, (1000,))).max()) == 0.0


class TestCompression:
    def test_round_trip(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(333, 17)).astype(np.float32))
        codes, scales = compress_int8(x)
        back = decompress_int8(codes, scales, x.shape)
        assert float(jnp.abs(back - x).max()) <= float(jnp.abs(x).max()) / 127 + 1e-6

    def test_error_feedback_reduces_bias(self):
        """With error feedback the long-run mean of compressed grads is the
        true gradient (the residual never disappears from the stream)."""
        rng = np.random.default_rng(6)
        g_true = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
        err = jnp.zeros_like(g_true)
        acc_fb = jnp.zeros_like(g_true)
        acc_nofb = jnp.zeros_like(g_true)
        steps = 100
        for _ in range(steps):
            codes, scales = compress_int8(g_true + err)
            sent = decompress_int8(codes, scales, g_true.shape)
            err = (g_true + err) - sent
            acc_fb += sent
            c2, s2 = compress_int8(g_true)
            acc_nofb += decompress_int8(c2, s2, g_true.shape)
        bias_fb = float(jnp.abs(acc_fb / steps - g_true).max())
        bias_nofb = float(jnp.abs(acc_nofb / steps - g_true).max())
        assert bias_fb <= bias_nofb + 1e-6
        assert bias_fb < 0.005


def test_warmup_cosine_shape():
    fn = warmup_cosine(1e-3, 100, 1000)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert abs(float(fn(jnp.asarray(100))) - 1e-3) < 1e-9
    assert float(fn(jnp.asarray(1000))) == pytest.approx(1e-4, rel=1e-3)
    assert float(fn(jnp.asarray(50))) == pytest.approx(5e-4, rel=1e-3)


def test_global_norm_clip():
    from repro.optim.adamw import clip_by_global_norm
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(250), rel=1e-6)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
