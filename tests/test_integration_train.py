"""End-to-end integration: the full train loop (model + AdamW + SJPC monitor
+ checkpoint/restart driver) on a tiny LM; loss must drop and recovery must
be bit-exact with the uninterrupted run."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import ArchConfig, compute_dims
from repro.launch.train import make_train_step, make_train_state
from repro.optim import make_adamw
from repro.optim.schedules import constant
from repro.runtime import DriverConfig, TrainDriver, SimulatedFailure
from repro.sketchstream.monitor import SketchMonitorConfig, monitor_estimate

CFG = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                 num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                 head_dim=16)


def _mk(tmp_path, steps_batches):
    dims = compute_dims(CFG, tp=1)
    mcfg = SketchMonitorConfig(d=4, s=3, width=256, depth=2, shards=1)
    opt = make_adamw(constant(5e-3), weight_decay=0.0)
    state, mparams, _ = make_train_state(jax.random.PRNGKey(0), CFG, dims, opt,
                                         monitor_cfg=mcfg)
    step_fn = jax.jit(make_train_step(CFG, dims, opt, None, monitor_cfg=mcfg,
                                      monitor_params=mparams, remat="none",
                                      ssm_chunk=8, compute_dtype=jnp.float32))

    def make_batch(step):
        rng = np.random.default_rng(100 + step)
        toks = rng.integers(0, CFG.vocab_size, size=(4, 33), dtype=np.int32)
        toks[1] = toks[0]        # near-duplicate pair every batch
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    driver = TrainDriver(step_fn, state, make_batch,
                         DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=8,
                                      log_every=1, sketch_log_every=100),
                         monitor_cfg=mcfg)
    return driver, mcfg


def test_loss_drops_and_monitor_counts(tmp_path):
    driver, mcfg = _mk(tmp_path, 25)
    driver.run(25)
    losses = [m["loss"] for m in driver.metrics_log]
    assert losses[-1] < losses[0] - 0.1, losses[:3] + losses[-3:]
    est = monitor_estimate(mcfg, driver.state.monitor)
    assert est["n"] == 4 * 25
    # one duplicate pair per batch -> ~2*25 ordered 4-similar pairs
    g4 = est["g"][4] - est["n"]
    assert 20 <= g4 <= 90, est["g"]


def test_crash_recovery_bit_exact(tmp_path):
    d1, _ = _mk(tmp_path / "a", 20)
    d1.run(20)
    ref = jax.device_get(d1.state.params)

    d2, _ = _mk(tmp_path / "b", 20)
    d2.inject_failure_at = {11: SimulatedFailure("pod lost")}
    d2.run(20)
    got = jax.device_get(d2.state.params)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # monitor state also recovered exactly
    assert float(d2.state.monitor.n.sum()) == 80.0
