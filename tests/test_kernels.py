"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes, block sizes, and weight patterns."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import sketch as sk
from repro.core.hashing import P31
from repro.core.fingerprint import make_fingerprint_bases, np_subvalue_fingerprints
from repro.core.projections import level_combinations
from repro.kernels import ref
from repro.kernels.fingerprint import fingerprint_pallas
from repro.kernels.sketch_update import sketch_update_pallas
from repro.kernels.sketch_moments import sketch_moments_pallas


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(2024)


class TestFingerprintKernel:
    @pytest.mark.parametrize("d,k", [(3, 2), (6, 3), (6, 6), (8, 5), (10, 8), (12, 11)])
    @pytest.mark.parametrize("batch", [1, 17, 256])
    def test_matches_ref_and_numpy_oracle(self, rng, d, k, batch):
        lv = level_combinations(d, k)
        vals = rng.integers(0, 2**32, size=(batch, d), dtype=np.uint32)
        bases = make_fingerprint_bases(rng)
        f1p, f2p = fingerprint_pallas(jnp.asarray(vals), jnp.asarray(lv.masks),
                                      jnp.asarray(lv.ids), jnp.asarray(bases),
                                      interpret=True)
        f1r, f2r = ref.fingerprint_ref(jnp.asarray(vals), jnp.asarray(lv.masks),
                                       jnp.asarray(lv.ids), jnp.asarray(bases))
        np.testing.assert_array_equal(np.asarray(f1p), np.asarray(f1r))
        np.testing.assert_array_equal(np.asarray(f2p), np.asarray(f2r))
        f1n, f2n = np_subvalue_fingerprints(vals, lv.masks, lv.ids, bases)
        np.testing.assert_array_equal(np.asarray(f1p), f1n)
        np.testing.assert_array_equal(np.asarray(f2p), f2n)

    @pytest.mark.parametrize("block_b,block_m", [(8, 128), (64, 256), (512, 512)])
    def test_block_shape_invariance(self, rng, block_b, block_m):
        lv = level_combinations(7, 4)
        vals = rng.integers(0, 2**32, size=(50, 7), dtype=np.uint32)
        bases = jnp.asarray(make_fingerprint_bases(rng))
        f1a, f2a = fingerprint_pallas(jnp.asarray(vals), jnp.asarray(lv.masks),
                                      jnp.asarray(lv.ids), bases,
                                      block_b=block_b, block_m=block_m,
                                      interpret=True)
        f1r, f2r = ref.fingerprint_ref(jnp.asarray(vals), jnp.asarray(lv.masks),
                                       jnp.asarray(lv.ids), bases)
        np.testing.assert_array_equal(np.asarray(f1a), np.asarray(f1r))
        np.testing.assert_array_equal(np.asarray(f2a), np.asarray(f2r))

    def test_distinct_combos_distinct_fps(self, rng):
        """Identical values under different combinations must not collide
        (the paper's projection-tagging requirement)."""
        lv = level_combinations(4, 2)
        vals = np.zeros((1, 4), dtype=np.uint32)      # all-equal columns
        bases = jnp.asarray(make_fingerprint_bases(rng))
        f1, _ = fingerprint_pallas(jnp.asarray(vals), jnp.asarray(lv.masks),
                                   jnp.asarray(lv.ids), bases, interpret=True)
        f1 = np.asarray(f1)[0]
        assert len(np.unique(f1)) == lv.num


class TestSketchUpdateKernel:
    @pytest.mark.parametrize("n", [1, 100, 1024, 4097])
    @pytest.mark.parametrize("t,w", [(1, 256), (3, 1024), (5, 4096)])
    def test_matches_scatter_ref(self, rng, n, t, w):
        params = sk.make_sketch_params(rng, t)
        k1 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        k2 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        wt = jnp.asarray(rng.integers(0, 2, size=n).astype(np.int32))
        c0 = jnp.asarray(rng.integers(-7, 7, size=(t, w)).astype(np.int32))
        got = sketch_update_pallas(c0, k1, k2, params.bucket_coeffs,
                                   params.sign_coeffs, wt, interpret=True)
        want = ref.sketch_update_ref(c0, k1, k2, params.bucket_coeffs,
                                     params.sign_coeffs, wt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("block_n,block_w", [(128, 256), (512, 1024), (2048, 512)])
    def test_block_shape_invariance(self, rng, block_n, block_w):
        params = sk.make_sketch_params(rng, 3)
        n, w = 777, 1024
        k1 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        k2 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
        wt = jnp.ones((n,), jnp.int32)
        c0 = sk.empty_counters(3, w)
        got = sketch_update_pallas(c0, k1, k2, params.bucket_coeffs,
                                   params.sign_coeffs, wt,
                                   block_n=block_n, block_w=block_w,
                                   interpret=True)
        want = ref.sketch_update_ref(c0, k1, k2, params.bucket_coeffs,
                                     params.sign_coeffs, wt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_repeated_heavy_key_exact(self, rng):
        """A single heavy key must accumulate exactly (float32 one-hot matmul
        stays integral)."""
        params = sk.make_sketch_params(rng, 3)
        n, w = 2048, 512
        k1 = jnp.full((n,), jnp.uint32(12345))
        k2 = jnp.full((n,), jnp.uint32(67890))
        wt = jnp.ones((n,), jnp.int32)
        got = sketch_update_pallas(sk.empty_counters(3, w), k1, k2,
                                   params.bucket_coeffs, params.sign_coeffs,
                                   wt, interpret=True)
        got = np.asarray(got)
        assert (np.abs(got).sum(axis=1) == n).all()
        assert (np.abs(got).max(axis=1) == n).all()


class TestSketchMomentsKernel:
    @pytest.mark.parametrize("t,w,bw", [(1, 512, 512), (3, 4096, 1024), (7, 2048, 2048)])
    def test_matches_ref(self, rng, t, w, bw):
        a = jnp.asarray(rng.integers(-100, 100, size=(t, w)).astype(np.int32))
        b = jnp.asarray(rng.integers(-100, 100, size=(t, w)).astype(np.int32))
        got = sketch_moments_pallas(a, b, block_w=bw, interpret=True)
        want = ref.sketch_moments_ref(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestEndToEndKernelPath:
    def test_sjpc_update_with_kernels_matches_reference(self, rng):
        """Full SJPC batch update via Pallas kernels == reference jnp path."""
        import jax
        from repro.core import sjpc
        from repro.kernels import ops
        vals = rng.integers(0, 6, size=(64, 5)).astype(np.uint32)
        cfg = sjpc.SJPCConfig(d=5, s=3, ratio=0.5, width=512, depth=3, seed=1)
        params, s_ref = sjpc.init(cfg)
        key = jax.random.PRNGKey(99)
        s_k = sjpc.SJPCState(s_ref.counters, s_ref.n, s_ref.step)
        out_ref = sjpc.update(cfg, params, s_ref, jnp.asarray(vals), key=key)
        out_k = sjpc.update(cfg, params, s_k, jnp.asarray(vals), key=key,
                            update_fn=ops.make_sjpc_update_fn(use_pallas=True,
                                                              interpret=True))
        np.testing.assert_array_equal(np.asarray(out_ref.counters),
                                      np.asarray(out_k.counters))
