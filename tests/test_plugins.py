"""The DESIGN.md §19 extension surface, end to end: two estimator kinds
registered entirely from ``examples/plugins/`` serve through the service,
the planner, the accuracy auditor, the distributed wire format, and the
coordinator -- with zero edits under ``src/repro/{service,distributed,obs}``.

The module-scope import below registers "theta_kmv" and "ipf" before any
other module-scope ``estimators.available()`` enumeration in this test
process evaluates (pytest imports test modules alphabetically during
collection: test_estimators < test_plugins < test_wire), so the generic
conformance and wire suites parametrize over the plugin kinds for free.
"""
from __future__ import annotations

import importlib

import numpy as np
import pytest

import examples.plugins                     # registration side effect
from examples.plugins import inner_product, theta_sketch
from repro import estimators as E
from repro.core import exact
from repro.core.sjpc import SJPCConfig
from repro.distributed import harness
from repro.obs import MetricsRegistry, Observability, Tracer
from repro.service import ContinuousQuery, EstimationService, ServiceConfig

CFG = SJPCConfig(d=5, s=3, ratio=1.0, width=128, depth=2, seed=31)
PLUGIN_KINDS = ("theta_kmv", "ipf")


def _records(n, rng=None, hi=6):
    rng = rng or np.random.default_rng(0)
    return rng.integers(0, hi, size=(n, CFG.d), dtype=np.uint32)


def _service(**cfg_kw):
    reg = MetricsRegistry()
    obs = Observability(metrics=reg, tracer=Tracer(registry=reg))
    svc = EstimationService(ServiceConfig(batch_rows=64, **cfg_kw), obs=obs)
    svc.create_group("g", CFG)
    return svc, obs


# ---------------------------------------------------------------------------
# registry: completeness, idempotency, conflict diagnostics
# ---------------------------------------------------------------------------

class TestPluginRegistry:
    def test_plugin_kinds_fully_registered(self):
        for kind in PLUGIN_KINDS:
            assert kind in E.available()
            sp = E.spec(kind)
            assert sp.factory is not None and sp.state_cls is not None
            assert sp.linear is not None and sp.join_capable is not None
            assert sp.stderr_kind == "none"
        assert E.spec("ipf").linear and E.spec("ipf").join_capable
        assert E.spec("ipf").wire_mode == "merge"
        assert E.spec("ipf").exact_oracle is not None
        sp = E.spec("theta_kmv")
        assert not sp.linear and not sp.join_capable
        assert sp.wire_mode == "replace" and sp.exact_oracle is None

    def test_reimport_and_reload_are_idempotent(self):
        before = {k: E.spec(k) for k in E.available()}
        import examples.plugins as again                    # noqa: F401
        importlib.reload(theta_sketch)
        importlib.reload(inner_product)
        assert set(E.available()) == set(before)
        for kind in PLUGIN_KINDS:
            assert E.spec(kind).state_cls.__name__ == \
                before[kind].state_cls.__name__

    def test_conflicting_reregistration_names_both_parties(self):
        def other_factory(cfg, *, params=None, estimator_cfg=None,
                          opts=None):                        # pragma: no cover
            raise AssertionError

        with pytest.raises(ValueError) as ei:
            E.register("theta_kmv", other_factory, linear=True)
        msg = str(ei.value)
        assert "theta_kmv" in msg
        assert "examples.plugins.theta_sketch" in msg        # prior claimant
        assert "test_plugins" in msg                         # new claimant
        # the registry survives the refusal untouched
        assert E.spec("theta_kmv").factory.__module__ == \
            "examples.plugins.theta_sketch"

    def test_load_plugins_env_roundtrip(self, monkeypatch):
        monkeypatch.setenv("REPRO_PLUGINS", "examples.plugins")
        E.load_plugins()                 # re-registration: identical, no-op
        assert set(PLUGIN_KINDS) <= set(E.available())


# ---------------------------------------------------------------------------
# service: plugin kinds served side by side with the builtins
# ---------------------------------------------------------------------------

class TestPluginService:
    def test_plugins_serve_alongside_builtins(self):
        svc, _ = _service()
        recs = _records(400)
        for kind in E.available():
            svc.create_stream(kind, "g", estimator=kind)
            svc.ingest(kind, recs)
        snap = svc.snapshot()
        x = np.asarray(exact.exact_pair_counts(recs))
        n = recs.shape[0]
        for kind in PLUGIN_KINDS:
            for s in range(CFG.s, CFG.d + 1):
                r = snap.self_join(kind, s=s)
                truth = float(x[s:].sum() + n)
                assert np.isfinite(r.estimate) and r.estimate >= 0
                assert r.stderr_kind == "none" and r.stderr == 0
                if kind == "ipf":        # a real estimator of the paper's g
                    assert r.estimate == pytest.approx(truth, rel=1.0)
        # theta's constant g column is n + duplicate-pair estimate: at the
        # top threshold (exact duplicates) it should be in the ballpark
        r = snap.self_join("theta_kmv", s=CFG.d)
        assert r.estimate == pytest.approx(float(x[CFG.d:].sum() + n),
                                           rel=0.5)

    def test_ipf_join_fused_matches_ref(self):
        recs_a, recs_b = _records(300), _records(200, np.random.default_rng(4))
        results = {}
        for fused in (True, False):
            svc, _ = _service(use_fused_query=fused)
            svc.create_stream("a", "g", estimator="ipf")
            svc.create_stream("b", "g", estimator="ipf")
            svc.ingest("a", recs_a)
            svc.ingest("b", recs_b)
            snap = svc.snapshot()
            results[fused] = [snap.join("a", "b", s=s).estimate
                              for s in range(CFG.s, CFG.d + 1)]
        assert results[True] == pytest.approx(results[False], rel=1e-6)
        truth = np.asarray(exact.brute_force_join_counts(recs_a, recs_b))
        assert results[True][0] == pytest.approx(float(truth[CFG.s:].sum()),
                                                 rel=0.5)

    def test_theta_join_refused_via_spec(self):
        svc, _ = _service()
        svc.create_stream("a", "g", estimator="theta_kmv")
        svc.create_stream("b", "g", estimator="theta_kmv")
        svc.ingest("a", _records(50))
        svc.ingest("b", _records(50))
        with pytest.raises(ValueError, match="join-capable"):
            svc.snapshot().join("a", "b")

    def test_ipf_linear_window_expires_by_subtraction(self):
        svc, _ = _service(window_epochs=2)
        svc.create_stream("a", "g", estimator="ipf")
        rng = np.random.default_rng(9)
        per_epoch = [_records(60, rng) for _ in range(4)]
        for recs in per_epoch:
            svc.ingest("a", recs)
            svc.flush()
            svc.advance_epoch()
        mid = svc.registry.stream("a").window.total
        assert int(np.asarray(mid.n)) > 0          # window still live
        for _ in range(3):                         # idle epochs: all expire
            svc.advance_epoch()
        # every ingested epoch has rotated out: exact counter subtraction
        # (spec.linear delta-ring expiry) must leave the literal zero state
        total = svc.registry.stream("a").window.total
        assert int(np.asarray(total.n)) == 0
        assert not np.asarray(total.counters).any()


# ---------------------------------------------------------------------------
# observability: kinds without an exact oracle skip honestly
# ---------------------------------------------------------------------------

class TestPluginAudit:
    def test_no_oracle_kind_skips_with_reason(self):
        svc, obs = _service(audit_rate=1.0, window_epochs=4)
        svc.create_stream("t", "g", estimator="theta_kmv")
        svc.register_continuous(ContinuousQuery("q", "self_join", ("t",)))
        svc.ingest("t", _records(80))
        svc.poll()
        m = obs.metrics
        assert m.counter("accuracy_audit_skipped_total",
                         reason="no_exact_oracle") >= 1.0
        assert m.counter_total("accuracy_audits_total") == 0.0

    def test_oracle_bearing_plugin_is_audited(self):
        svc, obs = _service(audit_rate=1.0, window_epochs=4)
        svc.create_stream("p", "g", estimator="ipf")
        svc.register_continuous(ContinuousQuery("q", "self_join", ("p",)))
        svc.ingest("p", _records(80))
        svc.poll()
        m = obs.metrics
        assert m.counter("accuracy_audits_total", kind="ipf") == 1.0
        assert m.counter("accuracy_audit_skipped_total",
                         reason="no_exact_oracle") == 0.0


# ---------------------------------------------------------------------------
# distributed: plugin tenants through LocalWorker + Coordinator
# ---------------------------------------------------------------------------

class TestPluginDistributed:
    def test_plugin_cluster_matches_oracle(self):
        """The e2e proof: a 2-worker cluster whose tenants all run PLUGIN
        kinds syncs wire deltas (MODE_MERGE for ipf, MODE_REPLACE for
        theta) into coordinator replicas that match the single-process
        oracle -- ipf bit-exactly, both kinds to 1e-6 on estimates."""
        spec = harness.make_spec(4, kinds=("ipf", "theta_kmv"),
                                 d=CFG.d, s=CFG.s, width=CFG.width,
                                 depth=CFG.depth, seed=CFG.seed,
                                 window_epochs=3, batch_rows=64)
        cycles = 3
        batches = harness.make_batches(spec, cycles=cycles,
                                       rows_per_cycle=96, seed=5)
        run = harness.run_cluster(spec, batches, n_workers=2, cycles=cycles,
                                  local=True, keep_open=True)
        try:
            assert all(t["deltas"] > 0 for t in run.sync_trace)
            oracle = harness.run_oracle(spec, batches, cycles=cycles)
            agree = harness.compare_to_oracle(run.coordinator, oracle, spec)
            assert agree["linear_exact"], (
                "plugin replica state diverged from the single-process run")
            assert agree["worst_rel_err"] <= 1e-6
        finally:
            run.coordinator.close()
