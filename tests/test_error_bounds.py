"""Statistical guarantee suite: the estimator honors the Theorem 1/2 error
bounds at the stated confidence -- on the FUSED ingest path, since that is
what production traffic flows through.

For several (d, s, width, depth) points we run multiple seeded trials
(fresh hash/fingerprint draws per trial, fixed synthetic data with exact
g_s known from ``core.exact``) and check the Chebyshev consequence of
Theorem 2: var(G_s/g_s) <= B  implies  P(|G_s/g_s - 1| > k*sqrt(B)) <= 1/k^2.
At k = 3 at least 8/9 of trials must land within 3*sqrt(B); we assert a
slightly looser fraction so the (deterministic, seeded) suite is robust to
re-calibration of shapes rather than flaky.

Everything is seeded: the trials are reproducible bit-for-bit, so a failure
here means the estimator or its bounds changed, not bad luck."""
import math

import numpy as np
import jax
import pytest

from repro.core import exact, sjpc
from repro.core.sjpc import SJPCConfig

# (d, s, ratio, width, depth): small enough for CI, spread over the knobs
POINTS = [
    (4, 2, 0.5, 1024, 3),
    (4, 2, 1.0, 512, 3),
    (5, 3, 0.5, 2048, 3),
    (4, 3, 0.5, 512, 5),
]
N_RECORDS = 1500
TRIALS = 10
CONF_K = 3.0            # Chebyshev multiplier: >= 8/9 of trials inside
MIN_FRACTION = 0.8      # asserted fraction (slack below 8/9 ~ 0.889)


def _data(d: int) -> np.ndarray:
    rng = np.random.default_rng(2026)
    return rng.integers(0, 6, size=(N_RECORDS, d)).astype(np.uint32)


def _trial_estimates(cfg: SJPCConfig, values: np.ndarray) -> list[float]:
    """g_s estimates across TRIALS independent hash draws (fused path)."""
    out = []
    update = jax.jit(lambda p, st, v, k: sjpc.update_fused(
        cfg, p, st, v, key=k, use_pallas=False))
    for trial in range(TRIALS):
        tcfg = SJPCConfig(d=cfg.d, s=cfg.s, ratio=cfg.ratio, width=cfg.width,
                          depth=cfg.depth, seed=cfg.seed + trial)
        params, state = sjpc.init(tcfg)
        state = update(params, state, values, jax.random.PRNGKey(9000 + trial))
        out.append(sjpc.estimate(tcfg, state).g_s)
    return out


@pytest.mark.slow
@pytest.mark.parametrize("d,s,ratio,width,depth", POINTS)
def test_theorem2_bound_holds_at_stated_confidence(d, s, ratio, width, depth):
    cfg = SJPCConfig(d=d, s=s, ratio=ratio, width=width, depth=depth, seed=100)
    values = _data(d)
    g = exact.exact_g(values, s)
    assert g > 0
    sigma = math.sqrt(sjpc.online_variance_bound(d, s, ratio, width,
                                                 float(N_RECORDS), g))
    rel = np.array([(est - g) / g for est in _trial_estimates(cfg, values)])
    inside = float(np.mean(np.abs(rel) <= CONF_K * sigma))
    assert inside >= MIN_FRACTION, (
        f"(d={d}, s={s}, r={ratio}, w={width}, t={depth}): only "
        f"{inside:.0%} of {TRIALS} trials within {CONF_K}*sigma "
        f"(sigma={sigma:.3f}, rel errs={np.round(rel, 3)})")
    # the bound should not be vacuously loose for these shapes: the mean
    # absolute error must sit well inside one bound-sigma
    assert float(np.mean(np.abs(rel))) <= sigma, (
        f"mean |rel err| {np.mean(np.abs(rel)):.3f} exceeds sigma {sigma:.3f}")


@pytest.mark.parametrize("d,s,ratio", [(4, 2, 0.5), (5, 3, 1.0)])
def test_offline_bound_dominated_by_online(d, s, ratio):
    """Theorem 1 (sampling only) must lower-bound Theorem 2 (sampling +
    sketch): the sketch can only add variance."""
    values = _data(d)
    g = exact.exact_g(values, s)
    off = sjpc.offline_variance_bound(d, s, ratio, g)
    for width in (256, 1024, 4096):
        on = sjpc.online_variance_bound(d, s, ratio, width, float(N_RECORDS), g)
        assert on > off
    # and the online bound tightens monotonically with width
    bounds = [sjpc.online_variance_bound(d, s, ratio, w, float(N_RECORDS), g)
              for w in (256, 1024, 4096)]
    assert bounds[0] > bounds[1] > bounds[2]


@pytest.mark.slow
def test_estimator_concentrates_with_width():
    """Sanity companion to the bound: empirical spread shrinks as the
    sketch widens (holding data + trials fixed)."""
    d, s, ratio = 4, 2, 1.0
    values = _data(d)
    g = exact.exact_g(values, s)
    spreads = []
    for width in (256, 4096):
        cfg = SJPCConfig(d=d, s=s, ratio=ratio, width=width, depth=3, seed=300)
        rel = np.array([(e - g) / g for e in _trial_estimates(cfg, values)])
        spreads.append(float(np.sqrt(np.mean(rel ** 2))))
    assert spreads[1] < spreads[0]
