"""Baselines (§2): sanity + the Lemma-1 separation SJPC is compared against."""
import numpy as np
import pytest

from repro.core import baselines, exact


def _dups_dataset(rng, n=400, d=5, dup_frac=0.5):
    base = rng.integers(0, 50, size=(n, d)).astype(np.uint32)
    n_dup = int(n * dup_frac) // 2
    for i in range(n_dup):
        base[n - 1 - i] = base[i]
        base[n - 1 - i, rng.integers(0, d)] += 1000   # 4-similar partner
    return base


class TestRandomSampling:
    def test_full_sample_is_exact(self):
        rng = np.random.default_rng(0)
        vals = _dups_dataset(rng)
        x_est = baselines.random_sampling_pair_counts(vals, len(vals), rng)
        np.testing.assert_allclose(x_est, exact.brute_force_pair_counts(vals))

    def test_unbiased_at_half_sample(self):
        rng = np.random.default_rng(1)
        vals = _dups_dataset(rng)
        true_g = exact.exact_g(vals, 4)
        ests = [baselines.random_sampling_g(vals, 4, 200, np.random.default_rng(s))
                for s in range(40)]
        assert abs(np.mean(ests) - true_g) / true_g < 0.2

    def test_small_sample_misses_similar_pairs(self):
        """Lemma 1: o(sqrt(n)) samples typically see zero similar pairs and
        estimate g_s ~= n."""
        rng = np.random.default_rng(2)
        n = 2000
        vals = rng.integers(0, 2**30, size=(n, 5)).astype(np.uint32)
        vals[1] = vals[0]                          # one duplicate pair only
        misses = 0
        for s in range(20):
            g = baselines.random_sampling_g(vals, 5, 8, np.random.default_rng(s))
            misses += (g == n)
        assert misses >= 18


class TestLSHSS:
    def test_reasonable_estimate_on_dups(self):
        rng = np.random.default_rng(3)
        vals = _dups_dataset(rng, n=300)
        true_g = exact.exact_g(vals, 4)
        ests = [baselines.lsh_ss_g(vals, 4, np.random.default_rng(100 + s))
                for s in range(10)]
        # LSH-SS is the weaker baseline in the paper; allow generous error
        assert abs(np.median(ests) - true_g) / true_g < 1.0

    def test_no_duplicates_estimates_near_n(self):
        rng = np.random.default_rng(4)
        vals = rng.integers(0, 2**30, size=(500, 5)).astype(np.uint32)
        g = baselines.lsh_ss_g(vals, 4, rng)
        assert abs(g - 500) / 500 < 0.5


class TestSpaceAccounting:
    def test_sample_size_for_bytes(self):
        # Fig. 8 setting: 48,000 bytes, 48-byte records -> 1000 records
        assert baselines.sample_size_for_bytes(48_000, 48) == 1000
