"""Baselines (§2): sanity + the Lemma-1 separation SJPC is compared against."""
import numpy as np
import pytest

from repro.core import baselines, exact


def _dups_dataset(rng, n=400, d=5, dup_frac=0.5):
    base = rng.integers(0, 50, size=(n, d)).astype(np.uint32)
    n_dup = int(n * dup_frac) // 2
    for i in range(n_dup):
        base[n - 1 - i] = base[i]
        base[n - 1 - i, rng.integers(0, d)] += 1000   # 4-similar partner
    return base


class TestRandomSampling:
    def test_full_sample_is_exact(self):
        rng = np.random.default_rng(0)
        vals = _dups_dataset(rng)
        x_est = baselines.random_sampling_pair_counts(vals, len(vals), rng)
        np.testing.assert_allclose(x_est, exact.brute_force_pair_counts(vals))

    def test_unbiased_at_half_sample(self):
        rng = np.random.default_rng(1)
        vals = _dups_dataset(rng)
        true_g = exact.exact_g(vals, 4)
        ests = [baselines.random_sampling_g(vals, 4, 200, np.random.default_rng(s))
                for s in range(40)]
        assert abs(np.mean(ests) - true_g) / true_g < 0.2

    def test_small_sample_misses_similar_pairs(self):
        """Lemma 1: o(sqrt(n)) samples typically see zero similar pairs and
        estimate g_s ~= n."""
        rng = np.random.default_rng(2)
        n = 2000
        vals = rng.integers(0, 2**30, size=(n, 5)).astype(np.uint32)
        vals[1] = vals[0]                          # one duplicate pair only
        misses = 0
        for s in range(20):
            g = baselines.random_sampling_g(vals, 5, 8, np.random.default_rng(s))
            misses += (g == n)
        assert misses >= 18


class TestDegenerateStreams:
    """n in {0, 1}: every offline estimator must degrade gracefully --
    empty streams used to crash random sampling (rng.choice(0, ...))."""

    def test_random_sampling_empty_stream(self):
        rng = np.random.default_rng(0)
        empty = np.zeros((0, 5), np.uint32)
        x = baselines.random_sampling_pair_counts(empty, 100, rng)
        np.testing.assert_array_equal(x, np.zeros(6))
        assert baselines.random_sampling_g(empty, 3, 100, rng) == 0.0

    def test_random_sampling_single_record(self):
        rng = np.random.default_rng(1)
        one = np.ones((1, 5), np.uint32)
        np.testing.assert_array_equal(
            baselines.random_sampling_pair_counts(one, 100, rng), np.zeros(6))
        assert baselines.random_sampling_g(one, 3, 100, rng) == 1.0

    def test_lsh_ss_empty_and_single(self):
        rng = np.random.default_rng(2)
        assert baselines.lsh_ss_g(np.zeros((0, 5), np.uint32), 3, rng) == 0.0
        assert baselines.lsh_ss_g(np.ones((1, 5), np.uint32), 3, rng) == 1.0

    def test_zero_sample_budget_returns_zero_histogram(self):
        """A sample budget of 0 or 1 records must yield the degenerate
        estimate (g = n), not crash and not silently inflate the sample."""
        rng = np.random.default_rng(3)
        vals = rng.integers(0, 9, size=(50, 5)).astype(np.uint32)
        for budget in (0, 1):
            x = baselines.random_sampling_pair_counts(vals, budget, rng)
            np.testing.assert_array_equal(x, np.zeros(6))
            assert baselines.random_sampling_g(vals, 3, budget, rng) == 50.0


class TestLSHSS:
    def test_reasonable_estimate_on_dups(self):
        rng = np.random.default_rng(3)
        vals = _dups_dataset(rng, n=300)
        true_g = exact.exact_g(vals, 4)
        ests = [baselines.lsh_ss_g(vals, 4, np.random.default_rng(100 + s))
                for s in range(10)]
        # LSH-SS is the weaker baseline in the paper; allow generous error
        assert abs(np.median(ests) - true_g) / true_g < 1.0

    def test_no_duplicates_estimates_near_n(self):
        rng = np.random.default_rng(4)
        vals = rng.integers(0, 2**30, size=(500, 5)).astype(np.uint32)
        g = baselines.lsh_ss_g(vals, 4, rng)
        assert abs(g - 500) / 500 < 0.5

    def test_num_hash_cols_validated(self):
        rng = np.random.default_rng(5)
        vals = rng.integers(0, 9, size=(40, 5)).astype(np.uint32)
        for bad in (0, -1, 6):
            with pytest.raises(ValueError, match="num_hash_cols"):
                baselines.lsh_ss_g(vals, 3, rng, num_hash_cols=bad)

    @pytest.mark.parametrize("num_hash_cols", [2, 5])
    def test_column_subset_used(self, num_hash_cols):
        """Larger column subsets refine the buckets; the estimate stays in
        a sane band on duplicate-structured data."""
        rng = np.random.default_rng(6)
        vals = _dups_dataset(rng, n=300)
        true_g = exact.exact_g(vals, 4)
        ests = [baselines.lsh_ss_g(vals, 4, np.random.default_rng(200 + s),
                                   num_hash_cols=num_hash_cols)
                for s in range(10)]
        assert all(np.isfinite(e) and e >= 300 for e in ests)
        assert abs(np.median(ests) - true_g) / true_g < 1.0

    def test_d_column_edge_case_buckets_are_exact_records(self):
        """Regression pin for c = d: the bucket key is the whole record, so
        the same-bucket stratum is exactly the duplicate pairs, every one
        d-similar (p1 = 1), and the s = d estimate is deterministic: the
        true ordered duplicate-pair count plus n (the cross stratum holds
        no d-similar pairs by construction)."""
        rng = np.random.default_rng(7)
        n, d = 200, 5
        vals = rng.integers(0, 2**30, size=(n, d)).astype(np.uint32)
        vals[n - 10:] = vals[:10]                 # 10 exact duplicate pairs
        true_g = exact.exact_g(vals, d)
        assert true_g == n + 20                   # ordered pairs
        for seed in range(3):
            g = baselines.lsh_ss_g(vals, d, np.random.default_rng(seed),
                                   num_hash_cols=d)
            assert g == true_g, (seed, g, true_g)


class TestSpaceAccounting:
    def test_sample_size_for_bytes(self):
        # Fig. 8 setting: 48,000 bytes, 48-byte records -> 1000 records
        assert baselines.sample_size_for_bytes(48_000, 48) == 1000

    def test_no_silent_floor(self):
        """A budget holding < 2 records reports the truth (0 or 1), and
        the downstream estimator degrades to the zero histogram instead of
        silently over-provisioning the sample."""
        assert baselines.sample_size_for_bytes(0, 48) == 0
        assert baselines.sample_size_for_bytes(47, 48) == 0
        assert baselines.sample_size_for_bytes(95, 48) == 1
        assert baselines.sample_size_for_bytes(96, 48) == 2
