"""The kernel capability registry (DESIGN.md §17) and its registry-driven
conformance matrix.

The matrix is GENERATED from the registry: one parametrized case per
(op, registered impl) x shape/depth/empty edge grid (tests/kernel_cases.py).
Registering a backend without an oracle is impossible
(``KernelRegistry.register`` refuses it), and a backend that drifts from
its oracle fails here by construction -- nobody has to remember to extend
``test_fused_*.py`` when a tier is added.

Also covered: resolution order per platform, forcing (context manager /
``REPRO_KERNEL_IMPL``), the dispatch-metric ``impl`` label, the
``fused_pairs`` R==0 accounting regression, the ``repro.platform``
bootstrap helpers, and hypothesis properties asserting every registered
impl of every kernel is VALUE-identical (not just close) under input
permutation and leading-dim reshapes on integer inputs.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, registry as registry_mod
from repro.kernels.registry import (JNP_REF, PALLAS_GPU, PALLAS_INTERPRET,
                                    PALLAS_TPU, KernelRegistry,
                                    RegistryError, kernel_registry,
                                    on_platforms)
from repro.obs.metrics import MetricsRegistry, set_default_registry

from kernel_cases import (KernelCase, entry_call, matrix_cases, oracle_call,
                          pairs_case, counter_stack, sketch_update_case,
                          ingest_inputs, fingerprint_case, flash_case)

REG = kernel_registry()

# completeness at COLLECTION time: an op losing its oracle-carrying impls
# aborts the whole module, not one test deep in the run
REG.check()

ALL_OPS = ("fingerprint", "sketch_update", "sketch_moments", "fused_ingest",
           "fused_query", "fused_pairs", "flash_attention")

MATRIX = [(case, impl.name) for case in matrix_cases()
          for impl in REG.impls(case.op)]


def _assert_matches(case, got, want):
    got, want = np.asarray(got), np.asarray(want)
    if case.tol is None:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=case.tol, atol=case.tol)


# ---------------------------------------------------------------------------
# the conformance matrix
# ---------------------------------------------------------------------------

class TestConformanceMatrix:
    @pytest.mark.parametrize("case,impl_name", MATRIX,
                             ids=[f"{c.id}-{n}" for c, n in MATRIX])
    def test_impl_matches_its_oracle(self, case, impl_name):
        """Every registered implementation == its attached oracle, called
        through the real ops dispatch layer with ``impl=`` forced."""
        impl = REG.get(case.op, impl_name)
        got = entry_call(case, impl_name)
        want = oracle_call(case, impl.oracle)
        _assert_matches(case, got, want)


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

class TestRegistryContract:
    def test_all_seven_ops_registered(self):
        assert REG.ops() == tuple(sorted(ALL_OPS))

    def test_every_op_has_at_least_two_impls_and_ref_fallback(self):
        for op in REG.ops():
            names = {i.name for i in REG.impls(op)}
            assert len(names) >= 2, (op, names)
            assert JNP_REF in names, (op, names)
            assert PALLAS_INTERPRET in names, (op, names)

    def test_gpu_tier_registered_for_the_four_fused_kernels(self):
        for op in ("fingerprint", "fused_ingest", "fused_query",
                   "fused_pairs"):
            assert PALLAS_GPU in {i.name for i in REG.impls(op)}, op

    def test_registering_without_oracle_is_refused(self):
        """The auto-attachment contract: an impl with no oracle cannot
        exist, so the matrix above can never silently under-cover."""
        private = KernelRegistry()
        with pytest.raises(RegistryError, match="oracle"):
            private.register("op", "x", fn=lambda: None, oracle=None,
                             predicate=on_platforms("cpu"), priority=1)

    def test_duplicate_registration_is_refused(self):
        private = KernelRegistry()
        private.register("op", "x", fn=lambda: None, oracle=lambda: None,
                         predicate=on_platforms("cpu"), priority=1)
        with pytest.raises(RegistryError, match="already registered"):
            private.register("op", "x", fn=lambda: None, oracle=lambda: None,
                             predicate=on_platforms("cpu"), priority=1)

    def test_check_flags_single_impl_ops(self):
        private = KernelRegistry()
        private.register("lonely", JNP_REF, fn=lambda: None,
                         oracle=lambda: None,
                         predicate=on_platforms("cpu"), priority=1)
        with pytest.raises(RegistryError, match="need >= 2"):
            private.check()

    def test_matrix_axis_covers_every_registration(self):
        axis = set(REG.matrix())
        for op in REG.ops():
            for impl in REG.impls(op):
                assert (op, impl.name) in axis


class TestResolution:
    @pytest.fixture(autouse=True)
    def _no_env_force(self, monkeypatch):
        """These tests pin the UN-forced resolution order; neutralize any
        ambient REPRO_KERNEL_IMPL (the CI pallas-interpret lane exports it
        for the whole module)."""
        monkeypatch.delenv(registry_mod.FORCE_ENV, raising=False)

    def test_platform_resolution_order(self):
        """cpu -> jnp_ref; tpu -> pallas_tpu; gpu -> pallas_gpu where
        registered, jnp_ref fallback elsewhere (the acceptance contract:
        the gpu tier falls back cleanly on machines without one)."""
        assert set(REG.resolution("cpu").values()) == {JNP_REF}
        assert set(REG.resolution("tpu").values()) == {PALLAS_TPU}
        gpu_res = REG.resolution("gpu")
        for op in ("fingerprint", "fused_ingest", "fused_query",
                   "fused_pairs"):
            assert gpu_res[op] == PALLAS_GPU
        for op in ("sketch_update", "sketch_moments", "flash_attention"):
            assert gpu_res[op] == JNP_REF

    def test_force_context_redirects_auto_dispatch_only(self):
        with REG.force(PALLAS_INTERPRET):
            assert REG.resolve("fused_pairs").name == PALLAS_INTERPRET
            assert REG.resolve("sketch_update").name == PALLAS_INTERPRET
        assert REG.resolve("fused_pairs", "cpu").name == JNP_REF

    def test_force_per_op_wins_over_wildcard(self):
        with REG.force(PALLAS_INTERPRET):
            with REG.force(PALLAS_GPU, op="fused_pairs"):
                assert REG.resolve("fused_pairs").name == PALLAS_GPU
                assert REG.resolve("fused_query").name == PALLAS_INTERPRET

    def test_env_forcing(self, monkeypatch):
        monkeypatch.setenv(registry_mod.FORCE_ENV,
                           "fused_pairs=pallas_gpu,*=jnp_ref")
        assert REG.resolve("fused_pairs").name == PALLAS_GPU
        assert REG.resolve("fused_query").name == JNP_REF
        monkeypatch.delenv(registry_mod.FORCE_ENV)
        assert REG.resolve("fused_pairs", "cpu").name == JNP_REF

    def test_explicit_impl_wins_over_force(self):
        rng = np.random.default_rng(0)
        items, valid = pairs_case(rng, 1, 12, 3)
        with REG.force(PALLAS_INTERPRET):
            fresh = MetricsRegistry()
            prev = set_default_registry(fresh)
            try:
                ops.fused_pairs(items, valid, use_pallas=False)
                assert fresh.counter("kernel_dispatch_total",
                                     kernel="fused_pairs", path="jnp",
                                     impl=JNP_REF) == 1.0
            finally:
                set_default_registry(prev)

    def test_unknown_names_raise(self):
        with pytest.raises(RegistryError, match="unknown kernel op"):
            REG.resolve("not_an_op")
        with pytest.raises(RegistryError, match="no implementation"):
            REG.get("fused_pairs", "not_a_tier")


# ---------------------------------------------------------------------------
# dispatch accounting (satellite: R==0 + the impl label)
# ---------------------------------------------------------------------------

class TestDispatchAccounting:
    @pytest.fixture(autouse=True)
    def _no_env_force(self, monkeypatch):
        monkeypatch.delenv(registry_mod.FORCE_ENV, raising=False)

    def _fresh(self):
        fresh = MetricsRegistry()
        return fresh, set_default_registry(fresh)

    def test_empty_reservoir_query_is_counted(self):
        """Regression: the fused_pairs R==0 early return used to skip
        ``kernel_dispatch_total`` -- empty-reservoir queries were invisible
        to dispatch telemetry."""
        fresh, prev = self._fresh()
        try:
            out = ops.fused_pairs(np.zeros((2, 0, 4), np.uint32),
                                  np.zeros((2, 0), np.int32))
            assert out.shape == (2, 5) and not np.asarray(out).any()
            assert fresh.counter("kernel_dispatch_total",
                                 kernel="fused_pairs", path="jnp",
                                 impl=JNP_REF) == 1.0
        finally:
            set_default_registry(prev)

    def test_counter_carries_impl_label(self):
        rng = np.random.default_rng(1)
        items, valid = pairs_case(rng, 1, 16, 3)
        fresh, prev = self._fresh()
        try:
            ops.fused_pairs(items, valid)                    # auto: jnp_ref
            ops.fused_pairs(items, valid, use_pallas=True)   # interpreter
            ops.fused_pairs(items, valid, impl=PALLAS_GPU)   # forced tier
            assert fresh.counter("kernel_dispatch_total",
                                 kernel="fused_pairs", path="jnp",
                                 impl=JNP_REF) == 1.0
            assert fresh.counter("kernel_dispatch_total",
                                 kernel="fused_pairs", path="pallas",
                                 impl=PALLAS_INTERPRET) == 1.0
            assert fresh.counter("kernel_dispatch_total",
                                 kernel="fused_pairs", path="pallas",
                                 impl=PALLAS_GPU) == 1.0
        finally:
            set_default_registry(prev)


# ---------------------------------------------------------------------------
# hypothesis: impl-identity under permutation / leading-dim reshape
# ---------------------------------------------------------------------------
# Integer kernels must agree bit-for-bit ACROSS impls and stay bit-stable
# under record permutation (scatter-add commutativity) and leading-dim
# reshapes (batch entries are independent).  flash_attention is the one
# float kernel: each impl must be exactly equivariant to batch permutation
# (independent batch entries), while cross-impl agreement is tolerance-based
# and covered by the matrix above.

def _impls(op):
    return [i.name for i in REG.impls(op)]


class TestImplIdentityProperties:
    @given(st.integers(min_value=0, max_value=2**20),
           st.integers(min_value=2, max_value=30),
           st.integers(min_value=2, max_value=6))
    @settings(max_examples=5, deadline=None)
    def test_fused_pairs_permutation_and_reshape(self, seed, r, d):
        rng = np.random.default_rng(seed)
        items, valid = pairs_case(rng, 2, r, d)
        perm = rng.permutation(r)
        outs = []
        for name in _impls("fused_pairs"):
            base = np.asarray(ops.fused_pairs(items, valid, impl=name))
            permed = np.asarray(ops.fused_pairs(items[:, perm],
                                                valid[:, perm], impl=name))
            np.testing.assert_array_equal(base, permed)
            lead = np.asarray(ops.fused_pairs(
                items.reshape(2, 1, r, d), valid.reshape(2, 1, r),
                impl=name))
            np.testing.assert_array_equal(base, lead.reshape(2, d + 1))
            outs.append(base)
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    @given(st.integers(min_value=0, max_value=2**20),
           st.integers(min_value=1, max_value=4),
           st.integers(min_value=5, max_value=8))
    @settings(max_examples=5, deadline=None)
    def test_fused_query_reshape(self, seed, t, logw):
        rng = np.random.default_rng(seed)
        a = counter_stack(rng, 2, 3, t, 2**logw)
        b = counter_stack(rng, 2, 3, t, 2**logw)
        outs = []
        for name in _impls("fused_query"):
            base = np.asarray(ops.fused_query(a, b, impl=name))
            flat = np.asarray(ops.fused_query(a.reshape(6, 1, t, 2**logw),
                                              b.reshape(6, 1, t, 2**logw),
                                              impl=name))
            np.testing.assert_array_equal(base, flat.reshape(2, 3, t))
            outs.append(base)
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    @given(st.integers(min_value=0, max_value=2**20),
           st.integers(min_value=1, max_value=64))
    @settings(max_examples=5, deadline=None)
    def test_sketch_update_batch_permutation(self, seed, n):
        rng = np.random.default_rng(seed)
        counters, fp1, fp2, bc, sc, weights = sketch_update_case(
            rng, n, 3, 128)
        perm = rng.permutation(n)
        outs = []
        for name in _impls("sketch_update"):
            base = np.asarray(entry_call(
                KernelCase("sketch_update", "p",
                           (counters, fp1, fp2, bc, sc, weights)), name))
            permed = np.asarray(entry_call(
                KernelCase("sketch_update", "p",
                           (counters, fp1[perm], fp2[perm], bc, sc,
                            weights[perm])), name))
            np.testing.assert_array_equal(base, permed)
            outs.append(base)
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    @given(st.integers(min_value=0, max_value=2**20),
           st.integers(min_value=1, max_value=40))
    @settings(max_examples=3, deadline=None)
    def test_fused_ingest_batch_permutation(self, seed, batch):
        from repro.core.sjpc import SJPCConfig
        rng = np.random.default_rng(seed)
        cfg = SJPCConfig(d=4, s=2, width=128, depth=2, seed=9)
        _, _, args = ingest_inputs(rng, cfg, batch)
        counters, values, masks, ids, bases, bc, sc, weights = args
        perm = rng.permutation(batch)
        outs = []
        for name in _impls("fused_ingest"):
            base = np.asarray(ops.fused_ingest(*args, impl=name))
            permed = np.asarray(ops.fused_ingest(
                counters, values[perm], masks, ids, bases, bc, sc,
                weights[perm], impl=name))
            np.testing.assert_array_equal(base, permed)
            outs.append(base)
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    @given(st.integers(min_value=0, max_value=2**20),
           st.integers(min_value=1, max_value=50))
    @settings(max_examples=5, deadline=None)
    def test_fingerprint_row_permutation_equivariant(self, seed, b):
        rng = np.random.default_rng(seed)
        args = fingerprint_case(rng, b, 5, 3)
        values = args[0]
        perm = rng.permutation(b)
        outs = []
        for name in _impls("fingerprint"):
            f1, f2 = ops.fingerprint(*args, impl=name)
            p1, p2 = ops.fingerprint(values[perm], *args[1:], impl=name)
            np.testing.assert_array_equal(np.asarray(f1)[perm],
                                          np.asarray(p1))
            np.testing.assert_array_equal(np.asarray(f2)[perm],
                                          np.asarray(p2))
            outs.append(np.asarray(f1))
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    @given(st.integers(min_value=0, max_value=2**20),
           st.integers(min_value=1, max_value=4))
    @settings(max_examples=5, deadline=None)
    def test_sketch_moments_row_reshape(self, seed, t):
        rng = np.random.default_rng(seed)
        a = counter_stack(rng, 1, 1, t, 256)[0, 0]
        b = counter_stack(rng, 1, 1, t, 256)[0, 0]
        outs = []
        for name in _impls("sketch_moments"):
            base = np.asarray(ops.sketch_moments(a, b, impl=name))
            outs.append(base)
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    @given(st.integers(min_value=0, max_value=2**18))
    @settings(max_examples=3, deadline=None)
    def test_flash_attention_batch_permutation_equivariant(self, seed):
        rng = np.random.default_rng(seed)
        q, k, v = flash_case(rng, 3, 32, 1, 8)
        perm = rng.permutation(3)
        for name in _impls("flash_attention"):
            base = np.asarray(ops.flash_attention(
                q, k, v, block_q=16, block_k=16, impl=name))
            permed = np.asarray(ops.flash_attention(
                q[perm], k[perm], v[perm], block_q=16, block_k=16,
                impl=name))
            np.testing.assert_array_equal(base[perm], permed)


# ---------------------------------------------------------------------------
# repro.platform bootstrap
# ---------------------------------------------------------------------------

class TestPlatformBootstrap:
    def test_bootstrap_auto_reports_active_backend(self):
        from repro import platform as plat
        assert plat.bootstrap("auto") == jax.default_backend()
        assert plat.current() == jax.default_backend()

    def test_service_config_platform_auto(self):
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig())
        assert svc.platform == jax.default_backend()

    def test_subprocess_env_forces_host_devices(self):
        from repro import platform as plat
        env = plat.subprocess_env(4)
        assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
        assert "XLA_FLAGS" not in os.environ \
            or env["XLA_FLAGS"] != os.environ.get("XLA_FLAGS") \
            or "device_count=4" in os.environ.get("XLA_FLAGS", "")

    def test_xla_flag_append_is_idempotent(self):
        from repro import platform as plat
        env = {"XLA_FLAGS": "--foo=1"}
        plat.force_host_device_count(2, env)
        plat.force_host_device_count(2, env)
        assert env["XLA_FLAGS"].count("device_count=2") == 1
        assert env["XLA_FLAGS"].startswith("--foo=1")

    def test_gpu_flags_constant_covers_triton_fusion(self):
        from repro import platform as plat
        assert "--xla_gpu_enable_triton_softmax_fusion=true" \
            in plat.GPU_XLA_FLAGS
