"""Fused batched query engine conformance (DESIGN.md §12).

Three layers, each against an independent oracle:

  kernel     fused_query_pallas (interpret mode -- pure CPU) vs the int64
             numpy moment oracle and the jnp fallback, across depths
             {1, 3, 5}, non-square (t != w, multi-tile) widths, and
             empty / single-record sketches;
  estimator  sjpc.estimate_batch / estimate_join_batch vs per-stream
             sjpc.estimate / estimate_join loops (bit-equal here: every
             intermediate is an exact-integer f32);
  service    the batched Snapshot (use_fused_query=True, the default) vs
             the per-stream numpy reference path within 1e-6.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sjpc
from repro.core import sketch as sk
from repro.core.sjpc import SJPCConfig
from repro.kernels.fused_query import fused_query_pallas
from repro.kernels.ops import fused_query
from repro.service import EstimationService, QueryEngine, ServiceConfig

# shape/depth grids and builders shared with the registry conformance
# matrix (kernel_cases.py / test_kernel_registry.py)
from kernel_cases import (QUERY_DEPTHS, QUERY_SHAPES,
                          counter_stack as _counter_stack,
                          oracle_moments as _oracle_moments)


class TestKernelConformance:
    @pytest.mark.parametrize("depth", QUERY_DEPTHS)
    @pytest.mark.parametrize("N,L,w,block_w", QUERY_SHAPES)
    def test_moments_match_int64_oracle(self, depth, N, L, w, block_w):
        rng = np.random.default_rng(depth * 1000 + N * 100 + w)
        a = _counter_stack(rng, N, L, depth, w)
        b = _counter_stack(rng, N, L, depth, w)
        out = fused_query_pallas(a, b, block_w=block_w, interpret=True)
        assert out.shape == (N, L, depth)
        np.testing.assert_array_equal(np.asarray(out),
                                      _oracle_moments(a, b).astype(np.float64))

    @pytest.mark.parametrize("depth", QUERY_DEPTHS)
    def test_pallas_bit_identical_to_jnp_fallback(self, depth):
        rng = np.random.default_rng(77 + depth)
        a = _counter_stack(rng, 4, 3, depth, 256)
        pal = fused_query_pallas(a, a, block_w=64, interpret=True)
        ref = fused_query(a, a, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))

    def test_self_case_is_f2(self):
        rng = np.random.default_rng(3)
        a = _counter_stack(rng, 2, 3, 3, 128)
        out = fused_query_pallas(a, a, interpret=True)
        f2 = (np.asarray(a, np.int64) ** 2).sum(axis=-1)
        np.testing.assert_array_equal(np.asarray(out), f2.astype(np.float64))

    def test_empty_sketch_gives_zero_moments(self):
        a = jnp.zeros((2, 3, 3, 128), jnp.int32)
        out = fused_query_pallas(a, a, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), 0.0)


class TestBatchEstimator:
    def _states(self, cfg, batches, seed0=0):
        params, s0 = sjpc.init(cfg)
        rng = np.random.default_rng(11)
        states = []
        for i, nb in enumerate(batches):
            st = s0
            for b in range(nb):
                vals = rng.integers(0, 5, size=(25, cfg.d)).astype(np.uint32)
                st = sjpc.update(cfg, params, st, vals,
                                 key=jax.random.PRNGKey(seed0 + 97 * i + b))
            states.append(st)
        return states

    @pytest.mark.parametrize("depth", QUERY_DEPTHS)
    def test_estimate_batch_matches_per_stream_reference(self, depth):
        cfg = SJPCConfig(d=5, s=3, ratio=0.5, width=256, depth=depth, seed=41)
        states = self._states(cfg, [0, 1, 3, 5])     # includes an EMPTY sketch
        be = sjpc.estimate_batch(
            cfg, jnp.stack([st.counters for st in states]),
            np.array([float(st.n) for st in states], np.float32))
        for i, st in enumerate(states):
            ref = sjpc.estimate(cfg, st)
            np.testing.assert_array_equal(be.y[i], ref.y)
            np.testing.assert_array_equal(be.x[i], ref.x)
            assert be.g[i, 0] == ref.g_s
            # every higher threshold agrees with the reference suffix sums
            for li in range(1, cfg.num_levels):
                assert be.g[i, li] == pytest.approx(
                    float(ref.x[li:].sum()) + ref.n, rel=1e-12, abs=1e-9)

    def test_single_record_sketch(self):
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=128, depth=3, seed=42)
        params, s0 = sjpc.init(cfg)
        st = sjpc.update(cfg, params, s0,
                         np.array([[1, 2, 3, 4]], np.uint32),
                         key=jax.random.PRNGKey(0))
        be = sjpc.estimate_batch(cfg, st.counters[None],
                                 np.array([1.0], np.float32))
        ref = sjpc.estimate(cfg, st)
        np.testing.assert_array_equal(be.x[0], ref.x)
        assert be.g[0, 0] == ref.g_s
        assert np.all(np.isfinite(be.stderr)) and np.all(be.stderr >= 0)

    def test_estimate_join_batch_matches_reference(self):
        cfg = SJPCConfig(d=5, s=3, ratio=0.5, width=256, depth=3, seed=43)
        states = self._states(cfg, [2, 3, 1, 4])
        pairs = [(0, 1), (2, 3), (0, 3)]
        bj = sjpc.estimate_join_batch(
            cfg,
            jnp.stack([states[a].counters for a, _ in pairs]),
            jnp.stack([states[b].counters for _, b in pairs]),
            np.array([float(states[a].n) for a, _ in pairs], np.float32),
            np.array([float(states[b].n) for _, b in pairs], np.float32))
        for i, (a, b) in enumerate(pairs):
            ref = sjpc.estimate_join(cfg, states[a], states[b])
            np.testing.assert_array_equal(bj.y[i], ref.y)
            np.testing.assert_array_equal(bj.x[i], ref.x)
            assert bj.g[i, 0] == ref.g_s

    def test_batch_bounds_match_scalar_theorems(self):
        cfg = SJPCConfig(d=5, s=3, ratio=0.5, width=256, depth=3, seed=44)
        states = self._states(cfg, [2, 4])
        be = sjpc.estimate_batch(
            cfg, jnp.stack([st.counters for st in states]),
            np.array([float(st.n) for st in states], np.float32))
        import math
        for i in range(2):
            for li, k in enumerate(range(cfg.s, cfg.d + 1)):
                g = be.g[i, li]
                if g <= 0:
                    assert be.stderr[i, li] == 0.0
                    continue
                off = math.sqrt(sjpc.offline_variance_bound(
                    cfg.d, k, cfg.ratio, g)) * g
                on = math.sqrt(sjpc.online_variance_bound(
                    cfg.d, k, cfg.ratio, cfg.width, be.n[i], g)) * g
                assert be.stderr_offline[i, li] == pytest.approx(off, rel=1e-12)
                assert be.stderr[i, li] == pytest.approx(on, rel=1e-12)


class TestSnapshotConformance:
    """The batched Snapshot (service default) == the per-stream reference
    path, every stream x threshold cell, within 1e-6."""

    def _service(self, use_fused_query):
        cfg = SJPCConfig(d=5, s=3, ratio=0.5, width=512, depth=3, seed=51)
        svc = EstimationService(ServiceConfig(batch_rows=64, window_epochs=3,
                                              use_fused_query=use_fused_query))
        svc.create_group("g", cfg)
        rng = np.random.default_rng(13)
        names = [f"t{i}" for i in range(5)]
        for nm in names:
            svc.create_stream(nm, "g")
        for ep in range(4):
            for j, nm in enumerate(names):
                if ep == 0 and j == 4:
                    continue                 # t4 starts empty in epoch 0
                svc.ingest(nm, rng.integers(0, 6, size=(30 + 11 * j, cfg.d))
                           .astype(np.uint32))
            svc.advance_epoch()
        return cfg, svc, names

    def test_batched_snapshot_matches_reference(self):
        cfg, svc, names = self._service(use_fused_query=True)
        ref_engine = QueryEngine(svc.registry, use_fused_query=False)
        snap, ref = svc.snapshot(), ref_engine.snapshot()
        for nm in names:
            for k in range(cfg.s, cfg.d + 1):
                a, b = snap.self_join(nm, k), ref.self_join(nm, k)
                assert a.estimate == pytest.approx(b.estimate, rel=1e-6,
                                                   abs=1e-6)
                assert a.stderr == pytest.approx(b.stderr, rel=1e-6, abs=1e-6)
                assert a.stderr_offline == pytest.approx(b.stderr_offline,
                                                         rel=1e-6, abs=1e-6)
                np.testing.assert_allclose(a.per_level, b.per_level,
                                           rtol=1e-6, atol=1e-6)
                assert a.n == b.n and a.window_epochs == b.window_epochs
        for a_nm, b_nm in [(names[0], names[1]), (names[2], names[4])]:
            ja, jb = snap.join(a_nm, b_nm), ref.join(a_nm, b_nm)
            assert ja.estimate == pytest.approx(jb.estimate, rel=1e-6,
                                                abs=1e-6)
            assert ja.stderr == pytest.approx(jb.stderr, rel=1e-6, abs=1e-6)
            np.testing.assert_allclose(ja.per_level, jb.per_level,
                                       rtol=1e-6, atol=1e-6)

    def test_unclamped_queries_match_too(self):
        cfg, svc, names = self._service(use_fused_query=True)
        ref_engine = QueryEngine(svc.registry, use_fused_query=False)
        snap, ref = svc.snapshot(), ref_engine.snapshot()
        for nm in names[:2]:
            for k in (cfg.s, cfg.d):
                a = snap.self_join(nm, k, clamp=False)
                b = ref.self_join(nm, k, clamp=False)
                assert a.estimate == pytest.approx(b.estimate, rel=1e-6,
                                                   abs=1e-6)

    def test_all_thresholds_single_compiled_batch(self):
        """all_thresholds over every stream shares ONE cached batch entry
        (the one-compiled-call contract)."""
        _, svc, names = self._service(use_fused_query=True)
        snap = svc.snapshot()
        for nm in names:
            snap.all_thresholds(nm)
        self_entries = [k for k in snap._cache if k[0] == "self"]
        assert len(self_entries) == 1

    def test_poll_prefetches_joins_in_one_batch(self):
        from repro.service import ContinuousQuery
        _, svc, names = self._service(use_fused_query=True)
        svc.register_continuous(ContinuousQuery("j01", "join",
                                                (names[0], names[1])))
        svc.register_continuous(ContinuousQuery("j23", "join",
                                                (names[2], names[3])))
        svc.register_continuous(ContinuousQuery("sj", "self_join",
                                                (names[4],)))
        out = svc.poll()
        assert set(out) == {"j01", "j23", "sj"}
        ref = QueryEngine(svc.registry, use_fused_query=False).snapshot()
        assert out["j01"].estimate == pytest.approx(
            ref.join(names[0], names[1]).estimate, rel=1e-6, abs=1e-6)


class TestSketchMomentOracle:
    def test_np_estimate_inner_exact_matches_f2_on_self(self):
        rng = np.random.default_rng(9)
        c = rng.integers(-40, 40, size=(3, 4, 256)).astype(np.int32)
        np.testing.assert_array_equal(sk.np_estimate_inner_exact(c, c),
                                      sk.np_estimate_f2_exact(c))
