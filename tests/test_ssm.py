"""SSD (Mamba2) mixer: chunked scan vs naive recurrence oracle, chunk-size
invariance, decode-step equivalence, state passing."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked


def _naive_ssd(x, a, dt, bm, cm):
    """Reference recurrence: h_t = exp(a_t) h_{t-1} + dt_t B_t (x_t)."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    hg = h // g
    hstate = np.zeros((b, h, n, p), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    x = np.asarray(x, np.float64)
    a = np.asarray(a, np.float64)
    dt = np.asarray(dt, np.float64)
    bm = np.asarray(bm, np.float64)
    cm = np.asarray(cm, np.float64)
    for t in range(s):
        for hh in range(h):
            gg = hh // hg
            decay = np.exp(a[:, t, hh])[:, None, None]
            outer = (bm[:, t, gg, :, None] *
                     (dt[:, t, hh, None] * x[:, t, hh, :])[:, None, :])
            hstate[:, hh] = decay * hstate[:, hh] + outer
            ys[:, t, hh] = np.einsum("bn,bnp->bp", cm[:, t, gg], hstate[:, hh])
    return ys, hstate


def _rand(seed, b=2, s=16, h=4, p=8, g=2, n=6):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(b, s, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.1, 2.0, size=(b, s, h)).astype(np.float32)) * dt
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32))
    return x, a, dt, bm, cm


class TestSSD:
    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_matches_naive_recurrence(self, seed):
        x, a, dt, bm, cm = _rand(seed)
        y, hf = ssd_chunked(x, a, dt, bm, cm, chunk=4)
        y_ref, h_ref = _naive_ssd(x, a, dt, bm, cm)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("chunk", [2, 4, 8, 16])
    def test_chunk_size_invariance(self, chunk):
        x, a, dt, bm, cm = _rand(7)
        y_full, h_full = ssd_chunked(x, a, dt, bm, cm, chunk=16)
        y_c, h_c = ssd_chunked(x, a, dt, bm, cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_full),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_full),
                                   rtol=1e-4, atol=1e-4)

    def test_initial_state_continuation(self):
        """SSD over [first half] then [second half with carried state] ==
        SSD over the full sequence (prefill-chaining invariant)."""
        x, a, dt, bm, cm = _rand(9, s=16)
        y_full, h_full = ssd_chunked(x, a, dt, bm, cm, chunk=4)
        y1, h1 = ssd_chunked(x[:, :8], a[:, :8], dt[:, :8], bm[:, :8],
                             cm[:, :8], chunk=4)
        y2, h2 = ssd_chunked(x[:, 8:], a[:, 8:], dt[:, 8:], bm[:, 8:],
                             cm[:, 8:], chunk=4, h0=h1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                                   rtol=1e-4, atol=1e-4)

    def test_decay_bounds_state(self):
        """Strongly negative a -> state forgets; y depends only on recent x."""
        x, a, dt, bm, cm = _rand(11, s=12)
        a_strong = jnp.full_like(a, -50.0)
        y, _ = ssd_chunked(x, a_strong, dt, bm, cm, chunk=4)
        # contribution of x_0 to y_6 is exp(sum a_1..6) ~ e^-300 ~ 0
        x2 = x.at[:, 0].set(x[:, 0] * 100)
        y2, _ = ssd_chunked(x2, a_strong, dt, bm, cm, chunk=4)
        np.testing.assert_allclose(np.asarray(y[:, 6:]), np.asarray(y2[:, 6:]),
                                   rtol=1e-5, atol=1e-5)
