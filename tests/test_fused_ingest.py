"""Kernel conformance: the fused Pallas ingest (interpret mode, so it runs
in CPU CI) must be bit-exact vs the pure-jnp reference chain across
non-power-of-two batch remainders, depths, and width tiles -- and the whole
``update_fused`` entry must be bit-exact vs the reference ``sjpc.update``
for the same key (the contract the service's fast path rests on)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sjpc
from repro.core.projections import padded_lattice
from repro.core.sjpc import SJPCConfig
from repro.kernels import ops, ref
from repro.kernels.fused_ingest import fused_ingest_pallas

# batch/depth/tile grids and the padded-lattice input builder are shared
# with the registry conformance matrix (kernel_cases.py)
from kernel_cases import (INGEST_BATCHES, INGEST_DEPTHS, INGEST_TILES,
                          ingest_inputs as _inputs)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(777)


class TestFusedKernelConformance:
    @pytest.mark.parametrize("batch", INGEST_BATCHES)
    def test_batch_remainders(self, rng, batch):
        """Non-power-of-two batches exercise the zero-padded tail block."""
        cfg = SJPCConfig(d=5, s=3, width=256, depth=2, seed=3)
        _, _, args = _inputs(rng, cfg, batch)
        got = fused_ingest_pallas(*args, block_b=64, interpret=True)
        want = ref.fused_ingest_ref(*args)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("depth", INGEST_DEPTHS)
    def test_depths(self, rng, depth):
        cfg = SJPCConfig(d=4, s=2, width=256, depth=depth, seed=4)
        _, _, args = _inputs(rng, cfg, 50)
        got = fused_ingest_pallas(*args, interpret=True)
        want = ref.fused_ingest_ref(*args)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("block_b,block_w", INGEST_TILES)
    def test_width_tiles(self, rng, block_b, block_w):
        """Counters tiled along width: every tile accumulates independently
        and the global bucket id is recovered from the tile offset."""
        cfg = SJPCConfig(d=5, s=3, width=512, depth=3, seed=5)
        _, _, args = _inputs(rng, cfg, 70)
        got = fused_ingest_pallas(*args, block_b=block_b, block_w=block_w,
                                  interpret=True)
        want = ref.fused_ingest_ref(*args)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_padded_slots_contribute_nothing(self, rng):
        """Zero-weight padded combo slots must not touch the counters even
        though their fingerprints are computed."""
        cfg = SJPCConfig(d=4, s=2, width=256, depth=2, seed=6)
        _, pad, args = _inputs(rng, cfg, 20)
        assert pad.m_max > min(pad.nums)          # real padding exists
        weights = np.asarray(args[7])
        assert (weights * (1 - pad.valid[None])).sum() == 0
        got = fused_ingest_pallas(*args, interpret=True)
        # garbage in the padded table slots must change nothing
        scrambled_ids = np.array(pad.ids)
        scrambled_ids[pad.valid == 0] = 0xDEAD
        scrambled_masks = np.array(pad.masks)
        scrambled_masks[pad.valid == 0] = 1
        got2 = fused_ingest_pallas(args[0], args[1],
                                   jnp.asarray(scrambled_masks),
                                   jnp.asarray(scrambled_ids), *args[4:],
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))

    def test_non_pow2_width_tile_rejected(self, rng):
        """A width tile that cannot divide the width must fail loudly, not
        silently skip tail columns."""
        cfg = SJPCConfig(d=4, s=2, width=512, depth=2, seed=3)
        _, _, args = _inputs(rng, cfg, 16)
        with pytest.raises(AssertionError, match="power of two"):
            fused_ingest_pallas(*args, block_w=384, interpret=True)

    def test_ops_dispatch(self, rng):
        """ops.fused_ingest: reference on CPU by default, Pallas on demand."""
        cfg = SJPCConfig(d=4, s=3, width=256, depth=2, seed=7)
        _, _, args = _inputs(rng, cfg, 33)
        auto = ops.fused_ingest(*args)
        pallas = ops.fused_ingest(*args, use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(pallas))


class TestUpdateFusedConformance:
    """``sjpc.update_fused`` (both executions) == ``sjpc.update`` bit-exact
    for the same key -- this is what lets the service switch paths freely."""

    @pytest.mark.parametrize("ratio", [1.0, 0.5, 0.3])
    @pytest.mark.parametrize("batch", [1, 19, 64])
    def test_fused_jnp_matches_reference(self, rng, ratio, batch):
        cfg = SJPCConfig(d=5, s=3, ratio=ratio, width=512, depth=3, seed=8)
        params, s0 = sjpc.init(cfg)
        vals = rng.integers(0, 9, size=(batch, cfg.d)).astype(np.uint32)
        mask = (rng.random(batch) < 0.8).astype(np.int32)
        key = jax.random.PRNGKey(55)
        want = sjpc.update(cfg, params, s0, vals, key=key, row_mask=mask)
        got = sjpc.update_fused(cfg, params, s0, vals, key=key, row_mask=mask,
                                use_pallas=False)
        np.testing.assert_array_equal(np.asarray(got.counters),
                                      np.asarray(want.counters))
        assert float(got.n) == float(want.n)
        assert int(got.step) == int(want.step)

    def test_fused_pallas_matches_reference(self, rng):
        cfg = SJPCConfig(d=5, s=3, ratio=0.5, width=256, depth=3, seed=9)
        params, s0 = sjpc.init(cfg)
        vals = rng.integers(0, 9, size=(41, cfg.d)).astype(np.uint32)
        key = jax.random.PRNGKey(56)
        want = sjpc.update(cfg, params, s0, vals, key=key)
        got = sjpc.update_fused(cfg, params, s0, vals, key=key,
                                use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(got.counters),
                                      np.asarray(want.counters))

    def test_estimates_unchanged_by_path(self, rng):
        """End to end: the estimate from a fused-ingested sketch equals the
        reference path's estimate exactly."""
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=512, depth=3, seed=10)
        params, s_ref = sjpc.init(cfg)
        _, s_fus = sjpc.init(cfg)
        for i in range(3):
            vals = rng.integers(0, 6, size=(40, cfg.d)).astype(np.uint32)
            key = jax.random.PRNGKey(i)
            s_ref = sjpc.update(cfg, params, s_ref, vals, key=key)
            s_fus = sjpc.update_fused(cfg, params, s_fus, vals, key=key,
                                      use_pallas=False)
        e_ref = sjpc.estimate(cfg, s_ref)
        e_fus = sjpc.estimate(cfg, s_fus)
        assert e_ref.g_s == e_fus.g_s
        np.testing.assert_array_equal(e_ref.x, e_fus.x)


class TestShardedIngestExecutor:
    def test_sharded_equals_per_shard_replay(self, rng):
        """The executor's deferred merge == manual per-shard updates with
        the executor's own fold-in keys, merged once."""
        cfg = SJPCConfig(d=5, s=3, ratio=0.5, width=512, depth=3, seed=11)
        params, _ = sjpc.init(cfg)
        sh = sjpc.ShardedIngest(cfg, params, num_shards=2,
                                devices=jax.devices()[:1])
        batches = [rng.integers(0, 9, size=(33, cfg.d)).astype(np.uint32)
                   for _ in range(3)]
        for b in batches:
            sh.ingest(b)
        merged = sh.merged()

        acc = [sjpc.init(cfg)[1] for _ in range(2)]
        for m, b in enumerate(batches):
            pad = (-b.shape[0]) % 2
            vals = np.pad(b, ((0, pad), (0, 0)))
            mask = np.pad(np.ones(b.shape[0], np.int32), (0, pad))
            per = vals.shape[0] // 2
            for j in range(2):
                acc[j] = sjpc.update(cfg, params, acc[j],
                                     vals[j * per:(j + 1) * per],
                                     key=sh.shard_key(m, j),
                                     row_mask=mask[j * per:(j + 1) * per])
        want = sjpc.merge(acc[0], acc[1])
        np.testing.assert_array_equal(np.asarray(merged.counters),
                                      np.asarray(want.counters))
        assert float(merged.n) == float(want.n) == 99.0
        assert int(merged.step) == int(want.step) == 6

    def test_merge_deferral_counts(self, rng):
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=256, depth=2, seed=12)
        params, _ = sjpc.init(cfg)
        sh = sjpc.ShardedIngest(cfg, params, num_shards=4,
                                devices=jax.devices()[:1])
        for _ in range(5):
            sh.ingest(rng.integers(0, 6, size=(16, cfg.d)).astype(np.uint32))
        assert sh.micro_batches == 5 and sh.merges == 0
        merged = sh.merged()
        assert sh.merges == 1
        assert float(merged.n) == 80.0

    def test_ratio_one_sharding_invariant(self, rng):
        """ratio=1 has no sampling randomness, so any shard count yields the
        same counters as one unsharded update of the whole batch."""
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=256, depth=2, seed=13)
        params, s0 = sjpc.init(cfg)
        batch = rng.integers(0, 6, size=(48, cfg.d)).astype(np.uint32)
        plain = sjpc.update(cfg, params, s0, batch)
        for shards in (2, 4):
            sh = sjpc.ShardedIngest(cfg, params, num_shards=shards,
                                    devices=jax.devices()[:1])
            sh.ingest(batch)
            merged = sh.merged()
            np.testing.assert_array_equal(np.asarray(merged.counters),
                                          np.asarray(plain.counters))
