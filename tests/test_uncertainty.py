"""The uncertainty subsystem (DESIGN.md §14): calibrated error bars for
every estimator kind, and the sample-window backing-epoch refill.

Four contracts:

  * **Served bars are real**: ``QueryResult.stderr`` is nonzero for
    reservoir and LSH-SS streams (the PR 4 regression: both kinds
    hard-zeroed the column), and ``stderr_kind`` names the method.
  * **Calibration**: over seeded multi-trial runs the 95% interval
    covers the exact answer at >= the stated per-kind floor for ALL
    three kinds -- analytic bounds (SJPC) must cover near-always,
    bootstrap bars (reservoir, LSH-SS) at a finite-sample floor.
  * **Refill**: with backing epochs enabled a windowed reservoir's
    effective sample size after W expiries is >= 2x the no-refill
    baseline on the same seeded stream, and its error bar shrinks.
  * **Acceptance exactness**: ``reservoir_accept`` decides on integer
    ranks (the f32 product form loses exactness past 2^24 arrivals);
    pinned structurally and statistically at the boundary.

Everything is seeded; failures mean the estimators changed, not bad luck.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import estimators as E
from repro.core import exact, sjpc
from repro.core.sjpc import SJPCConfig
from repro.estimators import uncertainty
from repro.estimators.reservoir import reservoir_accept

CFG = SJPCConfig(d=5, s=3, ratio=1.0, width=128, depth=2, seed=31)


def ingest_rounds(est, state, vals, batch, *, key_seed=0):
    """Multi-round protocol ingest of one stream (rounds of ``batch``)."""
    vals = np.ascontiguousarray(np.asarray(vals, np.uint32))
    n, d = vals.shape
    rounds = -(-n // batch)
    pad = rounds * batch - n
    v = np.concatenate([vals, np.zeros((pad, d), np.uint32)])
    mask = np.concatenate([np.ones(n, np.int32), np.zeros(pad, np.int32)])
    v = v.reshape(rounds, 1, batch, d)
    mask = mask.reshape(rounds, 1, batch)
    base = jax.random.fold_in(jax.random.PRNGKey(est.ingest_seed), key_seed)
    keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.arange(rounds))[:, None]
    new = est.ingest_rounds(E.stack_states([state]), v, mask, keys)
    return E.index_state(new, 0)


# ---------------------------------------------------------------------------
# served bars
# ---------------------------------------------------------------------------

class TestServedStderr:
    def test_sample_kinds_serve_nonzero_stderr(self):
        """The headline regression: a served reservoir / LSH-SS stream
        reports a nonzero stderr with the right stderr_kind (PR 4 shipped
        hard-zeroed columns for both)."""
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig(batch_rows=64,
                                              window_epochs=None))
        svc.create_group("g", CFG)
        rng = np.random.default_rng(5)
        vals = rng.integers(0, 6, size=(400, CFG.d)).astype(np.uint32)
        # the builtin stories are pinned literally (the PR 4 regression);
        # other registered kinds (plugins imported elsewhere in the test
        # session) are held to the story their spec declares
        pinned = {"sjpc": "analytic", "reservoir": "bootstrap",
                  "lsh_ss": "bootstrap_stratified"}
        for kind in E.available():
            svc.create_stream(kind, "g", estimator=kind)
            svc.ingest(kind, vals)
        snap = svc.snapshot()
        for kind in E.available():
            expect = pinned.get(kind) or E.spec(kind).stderr_kind or "none"
            r = snap.self_join(kind)
            assert r.stderr_kind == expect, kind
            if expect == "none":
                assert r.stderr == 0, (kind, r)
                continue
            assert r.stderr > 0, (kind, r)
            lo, hi = r.ci()
            assert 0 <= lo <= r.estimate <= hi, (kind, r)

    def test_bootstrap_disabled_reports_none(self):
        est = E.ReservoirEstimator(
            E.ReservoirConfig(d=5, s=3, capacity=32, seed=1),
            bootstrap_replicates=0)
        st = ingest_rounds(est, est.init(sid=0),
                           np.random.default_rng(0).integers(
                               0, 5, size=(200, 5)).astype(np.uint32), 64)
        t = est.estimate_batch(E.stack_states([st]))
        assert t.stderr_kind == "none"
        assert np.all(t.stderr == 0)

    def test_stderr_deterministic_per_state(self):
        """Same state -> same error bar (snapshot/cache coherence)."""
        est = E.ReservoirEstimator(
            E.ReservoirConfig(d=5, s=3, capacity=48, seed=2))
        st = ingest_rounds(est, est.init(sid=0),
                           np.random.default_rng(1).integers(
                               0, 5, size=(300, 5)).astype(np.uint32), 64)
        a = est.estimate_batch(E.stack_states([st])).stderr
        b = est.estimate_batch(E.stack_states([st])).stderr
        np.testing.assert_array_equal(a, b)

    def test_serfling_factor_bounds(self):
        f = uncertainty.serfling_factor(np.array([100.0, 100.0, 1.0, 0.0]),
                                        np.array([10.0, 100.0, 1.0, 0.0]))
        assert f[0] == pytest.approx(np.sqrt(1 - 9 / 100))
        assert f[1] == pytest.approx(np.sqrt(1 - 99 / 100))
        assert np.all((0 <= f) & (f <= 1))


# ---------------------------------------------------------------------------
# calibration: the 95% interval covers the exact answer
# ---------------------------------------------------------------------------

def _coverage(kind, trials, *, seed=17):
    """Seeded multi-trial coverage of the 95% interval at s=3."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 6, size=(400, CFG.d)).astype(np.uint32)
    g_true = exact.exact_g(vals, CFG.s)
    covered = 0
    if kind == "sjpc":
        # SJPC's randomness is the hash/params draw: redraw per trial
        for t in range(trials):
            params, _ = sjpc.init(dataclasses.replace(CFG, seed=1000 + t))
            est = E.SJPCEstimator(CFG, params)
            st = ingest_rounds(est, est.init(), vals, 100, key_seed=t)
            tab = est.estimate_ref(st)
            covered += (abs(float(tab.g[0, 0]) - g_true)
                        <= 1.96 * float(tab.stderr[0, 0]))
    else:
        est = E.make(kind, CFG, estimator_cfg=(
            E.ReservoirConfig(d=CFG.d, s=CFG.s, capacity=48, seed=9)
            if kind == "reservoir" else
            E.LSHSSConfig(d=CFG.d, s=CFG.s, num_hash_cols=1,
                          num_buckets=64, record_capacity=64,
                          pair_capacity=96, seed=9)))
        for t in range(trials):
            order = np.random.default_rng(100 + t).permutation(400)
            st = ingest_rounds(est, est.init(sid=0), vals[order], 50,
                               key_seed=t)
            tab = est.estimate_batch(E.stack_states([st]))
            covered += (abs(float(tab.g[0, 0]) - g_true)
                        <= 1.96 * float(tab.stderr[0, 0]))
    return covered / trials


class TestCalibration:
    """The acceptance contract: stated confidence floors per kind.  The
    analytic Theorem 1/2 bounds are conservative (floor 0.9); bootstrap
    bars are estimates, so their floor allows finite-sample slack (0.75
    at 24 trials is < 1e-3 likely under true 95% coverage)."""

    @pytest.mark.parametrize("kind,floor", [("sjpc", 0.9),
                                            ("reservoir", 0.75),
                                            ("lsh_ss", 0.75)])
    def test_interval_covers_exact_answer(self, kind, floor):
        trials = 16 if kind == "sjpc" else 24
        cov = _coverage(kind, trials)
        assert cov >= floor, (kind, cov)

    @pytest.mark.slow
    @pytest.mark.parametrize("kind,floor", [("sjpc", 0.95),
                                            ("reservoir", 0.85),
                                            ("lsh_ss", 0.82)])
    def test_interval_covers_exact_answer_slow(self, kind, floor):
        cov = _coverage(kind, 60, seed=23)
        assert cov >= floor, (kind, cov)


# ---------------------------------------------------------------------------
# backing-epoch refill
# ---------------------------------------------------------------------------

def _windowed_reservoir(backing, *, epochs=8, per_epoch=300, capacity=64):
    from repro.service import EstimationService, ServiceConfig
    svc = EstimationService(ServiceConfig(batch_rows=64, window_epochs=4))
    svc.create_group("g", CFG)
    svc.create_stream("w", "g", estimator="reservoir",
                      backing_epochs=backing,
                      estimator_cfg=E.ReservoirConfig(
                          d=CFG.d, s=CFG.s, capacity=capacity, seed=3))
    rng = np.random.default_rng(0)
    for _ in range(epochs):
        svc.ingest("w", rng.integers(0, 6, size=(per_epoch, CFG.d))
                   .astype(np.uint32))
        svc.advance_epoch()
    return svc


class TestBackingEpochRefill:
    def test_effective_sample_size_at_least_2x_no_refill(self):
        """The acceptance regression: after W expiries (8 rotations of a
        W=4 window) the refill window's effective sample size -- valid
        slots of the served total -- is >= 2x the no-refill baseline on
        the same seeded stream, and its bootstrap error bar is tighter."""
        base = _windowed_reservoir(0)
        refill = _windowed_reservoir(3)
        ess = {}
        stderr = {}
        for name, svc in (("base", base), ("refill", refill)):
            win = svc.registry.stream("w").window
            tags = np.asarray(win.total.tags)
            ess[name] = int((tags >= 0).sum())
            r = svc.snapshot().self_join("w")
            assert np.isfinite(r.estimate) and r.estimate >= 0
            stderr[name] = r.stderr
            assert win.n_live() == 900.0   # same live window both ways
        assert ess["base"] == 64           # fold compresses to capacity
        assert ess["refill"] >= 2 * ess["base"], ess
        assert stderr["refill"] < stderr["base"], stderr

    def test_refill_total_tags_are_live_epochs_only(self):
        """Refill must never resurrect expired data: the expanded total's
        tag set still equals the live epochs' sids exactly."""
        svc = _windowed_reservoir(3)
        win = svc.registry.stream("w").window
        tags = np.asarray(win.total.tags)
        # live epochs that retained data (the just-opened epoch 8 is empty)
        live_sids = {int(s.sid) for s in win._slots
                     if s is not None and int(s.n) > 0}
        assert set(tags[tags >= 0].tolist()) == live_sids
        # 8 rotations of a W=4 window: closed live epochs are 5..7
        assert live_sids == {5, 6, 7}

    def test_refill_memory_accounting(self):
        base = _windowed_reservoir(0).registry.stream("w").window
        refill = _windowed_reservoir(2).registry.stream("w").window
        extra = refill.memory_bytes() - base.memory_bytes()
        assert extra == 2 * (base.estimator.memory_bytes() // 2)

    def test_refill_rejects_linear_and_unbounded(self):
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig(window_epochs=4))
        svc.create_group("g", CFG)
        with pytest.raises(ValueError, match="linear"):
            svc.create_stream("s", "g", estimator="sjpc", backing_epochs=2)
        with pytest.raises(ValueError, match="bounded"):
            svc.create_stream("r", "g", estimator="reservoir",
                              window_epochs=None, backing_epochs=2)

    def test_config_default_applies_only_to_bounded_sample_windows(self):
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig(window_epochs=4,
                                              backing_epochs=2))
        svc.create_group("g", CFG)
        assert svc.create_stream("a", "g", estimator="reservoir") \
            .window.backing_epochs == 2
        assert svc.create_stream("b", "g", estimator="sjpc") \
            .window.backing_epochs == 0
        assert svc.create_stream("c", "g", estimator="reservoir",
                                 window_epochs=None) \
            .window.backing_epochs == 0

    def test_mixed_refill_cohort_batches_consistently(self):
        """Streams of one (group, kind) with different window geometry
        have different state shapes; the query engine must batch them in
        separate stacks and still answer both."""
        from repro.service import EstimationService, ServiceConfig
        svc = EstimationService(ServiceConfig(batch_rows=64,
                                              window_epochs=3))
        svc.create_group("g", CFG)
        svc.create_stream("plain", "g", estimator="reservoir")
        svc.create_stream("refill", "g", estimator="reservoir",
                          backing_epochs=2)
        rng = np.random.default_rng(2)
        for _ in range(4):
            vals = rng.integers(0, 6, size=(200, CFG.d)).astype(np.uint32)
            svc.ingest("plain", vals)
            svc.ingest("refill", vals)
            svc.advance_epoch()
        snap = svc.snapshot()
        for name in ("plain", "refill"):
            r = snap.self_join(name)
            assert np.isfinite(r.estimate) and r.stderr > 0, name


# ---------------------------------------------------------------------------
# acceptance-probability exactness (satellite: f32 drift past 2^24)
# ---------------------------------------------------------------------------

class TestAcceptanceExactness:
    def test_integer_rank_form_is_exact(self):
        """White-box pin of the precision-safe form: the accept decision
        must equal ``rank < capacity`` with rank an integer draw uniform
        on [0, gidx] -- recomputed here independently, including past the
        f32 boundary where the old ``u * (gidx+1)`` form collapses
        adjacent arrival indices."""
        cap = 1 << 20
        B = 256
        mask = np.ones(B, np.int32)
        for n0 in (0, 1000, (1 << 24) - 3, (1 << 24) + 5, (1 << 26) + 1):
            key = jax.random.PRNGKey(n0 & 0xFFFF)
            win, src, n_new = reservoir_accept(
                key, jnp.asarray(n0, jnp.int32), jnp.asarray(mask), cap)
            assert int(n_new) == n0 + B
            pos = np.arange(B)
            gidx = n0 + pos
            ku, ks = jax.random.split(key)
            rank = np.asarray(jax.random.randint(
                ku, (B,), 0, jnp.maximum(jnp.asarray(gidx) + 1, 1)))
            rand_slot = np.asarray(jax.random.randint(ks, (B,), 0, cap))
            accept = (gidx < cap) | (rank < cap)
            slot = np.where(gidx < cap, np.clip(gidx, 0, cap - 1), rand_slot)
            best = np.full(cap, -1, np.int64)
            for b in range(B):
                if accept[b]:
                    best[slot[b]] = max(best[slot[b]], b)
            win_ref = best >= 0
            np.testing.assert_array_equal(np.asarray(win), win_ref, err_msg=str(n0))
            got = np.asarray(src)[win_ref]
            np.testing.assert_array_equal(got, best[win_ref])

    def test_acceptance_rate_at_f32_boundary(self):
        """Statistical boundary regression: at arrival indices straddling
        2^24 the acceptance rate matches capacity/(g+1) within binomial
        noise (seeded)."""
        cap = 1 << 20
        B = 4096
        n0 = 1 << 24
        mask = jnp.ones((B,), jnp.int32)
        total = 0
        expect = 0.0
        gidx = n0 + np.arange(B)
        p = cap / (gidx + 1.0)
        keys = 40
        for k in range(keys):
            key = jax.random.PRNGKey(7000 + k)
            ku, _ = jax.random.split(key)
            rank = np.asarray(jax.random.randint(
                ku, (B,), 0, jnp.asarray(gidx) + 1))
            total += int((rank < cap).sum())
            expect += p.sum()
        sd = np.sqrt(expect * (1 - p.mean()))
        assert abs(total - expect) < 5 * sd, (total, expect, sd)
