"""SJPC end-to-end: exactness of the inversion, unbiasedness with sampling
and sketching, the paper's Table-1 example, join estimation, variance bounds.

These are the system's behavioural invariants; hypothesis drives the
property tests over random small tables where the O(n^2) oracle is cheap.
"""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import exact, sjpc
from repro.core.projections import sample_combo_weights, lattice


def _run_sjpc(vals, cfg, batch=None):
    params, state = sjpc.init(cfg)
    upd = jax.jit(lambda st, v: sjpc.update(cfg, params, st, v))
    batch = batch or len(vals)
    for i in range(0, len(vals), batch):
        chunk = vals[i:i + batch]
        if len(chunk) < batch:   # static shapes: pad the tail via two calls
            upd2 = jax.jit(lambda st, v: sjpc.update(cfg, params, st, v))
            state = upd2(state, jnp.asarray(chunk))
        else:
            state = upd(state, jnp.asarray(chunk))
    return state


class TestPaperExample:
    def test_table_1(self):
        """The running example: 4 rows, 3 cols, exactly 4 ordered 2-similar
        pairs and no 3-similar pairs (paper Table 1 / §3)."""
        tbl = np.array([[1, 10, 100],
                        [2, 20, 200],
                        [1, 10, 300],
                        [3, 20, 200]], dtype=np.uint32)
        x = exact.exact_pair_counts(tbl)
        assert x[3] == 0 and x[2] == 4 and x[1] == 0
        # g_2 = 4 + n = 8 ; the self-join sizes of Table 2: level 2 = 16
        y = exact.exact_level_join_sizes(tbl)
        assert y[2] == 16 and y[3] == 4
        assert exact.exact_g(tbl, 2) == 8.0


class TestExactOracles:
    @given(st.integers(0, 10_000), st.integers(2, 40), st.integers(2, 5),
           st.integers(2, 4))
    @settings(max_examples=40, deadline=None)
    def test_lattice_inversion_equals_brute_force(self, seed, n, d, card):
        rng = np.random.default_rng(seed)
        vals = rng.integers(0, card, size=(n, d)).astype(np.uint32)
        np.testing.assert_allclose(exact.exact_pair_counts(vals),
                                   exact.brute_force_pair_counts(vals))


class TestOfflineExactness:
    """r=1 and exact (numpy int64) F2 => the inversion is *exact* (Lemma 3)."""

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_r1_widesketch_close(self, seed):
        rng = np.random.default_rng(seed)
        vals = rng.integers(0, 4, size=(60, 4)).astype(np.uint32)
        cfg = sjpc.SJPCConfig(d=4, s=2, ratio=1.0, width=8192, depth=5,
                              seed=seed ^ 0xABC)
        state = _run_sjpc(vals, cfg)
        est = sjpc.estimate(cfg, state)
        true_g = exact.exact_g(vals, 2)
        # tiny stream + wide sketch: collisions are rare; near-exact
        assert abs(est.g_s - true_g) / true_g < 0.05

    def test_inversion_is_exact_given_exact_y(self):
        rng = np.random.default_rng(123)
        vals = rng.integers(0, 5, size=(300, 5)).astype(np.uint32)
        y = exact.exact_level_join_sizes(vals)          # r = 1 exact Y_k
        x_true = exact.exact_pair_counts(vals)
        for s in range(1, 6):
            x = sjpc.f2_to_pair_count(5, s, 300, 1.0, y[s:], clamp=False)
            np.testing.assert_allclose(x, x_true[s:], rtol=1e-12)


class TestUnbiasedness:
    def test_sampled_estimator_unbiased(self):
        """Eq. 4 inversion with r<1: mean over seeds within a few percent
        (would be ~+25% biased under the Algorithm-1 line-34 erratum)."""
        rng = np.random.default_rng(42)
        vals = rng.integers(0, 6, size=(400, 5)).astype(np.uint32)
        true_g = exact.exact_g(vals, 3)
        ests = []
        for seed in range(12):
            cfg = sjpc.SJPCConfig(d=5, s=3, ratio=0.5, width=4096, depth=5,
                                  seed=seed)
            est = sjpc.estimate(cfg, _run_sjpc(vals, cfg))
            ests.append(est.g_s)
        rel_bias = abs(np.mean(ests) - true_g) / true_g
        assert rel_bias < 0.08, (np.mean(ests), true_g)

    def test_error_within_theorem1_bound(self):
        rng = np.random.default_rng(7)
        vals = rng.integers(0, 6, size=(400, 5)).astype(np.uint32)
        true_g = exact.exact_g(vals, 3)
        bound_std = math.sqrt(sjpc.offline_variance_bound(5, 3, 0.5, true_g))
        ests = []
        for seed in range(12):
            cfg = sjpc.SJPCConfig(d=5, s=3, ratio=0.5, width=8192, depth=5,
                                  seed=1000 + seed)
            ests.append(sjpc.estimate(cfg, _run_sjpc(vals, cfg)).g_s)
        rel_std = np.std(ests) / true_g
        assert rel_std < bound_std, (rel_std, bound_std)


class TestStreamingInvariants:
    def test_batch_split_invariance(self):
        """One-pass semantics: the sketch state is identical however the
        stream is batched (given the same per-batch RNG stream)."""
        rng = np.random.default_rng(3)
        vals = rng.integers(0, 5, size=(128, 4)).astype(np.uint32)
        cfg = sjpc.SJPCConfig(d=4, s=2, ratio=1.0, width=512, depth=3, seed=5)
        params, s_all = sjpc.init(cfg)
        s_all = sjpc.update(cfg, params, s_all, jnp.asarray(vals))
        _, s_split = sjpc.init(cfg)
        # ratio=1 -> no sampling randomness -> merging must be exact
        s_a = sjpc.update(cfg, params, sjpc.init(cfg)[1], jnp.asarray(vals[:64]))
        s_b = sjpc.update(cfg, params, sjpc.init(cfg)[1], jnp.asarray(vals[64:]))
        merged = sjpc.merge(s_a, s_b)
        np.testing.assert_array_equal(np.asarray(s_all.counters),
                                      np.asarray(merged.counters))
        assert float(merged.n) == 128.0

    def test_counts_records(self):
        cfg = sjpc.SJPCConfig(d=3, s=2, ratio=1.0, width=256, depth=2)
        params, state = sjpc.init(cfg)
        state = sjpc.update(cfg, params, state, jnp.zeros((32, 3), jnp.uint32))
        state = sjpc.update(cfg, params, state, jnp.zeros((16, 3), jnp.uint32))
        assert float(state.n) == 48.0


class TestSampling:
    @given(st.integers(0, 1000), st.floats(0.2, 1.0), st.integers(2, 30))
    @settings(max_examples=30, deadline=None)
    def test_sample_weights_row_counts(self, seed, ratio, m):
        key = jax.random.PRNGKey(seed)
        w = np.asarray(sample_combo_weights(key, 64, m, ratio))
        assert w.shape == (64, m)
        lo = math.floor(m * ratio + 1e-9)
        counts = w.sum(axis=1)
        assert ((counts == lo) | (counts == min(lo + 1, m))).all()

    def test_inclusion_probability_uniform(self):
        """Each combination is included with probability ~r (Lemma 4's
        premise)."""
        key = jax.random.PRNGKey(0)
        w = np.asarray(sample_combo_weights(key, 20_000, 10, 0.35))
        incl = w.mean(axis=0)
        np.testing.assert_allclose(incl, 0.35, atol=0.02)

    def test_lattice_levels(self):
        lv = lattice(5, 2)
        assert [l.k for l in lv] == [2, 3, 4, 5]
        assert [l.num for l in lv] == [10, 10, 5, 1]
        # ids are globally unique bitmasks
        ids = np.concatenate([l.ids for l in lv])
        assert len(np.unique(ids)) == len(ids)


class TestJoinEstimation:
    def test_join_size_two_streams(self):
        rng = np.random.default_rng(21)
        a = rng.integers(0, 5, size=(300, 4)).astype(np.uint32)
        b = rng.integers(0, 5, size=(250, 4)).astype(np.uint32)
        true_j = exact.exact_join_g(a, b, 3)
        ests = []
        for seed in range(8):
            cfg = sjpc.SJPCConfig(d=4, s=3, ratio=1.0, width=4096, depth=5,
                                  seed=seed)
            params, sa = sjpc.init(cfg)
            sb = sjpc.SJPCState(sa.counters, sa.n, sa.step)
            sa = sjpc.update(cfg, params, sa, jnp.asarray(a))
            sb = sjpc.update(cfg, params, sb, jnp.asarray(b))
            ests.append(sjpc.estimate_join(cfg, sa, sb).g_s)
        assert abs(np.median(ests) - true_j) / max(true_j, 1) < 0.25

    def test_counterexample_selfjoin_bound_does_not_hold(self):
        """Paper §6: |A sim-join B| can exceed (SJ(A)+SJ(B))/2 -- the
        Alon et al. bound fails for similarity joins."""
        a = np.array([[1, 2, 3, 4]], dtype=np.uint32)
        b = np.array([[1, 2, 30, 40], [10, 20, 3, 4]], dtype=np.uint32)
        join_size = exact.exact_join_g(a, b, 2)
        sj_a = exact.exact_g(a, 2)    # 1 (self-pair only)
        sj_b = exact.exact_g(b, 2)    # 2
        assert join_size == 2
        assert join_size > (sj_a + sj_b) / 2 - 1e-9


class TestVarianceBounds:
    def test_bounds_monotone_in_gap(self):
        b1 = sjpc.offline_variance_bound(6, 5, 0.5, 1000)
        b2 = sjpc.offline_variance_bound(6, 3, 0.5, 1000)
        assert b2 > b1

    def test_online_adds_sketch_term(self):
        off = sjpc.offline_variance_bound(6, 4, 0.5, 1000)
        on = sjpc.online_variance_bound(6, 4, 0.5, 1024, 500, 1000)
        assert on > off
