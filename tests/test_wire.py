"""Property tests for the sketch-delta wire format (DESIGN.md §18.2).

The distributed service's correctness rests on three wire properties:

* **Bit-exact round-trip**: ``decode(encode(x))`` reproduces every leaf of
  every estimator kind's state byte-for-byte (dtype, shape, values) -- the
  replica merge algebra tolerates no drift.
* **Merge transparency**: merging a deserialized state equals merging the
  live state -- serialization must be invisible to the window algebra.
* **Version safety**: a payload from a different wire version is rejected
  whole (``WireVersionError`` naming both versions), never half-parsed.

Runs under the conftest hypothesis stub (tier-1) or real hypothesis (the
CI property job): only ``integers``/``sampled_from`` strategies.
"""
from __future__ import annotations

import struct

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.tree_util as jtu

from repro import estimators as E
from repro.core.sjpc import SJPCConfig
from repro.distributed import wire

CFG = SJPCConfig(d=5, s=3, ratio=0.5, width=64, depth=2, seed=7)
# Registry-driven: every registered kind (plugin kinds included, once
# their module is imported anywhere in the test session) must round-trip.
KINDS = tuple(E.available())
ESTS = {kind: E.make(kind, CFG) for kind in KINDS}


def _estimator(kind):
    return ESTS[kind]


def _ingest_round(est, state, seed, rows=32):
    """One protocol-path ingest round for a single stream (the
    test_estimators.py idiom)."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 50, size=(rows, CFG.d), dtype=np.uint32)
    keys = jax.random.fold_in(
        jax.random.PRNGKey(est.ingest_seed), seed)[None, None]
    new = est.ingest_rounds(E.stack_states([state]), vals[None, None],
                            np.ones((1, 1, rows), np.int32), keys)
    return E.index_state(new, 0)


def _ingested_state(kind, seed, rows=32):
    return _ingest_round(ESTS[kind], ESTS[kind].init(sid=0), seed, rows)


def _assert_leaves_bitexact(a, b):
    for name, la, lb in zip(a._fields, a, b):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype, name
        assert la.shape == lb.shape, name
        assert np.array_equal(la, lb, equal_nan=True), name


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(KINDS), st.integers(min_value=0, max_value=1000))
def test_roundtrip_bitexact(kind, seed):
    state = _ingested_state(kind, seed)
    msg = wire.DeltaMessage(kind=kind, stream=f"t-{seed}", epoch=seed % 7,
                            window_version=seed, mode=wire.MODE_REPLACE,
                            state=state)
    back = wire.decode_message(wire.encode_delta(msg))
    assert back.kind == kind and back.stream == f"t-{seed}"
    assert back.epoch == seed % 7 and back.window_version == seed
    assert type(back.state) is type(state)          # real class: pytree-safe
    _assert_leaves_bitexact(state, back.state)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=500),
       st.integers(min_value=0, max_value=500))
def test_merge_of_deserialized_equals_merge_of_live(sa, sb):
    """Serialization must be invisible to the merge algebra (every kind)."""
    for kind in KINDS:
        est = _estimator(kind)
        a = _ingested_state(kind, sa)
        b = _ingested_state(kind, 1000 + sb)
        rt = lambda s: wire.decode_message(wire.encode_delta(
            wire.DeltaMessage(kind=kind, stream="x", epoch=0,
                              window_version=0, mode=wire.MODE_MERGE,
                              state=s))).state
        live = est.merge(a, b)
        wired = est.merge(rt(a), rt(b))
        _assert_leaves_bitexact(jtu.tree_map(np.asarray, live),
                                jtu.tree_map(np.asarray, wired))


def test_roundtrip_backing_epoch_sample_window():
    """The ship-the-open-slot path for a backing-epoch sample window: the
    slot state round-trips bit-exact and installs on a mirror window."""
    from repro.service.window import WindowedSketch
    est = _estimator("reservoir")
    w = WindowedSketch(est, est.init(sid=0), 3, backing_epochs=2)
    for seed in range(2):
        w.absorb_delta(_ingest_round(est, w.ingest_base(), seed))
        w.advance_epoch()
    # rotation re-arms the export baseline: new open-epoch data exports
    w.absorb_delta(_ingest_round(est, w.ingest_base(), 99))
    mode, state = w.export_delta()
    assert mode == "replace"
    back = wire.decode_message(wire.encode_delta(wire.DeltaMessage(
        kind="reservoir", stream="t", epoch=w.epoch,
        window_version=w.version, mode=wire.MODE_REPLACE, state=state)))
    _assert_leaves_bitexact(jtu.tree_map(np.asarray, state), back.state)
    mirror = WindowedSketch(est, est.init(sid=0), 3, backing_epochs=2)
    mirror.absorb_delta(back.state)
    _assert_leaves_bitexact(jtu.tree_map(np.asarray, mirror.ingest_base()),
                            jtu.tree_map(np.asarray, state))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=65535))
def test_version_mismatch_rejected(version):
    """Any wire version but ours is refused with both versions named --
    BEFORE any state bytes are touched."""
    state = _ingested_state("sjpc", 0)
    payload = wire.encode_delta(wire.DeltaMessage(
        kind="sjpc", stream="t", epoch=0, window_version=0,
        mode=wire.MODE_MERGE, state=state))
    forged = payload[:4] + struct.pack("<H", version) + payload[6:]
    if version == wire.WIRE_VERSION:
        wire.decode_message(forged)          # our version: parses fine
        return
    with pytest.raises(wire.WireVersionError) as ei:
        wire.decode_message(forged)
    assert str(version) in str(ei.value)
    assert str(wire.WIRE_VERSION) in str(ei.value)


def test_heartbeat_is_zero_bytes_and_versionless():
    assert wire.encode_heartbeat() == b""
    assert wire.decode_message(b"") is wire.HEARTBEAT
    assert wire.decode_bundle(b"") is wire.HEARTBEAT


def test_bundle_roundtrip_and_truncation():
    msgs = [wire.encode_delta(wire.DeltaMessage(
        kind="sjpc", stream=f"t{i}", epoch=i, window_version=i,
        mode=wire.MODE_MERGE, state=_ingested_state("sjpc", i)))
        for i in range(3)]
    bundle = wire.encode_bundle(msgs)
    back = wire.decode_bundle(bundle)
    assert [m.stream for m in back] == ["t0", "t1", "t2"]
    with pytest.raises(wire.WireFormatError):
        wire.decode_bundle(bundle[:-3])
    with pytest.raises(wire.WireFormatError):
        wire.decode_message(b"XXXX" + bundle[4:40])


def test_field_order_and_count_are_checked():
    state = _ingested_state("sjpc", 0)
    payload = wire.encode_delta(wire.DeltaMessage(
        kind="sjpc", stream="t", epoch=0, window_version=0,
        mode=wire.MODE_MERGE, state=state))
    # flip the field-count byte: kind(B+4)... locate via a reparse offset
    # is brittle; instead corrupt the first leaf's name length so the
    # field-name check trips
    idx = payload.index(b"counters")
    bad = payload[:idx] + b"cowriter" + payload[idx + 8:]
    with pytest.raises(wire.WireFormatError):
        wire.decode_message(bad)


def test_register_state_type_conflicts():
    class Fake:
        _fields = ("x",)
    wire.register_state_type("_test_kind", Fake)
    wire.register_state_type("_test_kind", Fake)        # idempotent
    class Other:
        _fields = ("x",)
    with pytest.raises(ValueError):
        wire.register_state_type("_test_kind", Other)
    assert wire.state_type("_test_kind") is Fake
    with pytest.raises(KeyError):
        wire.state_type("_no_such_kind")
