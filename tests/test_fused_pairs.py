"""Conformance for the fused all-pairs similarity-histogram kernel
(kernels/fused_pairs.py) -- the reservoir estimator's query hot path.

Three-way agreement, required bit-exact (all paths count in exact integer
arithmetic):

  numpy oracle (core.exact.brute_force_pair_counts per valid sample)
    == jnp fallback (kernels.ref.fused_pairs_ref)
    == Pallas kernel (interpret mode on this CPU container)

across depths d, sample sizes R (tile remainders included), batch sizes
N, tile shapes, empty inputs, all-invalid masks, and duplicate-heavy data
(the diagonal/self-pair masking case).
"""
import numpy as np
import pytest

from repro.core import exact
from repro.kernels import ref
from repro.kernels.fused_pairs import fused_pairs_pallas
from repro.kernels.ops import fused_pairs

# the shape grid and input builder live in kernel_cases.py, shared with the
# registry conformance matrix (test_kernel_registry.py)
from kernel_cases import PAIRS_BLOCKS, PAIRS_SHAPES, pairs_case as _case


def _oracle(items, valid):
    out = []
    for i in range(items.shape[0]):
        sub = items[i][valid[i] != 0]
        out.append(exact.brute_force_pair_counts(sub) if sub.shape[0]
                   else np.zeros(items.shape[2] + 1))
    return np.stack(out).astype(np.int64)


class TestConformance:
    @pytest.mark.parametrize("N,R,d", PAIRS_SHAPES)
    def test_ref_and_pallas_match_oracle(self, N, R, d):
        rng = np.random.default_rng(N * 1000 + R * 10 + d)
        items, valid = _case(rng, N, R, d)
        want = _oracle(items, valid)
        got_ref = np.asarray(fused_pairs(items, valid, use_pallas=False))
        got_pal = np.asarray(fused_pairs(items, valid, use_pallas=True,
                                         interpret=True))
        np.testing.assert_array_equal(got_ref, want)
        np.testing.assert_array_equal(got_pal, want)

    @pytest.mark.parametrize("block_r", PAIRS_BLOCKS)
    def test_tile_shape_irrelevant(self, block_r):
        rng = np.random.default_rng(3)
        items, valid = _case(rng, 2, 100, 5)
        want = np.asarray(ref.fused_pairs_ref(items, valid))
        got = np.asarray(fused_pairs_pallas(items, valid, block_r=block_r,
                                            interpret=True))
        np.testing.assert_array_equal(got, want)

    def test_duplicate_heavy_diagonal_masked(self):
        """All-identical records: every ordered pair is d-similar and the
        R self-pairs are excluded -- the diagonal masking contract."""
        R, d = 50, 4
        items = np.ones((1, R, d), np.uint32) * 7
        valid = np.ones((1, R), np.int32)
        for use_pallas in (False, True):
            got = np.asarray(fused_pairs(items, valid, use_pallas=use_pallas,
                                         interpret=True))
            want = np.zeros(d + 1, np.int64)
            want[d] = R * (R - 1)
            np.testing.assert_array_equal(got[0], want)

    def test_empty_and_all_invalid(self):
        zero4 = np.zeros(5, np.int64)
        # R = 0: no slots at all
        got = np.asarray(fused_pairs(np.zeros((2, 0, 4), np.uint32),
                                     np.zeros((2, 0), np.int32)))
        assert got.shape == (2, 5) and not got.any()
        # all slots invalid
        rng = np.random.default_rng(5)
        items, _ = _case(rng, 2, 40, 4)
        none = np.zeros((2, 40), np.int32)
        for use_pallas in (False, True):
            got = np.asarray(fused_pairs(items, none, use_pallas=use_pallas,
                                         interpret=True))
            np.testing.assert_array_equal(got, np.stack([zero4, zero4]))

    def test_single_valid_record(self):
        items = np.arange(12, dtype=np.uint32).reshape(1, 3, 4)
        valid = np.array([[0, 1, 0]], np.int32)
        for use_pallas in (False, True):
            got = np.asarray(fused_pairs(items, valid, use_pallas=use_pallas,
                                         interpret=True))
            assert not got.any()
