"""Estimation service: batched multi-stream ingest == per-stream updates,
sliding-window expiry is bit-exact, windowed queries match offline
estimates, error bars are reported, and the training driver publishes
through the service client.  (DESIGN.md §10 invariants.)"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig
from repro.service import (ContinuousQuery, EstimationService, ServiceConfig,
                           MonitorServiceClient, ingest_key)
from repro.service.ingest import multi_stream_update


def _records(rng, n, d, card=6):
    return rng.integers(0, card, size=(n, d)).astype(np.uint32)


class TestMergeSemantics:
    def test_merge_sums_steps(self):
        """Post-merge updates must fold in a step no shard already used;
        the sum dominates both shards' consumed ranges (maximum does not)."""
        cfg = SJPCConfig(d=3, s=2, ratio=0.5, width=256, depth=2)
        params, sa = sjpc.init(cfg)
        _, sb = sjpc.init(cfg)
        rng = np.random.default_rng(0)
        for _ in range(3):
            sa = sjpc.update(cfg, params, sa, _records(rng, 8, 3))
            sb = sjpc.update(cfg, params, sb, _records(rng, 8, 3))
        merged = sjpc.merge(sa, sb)
        assert int(merged.step) == 6
        assert float(merged.n) == 48.0

    def test_subtract_removes_substream(self):
        cfg = SJPCConfig(d=3, s=2, ratio=1.0, width=256, depth=2)
        params, s0 = sjpc.init(cfg)
        rng = np.random.default_rng(1)
        a, b = _records(rng, 16, 3), _records(rng, 8, 3)
        sa = sjpc.update(cfg, params, s0, a)
        sab = sjpc.update(cfg, params, sa, b)
        back = sjpc.subtract(sab, sjpc.subtract(sab, sa))
        np.testing.assert_array_equal(np.asarray(back.counters),
                                      np.asarray(sa.counters))
        assert float(back.n) == 16.0


class TestMultiStreamUpdate:
    """Acceptance: the batched update produces counters identical to
    per-stream ``sjpc.update`` loops."""

    def test_row_mask_padding_matches_unpadded(self):
        """ratio=1 (no sampling randomness): a padded+masked update equals
        the unpadded update bit-exactly."""
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=512, depth=2, seed=9)
        params, s0 = sjpc.init(cfg)
        rng = np.random.default_rng(2)
        vals = _records(rng, 20, 4)
        plain = sjpc.update(cfg, params, s0, jnp.asarray(vals))
        padded = np.zeros((32, 4), np.uint32)
        padded[:20] = vals
        mask = np.zeros((32,), np.int32)
        mask[:20] = 1
        masked = sjpc.update(cfg, params, s0, jnp.asarray(padded),
                             row_mask=jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(plain.counters),
                                      np.asarray(masked.counters))
        assert float(masked.n) == 20.0

    def test_batched_equals_per_stream_loop(self):
        """ratio<1: one vmapped dispatch == S separate sjpc.update calls
        given the same keys and masks."""
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=512, depth=2, seed=5)
        params, s0 = sjpc.init(cfg)
        rng = np.random.default_rng(3)
        S, B = 3, 16
        values = np.stack([_records(rng, B, 4) for _ in range(S)])
        mask = (rng.random((S, B)) < 0.8).astype(np.int32)
        keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(S)])

        counters = jnp.stack([s0.counters] * S)
        n = jnp.stack([s0.n] * S)
        steps = jnp.stack([s0.step] * S)
        bc, bn, bs = multi_stream_update(cfg, params, counters, n, steps,
                                         jnp.asarray(values),
                                         jnp.asarray(mask), keys)
        for i in range(S):
            ref = sjpc.update(cfg, params, s0, jnp.asarray(values[i]),
                              key=keys[i], row_mask=jnp.asarray(mask[i]))
            np.testing.assert_array_equal(np.asarray(bc[i]),
                                          np.asarray(ref.counters))
            assert float(bn[i]) == float(ref.n)

    def test_pipeline_flush_equals_manual_replay(self):
        """Through the full service path: coalescing, padding, key
        derivation -- replayed per-stream with ingest_key -> identical."""
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=512, depth=2, seed=17)
        svc = EstimationService(ServiceConfig(batch_rows=32,
                                              window_epochs=None))
        svc.create_group("g", cfg)
        rng = np.random.default_rng(4)
        sizes = {"a": 50, "b": 20, "c": 0}
        data = {}
        for name, sz in sizes.items():
            svc.create_stream(name, "g")
            data[name] = _records(rng, sz, 4)
            if sz:
                svc.ingest(name, data[name])
        svc.flush()
        group = svc.registry.group("g")
        for name in sizes:
            entry = svc.registry.stream(name)
            _, ref = sjpc.init(cfg)
            rows = data[name]
            for r in range((rows.shape[0] + 31) // 32):
                chunk = rows[r * 32:(r + 1) * 32]
                padded = np.zeros((32, 4), np.uint32)
                padded[:chunk.shape[0]] = chunk
                mask = np.zeros((32,), np.int32)
                mask[:chunk.shape[0]] = 1
                ref = sjpc.update(cfg, group.params, ref, jnp.asarray(padded),
                                  key=ingest_key(cfg, entry.uid, r),
                                  row_mask=jnp.asarray(mask))
            np.testing.assert_array_equal(
                np.asarray(entry.window.total.counters),
                np.asarray(ref.counters), err_msg=name)
            assert float(entry.window.total.n) == float(sizes[name])


class TestFusedServicePaths:
    """The rewired pipeline: fused default == reference oracle bit-exactly,
    and the sharded flush == per-shard replay with the pipeline's keys."""

    def _ingest_all(self, sc, cfg, recs):
        svc = EstimationService(sc)
        svc.create_group("g", cfg)
        for nm, rows in recs.items():
            svc.create_stream(nm, "g")
            svc.ingest(nm, rows)
        svc.flush()
        return svc

    def test_fused_flush_equals_oracle_flush(self):
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=512, depth=2, seed=41)
        rng = np.random.default_rng(9)
        recs = {"a": _records(rng, 50, 4), "b": _records(rng, 20, 4)}
        fused = self._ingest_all(
            ServiceConfig(batch_rows=32, window_epochs=None), cfg, recs)
        oracle = self._ingest_all(
            ServiceConfig(batch_rows=32, window_epochs=None, use_fused=False),
            cfg, recs)
        for nm in recs:
            np.testing.assert_array_equal(
                np.asarray(fused.registry.stream(nm).window.total.counters),
                np.asarray(oracle.registry.stream(nm).window.total.counters),
                err_msg=nm)

    def test_sharded_flush_equals_per_shard_replay(self):
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=512, depth=2, seed=43)
        rng = np.random.default_rng(10)
        rows = _records(rng, 50, 4)
        svc = self._ingest_all(
            ServiceConfig(batch_rows=32, window_epochs=None, shards=2),
            cfg, {"a": rows})
        entry = svc.registry.stream("a")
        params = svc.registry.group("g").params
        shard_states = [sjpc.init(cfg)[1] for _ in range(2)]
        for r in range(2):                       # 50 rows -> 2 rounds of 32
            chunk = rows[r * 32:(r + 1) * 32]
            padded = np.zeros((32, 4), np.uint32)
            padded[:chunk.shape[0]] = chunk
            mask = np.zeros((32,), np.int32)
            mask[:chunk.shape[0]] = 1
            rkey = ingest_key(cfg, entry.uid, r)
            for j in range(2):                   # shard j gets rows [16j, 16j+16)
                shard_states[j] = sjpc.update(
                    cfg, params, shard_states[j], padded[j * 16:(j + 1) * 16],
                    key=jax.random.fold_in(rkey, j),
                    row_mask=mask[j * 16:(j + 1) * 16])
        want = sjpc.merge(shard_states[0], shard_states[1])
        np.testing.assert_array_equal(
            np.asarray(entry.window.total.counters), np.asarray(want.counters))
        assert float(entry.window.total.n) == 50.0 == float(want.n)


def _run_epochs(svc, cfg, name, epoch_batches):
    for rows in epoch_batches:
        if rows.shape[0]:
            svc.ingest(name, rows)
        svc.advance_epoch()


def _replay_window(cfg, group, entry, epoch_batches, live_epoch_ids,
                   batch_rows, rounds_per_epoch=None):
    """Offline rebuild of exactly the live epochs with the pipeline's keys.

    The replay coordinate is the stream's OWN consumed-round count: each
    epoch (one flush here) advances it by ceil(rows / batch_rows), no
    matter how many extra rounds a busier cohort-mate forced the shared
    dispatch to run (those are fully masked for this stream and consume
    none of its randomness).  ``rounds_per_epoch``, when given, asserts
    the expected per-epoch round count (fixed-size epochs)."""
    _, st = sjpc.init(cfg)
    rounds_of = [-(-b.shape[0] // batch_rows) for b in epoch_batches]
    for ep in live_epoch_ids:
        rows = epoch_batches[ep]
        start = sum(rounds_of[:ep])
        if rounds_per_epoch is not None:
            assert rounds_of[ep] == rounds_per_epoch
        for r in range(rounds_of[ep]):
            chunk = rows[r * batch_rows:(r + 1) * batch_rows]
            padded = np.zeros((batch_rows, cfg.d), np.uint32)
            padded[:chunk.shape[0]] = chunk
            mask = np.zeros((batch_rows,), np.int32)
            mask[:chunk.shape[0]] = 1
            st = sjpc.update(cfg, group.params, st, jnp.asarray(padded),
                             key=ingest_key(cfg, entry.uid, start + r),
                             row_mask=jnp.asarray(mask))
    return st


class TestWindowExpiry:
    """Satellite: ring-buffer subtraction over k epochs must bit-exactly
    equal a fresh sketch built from only the live epochs."""

    @pytest.mark.parametrize("ratio", [1.0, 0.5])
    def test_expiry_bit_exact_vs_fresh_sketch(self, ratio):
        cfg = SJPCConfig(d=4, s=2, ratio=ratio, width=512, depth=2, seed=23)
        svc = EstimationService(ServiceConfig(batch_rows=32, window_epochs=3))
        svc.create_group("g", cfg)
        entry = svc.create_stream("a", "g")
        group = svc.registry.group("g")
        rng = np.random.default_rng(5)
        epoch_batches = [_records(rng, 40, 4) for _ in range(6)]
        _run_epochs(svc, cfg, "a", epoch_batches)

        # live: epochs 4, 5 (+ empty open epoch); each epoch = 2 rounds of 32
        fresh = _replay_window(cfg, group, entry, epoch_batches, [4, 5],
                               batch_rows=32, rounds_per_epoch=2)
        win = entry.window.window_state()
        np.testing.assert_array_equal(np.asarray(win.counters),
                                      np.asarray(fresh.counters))
        assert float(win.n) == 80.0 == float(fresh.n)

    def test_ring_sum_invariant(self):
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=256, depth=2, seed=29)
        svc = EstimationService(ServiceConfig(batch_rows=16, window_epochs=4))
        svc.create_group("g", cfg)
        entry = svc.create_stream("a", "g")
        rng = np.random.default_rng(6)
        for _ in range(9):
            svc.ingest("a", _records(rng, rng.integers(1, 30), 4))
            svc.advance_epoch()
        rs = entry.window.ring_sum()
        np.testing.assert_array_equal(np.asarray(rs.counters),
                                      np.asarray(entry.window.total.counters))
        assert float(rs.n) == float(entry.window.total.n)

    def test_windowed_estimates_nonnegative_with_clamp(self):
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=256, depth=2, seed=31)
        svc = EstimationService(ServiceConfig(batch_rows=16, window_epochs=2))
        svc.create_group("g", cfg)
        svc.create_stream("a", "g")
        rng = np.random.default_rng(7)
        for _ in range(8):
            svc.ingest("a", _records(rng, 24, 4))
            svc.advance_epoch()
            res = svc.snapshot().all_thresholds("a", clamp=True)
            for k, r in res.items():
                assert r.estimate >= 0.0, (k, r.estimate)
                assert (r.per_level >= 0.0).all()


class TestServiceQueries:
    """Acceptance: windowed self-join/join estimates match an offline
    ``sjpc.estimate`` over the equivalent window; error bars reported."""

    def _build(self, window_epochs=2):
        cfg = SJPCConfig(d=4, s=2, ratio=0.5, width=1024, depth=3, seed=37)
        svc = EstimationService(ServiceConfig(batch_rows=32,
                                              window_epochs=window_epochs))
        svc.create_group("g", cfg)
        rng = np.random.default_rng(8)
        batches = {"a": [_records(rng, 40, 4) for _ in range(4)],
                   "b": [_records(rng, 30, 4) for _ in range(4)]}
        for name in batches:
            svc.create_stream(name, "g")
        for ep in range(4):
            for name in batches:
                svc.ingest(name, batches[name][ep])
            svc.advance_epoch()
        return cfg, svc, batches

    def test_self_join_matches_offline_estimate(self):
        cfg, svc, batches = self._build()
        group = svc.registry.group("g")
        snap = svc.snapshot()
        for name in ("a", "b"):
            entry = svc.registry.stream(name)
            # 40-row "a" epochs consume 2 rounds each, 30-row "b" epochs
            # just 1 -- b's replay coordinate must NOT be inflated by the
            # cohort rounds a forced (the PR 7 replay-determinism fix)
            offline_state = _replay_window(cfg, group, entry, batches[name],
                                           [3], batch_rows=32)
            offline = sjpc.estimate(cfg, offline_state)
            r = snap.self_join(name)
            assert r.estimate == pytest.approx(offline.g_s, rel=1e-12)
            np.testing.assert_allclose(r.per_level, offline.x, rtol=1e-12)

    def test_join_matches_offline_estimate_join(self):
        cfg, svc, batches = self._build()
        group = svc.registry.group("g")
        ea, eb = svc.registry.stream("a"), svc.registry.stream("b")
        sa = _replay_window(cfg, group, ea, batches["a"], [3], 32, 2)
        sb = _replay_window(cfg, group, eb, batches["b"], [3], 32)
        offline = sjpc.estimate_join(cfg, sa, sb)
        r = svc.snapshot().join("a", "b")
        assert r.estimate == pytest.approx(offline.g_s, rel=1e-12)

    def test_error_bars_reported(self):
        _, svc, _ = self._build()
        r = svc.snapshot().self_join("a")
        assert r.stderr > 0.0 and r.stderr_offline > 0.0
        # Theorem 2 (sampling + sketch) dominates Theorem 1 (sampling only)
        assert r.stderr > r.stderr_offline
        j = svc.snapshot().join("a", "b")
        assert j.stderr > 0.0

    def test_higher_thresholds_available(self):
        cfg, svc, _ = self._build()
        res = svc.snapshot().all_thresholds("a")
        assert sorted(res) == list(range(cfg.s, cfg.d + 1))
        # g_k is monotone non-increasing in k by construction (clamped X >= 0)
        gs = [res[k].estimate for k in sorted(res)]
        assert all(a >= b for a, b in zip(gs, gs[1:]))

    def test_cross_group_join_rejected(self):
        cfg, svc, _ = self._build()
        svc.create_group("other", SJPCConfig(d=4, s=2, width=512, depth=2,
                                             seed=99))
        svc.create_stream("x", "other")
        with pytest.raises(ValueError, match="hash group"):
            svc.snapshot().join("a", "x")

    def test_continuous_queries_poll_from_one_snapshot(self):
        _, svc, _ = self._build()
        svc.register_continuous(ContinuousQuery("sj", "self_join", ("a",)))
        svc.register_continuous(ContinuousQuery("jn", "join", ("a", "b")))
        svc.register_continuous(ContinuousQuery("all", "all_thresholds",
                                                ("b",)))
        out = svc.poll()
        assert set(out) == {"sj", "jn", "all"}
        assert out["sj"].kind == "self_join" and out["jn"].kind == "join"
        assert isinstance(out["all"], dict)
        with pytest.raises(ValueError):
            svc.register_continuous(ContinuousQuery("sj", "self_join", ("a",)))


class TestSnapshotCacheInvalidation:
    """Regression for the stale-F2 hazard: query results are memoized in a
    cache shared across an engine's snapshots, so the keys MUST carry the
    window version -- a snapshot taken after an expiry boundary (or any
    ingest) must never be served an earlier window's cached values."""

    def _build(self):
        cfg = SJPCConfig(d=4, s=2, ratio=1.0, width=256, depth=3, seed=71)
        svc = EstimationService(ServiceConfig(batch_rows=16, window_epochs=2))
        svc.create_group("g", cfg)
        svc.create_stream("a", "g")
        return cfg, svc

    def test_window_version_tracks_mutations(self):
        """version bumps exactly when ``total`` changes: on ingest commits
        and on expiry subtraction -- NOT on no-op flushes or rotations that
        leave the window contents untouched (those must keep caches warm)."""
        _, svc = self._build()
        win = svc.registry.stream("a").window      # window_epochs=2
        v0 = win.version
        svc.ingest("a", _records(np.random.default_rng(0), 8, 4))
        svc.flush()
        assert win.version > v0
        # first rotation: ring not yet full, total unchanged -> no bump
        v1 = win.version
        svc.advance_epoch()
        assert win.version == v1
        # fill the ring; the next rotation expires epoch 0 -> total changes
        svc.ingest("a", _records(np.random.default_rng(1), 8, 4))
        svc.advance_epoch()
        v2 = win.version
        svc.advance_epoch()                        # expiry subtraction
        assert win.version > v2
        # a flush with nothing pending must NOT invalidate caches
        v3 = win.version
        svc.flush()
        assert win.version == v3

    @pytest.mark.parametrize("use_fused_query", [True, False])
    def test_snapshot_across_expiry_boundary_not_stale(self, use_fused_query):
        from repro.service import QueryEngine
        cfg, svc = self._build()
        svc.cfg = ServiceConfig(batch_rows=16, window_epochs=2,
                                use_fused_query=use_fused_query)
        svc.engine = QueryEngine(svc.registry,
                                 use_fused_query=use_fused_query)
        rng = np.random.default_rng(5)
        svc.ingest("a", _records(rng, 24, 4))
        svc.advance_epoch()
        before = svc.snapshot().self_join("a")      # fills the shared cache
        # two more epochs: the first epoch's records expire out of the window
        for _ in range(2):
            svc.ingest("a", _records(rng, 24, 4))
            svc.advance_epoch()
        after = svc.snapshot().self_join("a")
        # independent engine with a COLD cache = ground truth
        fresh = QueryEngine(svc.registry,
                            use_fused_query=use_fused_query) \
            .snapshot().self_join("a")
        assert after.estimate == fresh.estimate
        np.testing.assert_array_equal(after.per_level, fresh.per_level)
        # the window really changed, so a stale cache hit would have been
        # observable (the test has teeth)
        assert before.n != after.n or before.estimate != after.estimate

    def test_unchanged_window_is_served_from_cache(self):
        cfg, svc = self._build()
        svc.ingest("a", _records(np.random.default_rng(6), 24, 4))
        svc.advance_epoch()
        s1 = svc.snapshot()
        r1 = s1.self_join("a")
        entries_after_first = len(svc.engine._cache)
        s2 = svc.snapshot()                         # no ingest in between
        r2 = s2.self_join("a")
        assert len(svc.engine._cache) == entries_after_first  # pure lookup
        assert r1.estimate == r2.estimate


class TestDriverServiceClient:
    def test_driver_publishes_windowed_estimates(self, tmp_path):
        from typing import NamedTuple

        from repro.runtime import DriverConfig, TrainDriver
        from repro.sketchstream.monitor import (MonitorState,
                                                SketchMonitorConfig,
                                                init_monitor,
                                                monitor_update_local)

        class S(NamedTuple):
            params: jax.Array
            opt: jax.Array
            monitor: MonitorState
            step: jax.Array

        mcfg = SketchMonitorConfig(d=4, s=3, width=256, depth=2, shards=1)
        mparams, monitor = init_monitor(mcfg)

        @jax.jit
        def step_fn(state, batch):
            c, n = monitor_update_local(mcfg, mparams,
                                        state.monitor.counters[0],
                                        state.monitor.n[0],
                                        batch["tokens"], state.step)
            mon = MonitorState(c[None], n[None], state.step)
            return (S(state.params, state.opt, mon, state.step + 1),
                    {"loss": jnp.zeros(())})

        def make_batch(step):
            rng = np.random.default_rng(1000 + step)
            return {"tokens": jnp.asarray(
                rng.integers(0, 999, size=(8, 32), dtype=np.int32))}

        svc = EstimationService(ServiceConfig(window_epochs=2))
        client = MonitorServiceClient(svc, "train", mcfg)
        init = S(jnp.zeros((4,)), jnp.zeros(()), monitor,
                 jnp.zeros((), jnp.int32))
        cfg = DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=50,
                           log_every=2, sketch_log_every=2)
        driver = TrainDriver(step_fn, init, make_batch, cfg,
                             monitor_cfg=mcfg, service_client=client)
        driver.run(6)
        assert len(driver.sketch_log) == 3          # steps 0, 2, 4
        for entry in driver.sketch_log:
            for k in range(mcfg.s, mcfg.d + 1):
                assert k in entry and f"stderr_{k}" in entry
                assert entry[k] >= 0.0
            assert entry["window_epochs"] == 2
        # window saturated at 2 epochs: later entries cover ~2 publishes'
        # worth of records, not the whole stream
        win_n = svc.snapshot().self_join("train").n[0]
        assert win_n <= 2 * 8 * 2 * 6   # generous cap: < whole stream anyway


class TestVersionStabilityAcrossCohortFlush:
    """The ingest pipeline must not thrash version-keyed query caches:
    a flush that carries no records for a stream -- even when cohort
    mates DO flush and the stream rides along fully masked for jit shape
    stability -- leaves that stream's window version (and flush replay
    coordinate) untouched."""

    def _build(self, estimator="sjpc"):
        cfg = SJPCConfig(d=4, s=3, ratio=1.0, width=128, depth=2, seed=7)
        svc = EstimationService(ServiceConfig(batch_rows=16,
                                              window_epochs=None))
        svc.create_group("g", cfg)
        svc.create_stream("busy", "g", estimator=estimator)
        svc.create_stream("idle", "g", estimator=estimator)
        return svc

    @pytest.mark.parametrize("estimator", ["sjpc", "reservoir"])
    def test_cohort_mate_flush_preserves_idle_version(self, estimator):
        svc = self._build(estimator)
        rng = np.random.default_rng(3)
        svc.ingest("busy", _records(rng, 40, 4))
        svc.ingest("idle", _records(rng, 40, 4))
        svc.flush()
        idle = svc.registry.stream("idle")
        v0, f0 = idle.window.version, idle.flushes
        r0 = svc.snapshot().self_join("idle")
        cached = len(svc.engine._cache)
        # three flushes with records for the cohort mate only
        for _ in range(3):
            svc.ingest("busy", _records(rng, 40, 4))
            svc.flush()
        assert idle.window.version == v0
        assert idle.flushes == f0
        r1 = svc.snapshot().self_join("idle")
        assert r1.estimate == r0.estimate
        # the idle stream's self-join batches alone after the mates moved,
        # so its cohort entry is recomputed at most once; versions did not
        # churn per flush
        assert len(svc.engine._cache) <= cached + 3

    def test_empty_submission_preserves_version_end_to_end(self):
        """service.ingest of an empty batch followed by flush is a no-op
        for the version even though submit() recorded a chunk."""
        svc = self._build()
        rng = np.random.default_rng(4)
        svc.ingest("busy", _records(rng, 24, 4))
        svc.flush()
        win = svc.registry.stream("busy").window
        v = win.version
        svc.ingest("busy", np.zeros((0, 4), np.uint32))
        svc.flush()
        assert win.version == v

    def test_equal_but_new_pytree_does_not_bump_version(self):
        """absorb_delta's no-op check is leaf-identity based: re-wrapping
        the unchanged leaves in a new state container must keep the
        version (the regression: `is` on the container alone)."""
        svc = self._build()
        svc.ingest("busy", _records(np.random.default_rng(5), 24, 4))
        svc.flush()
        win = svc.registry.stream("busy").window
        v = win.version
        win.absorb_delta(type(win.total)(*win.total))   # new tuple, same leaves
        assert win.version == v


class TestWindowedSampleProvenance:
    def test_total_tag_set_tracks_live_epochs_exactly(self):
        """After W rotations with interleaved ingest, the sample window's
        merged total must carry provenance tags of exactly the live
        non-empty epochs -- no expired epoch survives the fold, and every
        live epoch that kept data is represented."""
        from repro import estimators as E
        cfg = SJPCConfig(d=4, s=3, ratio=1.0, width=128, depth=2, seed=11)
        svc = EstimationService(ServiceConfig(batch_rows=32,
                                              window_epochs=3))
        svc.create_group("g", cfg)
        svc.create_stream(
            "w", "g", estimator="reservoir",
            estimator_cfg=E.ReservoirConfig(d=4, s=3, capacity=48, seed=2))
        rng = np.random.default_rng(9)
        win = svc.registry.stream("w").window
        for epoch in range(7):
            # interleaved ingest: two submissions + flushes per epoch
            svc.ingest("w", _records(rng, 60, 4))
            svc.flush()
            svc.ingest("w", _records(rng, 60, 4))
            svc.advance_epoch()
            live_sids = {int(s.sid) for s in win._slots
                         if s is not None and int(s.n) > 0}
            tags = np.asarray(win.total.tags)
            assert set(tags[tags >= 0].tolist()) == live_sids, epoch
            # the window keeps exactly the last W epochs' provenance
            assert live_sids == set(range(max(0, epoch - 1), epoch + 1))
