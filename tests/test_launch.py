"""Launch-layer integration on a 1x1 debug mesh: shardings resolve, the
jitted train step runs end-to-end (model + optimizer + shard_map'd monitor),
decode caches get coherent specs, and the roofline HLO parser works."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec

from repro import compat, configs
from repro.models import model as M
from repro.models.config import compute_dims
from repro.models.layers import split_tree
from repro.launch import shardings as SH
from repro.launch import roofline as RL
from repro.launch.mesh import make_debug_mesh, batch_axes
from repro.launch.train import make_train_step, make_train_state, state_shardings
from repro.launch.serve import cache_shardings
from repro.optim import make_adamw
from repro.optim.schedules import constant
from repro.sketchstream.monitor import SketchMonitorConfig


def test_param_pspecs_cover_every_leaf():
    for name in ["jamba-1.5-large-398b", "dbrx-132b", "seamless-m4t-large-v2",
                 "mamba2-370m"]:
        cfg = configs.reduced(name)
        dims = compute_dims(cfg, tp=1)
        ptree = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg, dims))
        params, axes = split_tree(ptree)
        mesh = make_debug_mesh(1, 1)
        specs = SH.param_pspecs(mesh, axes)
        n_p = len(jax.tree_util.tree_leaves(params))
        n_s = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec)))
        assert n_p == n_s, (name, n_p, n_s)


def test_train_step_runs_on_debug_mesh():
    cfg = configs.reduced("deepseek-moe-16b")     # moe + shared experts
    dims = compute_dims(cfg, tp=1)
    mesh = make_debug_mesh(1, 1)
    mcfg = SketchMonitorConfig(d=4, s=3, width=256, depth=2, shards=1)
    opt = make_adamw(constant(1e-3))
    state, mparams, axes = make_train_state(
        jax.random.PRNGKey(0), cfg, dims, opt, monitor_cfg=mcfg)
    step_fn = make_train_step(cfg, dims, opt, mesh, monitor_cfg=mcfg,
                              monitor_params=mparams, remat="none",
                              ssm_chunk=8, compute_dtype=jnp.float32)
    batch = {
        "tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(4, 32), dtype=np.int32)),
        "labels": jnp.asarray(np.random.default_rng(1).integers(
            0, cfg.vocab_size, size=(4, 32), dtype=np.int32)),
    }
    with compat.set_mesh(mesh):
        state2, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # monitor absorbed the batch
    assert float(state2.monitor.n.sum()) == 4.0
    assert int(jnp.abs(state2.monitor.counters).sum()) > 0


def test_monitor_shard_map_multi_shard():
    """2-shard data mesh: deferred-merge counters live per-shard."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")


def test_cache_pspecs_structure():
    cfg = configs.reduced("jamba-1.5-large-398b")
    dims = compute_dims(cfg, tp=1)
    mesh = make_debug_mesh(1, 1)
    cache_ab, shardings = cache_shardings(mesh, cfg, dims, batch=4, max_len=64)
    leaves_a = jax.tree_util.tree_leaves(cache_ab)
    leaves_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves_a) == len(leaves_s)


def test_roofline_parser():
    hlo = """
ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %x = bf16[1,512]{1,0} parameter(0)
  %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups={}
  %y = f32[1024]{0} parameter(1)
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %a2a = (f32[8,32]{1,0}, f32[8,32]{1,0}) all-to-all(%x, %x)
  %cp = u32[128]{0} collective-permute(%x), source_target_pairs={{0,1}}
  ROOT %r = f32[1024]{0} add(%ar, %ar)
}
"""
    out = RL.parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 512 * 2
    assert out["all-reduce"]["wire_bytes"] == 2 * 1024 * 4
    assert out["reduce-scatter"]["bytes"] == 64 * 4
    assert out["all-to-all"]["bytes"] == 2 * 8 * 32 * 4
    assert out["collective-permute"]["bytes"] == 128 * 4
    assert out["total_wire_bytes"] > 0


def test_roofline_parser_loops():
    """Trip-count multiplication: a collective in a while body counts x trip."""
    hlo = """
%cond.1 (p: (s32[])) -> pred[] {
  %iter = s32[] get-tuple-element(%p), index=0
  %limit = s32[] constant(7)
  ROOT %lt = pred[] compare(%iter, %limit), direction=LT
}

%body.1 (p: (s32[])) -> (s32[]) {
  %y2 = f32[256]{0} parameter(1)
  %ar2 = f32[256]{0} all-reduce(%y2), to_apply=%sum
  %d = f32[8,8]{1,0} dot(%m, %m), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[]) tuple(%iter)
}

ENTRY %main.2 (p0: s32[]) -> s32[] {
  %m = f32[8,8]{1,0} parameter(2)
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = s32[] get-tuple-element(%w), index=0
}
"""
    cost = RL.hlo_cost(hlo)
    assert cost["loops"] == {"body.1": 7}
    assert cost["collectives"]["all-reduce"]["count"] == 7
    assert cost["collectives"]["all-reduce"]["wire_bytes"] == 7 * 2 * 256 * 4
    # dot: 2 * 64 out * 8 contraction * 7 trips
    assert cost["flops"] == 7 * 2 * 8 * 8 * 8


def test_roofline_terms():
    r = RL.Roofline.build(flops=197e12, hbm_bytes=819e9 / 2,
                          wire_bytes=50e9 / 4, model_flops=98.5e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.dominant == "compute"
    assert r.useful_ratio == pytest.approx(0.5)


def test_cost_analysis_available():
    """cost_analysis + as_text work on this backend (the dry-run relies on
    both)."""
    def f(x, y):
        return jnp.einsum("ij,jk->ik", x, y)
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    assert cost.get("flops", 0) > 0
    assert "fusion" in compiled.as_text() or "dot" in compiled.as_text()
