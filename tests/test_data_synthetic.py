"""Synthetic generators: the planted similarity structure must be real
(checked against the exact oracles at small n)."""
import numpy as np

from repro.core import exact
from repro.data.synthetic import (dblp_like, shingle_records,
                                  near_uniform_40_60, skewed, yfcc_like)


def test_dblp_like_has_planted_near_dups():
    recs = dblp_like(400, d=5, seed=1, dup_fraction=0.1)
    x = exact.exact_pair_counts(recs)
    # 40 planted (d-1)-similar pairs (x2 ordered) + column-collision noise
    assert x[4] + x[5] >= 60, x


def test_shingle_groups_quadratic():
    recs = shingle_records(600, d=6, seed=2, group=5,
                           dup_profile=((6, 0.1),))
    x = exact.exact_pair_counts(recs)
    # ~60/4 = 15 groups of 5 -> >= 15 * 5*4 = 300 ordered 6-similar pairs
    assert x[6] >= 250, x


def test_near_uniform_structure():
    recs = near_uniform_40_60(500, seed=3)
    x = exact.exact_pair_counts(recs)
    pairs_4 = x[4] / 2
    assert 120 <= pairs_4 <= 160, x          # 30% of n pairs (60% of rows)


def test_skewed_structure():
    recs = skewed(512, frac_unique=0.2, group=16, seed=4)
    g4 = exact.exact_g(recs, 4) - 512
    # ~25 groups of 16 -> 16*15*25 = 6000 ordered pairs >= 4-similar
    assert g4 > 3000, g4


def test_yfcc_like_shape_and_skew():
    recs = yfcc_like(2000, seed=5)
    assert recs.shape == (2000, 5)
    # userid column is zipf-skewed: top user owns many rows
    _, counts = np.unique(recs[:, 0], return_counts=True)
    assert counts.max() > 20
