"""MoE capacity dispatch: conservation, capacity enforcement, drop behavior,
shared experts, and load-balance loss properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.moe import _dispatch_tensors, moe_ffn, init_moe
from repro.models.layers import split_tree


def _probs(g, s, e, seed=0):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (g, s, e))
    return jax.nn.softmax(logits, axis=-1)


class TestDispatchTensors:
    @given(st.integers(0, 100), st.integers(2, 8), st.integers(1, 3))
    @settings(max_examples=20, deadline=None)
    def test_capacity_never_exceeded(self, seed, e, k):
        k = min(k, e)
        probs = _probs(2, 16, e, seed)
        cap = 4
        dispatch, combine, gates, idx = _dispatch_tensors(probs, k, cap)
        # per (group, expert, slot): at most one token
        slot_load = np.asarray(dispatch).sum(axis=1)           # (G, E, C)
        assert (slot_load <= 1.0 + 1e-6).all()
        # per (group, expert): total <= capacity
        load = np.asarray(dispatch).sum(axis=(1, 3))
        assert (load <= cap + 1e-6).all()

    def test_no_drops_with_big_capacity(self):
        probs = _probs(1, 32, 4, 3)
        dispatch, combine, gates, idx = _dispatch_tensors(probs, 2, 64)
        # every token's every choice lands somewhere
        per_token = np.asarray(dispatch).sum(axis=(2, 3))       # (G, S)
        np.testing.assert_allclose(per_token, 2.0, rtol=1e-6)
        # combine weights sum to 1 per token (renormalized top-k gates)
        csum = np.asarray(combine).sum(axis=(2, 3))
        np.testing.assert_allclose(csum, 1.0, rtol=1e-5)

    def test_earlier_choices_win_capacity(self):
        """With capacity 1 and all tokens preferring expert 0, only the
        first token per group gets its 1st choice."""
        e = 4
        probs = jnp.zeros((1, 8, e)).at[:, :, 0].set(0.97)
        probs = probs.at[:, :, 1].set(0.01).at[:, :, 2].set(0.01).at[:, :, 3].set(0.01)
        dispatch, _, _, _ = _dispatch_tensors(probs, 1, 1)
        d = np.asarray(dispatch)[0]                             # (S, E, C)
        assert d[0, 0, 0] == 1.0
        assert d[1:, 0, :].sum() == 0.0                         # dropped


class TestMoeFfn:
    def test_forward_and_shapes(self):
        d, ff, e = 32, 64, 8
        p = split_tree(init_moe(jax.random.PRNGKey(0), d, ff, e, 1))[0]
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d))
        out, aux = moe_ffn(p, x, num_experts=e, top_k=2, capacity_factor=2.0)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux["moe_lb_loss"]) > 0.5    # ~1 for balanced routing
        assert np.isfinite(float(aux["moe_z_loss"]))

    def test_capacity_factor_controls_drops(self):
        """Tiny capacity -> output loses tokens (drops); huge -> none."""
        d, ff, e = 16, 32, 4
        p = split_tree(init_moe(jax.random.PRNGKey(0), d, ff, e, 0))[0]
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 128, d))
        out_small, _ = moe_ffn(p, x, num_experts=e, top_k=2,
                               capacity_factor=0.25)
        out_big, _ = moe_ffn(p, x, num_experts=e, top_k=2,
                             capacity_factor=float(e))
        # dropped tokens produce zero routed output -> rows differ
        diff = np.abs(np.asarray(out_small) - np.asarray(out_big)).sum(axis=-1)
        assert (diff[0] > 1e-6).any()

    def test_gradients_flow_to_router(self):
        d, ff, e = 16, 32, 4
        p = split_tree(init_moe(jax.random.PRNGKey(0), d, ff, e, 0))[0]
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, d))

        def loss(p):
            out, aux = moe_ffn(p, x, num_experts=e, top_k=2,
                               capacity_factor=2.0)
            return jnp.sum(out ** 2) + aux["moe_lb_loss"]

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]).sum()) > 0
        assert float(jnp.abs(g["w_gate"]).sum()) > 0
