"""Flash-attention Pallas kernel (interpret mode) vs the dense oracle,
swept over shapes, GQA ratios, block sizes, dtypes, and causality."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import full_attention


def _rand(seed, b, sq, skv, h, kv, hd, dtype):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kv, hd)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kv, hd)).astype(np.float32)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,skv,h,kv,hd", [
    (2, 64, 64, 4, 2, 16),      # GQA 2:1
    (1, 128, 128, 8, 8, 32),    # MHA
    (2, 64, 128, 4, 1, 16),     # MQA, cross lengths
    (1, 96, 96, 6, 3, 64),      # non-pow2 block count
])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_dense_oracle(b, sq, skv, h, kv, hd, causal):
    q, k, v = _rand(0, b, sq, skv, h, kv, hd, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (64, 32),
                                             (128, 128)])
def test_block_shape_invariance(block_q, block_k):
    q, k, v = _rand(1, 2, 128, 128, 4, 2, 32, jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k)
    want = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs():
    q, k, v = _rand(2, 1, 64, 64, 4, 2, 32, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = full_attention(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_first_token_sees_only_itself():
    """Causal row 0 attends to position 0 only -> output = v[0]."""
    q, k, v = _rand(3, 1, 32, 32, 2, 2, 16, jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(v[:, 0]),
                               rtol=1e-5, atol=1e-5)
