"""Shared kernel-conformance case grids and input builders.

One module owns the hand-enumerated shape/depth/width/empty edge lists that
used to be copy-pasted across ``test_fused_ingest.py`` /
``test_fused_query.py`` / ``test_fused_pairs.py``; those files now consume
these builders, and ``test_kernel_registry.py`` assembles the same grids
into the registry-generated conformance matrix (one case per
(op, registered impl) pair).  Canonical-argument convention: every builder
returns the *oracle's* positional arguments; :func:`entry_call` adapts them
to the public ``kernels.ops`` entry point so matrix cases exercise the real
dispatch layer with ``impl=`` forced.
"""
from __future__ import annotations

import dataclasses
import types
from typing import Callable

import numpy as np
import jax.numpy as jnp

from repro.core import sjpc
from repro.core import sketch as sk
from repro.core.hashing import P31
from repro.core.projections import padded_lattice
from repro.core.sjpc import SJPCConfig
from repro.kernels import ops

# ---------------------------------------------------------------------------
# fused_pairs
# ---------------------------------------------------------------------------

PAIRS_SHAPES = [
    (1, 1, 3),      # single record: no pairs
    (1, 7, 3),      # smaller than any tile
    (2, 64, 5),
    (1, 130, 6),    # tile remainder (128 + 2)
    (3, 33, 4),
    (1, 256, 2),    # exact multiple of the tile
]
PAIRS_BLOCKS = [8, 32, 128]


def pairs_case(rng, N, R, d, vocab=5, p_valid=0.8):
    items = rng.integers(0, vocab, size=(N, R, d)).astype(np.uint32)
    valid = (rng.random((N, R)) < p_valid).astype(np.int32)
    return items, valid


# ---------------------------------------------------------------------------
# fused_query
# ---------------------------------------------------------------------------

QUERY_DEPTHS = [1, 3, 5]
QUERY_SHAPES = [              # (N, L, w, block_w)
    (1, 1, 128, 128),         # single plane, one tile
    (3, 2, 256, 64),          # multi-tile width
    (2, 4, 512, 512),         # w >> t (non-square planes)
    (5, 3, 128, 32),          # many streams, many tiles
]


def counter_stack(rng, N, L, t, w, lo=-60, hi=60):
    return jnp.asarray(rng.integers(lo, hi, size=(N, L, t, w))
                       .astype(np.int32))


def oracle_moments(a, b):
    return (np.asarray(a, np.int64) * np.asarray(b, np.int64)).sum(axis=-1)


# ---------------------------------------------------------------------------
# fused_ingest
# ---------------------------------------------------------------------------

INGEST_BATCHES = [1, 17, 100, 257]       # non-pow2 tails included
INGEST_DEPTHS = [1, 3, 5]
INGEST_TILES = [(16, 128), (64, 256), (256, 512)]   # (block_b, block_w)


def ingest_inputs(rng, cfg, batch):
    """Padded-lattice ingest arguments (the fused kernel's canonical args)
    with random counters, values, and {0,1} weights zeroed on padded combo
    slots.  Returns (params, pad, args)."""
    params, _state = sjpc.init(cfg)
    pad = padded_lattice(cfg.d, cfg.s)
    values = rng.integers(0, 2**32, size=(batch, cfg.d), dtype=np.uint32)
    weights = (rng.integers(0, 2, size=(batch, pad.num_levels, pad.m_max))
               .astype(np.int32) * pad.valid[None].astype(np.int32))
    counters = rng.integers(-9, 9,
                            size=(cfg.num_levels, cfg.depth, cfg.width)
                            ).astype(np.int32)
    return params, pad, (jnp.asarray(counters), jnp.asarray(values),
                         jnp.asarray(pad.masks), jnp.asarray(pad.ids),
                         params.fp_bases, params.bucket_coeffs,
                         params.sign_coeffs, jnp.asarray(weights))


# ---------------------------------------------------------------------------
# fingerprint / sketch_update / sketch_moments / flash_attention
# ---------------------------------------------------------------------------

def fingerprint_case(rng, B, d, s, level=0):
    """One level's (values, combo_masks, combo_ids, bases)."""
    cfg = SJPCConfig(d=d, s=s, width=128, depth=1,
                     seed=int(rng.integers(1 << 16)))
    params, _ = sjpc.init(cfg)
    pad = padded_lattice(d, s)
    values = jnp.asarray(rng.integers(0, 2**32, size=(B, d),
                                      dtype=np.uint32))
    return (values, jnp.asarray(pad.masks[level]),
            jnp.asarray(pad.ids[level]), params.fp_bases)


def sketch_update_case(rng, n, t, w, all_zero_weights=False):
    params = sk.make_sketch_params(rng, t)
    fp1 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
    fp2 = jnp.asarray(rng.integers(0, int(P31), size=n, dtype=np.uint32))
    weights = jnp.zeros((n,), jnp.int32) if all_zero_weights \
        else jnp.asarray(rng.integers(-2, 3, size=n).astype(np.int32))
    counters = jnp.asarray(rng.integers(-9, 9, size=(t, w)).astype(np.int32))
    return (counters, fp1, fp2, params.bucket_coeffs, params.sign_coeffs,
            weights)


def flash_case(rng, B, S, H, hd):
    def t(shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)
    return t((B, S, H, hd)), t((B, S, H, hd)), t((B, S, H, hd))


# ---------------------------------------------------------------------------
# the registry conformance matrix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One conformance input: canonical (oracle-signature) args plus the
    kwargs both sides share (e.g. flash attention's causal flag)."""
    op: str
    case_id: str
    args: tuple
    kwargs: dict = dataclasses.field(default_factory=dict)
    entry_kwargs: dict = dataclasses.field(default_factory=dict)  # ops-only
    tol: float | None = None     # None = bit-exact (the integer kernels)

    @property
    def id(self) -> str:
        return f"{self.op}-{self.case_id}"


def entry_call(case: KernelCase, impl: str, interpret=None):
    """Run one case through the public ops entry point with ``impl``
    forced -- the same dispatch layer the service uses."""
    kw = dict(case.kwargs, **case.entry_kwargs,
              impl=impl, interpret=interpret)
    if case.op == "sketch_update":
        counters, fp1, fp2, bc, sc, weights = case.args
        params = types.SimpleNamespace(bucket_coeffs=bc, sign_coeffs=sc)
        return ops.sketch_update(counters, fp1, fp2, params, weights, **kw)
    return getattr(ops, case.op)(*case.args, **kw)


def oracle_call(case: KernelCase, oracle: Callable):
    return oracle(*case.args, **case.kwargs)


def matrix_cases():
    """The shape/depth/empty edge grid behind the (op, impl) matrix.

    Each op gets a handful of cases spanning: below-tile shapes, tile
    remainders, exact tile multiples, depth extremes, and the empty /
    all-masked edges.  Shapes stay small -- the matrix multiplies every
    case by every registered impl, and the interpreter tier is slow."""
    rng = np.random.default_rng(20240808)
    cases = []

    for i, (N, R, d) in enumerate([(1, 1, 3), (2, 64, 5), (1, 130, 6)]):
        cases.append(KernelCase("fused_pairs", f"N{N}R{R}d{d}",
                                pairs_case(rng, N, R, d)))
    items, _ = pairs_case(rng, 2, 40, 4)
    cases.append(KernelCase("fused_pairs", "all-invalid",
                            (items, np.zeros((2, 40), np.int32))))
    cases.append(KernelCase("fused_pairs", "duplicates-diagonal",
                            (np.full((1, 50, 4), 7, np.uint32),
                             np.ones((1, 50), np.int32))))

    for N, L, t, w in [(1, 1, 1, 128), (3, 2, 3, 256), (2, 4, 5, 512)]:
        cases.append(KernelCase("fused_query", f"N{N}L{L}t{t}w{w}",
                                (counter_stack(rng, N, L, t, w),
                                 counter_stack(rng, N, L, t, w))))
    zeros = jnp.zeros((2, 3, 3, 128), jnp.int32)
    cases.append(KernelCase("fused_query", "empty-sketch", (zeros, zeros)))

    for batch, depth in [(1, 2), (33, 2), (50, 3)]:
        cfg = SJPCConfig(d=4, s=2, width=256, depth=depth, seed=7 + batch)
        _, _, args = ingest_inputs(rng, cfg, batch)
        cases.append(KernelCase("fused_ingest", f"B{batch}t{depth}", args))

    for B, d, s in [(1, 4, 2), (37, 5, 3), (130, 6, 4)]:
        cases.append(KernelCase("fingerprint", f"B{B}d{d}s{s}",
                                fingerprint_case(rng, B, d, s)))

    for n, t, w in [(1, 3, 128), (257, 3, 256), (1024, 5, 512)]:
        cases.append(KernelCase("sketch_update", f"n{n}t{t}w{w}",
                                sketch_update_case(rng, n, t, w)))
    cases.append(KernelCase("sketch_update", "zero-weights",
                            sketch_update_case(rng, 64, 2, 128,
                                               all_zero_weights=True)))

    for t, w in [(1, 128), (3, 256), (5, 512)]:
        a = counter_stack(rng, 1, 1, t, w)[0, 0]
        b = counter_stack(rng, 1, 1, t, w)[0, 0]
        cases.append(KernelCase("sketch_moments", f"t{t}w{w}", (a, b)))

    for causal in (True, False):
        cases.append(KernelCase(
            "flash_attention", f"causal{int(causal)}",
            flash_case(rng, 2, 64, 2, 16),
            kwargs={"causal": causal, "block_q": 32, "block_k": 32},
            tol=2e-5))
    return cases


def cases_for(op: str):
    return [c for c in matrix_cases() if c.op == op]
