"""Q8Adam under shard_map on the debug mesh + elastic checkpoint restore
with target shardings."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import compat
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import make_debug_mesh
from repro.optim.q8sharded import make_q8adam_sharded, state_pspecs
from repro.optim.adamw import make_adamw
from repro.optim.schedules import constant


def _params():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)),
            "b": jnp.zeros((32,), jnp.float32)}


def _pspecs():
    return {"w": PartitionSpec(None, None), "b": PartitionSpec(None)}


def test_q8_sharded_matches_unsharded_semantics():
    """shard_map Q8 on a 1x1 mesh must track plain AdamW like q8adam does."""
    mesh = make_debug_mesh(1, 1)
    params = _params()
    target = jnp.ones_like(params["w"])

    def grad_fn(p):
        return {"w": 2 * (p["w"] - target), "b": p["b"] * 0}

    opt = make_q8adam_sharded(mesh, constant(0.05), _pspecs(),
                              weight_decay=0.0)
    ref = make_adamw(constant(0.05), weight_decay=0.0)
    with compat.set_mesh(mesh):
        s_q = opt.init(params)
        s_r = ref.init(params)
        p_q, p_r = params, dict(params)
        for _ in range(60):
            p_q, s_q, _ = jax.jit(opt.update)(grad_fn(p_q), s_q, p_q)
            p_r, s_r, _ = jax.jit(ref.update)(grad_fn(p_r), s_r, p_r)
    err_q = float(jnp.abs(p_q["w"] - target).mean())
    err_r = float(jnp.abs(p_r["w"] - target).mean())
    assert err_q < 0.25, err_q
    assert abs(err_q - err_r) < 0.15, (err_q, err_r)


def test_restore_with_target_shardings(tmp_path):
    """Elastic restore: checkpoint written chunked, restored with explicit
    NamedShardings (the restore-onto-a-different-mesh path)."""
    mesh = make_debug_mesh(1, 1)
    tree = _params()
    save_checkpoint(str(tmp_path), 4, tree, chunks=8)
    shardings = {
        "w": NamedSharding(mesh, PartitionSpec("data", None)),
        "b": NamedSharding(mesh, PartitionSpec()),
    }
    restored, man = restore_checkpoint(str(tmp_path), tree, shardings=shardings)
    assert man.step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]
