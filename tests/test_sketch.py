"""Fast-AGMS sketch: F2/inner-product accuracy, linearity, merge semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sketch as sk
from repro.core.hashing import P31


_jit_update = jax.jit(sk.sketch_update)
_jit_update_w = jax.jit(sk.sketch_update)


def _insert_multiset(counters, params, keys1, keys2, weights=None):
    if weights is None:
        return _jit_update(counters, jnp.asarray(keys1), jnp.asarray(keys2), params)
    return _jit_update_w(counters, jnp.asarray(keys1), jnp.asarray(keys2),
                         params, jnp.asarray(weights))


def _random_stream(rng, n_distinct, zipf=1.2, total=20_000):
    """A skewed multiset of (fp1, fp2) keys; returns keys + true F2.

    The total stream length is capped at ~``total`` (zipf tails are huge;
    uncapped streams made this a multi-minute CPU test).
    """
    freqs = rng.zipf(zipf, size=n_distinct).clip(max=total // 20).astype(np.int64)
    if freqs.sum() > total:
        freqs = np.maximum(1, freqs * total // freqs.sum())
    k1 = rng.integers(0, int(P31), size=n_distinct, dtype=np.uint32)
    k2 = rng.integers(0, int(P31), size=n_distinct, dtype=np.uint32)
    keys1 = np.repeat(k1, freqs)
    keys2 = np.repeat(k2, freqs)
    f2 = float((freqs ** 2).sum())
    return keys1, keys2, f2


class TestF2:
    @pytest.mark.parametrize("width,depth", [(1024, 5), (4096, 3)])
    def test_f2_relative_error(self, width, depth):
        rng = np.random.default_rng(10)
        keys1, keys2, f2 = _random_stream(rng, 3000)
        errs = []
        for seed in range(8):
            params = sk.make_sketch_params(np.random.default_rng(seed), depth)
            counters = sk.empty_counters(depth, width)
            counters = _insert_multiset(counters, params, keys1, keys2)
            est = float(sk.np_estimate_f2_exact(np.asarray(counters)))
            errs.append(abs(est - f2) / f2)
        # AGMS std <= sqrt(2/w) * F2; median-of-depth tightens tails.
        assert np.median(errs) < 3 * np.sqrt(2 / width), (np.median(errs), errs)

    def test_weights_mask_elements(self):
        rng = np.random.default_rng(11)
        params = sk.make_sketch_params(rng, 3)
        k1 = jnp.asarray(rng.integers(0, int(P31), size=100, dtype=np.uint32))
        k2 = jnp.asarray(rng.integers(0, int(P31), size=100, dtype=np.uint32))
        w = jnp.asarray((np.arange(100) % 2).astype(np.int32))
        c_half = sk.sketch_update(sk.empty_counters(3, 256), k1, k2, params, w)
        c_sub = sk.sketch_update(sk.empty_counters(3, 256), k1[1::2], k2[1::2], params)
        np.testing.assert_array_equal(np.asarray(c_half), np.asarray(c_sub))

    def test_empty_sketch_estimates_zero(self):
        assert float(sk.estimate_f2(sk.empty_counters(3, 256))) == 0.0


class TestLinearity:
    @given(st.integers(0, 2**31 - 2), st.integers(1, 50), st.integers(1, 50))
    @settings(max_examples=25, deadline=None)
    def test_merge_equals_concat(self, seed, na, nb):
        rng = np.random.default_rng(seed)
        params = sk.make_sketch_params(rng, 2)
        ka1 = rng.integers(0, int(P31), size=na, dtype=np.uint32)
        ka2 = rng.integers(0, int(P31), size=na, dtype=np.uint32)
        kb1 = rng.integers(0, int(P31), size=nb, dtype=np.uint32)
        kb2 = rng.integers(0, int(P31), size=nb, dtype=np.uint32)
        empty = sk.empty_counters(2, 128)
        ca = _insert_multiset(empty, params, ka1, ka2)
        cb = _insert_multiset(empty, params, kb1, kb2)
        c_all = _insert_multiset(empty, params, np.concatenate([ka1, kb1]),
                                 np.concatenate([ka2, kb2]))
        np.testing.assert_array_equal(np.asarray(sk.merge(ca, cb)), np.asarray(c_all))

    def test_update_order_invariant(self):
        rng = np.random.default_rng(12)
        params = sk.make_sketch_params(rng, 3)
        k1 = rng.integers(0, int(P31), size=500, dtype=np.uint32)
        k2 = rng.integers(0, int(P31), size=500, dtype=np.uint32)
        perm = rng.permutation(500)
        empty = sk.empty_counters(3, 512)
        c1 = _insert_multiset(empty, params, k1, k2)
        c2 = _insert_multiset(empty, params, k1[perm], k2[perm])
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


class TestInnerProduct:
    def test_join_size_estimate(self):
        """|A join B| via sketch inner product (paper §6 mechanics)."""
        rng = np.random.default_rng(13)
        shared = rng.integers(0, int(P31), size=(300, 2), dtype=np.uint32)
        only_a = rng.integers(0, int(P31), size=(500, 2), dtype=np.uint32)
        only_b = rng.integers(0, int(P31), size=(400, 2), dtype=np.uint32)
        # A has each shared key 2x -> true inner product = 2 * 300
        a1 = np.concatenate([shared[:, 0], shared[:, 0], only_a[:, 0]])
        a2 = np.concatenate([shared[:, 1], shared[:, 1], only_a[:, 1]])
        b1 = np.concatenate([shared[:, 0], only_b[:, 0]])
        b2 = np.concatenate([shared[:, 1], only_b[:, 1]])
        ests = []
        for seed in range(8):
            params = sk.make_sketch_params(np.random.default_rng(100 + seed), 5)
            empty = sk.empty_counters(5, 2048)
            ca = _insert_multiset(empty, params, a1, a2)
            cb = _insert_multiset(empty, params, b1, b2)
            ests.append(float(sk.estimate_inner(ca, cb)))
        assert abs(np.median(ests) - 600) / 600 < 0.25, ests
