"""Test bootstrap: provide a minimal ``hypothesis`` stand-in when the real
package is not installed (the container image has no network access, and the
tier-1 suite must run from the baked image alone).

The stub covers exactly the API surface these tests use -- ``given``,
``settings(max_examples=..., deadline=...)``, ``strategies.integers``,
``strategies.floats`` -- and drives each property with a deterministic
sequence of examples: the boundary corners first (hypothesis's own habit,
and where off-by-one bugs live), then seeded-random draws.  Runs are fully
reproducible across processes.

If real hypothesis is importable we use it untouched.

Also honors ``REPRO_PLUGINS`` (comma-separated module names): plugin
estimator kinds are registered BEFORE collection, so module-scope
``estimators.available()`` enumerations (test_estimators.KINDS,
test_wire.KINDS) parametrize over them too -- the CI plugin-conformance
job runs the whole matrix with ``REPRO_PLUGINS=examples.plugins``.
"""
from __future__ import annotations

import itertools
import os
import random
import sys
import types

try:
    import hypothesis  # noqa: F401
except ImportError:
    _CAP = int(os.environ.get("HYPOTHESIS_STUB_MAX_EXAMPLES", "50"))

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self._draw = lo, hi, draw

        def corners(self):
            return (self.lo, self.hi)

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(min_value, max_value,
                         lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(min_value, max_value,
                         lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(False, True, lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(seq[0], seq[-1], lambda rng: rng.choice(seq))

    class settings:                                        # noqa: N801
        def __init__(self, max_examples=20, deadline=None, **_kw):
            self.max_examples = max_examples
            self.deadline = deadline

        def __call__(self, fn):
            fn._stub_settings = self
            return fn

    def given(*strategies):
        def decorate(fn):
            # NB: no functools.wraps -- __wrapped__ would expose the original
            # signature and make pytest treat drawn params as fixtures.
            def wrapper(*args, **kwargs):
                cfg = getattr(fn, "_stub_settings", None)
                n = min(cfg.max_examples if cfg else 20, _CAP)
                rng = random.Random(fn.__qualname__)
                # boundary corners first (all-lo, all-hi, then mixed)
                corner_sets = list(itertools.islice(
                    itertools.product(*(s.corners() for s in strategies)), 8))
                for i in range(n):
                    if i < len(corner_sets):
                        vals = corner_sets[i]
                    else:
                        vals = tuple(s.draw(rng) for s in strategies)
                    try:
                        fn(*args, *vals, **kwargs)
                    except Exception:
                        print(f"[hypothesis-stub] falsifying example "
                              f"{fn.__qualname__}{vals}", file=sys.stderr)
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_stub = True
            return wrapper
        return decorate

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.floats = _floats
    strategies.booleans = _booleans
    strategies.sampled_from = _sampled_from

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    stub.strategies = strategies
    stub.__stub__ = True

    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies


def pytest_configure(config):
    """Register REPRO_PLUGINS estimator kinds before test collection so
    module-scope ``available()`` enumerations see them."""
    del config
    if os.environ.get("REPRO_PLUGINS"):
        sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
        from repro import estimators
        estimators.load_plugins()
