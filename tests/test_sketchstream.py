"""Stream monitor: recordizer correctness, deferred-merge equivalence,
monitor accuracy on planted duplicates, contamination (join) estimates."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import exact
from repro.data.recordize import records_from_tokens, np_records_from_tokens
from repro.data.synthetic import zipf_tokens, shingle_records
from repro.sketchstream.monitor import (SketchMonitorConfig, init_monitor,
                                        monitor_update_local, merge_monitor,
                                        monitor_estimate,
                                        contamination_estimate, MonitorState)


class TestRecordize:
    def test_matches_numpy_oracle(self):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 50000, size=(8, 64), dtype=np.int32)
        got = np.asarray(records_from_tokens(jnp.asarray(toks), 6))
        want = np_records_from_tokens(toks, 6)
        np.testing.assert_array_equal(got, want)

    def test_identical_sequences_identical_records(self):
        rng = np.random.default_rng(1)
        toks = rng.integers(0, 1000, size=(4, 48), dtype=np.int32)
        toks[2] = toks[0]
        recs = np.asarray(records_from_tokens(jnp.asarray(toks), 6))
        np.testing.assert_array_equal(recs[0], recs[2])
        assert not (recs[0] == recs[1]).all()

    def test_span_locality(self):
        """Editing tokens in one span changes exactly one column."""
        rng = np.random.default_rng(2)
        toks = rng.integers(0, 1000, size=(1, 60), dtype=np.int32)
        toks2 = toks.copy()
        toks2[0, 15] += 1          # span 1 of 6 (positions 10..19)
        r1 = np.asarray(records_from_tokens(jnp.asarray(toks), 6))[0]
        r2 = np.asarray(records_from_tokens(jnp.asarray(toks2), 6))[0]
        assert (r1 != r2).sum() == 1
        assert r1[1] != r2[1]


class TestMonitor:
    def test_deferred_merge_equals_single_stream(self):
        """counters(shard0)+counters(shard1) == counters(all records)."""
        cfg = SketchMonitorConfig(d=4, s=2, ratio=1.0, width=256, depth=2,
                                  shards=2)
        params, state = init_monitor(cfg)
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, 999, size=(8, 32), dtype=np.int32))
        step = jnp.zeros((), jnp.int32)
        c0, n0 = monitor_update_local(cfg, params, state.counters[0],
                                      state.n[0], toks[:4], step)
        c1, n1 = monitor_update_local(cfg, params, state.counters[1],
                                      state.n[1], toks[4:], step)
        two = merge_monitor(MonitorState(jnp.stack([c0, c1]),
                                         jnp.stack([n0, n1]), step))

        cfg1 = SketchMonitorConfig(d=4, s=2, ratio=1.0, width=256, depth=2,
                                   shards=1)
        params1, state1 = init_monitor(cfg1)
        ca, na = monitor_update_local(cfg1, params1, state1.counters[0],
                                      state1.n[0], toks, step)
        one = merge_monitor(MonitorState(ca[None], na[None], step))
        np.testing.assert_array_equal(np.asarray(two.counters),
                                      np.asarray(one.counters))
        assert float(two.n) == float(one.n)

    def test_detects_planted_duplicates(self):
        """Batch stream with duplicated sequences -> monitor's g_d ~ true
        duplicate pair count (r=1, exact-ish regime)."""
        d = 4
        cfg = SketchMonitorConfig(d=d, s=d, ratio=1.0, width=4096, depth=3,
                                  shards=1)
        params, state = init_monitor(cfg)
        rng = np.random.default_rng(4)
        all_recs = []
        step = jnp.zeros((), jnp.int32)
        counters, n = state.counters[0], state.n[0]
        for i in range(6):
            toks = rng.integers(0, 5000, size=(32, 32), dtype=np.int32)
            toks[1] = toks[0]                     # one duplicate pair per batch
            counters, n = monitor_update_local(
                cfg, params, counters, n,
                jnp.asarray(toks), step + i)
            all_recs.append(np_records_from_tokens(toks, d))
        state = MonitorState(counters[None], n[None], step)
        est = monitor_estimate(cfg, state)
        g_d_true = exact.exact_g(np.concatenate(all_recs), d)
        assert abs(est["g"][d] - g_d_true) / g_d_true < 0.2, (est["g"], g_d_true)

    def test_contamination_join(self):
        """Two streams sharing sequences -> §6 join estimate sees them."""
        d = 4
        cfg = SketchMonitorConfig(d=d, s=d, ratio=1.0, width=4096, depth=3,
                                  shards=1)
        params, st_a = init_monitor(cfg)
        _, st_b = init_monitor(cfg)
        rng = np.random.default_rng(5)
        a = rng.integers(0, 5000, size=(64, 32), dtype=np.int32)
        b = rng.integers(0, 5000, size=(64, 32), dtype=np.int32)
        b[:16] = a[:16]                           # 16 contaminated sequences
        step = jnp.zeros((), jnp.int32)
        ca, na = monitor_update_local(cfg, params, st_a.counters[0],
                                      st_a.n[0], jnp.asarray(a), step)
        cb, nb = monitor_update_local(cfg, params, st_b.counters[0],
                                      st_b.n[0], jnp.asarray(b), step)
        est = contamination_estimate(
            cfg, MonitorState(ca[None], na[None], step),
            MonitorState(cb[None], nb[None], step))
        # ordered-pair convention both directions -> 2 * 16... the join
        # estimator counts (a in A, b in B) matches once: 16 pairs, but our
        # inversion keeps the x2 convention of the self-join -> accept range
        j = est["join"][d]
        assert 10 < j < 45, est["join"]
