from .driver import DriverConfig, TrainDriver, SimulatedFailure
