"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler policy.

The driver owns the train loop around launch.train.make_train_step:

- **Checkpoint/restart**: the full TrainState (params, optimizer moments,
  SJPC monitor counters, step) is committed atomically every
  ``ckpt_every`` steps (checkpoint.chunked); on ANY step failure the driver
  restores the last committed state and replays -- the data iterator is
  seeded + step-indexed, so replayed batches are identical (deterministic
  recovery, same semantics as a real pod losing a host).
- **Failure injection**: ``inject_failure_at={step: exc}`` raises inside the
  loop to exercise the recovery path (tests/test_runtime.py kills the loop
  mid-run and asserts losses match an uninterrupted run).
- **Straggler policy**: per-step deadline = ``straggler_factor`` x the
  trailing-median step time.  A step exceeding it is recorded; after
  ``straggler_limit`` consecutive offenders the driver triggers mitigation
  (on a real cluster: evict + reshard via the elastic checkpoint; here the
  hook records the event and re-bases the deadline).
- **Sketch telemetry**: with only ``monitor_cfg`` the driver queries the
  whole-stream monitor directly (legacy).  Passing ``service_client`` (a
  :class:`repro.service.MonitorServiceClient`) instead publishes the
  monitor's delta to the estimation service each interval, making the
  trainer one tenant among many: the sketch log gains sliding-window
  estimates and error bars, and the same service can answer train<->eval
  contamination joins against other published streams.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Iterator

import numpy as np
import jax

from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.sketchstream.monitor import monitor_estimate


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    sketch_log_every: int = 50
    straggler_factor: float = 3.0
    straggler_limit: int = 3
    max_restarts: int = 5


class TrainDriver:
    def __init__(self, step_fn, init_state, make_batch: Callable[[int], Any],
                 cfg: DriverConfig, *, monitor_cfg=None, state_template=None,
                 shardings=None, service_client=None):
        """``make_batch(step) -> batch`` must be deterministic in step."""
        self.step_fn = step_fn
        self.cfg = cfg
        self.make_batch = make_batch
        self.monitor_cfg = monitor_cfg
        self.service_client = service_client
        self.shardings = shardings
        self.state = init_state
        self.template = state_template if state_template is not None else init_state
        self.metrics_log: list[dict] = []
        self.sketch_log: list[dict] = []
        self.events: list[dict] = []
        self.restarts = 0
        self._step_times: list[float] = []
        self._consecutive_slow = 0
        self.inject_failure_at: dict[int, Exception] = {}

    # ------------------------------------------------------------------
    @property
    def step(self) -> int:
        return int(jax.device_get(self.state.step))

    def _checkpoint(self):
        save_checkpoint(self.cfg.ckpt_dir, self.step, self.state,
                        keep=self.cfg.keep)
        self.events.append({"kind": "checkpoint", "step": self.step})

    def _restore(self):
        state, man = restore_checkpoint(self.cfg.ckpt_dir, self.template,
                                        shardings=self.shardings)
        self.state = state
        if self.service_client is not None and self.state.monitor is not None:
            self.service_client.resync(self.state.monitor)
        self.events.append({"kind": "restore", "step": man.step})
        return man.step

    def _straggler_check(self, dt: float, step: int):
        self._step_times.append(dt)
        window = self._step_times[-20:]
        if len(window) < 5:
            return
        med = statistics.median(window[:-1])
        if dt > self.cfg.straggler_factor * med:
            self._consecutive_slow += 1
            self.events.append({"kind": "straggler", "step": step,
                                "dt": dt, "median": med})
            if self._consecutive_slow >= self.cfg.straggler_limit:
                # mitigation: on a real cluster -> evict host + elastic
                # restore; single-process simulation re-bases the deadline.
                self.events.append({"kind": "straggler_mitigation",
                                    "step": step})
                self._step_times = [med]
                self._consecutive_slow = 0
        else:
            self._consecutive_slow = 0

    # ------------------------------------------------------------------
    def run(self, num_steps: int, *, slow_step_hook: Callable | None = None):
        """Run to self.step + num_steps with recovery; returns metrics log."""
        target = self.step + num_steps
        if latest_step(self.cfg.ckpt_dir) is None:
            self._checkpoint()                      # step-0 baseline
        while self.step < target:
            step = self.step
            try:
                if step in self.inject_failure_at:
                    exc = self.inject_failure_at.pop(step)
                    raise exc
                t0 = time.time()
                if slow_step_hook is not None:
                    slow_step_hook(step)
                batch = self.make_batch(step)
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                self._straggler_check(dt, step)
                if step % self.cfg.log_every == 0:
                    m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                    m["step"] = step
                    m["dt"] = dt
                    self.metrics_log.append(m)
                if ((self.service_client is not None
                     or self.monitor_cfg is not None)
                        and getattr(self.state, "monitor", None) is not None
                        and step % self.cfg.sketch_log_every == 0):
                    if self.service_client is not None:
                        self.service_client.publish(self.state.monitor)
                        self.sketch_log.append(
                            self.service_client.log_entry(step))
                    else:
                        est = monitor_estimate(self.monitor_cfg,
                                               self.state.monitor)
                        self.sketch_log.append({"step": step, **est["g"]})
                if step > 0 and step % self.cfg.ckpt_every == 0:
                    self._checkpoint()
            except Exception as e:                   # noqa: BLE001
                self.restarts += 1
                self.events.append({"kind": "failure", "step": step,
                                    "error": repr(e)})
                if self.restarts > self.cfg.max_restarts:
                    raise
                self._restore()
        self._checkpoint()
        return self.metrics_log
