"""Pallas TPU kernel: fused fingerprint -> multi-level Fast-AGMS ingest.

This is Step 1 of Algorithm 1 as ONE kernel launch.  The unfused path
(kernels/fingerprint.py + kernels/sketch_update.py) round-trips the (B, M)
fingerprint matrix through HBM between two dispatches and launches once per
lattice level; here every level's projection fingerprints are produced in
VMEM and immediately contracted into that level's counters, so the record
slab is read once and nothing intermediate ever leaves the chip:

  grid (L, w_tiles, b_blocks):
    level axis      -- parallel; each level has its own combo table, hash
                       coefficients, and (t, w) counter plane
    width axis      -- parallel; counters are tiled (t, block_w)
    batch axis      -- innermost + sequential: the (t, block_w) counter tile
                       stays resident in VMEM while every record block's
                       contribution accumulates into it (the deferred-flush
                       analogue of the cross-device merge deferral)

  per cell:  masked-Horner fingerprints (block_b, m_max) for this level's
             combos, flattened to a key block, then per depth row the
             one-hot bucket matrix is contracted against sign*weight on the
             MXU (exact in f32: products are +-1*weight and the contraction
             length block_b*m_max << 2^24).

Levels are padded to a rectangular (L, m_max) combo table; padded slots
carry weight 0 everywhere (enforced by the caller via
``projections.PaddedLattice.valid``), so they contribute nothing -- the
kernel output is bit-identical to the per-level reference chain
(asserted across remainders/depths/tiles in tests/test_fused_ingest.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import addmod_p31, cw_hash_pair, hash_sign, mulmod_p31, reduce_p31

DEFAULT_BLOCK_B = 256
DEFAULT_BLOCK_W = 1024


def _kernel(values_ref, masks_ref, ids_ref, bases_ref, wt_ref, counters_ref,
            bcoef_ref, scoef_ref, out_ref, *, d: int, depth: int, block_w: int):
    gb = pl.program_id(2)

    @pl.when(gb == 0)
    def _init():
        out_ref[...] = counters_ref[...]

    # --- fingerprints for this (record block, level) pair, in VMEM --------
    values = reduce_p31(values_ref[...])                     # (BB, d)
    masks = masks_ref[0]                                     # (M, d)
    seed = addmod_p31(reduce_p31(ids_ref[0]), jnp.uint32(1))  # (M,)
    fps = []
    for which in (0, 1):
        base = bases_ref[which]
        fp = jnp.broadcast_to(seed[None, :], (values.shape[0], seed.shape[0]))
        for col in range(d):                                 # d is static
            v = addmod_p31(values[:, col:col + 1], jnp.uint32(1))
            nxt = addmod_p31(mulmod_p31(fp, base), v)
            fp = jnp.where(masks[None, :, col] != 0, nxt, fp)
        fps.append(fp.reshape(-1))
    fp1, fp2 = fps                                           # (BB*M,) each

    # --- straight into the sketch: one-hot MXU contraction per depth row --
    weight = wt_ref[:, 0, :].reshape(-1).astype(jnp.float32)  # (BB*M,)
    w_total = out_ref.shape[2] * pl.num_programs(1)
    w_lo = (pl.program_id(1) * block_w).astype(jnp.int32)
    col = jax.lax.broadcasted_iota(jnp.int32, (fp1.shape[0], block_w), 1)
    for i in range(depth):                                   # depth is static
        hb = cw_hash_pair(fp1, fp2, bcoef_ref[0, i])
        bucket = (hb & jnp.uint32(w_total - 1)).astype(jnp.int32)
        onehot = (bucket[:, None] - w_lo == col).astype(jnp.float32)
        sign = hash_sign(cw_hash_pair(fp1, fp2, scoef_ref[0, i])).astype(jnp.float32)
        contrib = jnp.dot((sign * weight)[None, :], onehot,
                          preferred_element_type=jnp.float32)    # (1, BW)
        out_ref[0, i, :] += contrib[0].astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_w", "interpret"))
def fused_ingest_pallas(counters, values, masks, ids, bases,
                        bucket_coeffs, sign_coeffs, weights,
                        *, block_b: int = DEFAULT_BLOCK_B,
                        block_w: int = DEFAULT_BLOCK_W,
                        interpret: bool = True):
    """One launch: records -> fingerprints -> every level's sketch.

    counters (L, t, w) int32; values (B, d) uint32; masks (L, m_max, d) /
    ids (L, m_max) padded combo tables; bases (2,); bucket/sign_coeffs
    (L, t, 2, 4); weights (B, L, m_max) int32 with 0 in padded slots (and in
    masked-out rows).  Returns updated (L, t, w) counters.

    ``interpret=True`` is the CPU-correctness mode (this container); on real
    TPU pass interpret=False.
    """
    L, t, w = counters.shape
    B, d = values.shape
    m_max = ids.shape[1]
    values = values.astype(jnp.uint32)
    weights = weights.astype(jnp.int32)

    block_b = min(block_b, max(B, 8))
    block_w = min(block_w, w)
    # the bucket mask `& (w_total - 1)` and the untiled-tail hazard both
    # require power-of-two tiles that divide the (power-of-two) width
    assert w & (w - 1) == 0, "sketch width must be a power of two"
    assert block_w & (block_w - 1) == 0, \
        f"block_w={block_w} must be a power of two (so it divides w={w})"
    pad_b = (-B) % block_b
    if pad_b:
        values = jnp.pad(values, ((0, pad_b), (0, 0)))
        weights = jnp.pad(weights, ((0, pad_b), (0, 0), (0, 0)))
    b_pad = B + pad_b

    grid = (L, w // block_w, b_pad // block_b)
    kernel = functools.partial(_kernel, d=d, depth=t, block_w=block_w)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda l, gw, gb: (gb, 0)),
            pl.BlockSpec((1, m_max, d), lambda l, gw, gb: (l, 0, 0)),
            pl.BlockSpec((1, m_max), lambda l, gw, gb: (l, 0)),
            pl.BlockSpec((2,), lambda l, gw, gb: (0,)),
            pl.BlockSpec((block_b, 1, m_max), lambda l, gw, gb: (gb, l, 0)),
            pl.BlockSpec((1, t, block_w), lambda l, gw, gb: (l, 0, gw)),
            pl.BlockSpec((1, t, 2, 4), lambda l, gw, gb: (l, 0, 0, 0)),
            pl.BlockSpec((1, t, 2, 4), lambda l, gw, gb: (l, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, block_w), lambda l, gw, gb: (l, 0, gw)),
        out_shape=jax.ShapeDtypeStruct((L, t, w), jnp.int32),
        interpret=interpret,
    )(values, masks, ids, bases, weights, counters, bucket_coeffs, sign_coeffs)
