"""Pallas TPU kernel: Fast-AGMS sketch update as one-hot MXU matmuls.

The paper's hot loop is ``C[i, h2(e)] += h1(e)`` per stream element -- a
random-access scatter, which TPUs execute miserably.  TPU-native adaptation:
for a block of keys, build the (block, w_tile) one-hot bucket matrix and
contract it against the sign vector on the MXU:

    delta[i, :] = signs_i^T (1 x BN)  @  onehot_i (BN x BW)

Products are ±1 and the contraction length is the block size, so float32
accumulation is exact (|sum| <= BN << 2^24).  Counters stay resident in VMEM
across the sequential key-block grid dimension; the width dimension is
blocked as a parallel grid dimension (hashes are recomputed per width tile
-- 12 uint32 multiplies per key, negligible).

Grid: (num_key_blocks [sequential accumulate], num_width_blocks [parallel]).
The kernel emits counters_in + delta so callers treat it as a pure update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import cw_hash_pair, hash_sign

DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_W = 1024


def _kernel(fp1_ref, fp2_ref, weight_ref, counters_ref, bcoef_ref, scoef_ref,
            out_ref, *, depth: int, block_w: int):
    gn = pl.program_id(0)
    gw = pl.program_id(1)

    @pl.when(gn == 0)
    def _init():
        out_ref[...] = counters_ref[...]

    fp1 = fp1_ref[...]                      # (BN,) uint32
    fp2 = fp2_ref[...]
    weight = weight_ref[...].astype(jnp.float32)          # (BN,)
    w_lo = (gw * block_w).astype(jnp.int32)

    col = jax.lax.broadcasted_iota(jnp.int32, (fp1.shape[0], block_w), 1)
    for i in range(depth):                  # depth is small + static
        hb = cw_hash_pair(fp1, fp2, bcoef_ref[i])          # (BN,) uint32
        # global bucket id; the width tile covers [w_lo, w_lo + BW)
        bucket = (hb & jnp.uint32(out_ref.shape[1] * pl.num_programs(1) - 1)).astype(jnp.int32)
        onehot = (bucket[:, None] - w_lo == col).astype(jnp.float32)   # (BN, BW)
        sign = hash_sign(cw_hash_pair(fp1, fp2, scoef_ref[i])).astype(jnp.float32)
        contrib = jnp.dot((sign * weight)[None, :], onehot,
                          preferred_element_type=jnp.float32)          # (1, BW)
        out_ref[i, :] += contrib[0].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_w", "interpret"))
def sketch_update_pallas(counters, fp1, fp2, bucket_coeffs, sign_coeffs, weights,
                         *, block_n: int = DEFAULT_BLOCK_N,
                         block_w: int = DEFAULT_BLOCK_W,
                         interpret: bool = True):
    """counters (t, w) int32 + flat keys (N,) -> updated (t, w) counters.

    ``interpret=True`` is the CPU-correctness mode (this container); on real
    TPU pass interpret=False.  N is padded to a block multiple with weight-0
    elements; w must be a power of two (sketch invariant).
    """
    t, w = counters.shape
    fp1 = fp1.reshape(-1)
    fp2 = fp2.reshape(-1)
    weights = weights.reshape(-1).astype(jnp.int32)
    n = fp1.shape[0]

    block_n = min(block_n, max(n, 128))
    block_w = min(block_w, w)
    # non-divisor width tiles would leave tail columns unwritten and break
    # the `& (w_total - 1)` bucket mask -- fail loudly instead
    assert w & (w - 1) == 0, "sketch width must be a power of two"
    assert block_w & (block_w - 1) == 0, \
        f"block_w={block_w} must be a power of two (so it divides w={w})"
    pad = (-n) % block_n
    if pad:
        fp1 = jnp.pad(fp1, (0, pad))
        fp2 = jnp.pad(fp2, (0, pad))
        weights = jnp.pad(weights, (0, pad))
    n_pad = n + pad

    grid = (n_pad // block_n, w // block_w)
    kernel = functools.partial(_kernel, depth=t, block_w=block_w)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda gn, gw: (gn,)),
            pl.BlockSpec((block_n,), lambda gn, gw: (gn,)),
            pl.BlockSpec((block_n,), lambda gn, gw: (gn,)),
            pl.BlockSpec((t, block_w), lambda gn, gw: (0, gw)),
            pl.BlockSpec((t, 2, 4), lambda gn, gw: (0, 0, 0)),
            pl.BlockSpec((t, 2, 4), lambda gn, gw: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((t, block_w), lambda gn, gw: (0, gw)),
        out_shape=jax.ShapeDtypeStruct((t, w), jnp.int32),
        interpret=interpret,
    )(fp1, fp2, weights, counters, bucket_coeffs, sign_coeffs)
