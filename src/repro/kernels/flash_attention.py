"""Pallas TPU flash attention: O(S·block) HBM traffic for the train/prefill
hotspot.

EXPERIMENTS.md §Perf Iteration 4 showed the dominant memory term of every
attention train/prefill cell is the O(S²) score/probability matrices
materializing at XLA fusion boundaries — and that no jnp-level change
removes them (the dot operand must exist).  This kernel is the fix the
analysis calls for: the (bq, bk) score tile lives ONLY in VMEM scratch;
HBM sees just Q, K, V and O.  Memory-term napkin for deepseek-coder
train_4k attention: 35 TB -> ~0.3 TB per step per device (the residual
QKV/O streaming).

Layout: grid (BH, nq, nk) with the kv axis innermost (sequential); online
softmax state (m, l, acc) lives in VMEM scratch across the kv sweep, and
the output block is written once on the last kv step.  Causal tiles fully
above the diagonal are skipped with pl.when.  GQA: the index_map for K/V
divides the head index, so KV heads are never repeat-expanded in HBM.

``interpret=True`` validates on CPU (this container); compiled path is the
TPU target.  Oracle: models.attention.full_attention.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * block_q
    k_lo = ik * block_k

    def visible():      # any (q, k) pair in this tile with q >= k?
        return q_lo + block_q - 1 >= k_lo

    @pl.when((not causal) or visible())
    def _tile():
        q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where((m_new > 0.5 * NEG_INF)[:, None], p, 0.0)
        alpha = jnp.where(m_prev > 0.5 * NEG_INF,
                          jnp.exp(m_prev - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "q_heads_per_kv", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_k: int = 512,
                           q_heads_per_kv: int = 1, interpret: bool = True):
    """q (BH, Sq, hd) flattened over batch x q-heads; k, v (BKV, Skv, hd)
    flattened over batch x kv-heads, with BH = BKV * q_heads_per_kv
    (GQA: q head h reads kv head h // q_heads_per_kv -- no HBM expansion).

    Returns (BH, Sq, hd) in q.dtype.
    """
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    assert bh == bkv * q_heads_per_kv, (bh, bkv, q_heads_per_kv)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, block_q, skv, block_k)
    grid = (bh, sq // block_q, skv // block_k)
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, iq, ik, g=q_heads_per_kv: (b // g, ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, iq, ik, g=q_heads_per_kv: (b // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # normalizer l
            pltpu.VMEM((block_q, hd), jnp.float32),    # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True):
    """Model-layout wrapper: q (B, Sq, H, hd), k/v (B, Skv, KV, hd) ->
    (B, Sq, H, hd).  Flattens batch x heads, maps GQA via index arithmetic.
    """
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    # (B, S, H, hd) -> (B*H, S, hd) with h-major so h // g maps to kv head
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    of = flash_attention_pallas(qf, kf, vf, causal=causal, block_q=block_q,
                                block_k=block_k, q_heads_per_kv=g,
                                interpret=interpret)
    return of.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
