"""Pallas TPU kernel: fused batched query moments for stacked sketches.

Step 2 of Algorithm 1 (and its §6 join analogue) for MANY sketches at once:
given counter stacks of shape (N, L, t, w) -- N streams, L lattice levels,
depth t, width w -- compute every (stream, level, depth-row) moment

  out[i, l, k] = sum_j A[i, l, k, j] * B[i, l, k, j]

in ONE launch.  F2 (self-join) is the A = B case; the similarity-join
estimator uses two different stacks sketched with identical hash params.
The median over the depth axis and the lattice inversion are O(N*L*t)
scalars and stay in the surrounding jit (`sjpc._estimate_batch_core`).

  grid (N, L, w_tiles):
    stream axis     -- parallel; each stream owns an (L, t, w) counter block
    level axis      -- parallel; each level owns a (t, w) counter plane
    width axis      -- innermost + sequential: the (t,) accumulator stays
                      resident in VMEM while every (t, block_w) counter tile
                      of the plane reduces into it (counters-squared
                      reduction never leaves the chip)

f32 products/sums are exact while every partial sum stays below 2^24 --
the paper's O(log n)-bit counter analysis puts SJPC magnitudes well inside
that for the widths used here; the int64-exact numpy oracle
(`core.sketch.np_estimate_f2_exact` / `np_estimate_inner_exact`) remains
the reference for anything larger.  The pure-jnp fallback
(`kernels.ref.fused_query_ref`) is bit-identical on such exact-integer
inputs (asserted in tests/test_fused_query.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 2048


def _kernel(a_ref, b_ref, out_ref):
    gw = pl.program_id(2)

    @pl.when(gw == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[0, 0].astype(jnp.float32)          # (t, block_w)
    b = b_ref[0, 0].astype(jnp.float32)
    out_ref[0, 0] += jnp.sum(a * b, axis=-1)     # (t,)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def fused_query_pallas(counters_a, counters_b, *,
                       block_w: int = DEFAULT_BLOCK_W,
                       interpret: bool = True):
    """(N, L, t, w) x (N, L, t, w) -> (N, L, t) float32 row moments.

    ``interpret=True`` is the CPU-correctness mode (this container); on real
    TPU pass interpret=False.
    """
    assert counters_a.shape == counters_b.shape, \
        (counters_a.shape, counters_b.shape)
    N, L, t, w = counters_a.shape
    bw = min(block_w, w)
    # widths are powers of two (sketch invariant), so any pow2 tile divides
    assert w % bw == 0, f"block_w={bw} must divide width w={w}"
    return pl.pallas_call(
        _kernel,
        grid=(N, L, w // bw),
        in_specs=[
            pl.BlockSpec((1, 1, t, bw), lambda i, l, gw: (i, l, 0, gw)),
            pl.BlockSpec((1, 1, t, bw), lambda i, l, gw: (i, l, 0, gw)),
        ],
        out_specs=pl.BlockSpec((1, 1, t), lambda i, l, gw: (i, l, 0)),
        out_shape=jax.ShapeDtypeStruct((N, L, t), jnp.float32),
        interpret=interpret,
    )(counters_a, counters_b)
