"""Pallas TPU kernel: row-wise sketch moments (F2 / inner product).

For counters A, B of shape (t, w): out[i] = sum_j A[i, j] * B[i, j] in
float32 (exact for SJPC counter magnitudes: |c| <= stream length < 2^24
per the paper's O(log n)-bit counter analysis).  F2 is the self case A = B;
the similarity-join estimator (§6) uses two different sketches.

Width is blocked over a sequential grid dimension with a VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 2048


def _kernel(a_ref, b_ref, out_ref):
    gw = pl.program_id(0)

    @pl.when(gw == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(a * b, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def sketch_moments_pallas(counters_a, counters_b, *,
                          block_w: int = DEFAULT_BLOCK_W,
                          interpret: bool = True):
    """(t, w) x (t, w) -> (t,) float32 row inner products."""
    t, w = counters_a.shape
    bw = min(block_w, w)
    assert w % bw == 0
    return pl.pallas_call(
        _kernel,
        grid=(w // bw,),
        in_specs=[
            pl.BlockSpec((t, bw), lambda gw: (0, gw)),
            pl.BlockSpec((t, bw), lambda gw: (0, gw)),
        ],
        out_specs=pl.BlockSpec((t,), lambda gw: (0,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=interpret,
    )(counters_a, counters_b)
