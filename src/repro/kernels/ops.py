"""Public jit'd entry points for the SJPC kernels.

``use_pallas`` selects the Pallas path (interpret=True on CPU -- this
container -- or compiled on real TPU); the default dispatch picks Pallas on
TPU backends and the pure-jnp reference elsewhere, so the library is always
correct and becomes fast where it matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sketch import SketchParams
from repro.obs.metrics import default_registry
from . import ref
from .fingerprint import fingerprint_pallas
from .fused_ingest import fused_ingest_pallas
from .fused_pairs import fused_pairs_pallas
from .fused_query import fused_query_pallas
from .sketch_update import sketch_update_pallas
from .sketch_moments import sketch_moments_pallas
from .flash_attention import flash_attention as flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _count(kernel: str, use_pallas: bool) -> None:
    """``kernel_dispatch_total{kernel, path}`` in the process-global
    registry: which path (pallas vs jnp reference) each entry point
    resolved to.  Calls under an enclosing jit count once per *trace*,
    not per execution -- the number answers "which kernels compiled,
    via which path", the dispatch-shape question DESIGN.md §15.3 cares
    about."""
    reg = default_registry()
    if reg.enabled:
        reg.inc("kernel_dispatch_total", kernel=kernel,
                path="pallas" if use_pallas else "jnp")


def fingerprint(values, combo_masks, combo_ids, bases, *, use_pallas=None,
                interpret=None):
    """(B, d) records -> two (B, M) sub-value fingerprints."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("fingerprint", use_pallas)
    if not use_pallas:
        return ref.fingerprint_ref(values, combo_masks, combo_ids, bases)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return fingerprint_pallas(values, combo_masks, combo_ids, bases,
                              interpret=interpret)


def sketch_update(counters, fp1, fp2, params: SketchParams, weights,
                  *, use_pallas=None, interpret=None):
    """Fast-AGMS update of one (t, w) sketch with flat fingerprint keys."""
    if weights is None:
        weights = jnp.ones(fp1.reshape(-1).shape, jnp.int32)
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("sketch_update", use_pallas)
    if not use_pallas:
        return ref.sketch_update_ref(counters, fp1, fp2,
                                     params.bucket_coeffs, params.sign_coeffs,
                                     weights)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return sketch_update_pallas(counters, fp1, fp2,
                                params.bucket_coeffs, params.sign_coeffs,
                                weights, interpret=interpret)


def sketch_moments(counters_a, counters_b=None, *, use_pallas=None,
                   interpret=None):
    """Row inner products; F2 when counters_b is None."""
    if counters_b is None:
        counters_b = counters_a
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("sketch_moments", use_pallas)
    if not use_pallas:
        return ref.sketch_moments_ref(counters_a, counters_b)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return sketch_moments_pallas(counters_a, counters_b, interpret=interpret)


def fused_ingest(counters, values, masks, ids, bases, bucket_coeffs,
                 sign_coeffs, weights, *, use_pallas=None, interpret=None,
                 block_b=None, block_w=None):
    """Fused fingerprint -> multi-level sketch ingest, one launch.

    Padded-lattice layout (see ``projections.padded_lattice``): counters
    (L, t, w), values (B, d), masks (L, m_max, d), ids (L, m_max), coeffs
    (L, t, 2, 4), weights (B, L, m_max).  The Pallas path keeps fingerprints
    in VMEM and counters resident across the batch grid; the fallback is the
    unfused per-level reference chain (bit-identical output).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("fused_ingest", use_pallas)
    if not use_pallas:
        return ref.fused_ingest_ref(counters, values, masks, ids, bases,
                                    bucket_coeffs, sign_coeffs, weights)
    interpret = (not _on_tpu()) if interpret is None else interpret
    kwargs = {}
    if block_b is not None:
        kwargs["block_b"] = block_b
    if block_w is not None:
        kwargs["block_w"] = block_w
    return fused_ingest_pallas(counters, values, masks, ids, bases,
                               bucket_coeffs, sign_coeffs, weights,
                               interpret=interpret, **kwargs)


def fused_query(counters_a, counters_b=None, *, use_pallas=None,
                interpret=None, block_w=None):
    """Batched multi-level row moments for the fused query engine.

    counters (N, L, t, w) stacks -> (N, L, t) float32: every (stream, level,
    depth-row) F2 (``counters_b is None``) or cross-sketch inner product in
    one launch.  The Pallas path keeps the per-row accumulator VMEM-resident
    across width tiles; the fallback is the one-line jnp reduction
    (bit-identical on exact-integer inputs).
    """
    if counters_b is None:
        counters_b = counters_a
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("fused_query", use_pallas)
    if not use_pallas:
        return ref.fused_query_ref(counters_a, counters_b)
    interpret = (not _on_tpu()) if interpret is None else interpret
    kwargs = {} if block_w is None else {"block_w": block_w}
    return fused_query_pallas(counters_a, counters_b, interpret=interpret,
                              **kwargs)


def fused_pairs(items, valid, *, use_pallas=None, interpret=None,
                block_r=None):
    """All-pairs similarity histogram of stacked reservoir samples.

    items (..., R, d) uint32, valid (..., R) -> (..., d+1) int32 counts
    of ordered valid pairs agreeing on exactly k columns (the reservoir
    estimator's query hot path).  Extra leading dims collapse into the
    kernel's N grid axis and are restored on the output -- the bootstrap
    error bars (DESIGN.md §14) push their whole (streams, replicates)
    stack through ONE launch this way.  Pallas keeps the histogram
    accumulator VMEM-resident across pair tiles; the fallback is the jnp
    per-column reduction (bit-identical -- both are exact integer counts).
    """
    items = jnp.asarray(items)
    valid = jnp.asarray(valid)
    lead = items.shape[:-2]
    assert valid.shape == lead + items.shape[-2:-1], (items.shape,
                                                      valid.shape)
    R, d = items.shape[-2:]
    if R == 0:                                 # empty sample: zero histogram
        return jnp.zeros(lead + (d + 1,), jnp.int32)
    items = items.reshape((-1, R, d))
    valid = valid.reshape((-1, R))
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("fused_pairs", use_pallas)
    if not use_pallas:
        out = ref.fused_pairs_ref(items, valid)
    else:
        interpret = (not _on_tpu()) if interpret is None else interpret
        kwargs = {} if block_r is None else {"block_r": block_r}
        out = fused_pairs_pallas(items, valid, interpret=interpret, **kwargs)
    return out.reshape(lead + (d + 1,))


def make_sjpc_update_fn(*, use_pallas=None, interpret=None):
    """An ``update_fn`` for :func:`repro.core.sjpc.update` using kernels."""
    def fn(counters, fp1, fp2, level_params, weights):
        return sketch_update(counters, fp1, fp2, level_params, weights,
                             use_pallas=use_pallas, interpret=interpret)
    return fn


def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    use_pallas=None, interpret=None):
    """Memory-optimal attention (B,Sq,H,hd)x(B,Skv,KV,hd)->(B,Sq,H,hd).

    Pallas path keeps the score tiles in VMEM (the fix for the dominant
    memory term of train/prefill cells; EXPERIMENTS.md §Perf It. 4); the
    fallback is the jnp online-softmax chunked implementation.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count("flash_attention", use_pallas)
    if not use_pallas:
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal,
                                 q_chunk=block_q, kv_chunk=block_k)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_kernel(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
