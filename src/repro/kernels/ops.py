"""Public jit'd entry points for the SJPC kernels, routed through the
capability registry (``kernels/registry.py``, DESIGN.md §17).

Each op registers its named implementations below -- the ``jnp_ref``
oracle itself, the ``pallas_tpu`` tier, a ``pallas_gpu`` Triton/Mosaic
lowering for the four fused kernels, and a ``pallas_interpret`` tier
(the TPU kernel under the Pallas interpreter, runnable anywhere) -- and
dispatch resolves the fastest available one for the current backend.

The legacy keyword surface is preserved: ``use_pallas=True`` picks the
native pallas tier for this backend (interpreter elsewhere),
``use_pallas=False`` pins the jnp reference, and the new ``impl=`` kwarg
forces any registered implementation by name.  Explicit ``use_pallas=``/
``impl=`` always wins over :meth:`KernelRegistry.force` /
``REPRO_KERNEL_IMPL`` pinning, which only redirect auto dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sketch import SketchParams
from repro.obs.metrics import default_registry
from . import ref, registry
from .registry import (JNP_REF, PALLAS_GPU, PALLAS_INTERPRET, PALLAS_TPU,
                       PRIORITY_INTERPRET, PRIORITY_NATIVE, PRIORITY_REF,
                       KernelImpl, kernel_registry, on_platforms)
from .fingerprint import fingerprint_pallas
from .fused_ingest import fused_ingest_pallas
from .fused_pairs import fused_pairs_pallas
from .fused_query import fused_query_pallas
from .sketch_update import sketch_update_pallas
from .sketch_moments import sketch_moments_pallas
from .flash_attention import flash_attention as flash_attention_kernel
from . import gpu

_REG = kernel_registry()

GPU_PLATFORMS = ("gpu", "cuda", "rocm")


def _any_platform(_platform: str) -> bool:
    return True


def _count(kernel: str, impl: KernelImpl) -> None:
    """``kernel_dispatch_total{kernel, path, impl}`` in the process-global
    registry: which implementation each entry point resolved to.  ``path``
    keeps the legacy two-way pallas/jnp label; ``impl`` is the registry
    name.  Calls under an enclosing jit count once per *trace*, not per
    execution -- the number answers "which kernels compiled, via which
    implementation", the dispatch-shape question DESIGN.md §15.3 cares
    about."""
    reg = default_registry()
    if reg.enabled:
        reg.inc("kernel_dispatch_total", kernel=kernel, path=impl.path,
                impl=impl.name)


def _pallas_impl(op: str) -> KernelImpl:
    """The pallas tier ``use_pallas=True`` means on this backend: the
    native compiled tier if the op has one here, else the interpreter."""
    platform = jax.default_backend()
    names = {i.name for i in _REG.impls(op)}
    if platform == "tpu" and PALLAS_TPU in names:
        return _REG.get(op, PALLAS_TPU)
    if platform in GPU_PLATFORMS and PALLAS_GPU in names:
        return _REG.get(op, PALLAS_GPU)
    return _REG.get(op, PALLAS_INTERPRET)


def _dispatch(op: str, use_pallas, impl: str | None) -> KernelImpl:
    """Resolve one call's implementation and account for it."""
    if impl is not None:
        chosen = _REG.get(op, impl)
    elif use_pallas is None:
        chosen = _REG.resolve(op)
    elif use_pallas:
        chosen = _pallas_impl(op)
    else:
        chosen = _REG.get(op, JNP_REF)
    _count(op, chosen)
    return chosen


def _drop_blocks(fn):
    """Adapt a ref.py oracle to the dispatch calling convention: ignore
    the tile-size hints that only parameterize pallas tiers."""
    @functools.wraps(fn)
    def wrapped(*args, **kw):
        return fn(*args, **{k: v for k, v in kw.items()
                            if not k.startswith("block_")})
    return wrapped


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def fingerprint(values, combo_masks, combo_ids, bases, *, use_pallas=None,
                interpret=None, impl=None):
    """(B, d) records -> two (B, M) sub-value fingerprints."""
    chosen = _dispatch("fingerprint", use_pallas, impl)
    return chosen.call(values, combo_masks, combo_ids, bases,
                       interpret=interpret)


def sketch_update(counters, fp1, fp2, params: SketchParams, weights,
                  *, use_pallas=None, interpret=None, impl=None):
    """Fast-AGMS update of one (t, w) sketch with flat fingerprint keys."""
    if weights is None:
        weights = jnp.ones(fp1.reshape(-1).shape, jnp.int32)
    chosen = _dispatch("sketch_update", use_pallas, impl)
    return chosen.call(counters, fp1, fp2, params.bucket_coeffs,
                       params.sign_coeffs, weights, interpret=interpret)


def sketch_moments(counters_a, counters_b=None, *, use_pallas=None,
                   interpret=None, impl=None):
    """Row inner products; F2 when counters_b is None."""
    if counters_b is None:
        counters_b = counters_a
    chosen = _dispatch("sketch_moments", use_pallas, impl)
    return chosen.call(counters_a, counters_b, interpret=interpret)


def fused_ingest(counters, values, masks, ids, bases, bucket_coeffs,
                 sign_coeffs, weights, *, use_pallas=None, interpret=None,
                 impl=None, block_b=None, block_w=None):
    """Fused fingerprint -> multi-level sketch ingest, one launch.

    Padded-lattice layout (see ``projections.padded_lattice``): counters
    (L, t, w), values (B, d), masks (L, m_max, d), ids (L, m_max), coeffs
    (L, t, 2, 4), weights (B, L, m_max).  The Pallas tiers keep fingerprints
    on-chip and counters resident across the batch; the fallback is the
    unfused per-level reference chain (bit-identical output).
    """
    chosen = _dispatch("fused_ingest", use_pallas, impl)
    kwargs = {}
    if block_b is not None:
        kwargs["block_b"] = block_b
    if block_w is not None:
        kwargs["block_w"] = block_w
    return chosen.call(counters, values, masks, ids, bases, bucket_coeffs,
                       sign_coeffs, weights, interpret=interpret, **kwargs)


def fused_query(counters_a, counters_b=None, *, use_pallas=None,
                interpret=None, impl=None, block_w=None):
    """Batched multi-level row moments for the fused query engine.

    counters (N, L, t, w) stacks -> (N, L, t) float32: every (stream, level,
    depth-row) F2 (``counters_b is None``) or cross-sketch inner product in
    one launch.  The Pallas tiers keep the per-row accumulator on-chip; the
    fallback is the one-line jnp reduction (bit-identical on exact-integer
    inputs).
    """
    if counters_b is None:
        counters_b = counters_a
    chosen = _dispatch("fused_query", use_pallas, impl)
    kwargs = {} if block_w is None else {"block_w": block_w}
    return chosen.call(counters_a, counters_b, interpret=interpret, **kwargs)


def fused_pairs(items, valid, *, use_pallas=None, interpret=None, impl=None,
                block_r=None):
    """All-pairs similarity histogram of stacked reservoir samples.

    items (..., R, d) uint32, valid (..., R) -> (..., d+1) int32 counts
    of ordered valid pairs agreeing on exactly k columns (the reservoir
    estimator's query hot path).  Extra leading dims collapse into the
    kernel's N grid axis and are restored on the output -- the bootstrap
    error bars (DESIGN.md §14) push their whole (streams, replicates)
    stack through ONE launch this way.  The Pallas tiers keep the histogram
    accumulator on-chip across pair tiles; the fallback is the jnp
    per-column reduction (bit-identical -- both are exact integer counts).
    """
    items = jnp.asarray(items)
    valid = jnp.asarray(valid)
    lead = items.shape[:-2]
    assert valid.shape == lead + items.shape[-2:-1], (items.shape,
                                                      valid.shape)
    R, d = items.shape[-2:]
    chosen = _dispatch("fused_pairs", use_pallas, impl)
    if R == 0:
        # empty sample: the zero histogram still goes through dispatch
        # accounting above, so empty-reservoir queries remain visible to
        # kernel_dispatch_total
        return jnp.zeros(lead + (d + 1,), jnp.int32)
    items = items.reshape((-1, R, d))
    valid = valid.reshape((-1, R))
    kwargs = {} if block_r is None else {"block_r": block_r}
    out = chosen.call(items, valid, interpret=interpret, **kwargs)
    return out.reshape(lead + (d + 1,))


def make_sjpc_update_fn(*, use_pallas=None, interpret=None, impl=None):
    """An ``update_fn`` for :func:`repro.core.sjpc.update` using kernels."""
    def fn(counters, fp1, fp2, level_params, weights):
        return sketch_update(counters, fp1, fp2, level_params, weights,
                             use_pallas=use_pallas, interpret=interpret,
                             impl=impl)
    return fn


def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    use_pallas=None, interpret=None, impl=None):
    """Memory-optimal attention (B,Sq,H,hd)x(B,Skv,KV,hd)->(B,Sq,H,hd).

    Pallas path keeps the score tiles in VMEM (the fix for the dominant
    memory term of train/prefill cells; EXPERIMENTS.md §Perf It. 4); the
    fallback is the jnp online-softmax chunked implementation.
    """
    chosen = _dispatch("flash_attention", use_pallas, impl)
    return chosen.call(q, k, v, causal=causal, block_q=block_q,
                       block_k=block_k, interpret=interpret)


# ---------------------------------------------------------------------------
# registrations: seven ops x {jnp_ref, pallas tiers}
# ---------------------------------------------------------------------------
# The jnp_ref rows register the oracle AS an implementation pointing at
# itself -- the reference tier is definitionally conformant, and keeping it
# in the matrix means the conformance tests also pin the oracle's own
# calling convention.

def _register_all(reg=_REG) -> None:
    def ref_impl(op, fn):
        reg.register(op, JNP_REF, fn=_drop_blocks(fn), oracle=fn,
                     predicate=_any_platform, priority=PRIORITY_REF,
                     takes_interpret=False)

    def tpu_impl(op, fn, oracle):
        reg.register(op, PALLAS_TPU, fn=fn, oracle=oracle,
                     predicate=on_platforms("tpu"), priority=PRIORITY_NATIVE,
                     native=("tpu",))

    def gpu_impl(op, fn, oracle):
        reg.register(op, PALLAS_GPU, fn=fn, oracle=oracle,
                     predicate=on_platforms(*GPU_PLATFORMS),
                     priority=PRIORITY_NATIVE, native=GPU_PLATFORMS)

    def interp_impl(op, fn, oracle):
        # the TPU kernel under the Pallas interpreter: correct on every
        # backend (native=() so interpret defaults to True everywhere),
        # priority below jnp_ref so it only runs when forced
        reg.register(op, PALLAS_INTERPRET, fn=fn, oracle=oracle,
                     predicate=_any_platform, priority=PRIORITY_INTERPRET)

    ref_impl("fingerprint", ref.fingerprint_ref)
    tpu_impl("fingerprint", fingerprint_pallas, ref.fingerprint_ref)
    gpu_impl("fingerprint", gpu.fingerprint_gpu, ref.fingerprint_ref)
    interp_impl("fingerprint", fingerprint_pallas, ref.fingerprint_ref)

    ref_impl("sketch_update", ref.sketch_update_ref)
    tpu_impl("sketch_update", sketch_update_pallas, ref.sketch_update_ref)
    interp_impl("sketch_update", sketch_update_pallas, ref.sketch_update_ref)

    ref_impl("sketch_moments", ref.sketch_moments_ref)
    tpu_impl("sketch_moments", sketch_moments_pallas, ref.sketch_moments_ref)
    interp_impl("sketch_moments", sketch_moments_pallas,
                ref.sketch_moments_ref)

    ref_impl("fused_ingest", ref.fused_ingest_ref)
    tpu_impl("fused_ingest", fused_ingest_pallas, ref.fused_ingest_ref)
    gpu_impl("fused_ingest", gpu.fused_ingest_gpu, ref.fused_ingest_ref)
    interp_impl("fused_ingest", fused_ingest_pallas, ref.fused_ingest_ref)

    ref_impl("fused_query", ref.fused_query_ref)
    tpu_impl("fused_query", fused_query_pallas, ref.fused_query_ref)
    gpu_impl("fused_query", gpu.fused_query_gpu, ref.fused_query_ref)
    interp_impl("fused_query", fused_query_pallas, ref.fused_query_ref)

    ref_impl("fused_pairs", ref.fused_pairs_ref)
    tpu_impl("fused_pairs", fused_pairs_pallas, ref.fused_pairs_ref)
    gpu_impl("fused_pairs", gpu.fused_pairs_gpu, ref.fused_pairs_ref)
    interp_impl("fused_pairs", fused_pairs_pallas, ref.fused_pairs_ref)

    reg.register("flash_attention", JNP_REF, fn=ref.flash_attention_ref,
                 oracle=ref.flash_attention_ref, predicate=_any_platform,
                 priority=PRIORITY_REF, takes_interpret=False)
    tpu_impl("flash_attention", flash_attention_kernel,
             ref.flash_attention_ref)
    interp_impl("flash_attention", flash_attention_kernel,
                ref.flash_attention_ref)

    reg.check()


_register_all()

# re-exported for call sites that want the registry without a second import
__all__ = [
    "fingerprint", "sketch_update", "sketch_moments", "fused_ingest",
    "fused_query", "fused_pairs", "flash_attention", "make_sjpc_update_fn",
    "kernel_registry", "registry",
]
