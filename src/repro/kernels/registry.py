"""Kernel capability registry (DESIGN.md §17).

``kernels/ops.py`` used to hard-code seven ``if use_pallas`` dispatchers;
this module replaces them with a declarative capability table.  Each op
registers named implementations -- ``jnp_ref``, ``pallas_tpu``,
``pallas_interpret``, and (for the four fused kernels) a ``pallas_gpu``
Triton/Mosaic-GPU lowering -- where every registration carries:

  * a **platform predicate** (``jax.default_backend()`` string -> bool):
    where the implementation runs natively;
  * a **priority**: among the available implementations the highest
    priority wins (native compiled tiers > jnp reference > interpreter);
  * a **mandatory oracle pointer** into ``kernels/ref.py``: the pure-jnp
    semantic ground truth the implementation must match bit-exact (integer
    kernels) or to <= 1e-6 (flash attention).  ``register`` *refuses*
    an implementation without a callable oracle, so the conformance matrix
    in tests/test_kernel_registry.py -- generated from this registry -- can
    never silently under-cover a backend.

Dispatch (``resolve``) picks the fastest available implementation for the
current backend; tests and the CI ``pallas-interpret`` lane can pin any op
(or every op) to a named implementation via :meth:`KernelRegistry.force`
or the ``REPRO_KERNEL_IMPL`` environment variable
(``pallas_interpret`` or ``fused_pairs=pallas_gpu,*=jnp_ref``).  A forced
implementation only overrides *auto* dispatch -- call sites that pass an
explicit ``use_pallas=``/``impl=`` (the conformance oracles) keep what
they asked for.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable

import jax

# conventional tier names (ops may register more)
JNP_REF = "jnp_ref"
PALLAS_TPU = "pallas_tpu"
PALLAS_GPU = "pallas_gpu"
PALLAS_INTERPRET = "pallas_interpret"

FORCE_ENV = "REPRO_KERNEL_IMPL"

# priorities: native compiled tiers beat the jnp reference, which beats the
# interpreter (correct everywhere, fast nowhere -- forced for conformance)
PRIORITY_NATIVE = 100
PRIORITY_REF = 50
PRIORITY_INTERPRET = 10


class RegistryError(ValueError):
    """A registration or completeness-contract violation."""


def _always(_platform: str) -> bool:
    return True


def on_platforms(*names: str) -> Callable[[str], bool]:
    """Predicate factory: native on exactly these backend names."""
    def pred(platform: str) -> bool:
        return platform in names
    return pred


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation of one op."""

    op: str
    name: str
    fn: Callable                       # fn(*args, **kw) -> out
    oracle: Callable                   # ground truth (kernels/ref.py)
    predicate: Callable[[str], bool]   # platform -> natively available
    priority: int
    native: tuple[str, ...] = ()       # platforms where compiled lowering
                                       # works (interpret defaults to True
                                       # anywhere else); () = interpret-only
    takes_interpret: bool = True       # fn accepts an ``interpret=`` kwarg

    @property
    def path(self) -> str:
        """The legacy two-way metric label (pallas vs jnp reference)."""
        return "jnp" if self.name == JNP_REF else "pallas"

    def available(self, platform: str) -> bool:
        return bool(self.predicate(platform))

    def call(self, *args, interpret: bool | None = None,
             platform: str | None = None, **kw):
        """Invoke with the op's canonical positional args.

        ``interpret=None`` resolves to "interpreter unless this platform is
        one the impl compiles natively on" -- the same auto rule the old
        hand-written dispatchers applied per call site.
        """
        if self.takes_interpret:
            if interpret is None:
                if platform is None:
                    platform = jax.default_backend()
                interpret = platform not in self.native
            kw["interpret"] = interpret
        return self.fn(*args, **kw)


def _parse_force(spec: str) -> dict[str, str]:
    """``"pallas_interpret"`` -> {"*": ...};
    ``"fused_pairs=pallas_gpu,*=jnp_ref"`` -> per-op map."""
    out: dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            op, name = part.split("=", 1)
            out[op.strip()] = name.strip()
        else:
            out["*"] = part
    return out


class KernelRegistry:
    """The per-process capability table.  ``kernel_registry()`` is the
    instance ops.py populates at import; tests may build private ones."""

    def __init__(self):
        self._impls: dict[str, dict[str, KernelImpl]] = {}
        self._forced: dict[str, str] = {}
        self._env_cache: tuple[str | None, dict[str, str]] = (None, {})

    # -- registration ---------------------------------------------------
    def register(self, op: str, name: str, *, fn: Callable,
                 oracle: Callable, predicate: Callable[[str], bool],
                 priority: int, native: tuple[str, ...] = (),
                 takes_interpret: bool = True) -> KernelImpl:
        """Register one implementation.  The oracle is MANDATORY: an impl
        with no (or a non-callable) oracle is rejected here, which makes
        the registry-generated conformance matrix fail at *collection*
        rather than at someone remembering to extend a test file."""
        if not callable(oracle):
            raise RegistryError(
                f"{op}/{name}: every registered kernel implementation must "
                f"point at its conformance oracle in kernels/ref.py "
                f"(got {oracle!r})")
        if not callable(fn):
            raise RegistryError(f"{op}/{name}: fn must be callable")
        if not callable(predicate):
            raise RegistryError(f"{op}/{name}: predicate must be callable")
        ops = self._impls.setdefault(op, {})
        if name in ops:
            raise RegistryError(f"{op}/{name}: already registered")
        impl = KernelImpl(op=op, name=name, fn=fn, oracle=oracle,
                          predicate=predicate, priority=priority,
                          native=tuple(native),
                          takes_interpret=takes_interpret)
        ops[name] = impl
        return impl

    # -- introspection --------------------------------------------------
    def ops(self) -> tuple[str, ...]:
        return tuple(sorted(self._impls))

    def impls(self, op: str) -> tuple[KernelImpl, ...]:
        try:
            fam = self._impls[op]
        except KeyError:
            raise RegistryError(f"unknown kernel op {op!r}") from None
        return tuple(fam[n] for n in sorted(fam))

    def get(self, op: str, name: str) -> KernelImpl:
        fam = self._impls.get(op, {})
        if name not in fam:
            raise RegistryError(
                f"{op!r} has no implementation named {name!r} "
                f"(registered: {sorted(fam)})")
        return fam[name]

    def matrix(self) -> list[tuple[str, str]]:
        """Every (op, impl name) pair -- the conformance-matrix axis."""
        return [(op, impl.name) for op in self.ops()
                for impl in self.impls(op)]

    # -- completeness contract ------------------------------------------
    def check(self) -> None:
        """The CI completeness gate: every op has >= 2 implementations,
        every op has the jnp reference fallback, and (enforced at register
        time, re-asserted here) every impl carries a callable oracle."""
        problems = []
        for op in self.ops():
            fam = self.impls(op)
            if len(fam) < 2:
                problems.append(f"{op}: only {len(fam)} implementation(s); "
                                f"need >= 2 (a native tier and a fallback)")
            if JNP_REF not in {i.name for i in fam}:
                problems.append(f"{op}: missing the {JNP_REF} fallback")
            for impl in fam:
                if not callable(impl.oracle):
                    problems.append(f"{op}/{impl.name}: oracle not callable")
        if problems:
            raise RegistryError("kernel registry incomplete:\n  "
                                + "\n  ".join(problems))

    # -- forcing --------------------------------------------------------
    @contextlib.contextmanager
    def force(self, name: str, op: str = "*"):
        """Pin auto dispatch of ``op`` (or every op) to impl ``name``."""
        prev = self._forced.get(op)
        self._forced[op] = name
        try:
            yield
        finally:
            if prev is None:
                self._forced.pop(op, None)
            else:
                self._forced[op] = prev

    def _env_forced(self) -> dict[str, str]:
        spec = os.environ.get(FORCE_ENV)
        cached_spec, cached = self._env_cache
        if spec != cached_spec:
            cached = _parse_force(spec) if spec else {}
            self._env_cache = (spec, cached)
        return cached

    def forced_name(self, op: str) -> str | None:
        """The forced impl name for ``op``, if any (context manager wins
        over the environment; per-op entries win over ``*``)."""
        for source in (self._forced, self._env_forced()):
            name = source.get(op, source.get("*"))
            if name is not None:
                return name
        return None

    # -- resolution -----------------------------------------------------
    def resolve(self, op: str, platform: str | None = None) -> KernelImpl:
        """The fastest implementation for this backend: a forced name if
        one is registered for the op, else highest priority among impls
        whose platform predicate holds (ties break lexicographically)."""
        if platform is None:
            platform = jax.default_backend()
        fam = self._impls.get(op)
        if not fam:
            raise RegistryError(f"unknown kernel op {op!r}")
        forced = self.forced_name(op)
        if forced is not None and forced in fam:
            return fam[forced]
        best = None
        for impl in fam.values():
            if not impl.available(platform):
                continue
            if best is None or (impl.priority, impl.name) > (best.priority,
                                                             best.name):
                best = impl
        if best is None:
            raise RegistryError(
                f"{op!r}: no implementation available on platform "
                f"{platform!r} (registered: {sorted(fam)})")
        return best

    def resolution(self, platform: str | None = None) -> dict[str, str]:
        """op -> resolved impl name for this backend (what benchmarks
        record next to their rows)."""
        return {op: self.resolve(op, platform).name for op in self.ops()}


_REGISTRY = KernelRegistry()


def kernel_registry() -> KernelRegistry:
    """The process-global registry, populated by ``kernels.ops`` at
    import (importing ops is what fills it)."""
    return _REGISTRY
