"""Pallas TPU kernel: batched sub-value fingerprinting (masked Horner).

Computes the (B, M) matrix of polynomial fingerprints of every record
projected under every level-k column combination -- the projection-
generation step of Algorithm 1, fully dense (no gathers; excluded columns
are `where`-skipped using the static combination-mask table).

Tiling: grid (B_tiles, M_tiles); each kernel instance holds a
(block_b, d) slab of records and a (block_m, d) slab of combination masks in
VMEM and emits a (block_b, block_m) fingerprint tile.  d is a static python
loop (d <= ~12 for SJPC's practical regime, paper §9).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import addmod_p31, mulmod_p31, reduce_p31

DEFAULT_BLOCK_B = 256
DEFAULT_BLOCK_M = 256


def _kernel(values_ref, masks_ref, ids_ref, bases_ref, out1_ref, out2_ref, *, d: int):
    values = reduce_p31(values_ref[...])                 # (BB, d)
    seed = addmod_p31(reduce_p31(ids_ref[...]), jnp.uint32(1))   # (BM,)
    for which, out_ref in ((0, out1_ref), (1, out2_ref)):
        base = bases_ref[which]
        fp = jnp.broadcast_to(seed[None, :], (values.shape[0], seed.shape[0]))
        for col in range(d):
            v = addmod_p31(values[:, col:col + 1], jnp.uint32(1))     # (BB, 1)
            nxt = addmod_p31(mulmod_p31(fp, base), v)
            fp = jnp.where(masks_ref[...][None, :, col] != 0, nxt, fp)
        out_ref[...] = fp


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "interpret"))
def fingerprint_pallas(values, combo_masks, combo_ids, bases,
                       *, block_b: int = DEFAULT_BLOCK_B,
                       block_m: int = DEFAULT_BLOCK_M,
                       interpret: bool = True):
    """values (B, d) x combos (M, d) -> (fp1, fp2) each (B, M) uint32."""
    values = values.astype(jnp.uint32)
    combo_masks = combo_masks.astype(jnp.uint32)
    combo_ids = combo_ids.astype(jnp.uint32)
    B, d = values.shape
    M = combo_ids.shape[0]

    bb = min(block_b, max(B, 8))
    bm = min(block_m, max(M, 128))
    pad_b = (-B) % bb
    pad_m = (-M) % bm
    if pad_b:
        values = jnp.pad(values, ((0, pad_b), (0, 0)))
    if pad_m:
        combo_masks = jnp.pad(combo_masks, ((0, pad_m), (0, 0)))
        combo_ids = jnp.pad(combo_ids, (0, pad_m))

    grid = (values.shape[0] // bb, combo_ids.shape[0] // bm)
    out_shape = (values.shape[0], combo_ids.shape[0])
    fp1, fp2 = pl.pallas_call(
        functools.partial(_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda gb, gm: (gb, 0)),
            pl.BlockSpec((bm, d), lambda gb, gm: (gm, 0)),
            pl.BlockSpec((bm,), lambda gb, gm: (gm,)),
            pl.BlockSpec((2,), lambda gb, gm: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda gb, gm: (gb, gm)),
            pl.BlockSpec((bb, bm), lambda gb, gm: (gb, gm)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(out_shape, jnp.uint32),
            jax.ShapeDtypeStruct(out_shape, jnp.uint32),
        ],
        interpret=interpret,
    )(values, combo_masks, combo_ids, bases)
    return fp1[:B, :M], fp2[:B, :M]
