"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels/tests assert against
(`np.testing.assert_allclose` / exact equality for integer outputs).  These
are also the implementations used on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import cw_hash_pair, hash_bucket, hash_sign
from repro.core.fingerprint import subvalue_fingerprints as _fp_ref


def fingerprint_ref(values, combo_masks, combo_ids, bases):
    """(B, d) records x (M, d) combination masks -> two (B, M) fingerprints."""
    return _fp_ref(values, combo_masks, combo_ids, bases)


def sketch_update_ref(counters, fp1, fp2, bucket_coeffs, sign_coeffs, weights):
    """Scatter-add reference for the Fast-AGMS update.

    counters: (t, w) int32; fp1/fp2/weights: (N,) flat.
    """
    t, w = counters.shape
    fp1 = fp1.reshape(-1)
    fp2 = fp2.reshape(-1)
    weights = weights.reshape(-1).astype(jnp.int32)

    def row(c_row, bc, sc):
        b = hash_bucket(cw_hash_pair(fp1, fp2, bc), w)
        s = hash_sign(cw_hash_pair(fp1, fp2, sc)) * weights
        return c_row.at[b].add(s)

    return jax.vmap(row)(counters, bucket_coeffs, sign_coeffs)


def fused_ingest_ref(counters, values, masks, ids, bases,
                     bucket_coeffs, sign_coeffs, weights):
    """Padded-layout oracle for the fused ingest kernel: the unfused
    fingerprint -> per-level scatter chain on the same rectangular tables.

    counters (L, t, w) int32; values (B, d) uint32; masks (L, m_max, d);
    ids (L, m_max); bases (2,); bucket/sign_coeffs (L, t, 2, 4); weights
    (B, L, m_max) int32 (0 in padded combo slots and masked-out rows).
    """
    outs = []
    for lvl in range(counters.shape[0]):
        fp1, fp2 = _fp_ref(values, masks[lvl], ids[lvl], bases)
        outs.append(sketch_update_ref(counters[lvl], fp1, fp2,
                                      bucket_coeffs[lvl], sign_coeffs[lvl],
                                      weights[:, lvl, :]))
    return jnp.stack(outs)


def sketch_moments_ref(counters_a, counters_b):
    """Row-wise inner products  sum_j A[i,j] * B[i,j]  -> (t,) float32.

    F2 = sketch_moments_ref(c, c); join inner product uses two sketches.
    """
    return jnp.sum(counters_a.astype(jnp.float32) * counters_b.astype(jnp.float32),
                   axis=-1)


def fused_pairs_ref(items, valid):
    """All-pairs similarity histograms of stacked samples (reservoir query).

    items (N, R, d) uint32; valid (N, R) int32 -> (N, d+1) int32:
    out[i, k] = #ordered pairs (a != b, both slots valid) of stream i's
    sample whose records agree on exactly k columns.  Bit-identical to the
    Pallas kernel (both count in exact integer arithmetic); the O(n^2)
    numpy oracle is core.exact.brute_force_pair_counts per sample.
    """
    items = items.astype(jnp.uint32)
    N, R, d = items.shape
    if R == 0:
        return jnp.zeros((N, d + 1), jnp.int32)
    valid = valid.astype(jnp.int32)
    # (N, R, R) match counts, built per column to avoid an (N, R, R, d) blob
    match = jnp.zeros((N, R, R), jnp.int32)
    for c in range(d):
        match += (items[:, :, None, c] == items[:, None, :, c]) \
            .astype(jnp.int32)
    ok = (valid[:, :, None] != 0) & (valid[:, None, :] != 0) \
        & ~jnp.eye(R, dtype=bool)[None]
    flat = jnp.where(ok, match, -1)                        # -1 = masked out
    # bin per level (d+1 passes over the (N, R, R) match tensor) rather
    # than one (N, R, R, d+1) one-hot -- at reservoir capacities R ~ 2.6k
    # that blob would be ~200 MB per query on the CPU path
    return jnp.stack([jnp.sum((flat == k).astype(jnp.int32), axis=(1, 2))
                      for k in range(d + 1)], axis=1)


def flash_attention_ref(q, k, v, *, causal=True, block_q=512, block_k=512):
    """Online-softmax chunked attention, model layout (B, S, H, hd).

    The chunked jnp implementation from ``repro.models.attention`` is the
    semantic ground truth of the flash kernel (<= 1e-6 in f32; the only
    non-integer oracle in this file).  Imported lazily so importing the
    kernels package never pulls in the models tree."""
    from repro.models.attention import chunked_attention
    return chunked_attention(q, k, v, causal=causal,
                             q_chunk=block_q, kv_chunk=block_k)


def fused_query_ref(counters_a, counters_b):
    """Batched multi-level row moments: (N, L, t, w) x (N, L, t, w) ->
    (N, L, t) float32.  Oracle for the fused query kernel; bit-identical to
    it whenever all partial sums are exact-integer f32 (< 2^24), which the
    SJPC counter magnitudes guarantee for the widths in use.  The reduction
    is exactly :func:`sketch_moments_ref` broadcast over the (N, L) leading
    dims -- one implementation, one exactness contract."""
    return sketch_moments_ref(counters_a, counters_b)
