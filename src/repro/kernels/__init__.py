"""Pallas TPU kernels for the SJPC hot path (validated in interpret mode on
CPU against the pure-jnp oracles in ref.py)."""
from .ops import fingerprint, sketch_update, sketch_moments, make_sjpc_update_fn  # noqa: F401
