"""Pallas TPU kernels for the SJPC hot path (validated in interpret mode on
CPU against the pure-jnp oracles in ref.py)."""
from .ops import (fingerprint, fused_query, sketch_update,  # noqa: F401
                  sketch_moments, make_sjpc_update_fn)
