"""SJPC kernel package: jnp oracles, Pallas TPU/GPU tiers, and the
capability registry that dispatches between them (validated in interpret
mode on CPU against the pure-jnp oracles in ref.py)."""
from .ops import (fingerprint, fused_ingest, fused_pairs,  # noqa: F401
                  fused_query, sketch_update, sketch_moments,
                  flash_attention, make_sjpc_update_fn)
from .registry import kernel_registry, KernelRegistry  # noqa: F401
