"""Pallas GPU (Triton / Mosaic-GPU lowering) tier for the four fused
kernels: ``fingerprint``, ``fused_ingest``, ``fused_query``,
``fused_pairs``.

The TPU kernels in this package lean on a TPU-only guarantee: grid axes
iterate *sequentially*, so a kernel may revisit the same output block
across grid steps and accumulate into it (the VMEM-resident-accumulator
pattern).  On GPU every grid cell is an independent, concurrently-running
program -- cross-step accumulation into a shared output block is a data
race.  These lowerings therefore restructure each kernel so that every
program owns its output block exclusively:

  fingerprint    (B_tiles, M_tiles) grid -- already race-free (each tile
                 writes only itself); re-tiled with GPU-friendly blocks.
  fused_query    one program per (stream, level, depth-row): the whole
                 width-w row is reduced inside the program, no partials.
  fused_pairs    (N, i_tiles) grid; each program holds its i-tile of the
                 sample against the FULL sample row and emits a private
                 (d+1,) partial histogram; partials are summed outside the
                 kernel (split-K style).
  fused_ingest   (L, w_tiles) grid; each program owns one (t, block_w)
                 counter tile and loops over the batch *inside* the
                 program, so the accumulator lives in registers and no two
                 programs touch the same counters.

Only generic ``pl.pallas_call`` features are used (no ``pltpu`` imports),
so the same kernels run under ``interpret=True`` on any backend -- which
is how the CPU CI lane conformance-tests this tier bit-exact against the
``kernels/ref.py`` oracles without a GPU.  On a real GPU backend
(``jax.default_backend() == "gpu"``) the registry dispatches here with
``interpret=False`` and pallas lowers through Triton (or Mosaic GPU on
newer jax).  Counts stay exact for the same reason as on TPU: every f32
partial sum is an integer below 2^24 and cross-block accumulation is
int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import (addmod_p31, cw_hash_pair, hash_sign,
                                mulmod_p31, reduce_p31)

DEFAULT_BLOCK_B = 128      # fingerprint / ingest batch tile
DEFAULT_BLOCK_M = 64       # fingerprint combination tile
DEFAULT_BLOCK_R = 128      # fused_pairs i-tile
DEFAULT_BLOCK_W = 1024     # fused_ingest width tile


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

def _masked_horner(values, masks, ids, base):
    """(BB, d) reduced values x (BM, d) masks -> (BB, BM) fingerprints."""
    seed = addmod_p31(reduce_p31(ids), jnp.uint32(1))              # (BM,)
    fp = jnp.broadcast_to(seed[None, :], (values.shape[0], seed.shape[0]))
    for col in range(values.shape[1]):                             # d static
        v = addmod_p31(values[:, col:col + 1], jnp.uint32(1))
        nxt = addmod_p31(mulmod_p31(fp, base), v)
        fp = jnp.where(masks[None, :, col] != 0, nxt, fp)
    return fp


def _fingerprint_kernel(values_ref, masks_ref, ids_ref, bases_ref,
                        out1_ref, out2_ref):
    values = reduce_p31(values_ref[...])
    for which, out_ref in ((0, out1_ref), (1, out2_ref)):
        out_ref[...] = _masked_horner(values, masks_ref[...], ids_ref[...],
                                      bases_ref[which])


@functools.partial(jax.jit, static_argnames=("block_b", "block_m",
                                             "interpret"))
def fingerprint_gpu(values, combo_masks, combo_ids, bases,
                    *, block_b: int = DEFAULT_BLOCK_B,
                    block_m: int = DEFAULT_BLOCK_M,
                    interpret: bool = True):
    """values (B, d) x combos (M, d) -> (fp1, fp2) each (B, M) uint32."""
    values = values.astype(jnp.uint32)
    combo_masks = combo_masks.astype(jnp.uint32)
    combo_ids = combo_ids.astype(jnp.uint32)
    B, d = values.shape
    M = combo_ids.shape[0]
    bb = min(block_b, max(B, 8))
    bm = min(block_m, max(M, 8))
    pad_b, pad_m = (-B) % bb, (-M) % bm
    if pad_b:
        values = jnp.pad(values, ((0, pad_b), (0, 0)))
    if pad_m:
        combo_masks = jnp.pad(combo_masks, ((0, pad_m), (0, 0)))
        combo_ids = jnp.pad(combo_ids, (0, pad_m))
    grid = (values.shape[0] // bb, combo_ids.shape[0] // bm)
    out_shape = (values.shape[0], combo_ids.shape[0])
    fp1, fp2 = pl.pallas_call(
        _fingerprint_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda gb, gm: (gb, 0)),
            pl.BlockSpec((bm, d), lambda gb, gm: (gm, 0)),
            pl.BlockSpec((bm,), lambda gb, gm: (gm,)),
            pl.BlockSpec((2,), lambda gb, gm: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda gb, gm: (gb, gm)),
            pl.BlockSpec((bb, bm), lambda gb, gm: (gb, gm)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(out_shape, jnp.uint32),
            jax.ShapeDtypeStruct(out_shape, jnp.uint32),
        ],
        interpret=interpret,
    )(values, combo_masks, combo_ids, bases)
    return fp1[:B, :M], fp2[:B, :M]


# ---------------------------------------------------------------------------
# fused_query
# ---------------------------------------------------------------------------

def _query_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...].astype(jnp.float32)                   # (1, w)
    b = b_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(a * b, axis=-1)               # (1,)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def fused_query_gpu(counters_a, counters_b, *, block_w: int | None = None,
                    interpret: bool = True):
    """(N, L, t, w) x (N, L, t, w) -> (N, L, t) float32 row moments.

    One program per (stream, level, depth) row; the full width reduces
    inside the program (w is at most a few thousand for SJPC sketches, so
    one row is a comfortable register/SMEM tile on GPU).  ``block_w`` is
    accepted for dispatch-signature parity and ignored: there is no
    cross-program accumulation to tile.
    """
    del block_w
    assert counters_a.shape == counters_b.shape, \
        (counters_a.shape, counters_b.shape)
    N, L, t, w = counters_a.shape
    rows = N * L * t
    a = counters_a.reshape(rows, w)
    b = counters_b.reshape(rows, w)
    out = pl.pallas_call(
        _query_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, w), lambda r: (r, 0)),
            pl.BlockSpec((1, w), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out.reshape(N, L, t)


# ---------------------------------------------------------------------------
# fused_pairs
# ---------------------------------------------------------------------------

def _pairs_kernel(items_i_ref, items_all_ref, valid_i_ref, valid_all_ref,
                  out_ref, *, d: int, block_r: int):
    gi = pl.program_id(1)
    a = items_i_ref[0]                                   # (BR, d) uint32
    b = items_all_ref[0]                                 # (R_pad, d)
    r_all = b.shape[0]
    match = jnp.zeros((block_r, r_all), jnp.int32)
    for c in range(d):                                   # d static, small
        match += (a[:, c:c + 1] == b[None, :, c]).astype(jnp.int32)
    row = jax.lax.broadcasted_iota(jnp.int32, (block_r, r_all), 0) \
        + gi * block_r
    col = jax.lax.broadcasted_iota(jnp.int32, (block_r, r_all), 1)
    ok = (valid_i_ref[0][:, None] != 0) & (valid_all_ref[0][None, :] != 0) \
        & (row != col)
    flat = jnp.where(ok, match, -1)                      # -1 = masked out
    for k in range(d + 1):
        out_ref[0, 0, k] = jnp.sum((flat == k).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def fused_pairs_gpu(items, valid, *, block_r: int = DEFAULT_BLOCK_R,
                    interpret: bool = True):
    """(N, R, d) samples x (N, R) validity -> (N, d+1) int32 histograms.

    Each (stream, i-tile) program scans its record tile against the whole
    sample row and emits a private partial histogram; partials reduce in
    one ``jnp.sum`` outside the kernel, so no two programs ever write the
    same memory (split-K).
    """
    N, R, d = items.shape
    assert valid.shape == (N, R), (valid.shape, (N, R))
    items = items.astype(jnp.uint32)
    valid = valid.astype(jnp.int32)
    block_r = min(block_r, max(R, 8))
    pad_r = (-R) % block_r
    if pad_r:                     # padded slots carry valid=0: contribute 0
        items = jnp.pad(items, ((0, 0), (0, pad_r), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad_r)))
    r_pad = R + pad_r
    tiles = r_pad // block_r
    kernel = functools.partial(_pairs_kernel, d=d, block_r=block_r)
    partials = pl.pallas_call(
        kernel,
        grid=(N, tiles),
        in_specs=[
            pl.BlockSpec((1, block_r, d), lambda n, gi: (n, gi, 0)),
            pl.BlockSpec((1, r_pad, d), lambda n, gi: (n, 0, 0)),
            pl.BlockSpec((1, block_r), lambda n, gi: (n, gi)),
            pl.BlockSpec((1, r_pad), lambda n, gi: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d + 1), lambda n, gi: (n, gi, 0)),
        out_shape=jax.ShapeDtypeStruct((N, tiles, d + 1), jnp.int32),
        interpret=interpret,
    )(items, items, valid, valid)
    return jnp.sum(partials, axis=1, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# fused_ingest
# ---------------------------------------------------------------------------

def _ingest_kernel(values_ref, masks_ref, ids_ref, bases_ref, wt_ref,
                   counters_ref, bcoef_ref, scoef_ref, out_ref,
                   *, d: int, depth: int, block_b: int, block_w: int,
                   num_blocks: int):
    w_total = block_w * pl.num_programs(1)
    w_lo = (pl.program_id(1) * block_w).astype(jnp.int32)
    acc = counters_ref[0]                                # (t, block_w) int32
    masks = masks_ref[0]                                 # (m_max, d)
    ids = ids_ref[0]                                     # (m_max,)
    for blk in range(num_blocks):                        # batch loop INSIDE
        lo = blk * block_b
        values = reduce_p31(values_ref[lo:lo + block_b, :])
        fp1 = _masked_horner(values, masks, ids, bases_ref[0]).reshape(-1)
        fp2 = _masked_horner(values, masks, ids, bases_ref[1]).reshape(-1)
        weight = wt_ref[lo:lo + block_b, 0, :].reshape(-1) \
            .astype(jnp.float32)                         # (BB*m_max,)
        col = jax.lax.broadcasted_iota(jnp.int32,
                                       (fp1.shape[0], block_w), 1)
        rows = []
        for i in range(depth):                           # depth static
            hb = cw_hash_pair(fp1, fp2, bcoef_ref[0, i])
            bucket = (hb & jnp.uint32(w_total - 1)).astype(jnp.int32)
            onehot = (bucket[:, None] - w_lo == col).astype(jnp.float32)
            sign = hash_sign(cw_hash_pair(fp1, fp2, scoef_ref[0, i])) \
                .astype(jnp.float32)
            contrib = jnp.sum((sign * weight)[:, None] * onehot, axis=0)
            rows.append(contrib.astype(jnp.int32))       # exact: ints < 2^24
        acc = acc + jnp.stack(rows)
    out_ref[0] = acc


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_w", "interpret"))
def fused_ingest_gpu(counters, values, masks, ids, bases,
                     bucket_coeffs, sign_coeffs, weights,
                     *, block_b: int = DEFAULT_BLOCK_B,
                     block_w: int = DEFAULT_BLOCK_W,
                     interpret: bool = True):
    """One launch: records -> fingerprints -> every level's sketch.

    Same contract and padded-lattice layout as
    :func:`repro.kernels.fused_ingest.fused_ingest_pallas`; the grid is
    (L, w_tiles) with the batch loop moved inside the program so each
    (level, width-tile) counter block has exactly one writer.
    """
    L, t, w = counters.shape
    B, d = values.shape
    m_max = ids.shape[1]
    values = values.astype(jnp.uint32)
    weights = weights.astype(jnp.int32)
    block_b = min(block_b, max(B, 8))
    block_w = min(block_w, w)
    assert w & (w - 1) == 0, "sketch width must be a power of two"
    assert block_w & (block_w - 1) == 0, \
        f"block_w={block_w} must be a power of two (so it divides w={w})"
    pad_b = (-B) % block_b
    if pad_b:                    # padded rows carry weight 0: contribute 0
        values = jnp.pad(values, ((0, pad_b), (0, 0)))
        weights = jnp.pad(weights, ((0, pad_b), (0, 0), (0, 0)))
    b_pad = B + pad_b
    kernel = functools.partial(_ingest_kernel, d=d, depth=t,
                               block_b=block_b, block_w=block_w,
                               num_blocks=b_pad // block_b)
    return pl.pallas_call(
        kernel,
        grid=(L, w // block_w),
        in_specs=[
            pl.BlockSpec((b_pad, d), lambda l, gw: (0, 0)),
            pl.BlockSpec((1, m_max, d), lambda l, gw: (l, 0, 0)),
            pl.BlockSpec((1, m_max), lambda l, gw: (l, 0)),
            pl.BlockSpec((2,), lambda l, gw: (0,)),
            pl.BlockSpec((b_pad, 1, m_max), lambda l, gw: (0, l, 0)),
            pl.BlockSpec((1, t, block_w), lambda l, gw: (l, 0, gw)),
            pl.BlockSpec((1, t, 2, 4), lambda l, gw: (l, 0, 0, 0)),
            pl.BlockSpec((1, t, 2, 4), lambda l, gw: (l, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, block_w), lambda l, gw: (l, 0, gw)),
        out_shape=jax.ShapeDtypeStruct((L, t, w), jnp.int32),
        interpret=interpret,
    )(values, masks, ids, bases, weights, counters, bucket_coeffs,
      sign_coeffs)
