"""Pallas TPU kernel: fused all-pairs similarity histogram of a reservoir.

The reservoir-sampling estimator's query hot path: given the stored sample
of a stream -- items (R, d) plus a validity mask -- count, for every level
k in [0, d], the ordered pairs (i != j, both valid) whose records agree on
exactly k columns.  The scaled suffix sums of that histogram are the
estimator's x[k] / g_s table (core/baselines.py eq.; DESIGN.md §13.3).

Done naively on host numpy this is O(R^2 d) Python-driven work per query;
here it is ONE kernel launch over stacked samples:

  grid (N, i_tiles, j_tiles):
    stream axis     -- parallel; each stream owns an (R, d) sample slab
    i/j tile axes   -- sequential; the (d+1,) histogram accumulator stays
                       resident in VMEM while every (block_r, block_r) pair
                       tile of the R x R match matrix reduces into it

  per cell:  the Hamming-match tile  M[a, b] = #{c : A[a, c] == B[b, c]}
             builds column-by-column on the VPU (d is static and small);
             pair validity (both slots live, a != b on the diagonal tile)
             masks it, and the histogram bin counts come from ONE MXU
             contraction -- ones(1, block_r^2) @ onehot(block_r^2, d+1) --
             so the R^2-sized match matrix never leaves the chip.

Counts are exact: the per-tile one-hot contraction accumulates at most
block_r^2 <= 2^14 in f32 (integral, < 2^24), and cross-tile accumulation is
int32.  The pure-jnp fallback (kernels/ref.py:fused_pairs_ref) is
bit-identical; both are tested against the O(n^2) numpy oracle
(core/exact.py:brute_force_pair_counts) across depths/widths/empty inputs
in tests/test_fused_pairs.py.

The N grid axis is the batching surface for more than streams: the
bootstrap error bars (estimators/uncertainty.py, DESIGN.md §14) flatten
their (streams, replicates) stack into it through ``kernels.ops
.fused_pairs`` (which accepts arbitrary leading dims), so B resampled
histograms per stream cost one launch, not B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 128


def _kernel(items_i_ref, items_j_ref, valid_i_ref, valid_j_ref, out_ref,
            *, d: int, block_r: int):
    gi, gj = pl.program_id(1), pl.program_id(2)

    @pl.when(jnp.logical_and(gi == 0, gj == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = items_i_ref[0]                                   # (BR, d) uint32
    b = items_j_ref[0]                                   # (BR, d) uint32
    # Hamming-match tile, column by column (d is static and tiny)
    match = jnp.zeros((block_r, block_r), jnp.int32)
    for c in range(d):
        match += (a[:, c:c + 1] == b[None, :, c]).astype(jnp.int32)

    # pair validity: both slots live, and not the self-pair on the diagonal
    row = jax.lax.broadcasted_iota(jnp.int32, (block_r, block_r), 0) \
        + gi * block_r
    col = jax.lax.broadcasted_iota(jnp.int32, (block_r, block_r), 1) \
        + gj * block_r
    ok = (valid_i_ref[0][:, None] != 0) & (valid_j_ref[0][None, :] != 0) \
        & (row != col)

    # bin into the histogram with one MXU contraction:
    # ones(1, BR^2) @ onehot(BR^2, d+1); per-tile counts <= BR^2 < 2^24 so
    # the f32 accumulation is exact, then int32 across tiles
    flat = jnp.where(ok, match, -1).reshape(-1)          # -1 = masked out
    onehot = (flat[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0], d + 1), 1)
              ).astype(jnp.float32)
    counts = jnp.dot(jnp.ones((1, flat.shape[0]), jnp.float32), onehot,
                     preferred_element_type=jnp.float32)  # (1, d+1)
    out_ref[0, :] += counts[0].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def fused_pairs_pallas(items, valid, *, block_r: int = DEFAULT_BLOCK_R,
                       interpret: bool = True):
    """(N, R, d) samples x (N, R) validity -> (N, d+1) int32 histograms.

    out[i, k] = #ordered pairs (a != b, both valid) of stream i's sample
    agreeing on exactly k columns.  ``interpret=True`` is the
    CPU-correctness mode (this container); on real TPU pass interpret=False.
    """
    N, R, d = items.shape
    assert valid.shape == (N, R), (valid.shape, (N, R))
    items = items.astype(jnp.uint32)
    valid = valid.astype(jnp.int32)
    block_r = min(block_r, max(R, 8))
    pad_r = (-R) % block_r
    if pad_r:                     # padded slots carry valid=0: contribute 0
        items = jnp.pad(items, ((0, 0), (0, pad_r), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad_r)))
    r_pad = R + pad_r

    tiles = r_pad // block_r
    kernel = functools.partial(_kernel, d=d, block_r=block_r)
    return pl.pallas_call(
        kernel,
        grid=(N, tiles, tiles),
        in_specs=[
            pl.BlockSpec((1, block_r, d), lambda n, gi, gj: (n, gi, 0)),
            pl.BlockSpec((1, block_r, d), lambda n, gi, gj: (n, gj, 0)),
            pl.BlockSpec((1, block_r), lambda n, gi, gj: (n, gi)),
            pl.BlockSpec((1, block_r), lambda n, gi, gj: (n, gj)),
        ],
        out_specs=pl.BlockSpec((1, d + 1), lambda n, gi, gj: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d + 1), jnp.int32),
        interpret=interpret,
    )(items, items, valid, valid)
