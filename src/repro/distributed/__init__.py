"""repro.distributed -- multi-host service: sharded workers, a compact
sketch-delta wire protocol, and a coordinator that merges every worker's
epoch-aligned deltas into query replicas (DESIGN.md §18).

  wire.py         versioned delta serialization (bit-exact round-trips,
                  zero-byte idle heartbeats)
  transport.py    length-prefixed frames + the worker opcode set
  worker.py       one EstimationService shard per worker; subprocess entry
  coordinator.py  tenant-hash routing, delta merging, stale-on-failure
  harness.py      1/2/4-worker scale-out benchmark + oracle smoke run
"""
from .coordinator import (ClusterSpec, Coordinator, LocalWorker,
                          SubprocessWorker, shard_of)
from .wire import (HEARTBEAT, MODE_MERGE, MODE_REPLACE, WIRE_VERSION,
                   DeltaMessage, WireFormatError, WireVersionError,
                   decode_bundle, decode_message, encode_bundle,
                   encode_delta, encode_heartbeat, register_state_type,
                   state_type)
from .worker import WorkerRuntime, handle_request

__all__ = [
    "HEARTBEAT", "MODE_MERGE", "MODE_REPLACE", "WIRE_VERSION",
    "ClusterSpec", "Coordinator", "DeltaMessage", "LocalWorker",
    "SubprocessWorker", "WireFormatError", "WireVersionError",
    "WorkerRuntime", "decode_bundle", "decode_message", "encode_bundle",
    "encode_delta", "encode_heartbeat", "handle_request",
    "register_state_type", "shard_of", "state_type",
]
