"""Sketch-delta wire format: versioned, deterministic state serialization.

The multi-host service (DESIGN.md §18) ships estimator state between
worker processes and the coordinator.  Every estimator state in this repo
is a NamedTuple pytree of dense arrays (``SJPCState``, ``ReservoirState``,
``LSHSSState``), so one generic layout covers all kinds: leaves are
serialized **in NamedTuple field order** as (field name, dtype, shape,
little-endian C-order raw bytes).  That makes the encoding a pure function
of the state -- byte-identical across processes and runs -- and the
round-trip ``deserialize(serialize(x))`` bit-exact, which the window merge
algebra requires (a replica window must end up with the same counters the
worker holds, not approximately the same).

Two delta **modes** mirror the two window strategies of
``service/window.py``:

  ``MODE_MERGE``    linear kinds (SJPC): the payload is the leaf-wise
                    difference of the open epoch since the last export --
                    raw counter arrays -- applied on the replica through
                    the estimator's ``merge`` (counter addition).
  ``MODE_REPLACE``  sample kinds (reservoir, lsh_ss): a uniform sample
                    cannot be shipped as arithmetic deltas, so the open
                    epoch's full state (items + provenance tags) replaces
                    the replica's open slot; the replica refolds exactly
                    like the worker would.

Deserialization reconstructs the **real** state class -- not an anonymous
namedtuple -- via the kind -> class registry below.  jax pytree operations
(``tree_map`` across a live state and a deserialized one, ``stack_states``
over a cohort) match on the container *type*, so a duck-typed stand-in
would fail structure checks the moment a replica state meets a live one.
Plugin estimator kinds register theirs with :func:`register_state_type`.

The **zero-byte heartbeat**: a worker with nothing new since its last
export ships an empty frame instead of a delta bundle (the idle-tenant
fast path).  :func:`decode_message` maps the empty payload to
:data:`HEARTBEAT` without touching the version machinery -- heartbeats
carry no version, so a version bump can never invalidate idle workers.

Framing (length prefixes, the stdin/stdout loop) lives in transport.py;
this module is pure bytes <-> state.
"""
from __future__ import annotations

import dataclasses
import struct

import numpy as np

MAGIC = b"RSJD"                  # delta-message preamble
WIRE_VERSION = 1                 # bump on any layout change

MODE_MERGE = 1                   # linear delta: apply via estimator.merge
MODE_REPLACE = 2                 # sample state: replace the open slot


class WireVersionError(ValueError):
    """Peer speaks a different wire version; merging would corrupt state."""


class WireFormatError(ValueError):
    """Payload does not parse as a delta message."""


# -- kind -> state class registry -------------------------------------------
#
# One registration per kind: the state class lives in the estimator spec
# registry (``estimators.register`` / ``register_state_type``), and the
# wire codec reads it from there.  These thin delegates keep the historic
# ``wire.register_state_type`` entry point working; imports stay lazy so
# this module remains importable without pulling in jax.

def register_state_type(kind: str, cls: type) -> None:
    """Register an estimator kind's state NamedTuple class so
    :func:`decode_message` can rebuild genuine instances (pytree-compatible
    with live states).  Idempotent for the same class; a conflicting
    re-registration is an error.  Delegates to the estimator spec
    registry -- kinds registered through ``estimators.register`` with a
    ``state_cls`` need no separate call."""
    from repro import estimators
    estimators.register_state_type(kind, cls)


def state_type(kind: str) -> type:
    from repro import estimators
    return estimators.state_type(kind)


def mode_code(mode: str) -> int:
    """Wire byte for a window export mode string ("merge" / "replace" --
    ``EstimatorSpec.wire_mode``)."""
    try:
        return {"merge": MODE_MERGE, "replace": MODE_REPLACE}[mode]
    except KeyError:
        raise WireFormatError(f"unknown delta mode {mode!r}") from None


def mode_name(code: int) -> str:
    """Inverse of :func:`mode_code` (for the coordinator's merge path)."""
    try:
        return {MODE_MERGE: "merge", MODE_REPLACE: "replace"}[code]
    except KeyError:
        raise WireFormatError(f"unknown delta mode {code}") from None


# -- messages ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeltaMessage:
    """One stream's epoch-aligned export."""
    kind: str                    # estimator kind ("sjpc", ...)
    stream: str                  # stream (tenant) name
    epoch: int                   # the open epoch this delta belongs to
    window_version: int          # worker window version at export time
    mode: int                    # MODE_MERGE | MODE_REPLACE
    state: object                # the kind's state NamedTuple (numpy leaves)


class _Heartbeat:
    """Singleton marker for the zero-byte idle export."""

    def __repr__(self) -> str:   # pragma: no cover - repr cosmetics
        return "HEARTBEAT"


HEARTBEAT = _Heartbeat()


# -- encoding ---------------------------------------------------------------

def _pack_str(s: str, width: str = "H") -> bytes:
    raw = s.encode("utf-8")
    return struct.pack("<" + width, len(raw)) + raw


def _leaf_bytes(arr) -> tuple[str, tuple, bytes]:
    """(dtype-str, shape, raw) of one state leaf, normalized to
    little-endian C order so the encoding is platform-independent."""
    a = np.asarray(arr)
    if not a.flags["C_CONTIGUOUS"]:
        # NB: not ascontiguousarray -- that promotes 0-d leaves to (1,)
        a = np.ascontiguousarray(a)
    le = a.dtype.newbyteorder("<")
    if a.dtype != le:
        a = a.astype(le)
    return le.str, tuple(a.shape), a.tobytes(order="C")


def encode_delta(msg: DeltaMessage) -> bytes:
    """Serialize one delta message (deterministic: NamedTuple field
    order, fixed-width little-endian header fields)."""
    fields = getattr(msg.state, "_fields", None)
    if fields is None:
        raise WireFormatError(
            f"state of kind {msg.kind!r} is not a NamedTuple pytree "
            f"({type(msg.state).__name__})")
    out = [MAGIC, struct.pack("<HB", WIRE_VERSION, msg.mode),
           _pack_str(msg.kind, "B"), _pack_str(msg.stream),
           struct.pack("<qq", msg.epoch, msg.window_version),
           struct.pack("<B", len(fields))]
    for name in fields:
        dt, shape, raw = _leaf_bytes(getattr(msg.state, name))
        out.append(_pack_str(name, "B"))
        out.append(_pack_str(dt, "B"))
        out.append(struct.pack("<B", len(shape)))
        out.append(struct.pack(f"<{len(shape)}I", *shape) if shape else b"")
        out.append(struct.pack("<Q", len(raw)))
        out.append(raw)
    return b"".join(out)


def encode_heartbeat() -> bytes:
    """The idle-worker fast path: zero bytes.  No version field -- there
    is nothing to mismatch -- and nothing for the coordinator to merge."""
    return b""


class _Reader:
    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes):
        self.buf, self.pos = buf, 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise WireFormatError(
                f"truncated delta message: wanted {n} bytes at offset "
                f"{self.pos}, have {len(self.buf) - self.pos}")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def unpack(self, fmt: str):
        vals = struct.unpack("<" + fmt, self.take(struct.calcsize("<" + fmt)))
        return vals[0] if len(vals) == 1 else vals

    def take_str(self, width: str = "H") -> str:
        return self.take(self.unpack(width)).decode("utf-8")


def decode_message(payload: bytes):
    """Decode one export payload: :data:`HEARTBEAT` for the empty frame,
    a :class:`DeltaMessage` otherwise.  Raises :class:`WireVersionError`
    (naming both versions) on a wire-version mismatch BEFORE touching any
    state bytes -- cross-version payloads must never half-parse."""
    if not payload:
        return HEARTBEAT
    r = _Reader(payload)
    magic = r.take(len(MAGIC))
    if magic != MAGIC:
        raise WireFormatError(f"bad delta magic {magic!r} (expected {MAGIC!r})")
    version, mode = r.unpack("HB")
    if version != WIRE_VERSION:
        raise WireVersionError(
            f"wire version mismatch: peer sent version {version}, this "
            f"process speaks version {WIRE_VERSION}; refusing to merge")
    if mode not in (MODE_MERGE, MODE_REPLACE):
        raise WireFormatError(f"unknown delta mode {mode}")
    kind = r.take_str("B")
    stream = r.take_str()
    epoch, window_version = r.unpack("qq")
    n_fields = r.unpack("B")
    cls = state_type(kind)
    if n_fields != len(cls._fields):
        raise WireFormatError(
            f"kind {kind!r} delta carries {n_fields} leaves, state type "
            f"{cls.__name__} has {len(cls._fields)}")
    leaves = {}
    for i in range(n_fields):
        name = r.take_str("B")
        if name != cls._fields[i]:
            raise WireFormatError(
                f"kind {kind!r} leaf {i} is {name!r}, expected "
                f"{cls._fields[i]!r} (field order is part of the format)")
        dt = np.dtype(r.take_str("B"))
        ndim = r.unpack("B")
        if ndim:
            dims = r.unpack(f"{ndim}I")
            shape = (dims,) if ndim == 1 else tuple(dims)
        else:
            shape = ()
        nbytes = r.unpack("Q")
        raw = r.take(nbytes)
        # copy out of the frame buffer: leaves must be writable, C-order
        # arrays (they feed straight into the window merge algebra)
        leaves[name] = np.frombuffer(raw, dtype=dt).reshape(shape).copy()
    if r.pos != len(payload):
        raise WireFormatError(
            f"{len(payload) - r.pos} trailing bytes after delta message")
    return DeltaMessage(kind=kind, stream=stream, epoch=epoch,
                        window_version=window_version, mode=mode,
                        state=cls(**leaves))


def encode_bundle(messages: list[bytes]) -> bytes:
    """Concatenate encoded delta messages into one export payload:
    uint32 count, then (uint32 length, bytes) per message.  An empty
    message list is NOT a bundle -- idle workers ship
    :func:`encode_heartbeat` (zero bytes) instead."""
    out = [struct.pack("<I", len(messages))]
    for m in messages:
        out.append(struct.pack("<I", len(m)))
        out.append(m)
    return b"".join(out)


def decode_bundle(payload: bytes):
    """Inverse of :func:`encode_bundle`; the empty payload decodes to
    :data:`HEARTBEAT` (no messages, no version check, no merge work)."""
    if not payload:
        return HEARTBEAT
    r = _Reader(payload)
    count = r.unpack("I")
    msgs = []
    for _ in range(count):
        msgs.append(decode_message(r.take(r.unpack("I"))))
    if r.pos != len(payload):
        raise WireFormatError(
            f"{len(payload) - r.pos} trailing bytes after delta bundle")
    return msgs
