"""Scale-out harness: 1/2/4-worker clusters vs the single-process oracle.

Two jobs (DESIGN.md §18.5):

* **Benchmark** (:func:`run_scaleout`, surfaced as the ``distributed``
  suite in benchmarks/run.py): launch N subprocess workers, drive
  identical ingest through the coordinator, and report aggregate ingest
  records/sec, merge latency p50/p95, and replica query-freshness lag
  per worker count.  Worker environments are pinned identically
  (single forced host device, capped BLAS/OMP threads) so the scaling
  ratio measures sharding, not accidental thread-count differences.

* **Smoke/correctness** (:func:`run_smoke`, the CI ``distributed-smoke``
  job and the slow-lane subprocess test): a 2-worker cluster over a
  small geometry whose coordinator estimates must match a single-process
  oracle run -- bit-exact replica counters for linear kinds, |Δ|/max ≤
  1e-6 on every estimate -- plus a merge-latency trace written under
  ``benchmarks/out/`` for artifact upload.

Determinism contract: tenant uids are pinned globally (spec declaration
order), per-stream record sequences are identical, and the harness
flushes on the same per-cycle boundaries in both runs, so the per-
(stream, round) ingest PRNG grid -- and therefore every sketch -- is
reproduced exactly regardless of which process ingested the records.
Tenant names are salted at spec-build time so ``crc32 % 4`` (and hence
``% 2``) is perfectly balanced: the 1/2/4-worker runs shard the same
tenants evenly, keeping the scaling comparison honest.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
import zlib

import numpy as np

from .coordinator import ClusterSpec, Coordinator, LocalWorker, SubprocessWorker

_SRC = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# threads pinned identically for every worker count: the scale-out ratio
# must come from sharding, not from 1-worker runs grabbing more BLAS/OMP
# threads than 4-worker runs
_THREAD_CAPS = {"OMP_NUM_THREADS": "1", "OPENBLAS_NUM_THREADS": "1",
                "MKL_NUM_THREADS": "1"}


def worker_env() -> dict:
    """The pinned child environment: one forced host device
    (``repro.platform.subprocess_env``), CPU backend, capped threads,
    and a PYTHONPATH that reaches ``repro``."""
    from repro.platform import subprocess_env
    env = subprocess_env(1)
    env.update(_THREAD_CAPS)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _salted(i: int, want: int) -> str:
    """A tenant name whose crc32 lands in shard ``want`` mod 4 (balanced
    for 2- and 4-worker clusters alike)."""
    salt = 0
    while True:
        name = f"tenant-{i:02d}x{salt}"
        if zlib.crc32(name.encode()) % 4 == want:
            return name
        salt += 1


def make_spec(n_tenants: int = 8, *, kinds=("sjpc",), d: int = 6, s: int = 4,
              width: int = 1024, depth: int = 3, seed: int = 11,
              window_epochs: int = 4, backing_epochs: int = 0,
              batch_rows: int = 256) -> ClusterSpec:
    """A balanced cluster spec: ``n_tenants`` streams cycling through
    ``kinds``, names salted so every worker count shards them evenly."""
    from repro.core.sjpc import SJPCConfig
    streams = []
    for i in range(n_tenants):
        kind = kinds[i % len(kinds)]
        st = {"name": _salted(i, i % 4), "group": "g",
              "window_epochs": window_epochs, "estimator": kind}
        if backing_epochs and kind != "sjpc":
            st["backing_epochs"] = backing_epochs
        streams.append(st)
    return ClusterSpec(
        groups=(("g", SJPCConfig(d=d, s=s, ratio=0.5, width=width,
                                 depth=depth, seed=seed)),),
        streams=tuple(streams),
        service={"batch_rows": batch_rows, "window_epochs": window_epochs,
                 "platform": "cpu"})


def make_batches(spec: ClusterSpec, *, cycles: int, rows_per_cycle: int,
                 vocab: int = 400, seed: int = 0) -> dict:
    """Per-tenant record batches, one array per (tenant, cycle).  The
    same dict feeds the oracle and every cluster size, so the per-stream
    sequences -- and the PRNG round grid -- are identical everywhere."""
    d = spec.groups[0][1].d
    rng = np.random.default_rng(seed)
    return {s["name"]: [rng.integers(0, vocab, size=(rows_per_cycle, d),
                                     dtype=np.uint32) for _ in range(cycles)]
            for s in spec.streams}


# -- the oracle -------------------------------------------------------------

def run_oracle(spec: ClusterSpec, batches: dict, *, cycles: int):
    """The single-process reference: same topology (dense uids ==
    declaration order == the cluster's pinned uids), same records, same
    flush and epoch boundaries."""
    from repro.obs import Observability
    from repro.service import EstimationService, ServiceConfig
    svc = EstimationService(ServiceConfig(**spec.service),
                            obs=Observability.disabled())
    for gid, cfg in spec.groups:
        svc.create_group(gid, cfg)
    for st in spec.streams:
        kwargs = {k: st[k] for k in
                  ("window_epochs", "estimator", "backing_epochs")
                  if k in st}
        svc.create_stream(st["name"], st["group"], **kwargs)
    for c in range(cycles):
        for st in spec.streams:
            svc.ingest(st["name"], batches[st["name"]][c])
        svc.flush()
        svc.advance_epoch()
    return svc


# -- cluster runs -----------------------------------------------------------

@dataclasses.dataclass
class ClusterRun:
    n_workers: int
    records: int
    ingest_s: float              # route + flush + merge wall time
    rec_per_s: float
    merge_p50_s: float
    merge_p95_s: float
    freshness_p50_s: float
    freshness_p95_s: float
    sync_trace: list             # per-cycle {"cycle", "sync_s", "deltas"}
    coordinator: Coordinator


def run_cluster(spec: ClusterSpec, batches: dict, *, n_workers: int,
                cycles: int, local: bool = False,
                keep_open: bool = False) -> ClusterRun:
    """Drive one cluster through ``cycles`` ingest/sync/advance rounds.
    ``local=True`` uses in-process workers (unit tests: full protocol
    bytes, no subprocess startup); otherwise each worker is a child
    process with a pinned environment."""
    if local:
        workers = [LocalWorker() for _ in range(n_workers)]
    else:
        env = worker_env()
        workers = [SubprocessWorker(env=env) for _ in range(n_workers)]
    coord = Coordinator(spec, workers)
    # jit compilation lands inside cycle 0 on every worker -- it overlaps
    # across workers (send-all-then-recv-all broadcasts), so the wall
    # clock charges each cluster size comparably
    records = 0
    trace = []
    t0 = time.perf_counter()
    for c in range(cycles):
        for st in spec.streams:
            records += coord.ingest(st["name"], batches[st["name"]][c])
        ts = time.perf_counter()
        stats = coord.sync()
        trace.append({"cycle": c, "sync_s": time.perf_counter() - ts,
                      "deltas": stats["deltas"],
                      "heartbeats": stats["heartbeats"]})
        coord.advance_epoch()
    wall = time.perf_counter() - t0
    m = coord.obs.metrics
    run = ClusterRun(
        n_workers=n_workers, records=records, ingest_s=wall,
        rec_per_s=records / wall if wall > 0 else 0.0,
        merge_p50_s=_hist_quantile(m, "coordinator_merge_seconds", 0.50),
        merge_p95_s=_hist_quantile(m, "coordinator_merge_seconds", 0.95),
        freshness_p50_s=m.quantile("coordinator_freshness_lag_seconds", 0.50),
        freshness_p95_s=m.quantile("coordinator_freshness_lag_seconds", 0.95),
        sync_trace=trace, coordinator=coord)
    if not keep_open:
        coord.close()
    return run


def _hist_quantile(m, name: str, q: float) -> float:
    """Worst worker's quantile (the family is labeled ``worker=<i>``)."""
    hists = getattr(m, "_hists", {}).get(name, {})
    vals = [h.quantile(q) for h in hists.values()]
    return max(vals) if vals else 0.0


# -- correctness ------------------------------------------------------------

def compare_to_oracle(coord: Coordinator, oracle, spec: ClusterSpec) -> dict:
    """Replica-vs-oracle agreement: bit-exact counters/n for linear
    kinds, worst relative estimate gap across all tenants and kinds."""
    import jax.tree_util as jtu
    replica = coord.replicas[0]
    worst = 0.0
    linear_exact = True
    for st in spec.streams:
        name = st["name"]
        rw = replica.registry.stream(name)
        ow = oracle.registry.stream(name)
        if rw.window.spec.linear:
            a, b = rw.window.total, ow.window.total
            # step is worker-local PRNG history: the replica mirrors data
            # (counters, n), not the fold count
            if not (np.array_equal(np.asarray(a.counters), np.asarray(b.counters))
                    and np.array_equal(np.asarray(a.n), np.asarray(b.n))):
                linear_exact = False
        else:
            for la, lb in zip(jtu.tree_leaves(rw.window.window_state()),
                              jtu.tree_leaves(ow.window.window_state())):
                if not np.array_equal(np.asarray(la), np.asarray(lb)):
                    linear_exact = False
        est_c = coord.self_join(name).estimate
        est_o = oracle.snapshot([name]).self_join(name).estimate
        denom = max(abs(est_o), 1.0)
        worst = max(worst, abs(est_c - est_o) / denom)
    return {"linear_exact": linear_exact, "worst_rel_err": worst}


def run_smoke(out_path: str | None = None, *, local: bool = False) -> dict:
    """The CI smoke run: a 2-worker cluster (subprocess by default) over
    a small mixed-kind geometry; asserts coordinator == oracle and writes
    the merge-latency trace."""
    spec = make_spec(4, kinds=("sjpc", "reservoir"), width=256, depth=2,
                     window_epochs=3, batch_rows=64)
    cycles = 4
    batches = make_batches(spec, cycles=cycles, rows_per_cycle=128, seed=3)
    run = run_cluster(spec, batches, n_workers=2, cycles=cycles,
                      local=local, keep_open=True)
    try:
        oracle = run_oracle(spec, batches, cycles=cycles)
        agree = compare_to_oracle(run.coordinator, oracle, spec)
    finally:
        run.coordinator.close()
    report = {
        "workers": 2, "records": run.records,
        "rec_per_s": run.rec_per_s,
        "merge_p50_s": run.merge_p50_s, "merge_p95_s": run.merge_p95_s,
        "freshness_p95_s": run.freshness_p95_s,
        "sync_trace": run.sync_trace, **agree,
    }
    assert agree["linear_exact"], "linear replica state diverged from oracle"
    assert agree["worst_rel_err"] <= 1e-6, (
        f"coordinator estimates diverged: {agree['worst_rel_err']:.3e}")
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"smoke report -> {out_path}")
    return report


def run_scaleout(worker_counts=(1, 2, 4), *, n_tenants: int = 8,
                 cycles: int = 6, rows_per_cycle: int = 2048,
                 width: int = 1024, merge_budget_s: float = 1.0) -> dict:
    """The ``distributed`` benchmark suite: the same workload through
    1/2/4-worker clusters; rows keyed ``workers_{N}`` with speedup vs the
    1-worker baseline and the per-epoch merge budget check."""
    spec = make_spec(n_tenants, width=width)
    batches = make_batches(spec, cycles=cycles, rows_per_cycle=rows_per_cycle)
    out = {}
    base = None
    for n in worker_counts:
        run = run_cluster(spec, batches, n_workers=n, cycles=cycles)
        if base is None:
            base = run.rec_per_s
        out[f"workers_{n}"] = {
            "workers": n, "records": run.records,
            "ingest_s": run.ingest_s, "rec_per_s": run.rec_per_s,
            "speedup_vs_1w": run.rec_per_s / base if base else 0.0,
            "merge_p50_s": run.merge_p50_s, "merge_p95_s": run.merge_p95_s,
            "merge_budget_s": merge_budget_s,
            "merge_within_budget": run.merge_p95_s <= merge_budget_s,
            "freshness_p50_s": run.freshness_p50_s,
            "freshness_p95_s": run.freshness_p95_s,
        }
        print(f"workers={n}: {run.rec_per_s:,.0f} rec/s "
              f"(x{out[f'workers_{n}']['speedup_vs_1w']:.2f}), "
              f"merge p95 {run.merge_p95_s * 1e3:.2f} ms, "
              f"freshness p95 {run.freshness_p95_s * 1e3:.1f} ms")
    return out


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="2-worker correctness run vs the oracle")
    p.add_argument("--local", action="store_true",
                   help="in-process workers (no subprocesses)")
    p.add_argument("--out", default=None, help="JSON report path")
    p.add_argument("--workers", default="1,2,4",
                   help="scale-out worker counts (comma-separated)")
    args = p.parse_args(argv)
    if args.smoke:
        run_smoke(args.out, local=args.local)
        return 0
    counts = tuple(int(x) for x in args.workers.split(","))
    rows = run_scaleout(counts)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
