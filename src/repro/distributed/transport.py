"""Length-prefixed framing over byte streams (pipes, sockets, files).

The worker protocol is strictly request -> (optional) response over one
pair of unidirectional streams, so plain 4-byte big-endian length prefixes
are enough -- no interleaving, no reassembly.  A zero-length frame is
legal payload (the idle-worker heartbeat, wire.py) and distinct from EOF:
:func:`read_frame` returns ``b""`` for the former and ``None`` for the
latter.

Requests carry a 1-byte opcode before the body; :func:`pack_op` /
:func:`unpack_op` keep that convention in one place.
"""
from __future__ import annotations

import struct

_LEN = struct.Struct(">I")

MAX_FRAME = 1 << 30              # sanity bound: a corrupt length prefix
#   must fail loudly, not allocate gigabytes

# worker protocol opcodes (requests; see distributed/worker.py)
OP_CONFIG = 0x01                 # JSON topology -> JSON ack
OP_INGEST = 0x02                 # stream name + raw records; NO response
OP_FLUSH = 0x03                  # drain ingest buffers -> JSON ack
OP_EXPORT = 0x04                 # -> delta bundle | zero-byte heartbeat
OP_ADVANCE = 0x05                # close the open epoch -> JSON ack
OP_METRICS = 0x06                # -> JSON metrics collect() snapshot
OP_SHUTDOWN = 0x07               # -> JSON ack, then the worker exits


def write_frame(fp, payload: bytes) -> None:
    fp.write(_LEN.pack(len(payload)))
    if payload:
        fp.write(payload)
    fp.flush()


def _read_exact(fp, n: int) -> bytes | None:
    """Read exactly ``n`` bytes; None on clean EOF at a frame boundary.
    EOF *inside* a frame is a protocol error (a peer died mid-write)."""
    chunks = []
    got = 0
    while got < n:
        chunk = fp.read(n - got)
        if not chunk:
            if got == 0:
                return None
            raise ConnectionError(
                f"peer closed mid-frame ({got}/{n} bytes read)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_frame(fp) -> bytes | None:
    """One frame's payload; ``b""`` for a zero-length frame (heartbeat),
    ``None`` on EOF before any header byte."""
    hdr = _read_exact(fp, _LEN.size)
    if hdr is None:
        return None
    (n,) = _LEN.unpack(hdr)
    if n > MAX_FRAME:
        raise ConnectionError(f"frame length {n} exceeds bound {MAX_FRAME}")
    if n == 0:
        return b""
    return _read_exact(fp, n)


def pack_op(op: int, body: bytes = b"") -> bytes:
    return bytes((op,)) + body


def unpack_op(frame: bytes) -> tuple[int, bytes]:
    if not frame:
        raise ConnectionError("empty request frame (no opcode)")
    return frame[0], frame[1:]
