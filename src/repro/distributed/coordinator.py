"""Coordinator: tenant-hash routing, delta merging, replica serving.

The multi-host topology (DESIGN.md §18) is a star: N worker processes
each run an `EstimationService` shard for the tenants
``crc32(name) % N`` hashes onto them; the coordinator routes ingest,
drives the epoch protocol, merges exported deltas into **replica**
windows through the existing merge algebra, and answers any query from
any replica -- queries never wait on workers.

The epoch protocol per sync cycle (the coordinator is the only clock):

    ingest*  -> route records to the owning worker (fire-and-forget)
    flush    -> every worker drains its buffers (one ack each)
    sync     -> every worker exports its unshipped deltas (or the
                zero-byte heartbeat); the coordinator merges them into
                each replica (``coordinator_merge_seconds`` histogram)
    advance  -> every worker closes its open epoch; the replicas rotate
                in the same breath (export-before-advance keeps ring
                slots mirrored slot-for-slot)

**Failure semantics**: a worker whose pipe breaks is marked dead; its
tenants keep serving from the last merged replica state with
``stale=True`` on every result (the admission-control staleness channel,
reused).  No other tenant is affected; ingest routed to a dead worker is
counted and dropped.

Worker handles come in two flavors with one API (``send``/``recv``):
:class:`SubprocessWorker` frames the protocol over a child process's
stdin/stdout (the real deployment shape, and the benchmark harness);
:class:`LocalWorker` drives a `WorkerRuntime` in-process through the
SAME encoded bytes -- tests exercise the full protocol surface without
subprocess startup.
"""
from __future__ import annotations

import dataclasses
import json
import time
import zlib

import jax

from . import transport, wire
from .transport import (OP_ADVANCE, OP_CONFIG, OP_EXPORT, OP_FLUSH,
                        OP_INGEST, OP_METRICS, OP_SHUTDOWN)
from .worker import WorkerRuntime, encode_ingest, handle_request


def shard_of(name: str, n_workers: int) -> int:
    """The worker owning tenant ``name`` (stable content hash, so every
    process -- coordinator, workers, the oracle harness -- agrees)."""
    return zlib.crc32(name.encode("utf-8")) % n_workers


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The cluster topology: hash groups, tenant streams (declaration
    order defines the global uid every process pins), and the
    ``ServiceConfig`` kwargs workers and replicas share.  Streams are
    dicts ``{"name", "group"}`` plus optional ``window_epochs`` /
    ``estimator`` / ``backing_epochs`` overrides (JSON-shippable, so
    ``estimator_cfg`` objects are deliberately not part of the spec)."""
    groups: tuple                # ((group_id, SJPCConfig), ...)
    streams: tuple               # ({"name": ..., "group": ...}, ...)
    service: dict = dataclasses.field(default_factory=dict)

    def uid(self, name: str) -> int:
        for i, s in enumerate(self.streams):
            if s["name"] == name:
                return i
        raise KeyError(f"unknown stream {name!r}")

    def tenants_of(self, worker: int, n_workers: int) -> list[str]:
        return [s["name"] for s in self.streams
                if shard_of(s["name"], n_workers) == worker]

    def worker_spec(self, worker: int, n_workers: int) -> dict:
        """The OP_CONFIG payload for one worker: all groups, only its
        tenants, uids pinned to the global declaration index."""
        return {
            "worker": worker,
            "service": dict(self.service),
            "groups": [{"group_id": gid, "cfg": dataclasses.asdict(cfg)}
                       for gid, cfg in self.groups],
            "streams": [{**s, "uid": i} for i, s in enumerate(self.streams)
                        if shard_of(s["name"], n_workers) == worker],
        }


# -- worker handles ---------------------------------------------------------

class LocalWorker:
    """In-process handle: the same encoded request/response bytes as the
    subprocess protocol, dispatched straight into a `WorkerRuntime`.
    ``fail()`` severs it (the lost-worker tests' kill switch)."""

    def __init__(self):
        self._runtime: WorkerRuntime | None = None
        self._pending: list = []
        self.alive = True

    @property
    def runtime(self) -> WorkerRuntime | None:
        return self._runtime

    def fail(self) -> None:
        self.alive = False

    def send(self, op: int, body: bytes = b"") -> None:
        if not self.alive:
            raise ConnectionError("worker handle severed")
        self._runtime, resp = handle_request(self._runtime, op, body)
        if resp is not None:
            self._pending.append(resp)

    def recv(self) -> bytes:
        if not self.alive:
            raise ConnectionError("worker handle severed")
        return self._pending.pop(0)

    def close(self) -> None:
        self.alive = False


class SubprocessWorker:
    """Framed protocol over a child process's stdin/stdout.  ``env`` is
    typically ``repro.platform.subprocess_env(n)`` plus a PYTHONPATH that
    reaches ``repro`` (see distributed/harness.py); stderr is inherited,
    so worker-side tracebacks surface in the parent's console."""

    def __init__(self, *, env: dict | None = None, python: str | None = None,
                 stderr=None):
        import subprocess
        import sys
        # -c instead of -m: the package __init__ imports .worker, and
        # runpy warns when re-executing an already-imported submodule
        self._proc = subprocess.Popen(
            [python or sys.executable, "-c",
             "import sys; from repro.distributed.worker import main; "
             "sys.exit(main())"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, stderr=stderr,
            env=env)
        self.alive = True

    def send(self, op: int, body: bytes = b"") -> None:
        try:
            transport.write_frame(self._proc.stdin, transport.pack_op(op, body))
        except (OSError, ValueError) as e:
            self.alive = False
            raise ConnectionError(f"worker pipe broken: {e}") from e

    def recv(self) -> bytes:
        try:
            frame = transport.read_frame(self._proc.stdout)
        except (OSError, ValueError) as e:
            self.alive = False
            raise ConnectionError(f"worker pipe broken: {e}") from e
        if frame is None:
            self.alive = False
            raise ConnectionError("worker closed its pipe (EOF)")
        return frame

    def kill(self) -> None:
        self._proc.kill()
        self._proc.wait()
        self.alive = False

    def close(self) -> None:
        if self.alive:
            try:
                self.send(OP_SHUTDOWN)
                self.recv()
            except ConnectionError:
                pass
        self.alive = False
        try:
            self._proc.stdin.close()
        except OSError:
            pass
        self._proc.wait(timeout=10)


# -- the coordinator --------------------------------------------------------

class Coordinator:
    def __init__(self, spec: ClusterSpec, workers: list, *,
                 replicas: int = 1, obs=None):
        from repro.obs import MetricsRegistry, Observability, Tracer
        from repro.service import EstimationService, ServiceConfig

        if obs is None:
            metrics = MetricsRegistry()
            obs = Observability(metrics=metrics,
                                tracer=Tracer(registry=metrics))
        self.obs = obs
        self.spec = spec
        self.workers = list(workers)
        self.n_workers = len(self.workers)
        self._dead: set[int] = set()
        self._stale_tenants: set[str] = set()
        self._rr = 0                       # replica round-robin cursor
        self._unsynced_since: float | None = None
        # replicas: full-topology services that never ingest records --
        # they absorb worker deltas and serve every query.  Replica 0
        # shares the coordinator's obs bundle (one aggregated registry);
        # extra replicas run with obs disabled to keep series unambiguous.
        self.replicas = []
        for r in range(replicas):
            svc = EstimationService(
                ServiceConfig(**spec.service),
                obs=self.obs if r == 0 else Observability.disabled())
            for gid, cfg in spec.groups:
                svc.create_group(gid, cfg)
            for i, s in enumerate(spec.streams):
                kwargs = {k: s[k] for k in
                          ("window_epochs", "estimator", "backing_epochs")
                          if k in s}
                svc.create_stream(s["name"], s["group"], uid=i, **kwargs)
            self.replicas.append(svc)
        # configure the workers (their shard of the same topology)
        for w, h in enumerate(self.workers):
            h.send(OP_CONFIG, json.dumps(
                spec.worker_spec(w, self.n_workers)).encode("utf-8"))
        for w, h in enumerate(self.workers):
            ack = json.loads(h.recv())
            assert ack.get("ok") and ack.get("worker") == w, ack

    # -- failure bookkeeping -------------------------------------------
    def _mark_dead(self, w: int) -> None:
        if w in self._dead:
            return
        self._dead.add(w)
        tenants = self.spec.tenants_of(w, self.n_workers)
        self._stale_tenants.update(tenants)
        self.obs.metrics.inc("coordinator_worker_failures_total",
                             worker=str(w))
        self.obs.metrics.set("coordinator_stale_tenants",
                             float(len(self._stale_tenants)))

    def _alive(self):
        return [(w, h) for w, h in enumerate(self.workers)
                if w not in self._dead]

    def _broadcast(self, op: int) -> dict:
        """Send ``op`` to every live worker, then collect the responses
        (send-all-then-recv-all: flushes/exports run concurrently across
        workers).  A worker that errors on either leg is marked dead and
        dropped from the result -- the cycle continues for the rest."""
        sent = []
        for w, h in self._alive():
            try:
                h.send(op)
                sent.append((w, h))
            except ConnectionError:
                self._mark_dead(w)
        out = {}
        for w, h in sent:
            try:
                out[w] = h.recv()
            except ConnectionError:
                self._mark_dead(w)
        return out

    # -- ingest path ----------------------------------------------------
    def ingest(self, name: str, records) -> int:
        """Route one tenant's records to the owning worker (buffered,
        fire-and-forget -- no round-trip on the record path)."""
        import numpy as np
        w = shard_of(name, self.n_workers)
        m = self.obs.metrics
        arr = np.asarray(records)
        n = int(arr.shape[0])
        if w in self._dead:
            m.inc("coordinator_lost_ingest_records_total", value=float(n),
                  worker=str(w))
            return 0
        body = encode_ingest(name, arr)
        try:
            self.workers[w].send(OP_INGEST, body)
        except ConnectionError:
            self._mark_dead(w)
            m.inc("coordinator_lost_ingest_records_total", value=float(n),
                  worker=str(w))
            return 0
        if self._unsynced_since is None:
            self._unsynced_since = time.perf_counter()
        m.inc("coordinator_ingest_records_total", value=float(n),
              worker=str(w))
        return n

    def flush(self) -> dict:
        return {w: json.loads(r) for w, r in self._broadcast(OP_FLUSH).items()}

    # -- the merge cycle ------------------------------------------------
    def sync(self) -> dict:
        """Export every worker's deltas and merge them into the replicas.

        Per worker: decode the bundle (the zero-byte heartbeat short-
        circuits -- no version check, no merge work) and apply each
        message through the replica services' merge algebra, timing the
        whole apply under ``coordinator_merge_seconds{worker=}``.  The
        replica freshness lag -- how old the oldest unmerged ingest was
        when this sync landed -- is observed per cycle."""
        m = self.obs.metrics
        stats = {"deltas": 0, "heartbeats": 0, "workers": 0}
        for w, payload in self._broadcast(OP_EXPORT).items():
            stats["workers"] += 1
            t0 = time.perf_counter()
            msgs = wire.decode_bundle(payload)
            if msgs is wire.HEARTBEAT:
                stats["heartbeats"] += 1
                m.inc("coordinator_heartbeats_total", worker=str(w))
                continue
            touched = []
            for msg in msgs:
                mode = wire.mode_name(msg.mode)
                for svc in self.replicas:
                    svc.apply_remote_delta(msg.stream, mode, msg.state)
                touched.append(msg.stream)
            # device-inclusive merge latency: absorbing a delta enqueues
            # async jnp work; block on the touched windows before the
            # clock stops (the service-flush timing discipline)
            jax.block_until_ready([
                jax.tree_util.tree_leaves(
                    svc.registry.stream(nm).window.total)
                for svc in self.replicas for nm in touched])
            dt = time.perf_counter() - t0
            stats["deltas"] += len(msgs)
            m.observe("coordinator_merge_seconds", dt, worker=str(w))
            m.inc("coordinator_merges_total", value=float(len(msgs)),
                  worker=str(w))
        if self._unsynced_since is not None:
            m.observe("coordinator_freshness_lag_seconds",
                      time.perf_counter() - self._unsynced_since)
            self._unsynced_since = None
        return stats

    def advance_epoch(self) -> None:
        """Close the epoch everywhere: workers first (they rotate their
        own rings), then the replicas -- callers must sync() first so the
        closing slots are fully mirrored (export-before-advance)."""
        self._broadcast(OP_ADVANCE)
        for svc in self.replicas:
            svc.advance_epoch()

    # -- serving --------------------------------------------------------
    def _replica(self):
        svc = self.replicas[self._rr % len(self.replicas)]
        self._rr += 1
        return svc

    def _staleify(self, res):
        from repro.service import QueryResult
        if isinstance(res, QueryResult):
            if any(s in self._stale_tenants for s in res.streams):
                return res._replace(stale=True)
            return res
        return {k: self._staleify(r) for k, r in res.items()}

    def snapshot(self, names=None):
        """A query snapshot from the next replica (round-robin).  Results
        touching a lost worker's tenants are served from the last merged
        state -- marked via :meth:`stale_tenants`, which the caller (or
        :meth:`poll`) folds into ``stale=True``."""
        return self._replica().snapshot(names)

    def self_join(self, name: str, s: int | None = None):
        return self._staleify(self.snapshot([name]).self_join(name, s))

    def join(self, a: str, b: str, s: int | None = None):
        return self._staleify(self.snapshot([a, b]).join(a, b, s))

    def register_continuous(self, query) -> None:
        for svc in self.replicas:
            svc.register_continuous(query)

    def poll(self) -> dict:
        """Evaluate the standing queries on one replica (planner path:
        fusion + admission thread through untouched); lost-worker tenants
        come back ``stale=True`` on top of any admission staleness."""
        out = self._replica().poll()
        return {k: self._staleify(r) for k, r in out.items()}

    @property
    def stale_tenants(self) -> frozenset:
        return frozenset(self._stale_tenants)

    # -- observability ---------------------------------------------------
    def aggregate_metrics(self) -> dict:
        """Pull every live worker's metric snapshot and absorb it into
        the coordinator registry under a ``worker=<idx>`` label; returns
        the raw per-worker payloads (stats included)."""
        out = {}
        for w, payload in self._broadcast(OP_METRICS).items():
            rep = json.loads(payload)
            out[w] = rep
            self.obs.metrics.absorb(rep.get("metrics", {}), worker=str(w))
            for k, v in rep.get("stats", {}).items():
                self.obs.metrics.set(f"worker_stats:{k}", float(v),
                                     worker=str(w))
        return out

    def metrics_report(self) -> str:
        """One Prometheus text exposition for the whole cluster: replica-0
        service metrics, coordinator merge/failure series, and every
        worker's absorbed snapshot."""
        self.aggregate_metrics()
        self.replicas[0].refresh_gauges()
        return self.obs.metrics.to_prometheus()

    def close(self) -> None:
        for w, h in self._alive():
            try:
                h.close()
            except ConnectionError:
                pass
