"""Worker runtime: one `EstimationService` shard for a subset of tenants.

A worker owns the tenants the coordinator hashed onto it (DESIGN.md §18):
it buffers and flushes their records exactly like a single-process service
-- same cohort batching, same jit'd dispatch -- and, on request, exports
epoch-aligned window deltas in the wire format.  Two invariants make a
worker's sketches interchangeable with a single-process run:

* **Pinned uids**: every stream registers with its *global* tenant uid,
  so the per-(stream, round) ingest PRNG grid (``ingest.ingest_key``)
  matches the single-process oracle bit-for-bit.
* **Export-before-advance**: the coordinator exports every worker's
  deltas before broadcasting ``advance``, so ring slots are fully
  mirrored on the replicas when they close (window.py resets the export
  baseline on rotation).

The subprocess entry (``python -m repro.distributed.worker``) speaks the
framed opcode protocol of transport.py over stdin/stdout.  The protocol
stream is dup'd off fd 0/1 at startup and fd 1 is re-pointed at stderr,
so a stray ``print`` (or a library warning) can never corrupt a frame.
:func:`handle_request` is the single opcode dispatcher -- the in-process
``LocalWorker`` handle (coordinator.py) routes through the same function
with the same encoded bytes, so unit tests exercise the identical
protocol surface without paying subprocess startup.
"""
from __future__ import annotations

import json
import struct
import sys

import numpy as np

from . import transport, wire
from .transport import (OP_ADVANCE, OP_CONFIG, OP_EXPORT, OP_FLUSH,
                        OP_INGEST, OP_METRICS, OP_SHUTDOWN)


class WorkerRuntime:
    """The service shard behind one worker: built from the coordinator's
    JSON topology spec, queried through plain methods (the protocol layer
    below is a thin codec around these)."""

    def __init__(self, spec: dict, *, obs=None):
        from repro.core.sjpc import SJPCConfig
        from repro.obs import MetricsRegistry, Observability, Tracer
        from repro.service import EstimationService, ServiceConfig

        self.worker = int(spec.get("worker", 0))
        if obs is None:
            # a private registry: in-process workers (tests) must not
            # interleave their series with the coordinator's
            metrics = MetricsRegistry()
            obs = Observability(metrics=metrics, tracer=Tracer(registry=metrics))
        self.service = EstimationService(
            ServiceConfig(**spec.get("service", {})), obs=obs)
        for g in spec.get("groups", []):
            self.service.create_group(g["group_id"], SJPCConfig(**g["cfg"]))
        for s in spec.get("streams", []):
            kwargs = {k: s[k] for k in
                      ("window_epochs", "estimator", "backing_epochs", "uid")
                      if k in s}
            self.service.create_stream(s["name"], s["group"], **kwargs)
        self._rounds_exported = 0

    def ingest(self, name: str, records) -> int:
        return self.service.ingest(name, records)

    def flush(self) -> None:
        self.service.flush()

    def export(self) -> bytes:
        """The export payload: a delta bundle for every stream with new
        rounds since the last export, or the zero-byte heartbeat when the
        whole shard is idle (no serialization, no version field, nothing
        for the coordinator to merge)."""
        deltas = self.service.export_deltas()
        m = self.service.obs.metrics
        if not deltas:
            m.inc("worker_heartbeats_total")
            return wire.encode_heartbeat()
        msgs = [wire.encode_delta(wire.DeltaMessage(
            kind=kind, stream=name, epoch=epoch, window_version=version,
            mode=wire.mode_code(mode), state=state))
            for name, kind, epoch, version, mode, state in deltas]
        m.inc("worker_delta_messages_total", value=float(len(msgs)))
        return wire.encode_bundle(msgs)

    def advance(self) -> None:
        self.service.advance_epoch()

    def metrics(self) -> dict:
        """The shard's metric snapshot + service stats (the coordinator
        absorbs this under a ``worker=<idx>`` label)."""
        self.service.refresh_gauges()
        return {"worker": self.worker,
                "stats": dict(self.service.stats),
                "metrics": self.service.obs.metrics.collect()}


# -- protocol codec ---------------------------------------------------------

_INGEST_HDR = struct.Struct("<HII")      # name length, rows, dims


def encode_ingest(name: str, records: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(np.asarray(records, dtype=np.uint32))
    raw = arr.astype(arr.dtype.newbyteorder("<"), copy=False).tobytes()
    nm = name.encode("utf-8")
    return _INGEST_HDR.pack(len(nm), arr.shape[0], arr.shape[1]) + nm + raw


def decode_ingest(body: bytes) -> tuple[str, np.ndarray]:
    nlen, rows, dims = _INGEST_HDR.unpack_from(body)
    name = body[_INGEST_HDR.size:_INGEST_HDR.size + nlen].decode("utf-8")
    arr = np.frombuffer(body, dtype="<u4",
                        offset=_INGEST_HDR.size + nlen).reshape(rows, dims)
    return name, arr


def _ack(**kw) -> bytes:
    return json.dumps({"ok": True, **kw}).encode("utf-8")


def handle_request(runtime: WorkerRuntime | None, op: int, body: bytes):
    """Dispatch one request; returns ``(runtime, response_bytes | None)``.
    ``None`` responses (ingest) send nothing -- the one-directional
    opcode, so the coordinator can stream records without round-trips.
    Shared verbatim by the subprocess loop and the in-process handle."""
    if op == OP_CONFIG:
        runtime = WorkerRuntime(json.loads(body.decode("utf-8")))
        return runtime, _ack(worker=runtime.worker)
    if runtime is None:
        raise ConnectionError(f"opcode {op:#x} before OP_CONFIG")
    if op == OP_INGEST:
        runtime.ingest(*decode_ingest(body))
        return runtime, None
    if op == OP_FLUSH:
        runtime.flush()
        return runtime, _ack(flushes=runtime.service.stats["ingested_records"])
    if op == OP_EXPORT:
        return runtime, runtime.export()
    if op == OP_ADVANCE:
        runtime.advance()
        return runtime, _ack(epochs=runtime.service.stats["epochs"])
    if op == OP_METRICS:
        return runtime, json.dumps(runtime.metrics()).encode("utf-8")
    if op == OP_SHUTDOWN:
        return runtime, _ack(shutdown=True)
    raise ConnectionError(f"unknown opcode {op:#x}")


def main() -> int:
    """Subprocess entry: framed request loop over the original fd 0/1.
    fd 1 is re-pointed at stderr immediately so library chatter cannot
    corrupt protocol frames."""
    import os
    proto_in = os.fdopen(os.dup(0), "rb")
    proto_out = os.fdopen(os.dup(1), "wb")
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    runtime = None
    while True:
        frame = transport.read_frame(proto_in)
        if frame is None:
            return 0                     # coordinator closed the pipe
        op, body = transport.unpack_op(frame)
        runtime, resp = handle_request(runtime, op, body)
        if resp is not None:
            transport.write_frame(proto_out, resp)
        if op == OP_SHUTDOWN:
            return 0


if __name__ == "__main__":
    sys.exit(main())
