from .monitor import (SketchMonitorConfig, init_monitor, monitor_update_local,
                      merge_monitor, monitor_estimate, contamination_estimate)
