"""SJPC as a first-class training-pipeline feature: the stream monitor.

The monitor rides inside ``train_step``: every batch's sequences are reduced
to d-column super-shingle records (data.recordize) and absorbed into
device-LOCAL Fast-AGMS sketches.  Because sketches are linear, the merge
across data-parallel workers is a plain sum that can be DEFERRED -- counters
live as a (shards, levels, t, w) array sharded over the data axes, no
per-step collective (DESIGN.md §7.1, the deferred-merge optimization).  The
paper-faithful alternative (psum every step) is available for comparison
(``merge_every_step=True``) and is measured in EXPERIMENTS.md §Perf.

Query at any step (the paper's continuous queries): pull counters, sum the
shard axis on host, run the Eq. 4 inversion -> g_s for every s in [s_min, d].

Two-stream mode (``contamination_estimate``): sketch train and eval corpora
with the SAME hash params; the §6 join estimator (Eq. 7, sketch inner
products) gives the train<->eval near-duplicate count -- a contamination
signal no single-stream dedup provides.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCParams, SJPCState
from repro.data.recordize import records_from_tokens


@dataclasses.dataclass(frozen=True)
class SketchMonitorConfig:
    d: int = 6                 # super-shingle columns per sequence
    s: int = 3                 # minimum similarity threshold monitored
    ratio: float = 0.5
    width: int = 1024
    depth: int = 3
    shards: int = 1            # data-parallel shard count (leading axis)
    merge_every_step: bool = False
    seed: int = 0xD5

    @property
    def sjpc(self) -> SJPCConfig:
        return SJPCConfig(d=self.d, s=self.s, ratio=self.ratio,
                          width=self.width, depth=self.depth, seed=self.seed)


class MonitorState(NamedTuple):
    counters: jax.Array        # (shards, levels, t, w) int32
    n: jax.Array               # (shards,) float32 records seen per shard
    step: jax.Array            # () int32


def init_monitor(cfg: SketchMonitorConfig) -> tuple[SJPCParams, MonitorState]:
    params, st = sjpc.init(cfg.sjpc)
    counters = jnp.zeros((cfg.shards,) + st.counters.shape, jnp.int32)
    return params, MonitorState(counters=counters,
                                n=jnp.zeros((cfg.shards,), jnp.float32),
                                step=jnp.zeros((), jnp.int32))


def monitor_update_local(cfg: SketchMonitorConfig, params: SJPCParams,
                         local_counters, local_n, tokens, step):
    """Shard-local update (call inside shard_map, or directly when shards=1).

    local_counters: (levels, t, w); tokens: this shard's (b, S) slice.
    """
    records = records_from_tokens(tokens, cfg.d)
    st = SJPCState(counters=local_counters, n=local_n, step=step)
    st = sjpc.update(cfg.sjpc, params, st, records)
    return st.counters, st.n


def merge_monitor(state: MonitorState) -> SJPCState:
    """Deferred merge: sum the shard axis (linearity)."""
    return SJPCState(counters=state.counters.sum(axis=0),
                     n=state.n.sum(), step=state.step)


def monitor_estimate(cfg: SketchMonitorConfig, state: MonitorState):
    """Continuous query: g_s for every monitored threshold s..d."""
    merged = merge_monitor(state)
    est = sjpc.estimate(cfg.sjpc, merged)
    return {
        "n": est.n,
        "per_level_pairs": est.x,           # X_k for k = s..d
        "g": {k: float(est.x[k - cfg.s:].sum() + est.n)
              for k in range(cfg.s, cfg.d + 1)},
    }


def contamination_estimate(cfg: SketchMonitorConfig, train_state: MonitorState,
                           eval_state: MonitorState):
    """Train<->eval similarity JOIN size (paper §6; Eq. 7 inversion)."""
    a = merge_monitor(train_state)
    b = merge_monitor(eval_state)
    est = sjpc.estimate_join(cfg.sjpc, a, b)
    return {
        "per_level_pairs": est.x,
        "join": {k: float(est.x[k - cfg.s:].sum())
                 for k in range(cfg.s, cfg.d + 1)},
    }
