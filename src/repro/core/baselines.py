"""The paper's baselines (§2): random sampling and LSH-SS bucketing.

* Random sampling (§2.1): R records uniformly without replacement, all-pairs
  similarity histogram on the sample, scaled by n(n-1)/(R(R-1)).  The only
  other one-pass competitor (reservoir-style), used in the online comparison
  (Fig. 8) at *equal space*.
* LSH-SS (§2.3, Lee et al. [17]): records are bucketed by a Hamming LSH
  (values of a random column subset); two strata -- same-bucket pairs and
  cross-bucket pairs -- are sampled, the similar fraction of each stratum is
  measured, and stratum totals are scaled.  Multi-pass (bucket construction +
  pair sampling); included for the offline comparison (Figs. 4-6).
* Signature-pattern counting (§2.2, Lee et al. [21]) is intentionally NOT
  implemented: the paper demonstrates the published estimator can go negative
  (their Eq. 4 applied to the authors' own example yields -2) and excludes it
  from comparison; we follow suit (DESIGN.md §8).
"""
from __future__ import annotations

import numpy as np

from .exact import brute_force_pair_counts


def random_sampling_pair_counts(values: np.ndarray, sample_size: int,
                                rng: np.random.Generator) -> np.ndarray:
    """x[k] estimates (ordered pairs) from a uniform record sample.

    A sample of fewer than two records carries no pair information, so the
    zero histogram is returned (and g_s degenerates to n) -- in particular
    for the empty stream, where ``rng.choice(0, ...)`` would raise."""
    values = np.asarray(values)
    n = values.shape[0]
    R = min(sample_size, n)
    if R < 2:
        return np.zeros(values.shape[1] + 1)
    idx = rng.choice(n, size=R, replace=False)
    x_sample = brute_force_pair_counts(values[idx])
    scale = (n * (n - 1)) / (R * (R - 1))
    return x_sample * scale


def random_sampling_g(values: np.ndarray, s: int, sample_size: int,
                      rng: np.random.Generator) -> float:
    x = random_sampling_pair_counts(values, sample_size, rng)
    return float(x[s:].sum() + values.shape[0])


def sample_size_for_bytes(space_bytes: int, record_bytes: int) -> int:
    """Records storable in the space budget (the Fig. 8 equal-space rule).

    Honest accounting: a budget that holds fewer than two records yields
    that many (0 or 1) -- no silent floor to 2, which would quietly grant
    the sampling competitor more space than the sketch it is compared
    against.  ``random_sampling_pair_counts`` handles R < 2 by returning
    the zero histogram."""
    return space_bytes // max(record_bytes, 1)


def _bucket_keys(values: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """Group records by their values on `cols` -> integer bucket ids."""
    proj = np.ascontiguousarray(values[:, cols])
    void = proj.view([('', proj.dtype)] * proj.shape[1]).ravel()
    _, inv = np.unique(void, return_inverse=True)
    return inv


def lsh_ss_g(values: np.ndarray, s: int, rng: np.random.Generator,
             m_h: int | None = None, m_l: int | None = None,
             num_hash_cols: int = 1) -> float:
    """LSH-SS stratified estimate of g_s (ordered pairs + self-pairs).

    m_h / m_l: pair-sample sizes for the same-bucket (high similarity) and
    cross-bucket (low) strata; the authors suggest m_h = m_l = n.

    ``num_hash_cols`` is the size c of the random column subset the LSH
    buckets hash (the paper's LSH-SS uses a subset, not a single column);
    validated to 1 <= c <= d.  At c = d buckets are exact records, so the
    same stratum is exactly the duplicate pairs (regression-pinned in
    tests/test_baselines.py).
    """
    values = np.asarray(values)
    n, d = values.shape
    if not 1 <= num_hash_cols <= d:
        raise ValueError(
            f"num_hash_cols={num_hash_cols} outside [1, d={d}]"
            " (the LSH bucket key is a random column subset)")
    if n < 2:
        return float(n)                 # no pairs; g_s is the self-pairs
    m_h = n if m_h is None else m_h
    m_l = n if m_l is None else m_l

    cols = rng.choice(d, size=num_hash_cols, replace=False)
    bucket = _bucket_keys(values, cols)
    order = np.argsort(bucket, kind="stable")
    sorted_b = bucket[order]
    # bucket boundaries
    starts = np.flatnonzero(np.r_[True, sorted_b[1:] != sorted_b[:-1]])
    ends = np.r_[starts[1:], n]
    sizes = (ends - starts).astype(np.float64)

    same_pairs = float((sizes * (sizes - 1)).sum())          # ordered
    total_pairs = float(n) * (n - 1)
    cross_pairs = total_pairs - same_pairs

    sim_count = lambda i, j: int((values[i] == values[j]).sum())

    # stratum 1: same-bucket pairs, sampled bucket-proportionally
    p1 = 0.0
    if same_pairs > 0 and m_h > 0:
        probs = (sizes * (sizes - 1)) / same_pairs
        picks = rng.choice(len(sizes), size=m_h, p=probs)
        hits = 0
        for b in picks:
            lo, hi = starts[b], ends[b]
            i, j = rng.choice(np.arange(lo, hi), size=2, replace=False)
            hits += sim_count(order[i], order[j]) >= s
        p1 = hits / m_h

    # stratum 2: cross-bucket pairs, rejection-sampled
    p2 = 0.0
    if cross_pairs > 0 and m_l > 0:
        hits = 0
        got = 0
        attempts = 0
        while got < m_l and attempts < 50 * m_l:
            attempts += 1
            i, j = rng.integers(0, n, size=2)
            if i == j or bucket[i] == bucket[j]:
                continue
            got += 1
            hits += sim_count(i, j) >= s
        p2 = hits / max(got, 1)

    return p1 * same_pairs + p2 * cross_pairs + n
