"""Exact similarity self-join / join sizes (test oracles + "offline SJPC").

Two independent exact methods:

* ``brute_force_pair_counts`` -- O(n^2 d) all-pairs comparison (tiny inputs;
  the ground truth every other path is tested against).
* ``exact_pair_counts`` -- O(2^d n) group-by per lattice combination:
  y_k = sum over level-k combinations of sum_v m_v^2, then the *exact*
  Lemma 3 inversion x_k = y_k - C(d,k) n - sum_{j>k} C(j,k) x_j.
  This is the paper's "offline case" with r = 1 and no sketching, and doubles
  as the materialized-sub-value-stream variant of §7.2.
"""
from __future__ import annotations

import itertools
import math

import numpy as np


def _row_group_counts(proj: np.ndarray) -> np.ndarray:
    """Multiplicities of distinct rows of a 2-D int array (exact)."""
    arr = np.ascontiguousarray(proj)
    void = arr.view([('', arr.dtype)] * arr.shape[1]).ravel()
    _, counts = np.unique(void, return_counts=True)
    return counts


def exact_level_join_sizes(values: np.ndarray, s: int = 1) -> np.ndarray:
    """y[k] for k = 0..d (y[k] = 0 for k < s): level-k self-join sizes.

    y_k counts ordered pairs (including self-pairs) of level-k sub-values
    that agree -- exactly the paper's y_k with sampling ratio r = 1.
    """
    values = np.asarray(values)
    n, d = values.shape
    y = np.zeros(d + 1, dtype=np.float64)
    for k in range(max(s, 1), d + 1):
        total = 0
        for cols in itertools.combinations(range(d), k):
            counts = _row_group_counts(values[:, list(cols)])
            total += int((counts.astype(np.int64) ** 2).sum())
        y[k] = total
    return y


def exact_pair_counts(values: np.ndarray) -> np.ndarray:
    """x[k] for k = 0..d: exact #ordered pairs (i != j) exactly k-similar.

    Lemma 3 inversion of the exact level join sizes.
    """
    values = np.asarray(values)
    n, d = values.shape
    y = exact_level_join_sizes(values, s=1)
    x = np.zeros(d + 1, dtype=np.float64)
    for k in range(d, 0, -1):
        acc = y[k] - math.comb(d, k) * n
        for j in range(k + 1, d + 1):
            acc -= math.comb(j, k) * x[j]
        x[k] = acc
    # level 0: the empty projection joins everything (y_0 = n^2)
    x[0] = float(n) * n - n - x[1:].sum()
    return x


def brute_force_pair_counts(values: np.ndarray) -> np.ndarray:
    """x[k] by O(n^2) comparison (ordered pairs, i != j).  Tiny inputs only."""
    values = np.asarray(values)
    n, d = values.shape
    x = np.zeros(d + 1, dtype=np.float64)
    for i in range(n):
        sim = (values[i] == values).sum(axis=1)
        cnt = np.bincount(sim, minlength=d + 1).astype(np.float64)
        cnt[(values[i] == values[i]).sum()] -= 1          # drop the self-pair
        x += cnt
    return x


def exact_g(values: np.ndarray, s: int) -> float:
    """The paper's g_s (Eq. 2): sum_{k>=s} x_k + n."""
    x = exact_pair_counts(values)
    return float(x[s:].sum() + values.shape[0])


def brute_force_join_counts(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """x[k]: #pairs (i in A, j in B) exactly k-similar (unordered across
    relations -- each cross pair counted once, matching §6)."""
    a = np.asarray(a)
    b = np.asarray(b)
    d = a.shape[1]
    assert b.shape[1] == d
    x = np.zeros(d + 1, dtype=np.float64)
    for i in range(a.shape[0]):
        sim = (a[i] == b).sum(axis=1)
        x += np.bincount(sim, minlength=d + 1).astype(np.float64)
    return x


def exact_join_g(a: np.ndarray, b: np.ndarray, s: int) -> float:
    return float(brute_force_join_counts(a, b)[s:].sum())
