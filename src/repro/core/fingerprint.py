"""Rabin-style polynomial fingerprints of projected sub-values.

A level-k "sub-value" of a record is (combination-id, v_{c1}, ..., v_{ck}) --
the paper encodes it as the string ``ABC.a1.b1.c3``.  We encode it as a
polynomial fingerprint over GF(2^31-1):

    fp(base) = Horner(base, [combo_id + 1, v_{c1} + 1, ..., v_{ck} + 1])

evaluated with a **masked Horner scheme** over the full d columns (excluded
columns are skipped), so the whole (batch, n_combos) fingerprint matrix is a
static d-step loop of vectorized uint32 ops -- no gathers, TPU-friendly.

Two independent random bases give two 31-bit fingerprints; the pair is the
sketch key.  Collision probability per distinct sub-value pair is
<= ((d+1)/p)^2 ~ 3e-17, matching the paper's 64-bit Rabin fingerprints.

The combination id (the integer column bitmask) seeds the Horner state so
identical values under different projections never collide by construction
(the paper's "attach the projection ordering" device).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .hashing import P31, addmod_p31, mulmod_p31, reduce_p31, random_field_elements


def make_fingerprint_bases(rng: np.random.Generator) -> np.ndarray:
    """Two independent random bases in [2, p) -- shape (2,) uint32."""
    return (random_field_elements(rng, (2,)) % np.uint32(int(P31) - 2)) + np.uint32(2)


def subvalue_fingerprints(values, combo_masks, combo_ids, bases):
    """Fingerprint every (record, combination) sub-value.

    Args:
      values: (B, d) uint32 record columns (arbitrary uint32; reduced mod p).
      combo_masks: (M, d) {0,1} uint32 column-inclusion masks.
      combo_ids: (M,) uint32 unique combination ids (the column bitmask).
      bases: (2,) uint32 fingerprint bases.

    Returns:
      (fp1, fp2): each (B, M) uint32 canonical field elements.
    """
    values = reduce_p31(values)                      # (B, d)
    d = values.shape[-1]
    seed = addmod_p31(reduce_p31(combo_ids), jnp.uint32(1))   # (M,)

    outs = []
    for base in (bases[0], bases[1]):
        fp = jnp.broadcast_to(seed[None, :], (values.shape[0], combo_ids.shape[0]))
        for col in range(d):
            v = addmod_p31(values[:, col:col + 1], jnp.uint32(1))       # (B, 1)
            nxt = addmod_p31(mulmod_p31(fp, base), v)                   # (B, M)
            fp = jnp.where(combo_masks[None, :, col] != 0, nxt, fp)
        outs.append(fp)
    return outs[0], outs[1]


def np_subvalue_fingerprints(values, combo_masks, combo_ids, bases):
    """NumPy uint64 oracle for the kernel tests."""
    p = np.uint64(int(P31))
    values = values.astype(np.uint64) % p
    B, d = values.shape
    M = combo_ids.shape[0]
    outs = []
    for base in bases.astype(np.uint64):
        fp = np.broadcast_to((combo_ids.astype(np.uint64) % p + 1) % p, (B, M)).copy()
        for col in range(d):
            v = (values[:, col:col + 1] + 1) % p
            nxt = (fp * base + v) % p
            fp = np.where(combo_masks[None, :, col] != 0, nxt, fp)
        outs.append(fp.astype(np.uint32))
    return outs[0], outs[1]
