"""4-universal hashing over GF(2^31 - 1) in pure uint32 arithmetic.

TPU adaptation of the paper's Carter-Wegman polynomial hashing: TPUs have no
64-bit integer multiplier, so instead of the usual p = 2^61 - 1 field we work
in the Mersenne-31 field p = 2^31 - 1 and implement ``a * b mod p`` with
16-bit limb decomposition -- every intermediate product fits in uint32.
Degree-3 polynomials keep the 4-universality guarantee *exact* (it is a
property of the field, not of its width).  The narrower field only affects
fingerprint collision probability, which is compensated by double
fingerprinting (see :mod:`repro.core.fingerprint`).

All functions are shape-polymorphic jnp ops usable inside jit / shard_map /
Pallas (the same limb arithmetic is reused by the Pallas kernels).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Mersenne prime 2^31 - 1.
P31 = np.uint32(0x7FFFFFFF)
_U16 = np.uint32(0xFFFF)
_ONE = np.uint32(1)


def _u32(x):
    return jnp.asarray(x, dtype=jnp.uint32)


def fold_p31(x):
    """One folding step of reduction mod 2^31-1: x -> (x & p) + (x >> 31).

    For x < 2^32 the result is < 2^31 + 2 and congruent to x (mod p).
    """
    x = _u32(x)
    return (x & P31) + (x >> np.uint32(31))


def reduce_p31(x):
    """Fully reduce a uint32 into the canonical range [0, p)."""
    x = fold_p31(fold_p31(x))
    return jnp.where(x >= P31, x - P31, x)


def mulmod_p31(a, b):
    """(a * b) mod (2^31 - 1) for canonical a, b in [0, p), pure uint32.

    16-bit limb decomposition: a = a1*2^16 + a0, b = b1*2^16 + b0 with
    a1, b1 < 2^15; every partial product fits in uint32.  The 64-bit product
    hi*2^32 + lo is reduced using 2^31 = 1 (mod p).
    """
    a = _u32(a)
    b = _u32(b)
    a0 = a & _U16
    a1 = a >> np.uint32(16)
    b0 = b & _U16
    b1 = b >> np.uint32(16)

    hihi = a1 * b1                      # < 2^30
    mid = a1 * b0 + a0 * b1             # < 2^32 (each term < 2^31)
    lolo = a0 * b0                      # < 2^32

    mid_lo = mid << np.uint32(16)       # low 16 bits of mid, shifted
    lo = lolo + mid_lo                  # wraps mod 2^32
    carry = (lo < lolo).astype(jnp.uint32)
    hi = hihi + (mid >> np.uint32(16)) + carry   # <= 2^30 + 2^16 + 1

    # x = hi*2^32 + lo ≡ 2*hi + (lo >> 31) + (lo & p)   (mod p)
    t = (hi << _ONE) + (lo >> np.uint32(31))      # <= 2^31 + 3, fits
    t = fold_p31(t)                               # <= p + 1
    r = t + (lo & P31)                            # < 2^32
    r = fold_p31(r)
    r = fold_p31(r)
    return jnp.where(r >= P31, r - P31, r)


def addmod_p31(a, b):
    """(a + b) mod p for canonical a, b in [0, p)."""
    r = _u32(a) + _u32(b)               # < 2^32
    r = fold_p31(r)
    return jnp.where(r >= P31, r - P31, r)


def cw_hash(x, coeffs):
    """Degree-3 Carter-Wegman polynomial hash: 4-universal on [0, p).

    ``x``: canonical field elements, any shape.
    ``coeffs``: (..., 4) canonical field elements, broadcast against x
      (typically shape (4,) or (t, 4) with x expanded).
    Returns canonical field elements, shape = broadcast(x, coeffs[..., 0]).
    """
    x = _u32(x)
    c = _u32(coeffs)
    h = jnp.broadcast_to(c[..., 3], jnp.broadcast_shapes(x.shape, c[..., 3].shape))
    h = addmod_p31(mulmod_p31(h, x), c[..., 2])
    h = addmod_p31(mulmod_p31(h, x), c[..., 1])
    h = addmod_p31(mulmod_p31(h, x), c[..., 0])
    return h


def cw_hash_pair(x, y, coeffs):
    """4-universal hash of a pair of field elements.

    Sum of two independent degree-3 CW hashes is 4-wise independent on
    distinct pairs.  ``coeffs``: (..., 2, 4).
    """
    return addmod_p31(cw_hash(x, coeffs[..., 0, :]), cw_hash(y, coeffs[..., 1, :]))


def hash_bucket(h, width):
    """Map a field element to a bucket in [0, width); width must be pow2.

    Bias relative to uniform is O(width / 2^31) -- negligible for the sketch
    widths used here (<= 2^20).
    """
    return (h & np.uint32(width - 1)).astype(jnp.int32)


def hash_sign(h):
    """Map a field element to ±1 (int32)."""
    return (_ONE.astype(jnp.int32) - (h & _ONE).astype(jnp.int32) * 2)


def random_field_elements(rng: np.random.Generator, shape) -> np.ndarray:
    """Uniform elements of [0, p) as a uint32 numpy array (host-side init)."""
    return rng.integers(0, int(P31), size=shape, dtype=np.uint32)


# ---------------------------------------------------------------------------
# NumPy uint64 oracle (tests validate the limb arithmetic against this).
# ---------------------------------------------------------------------------

def np_mulmod_p31(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return ((a.astype(np.uint64) * b.astype(np.uint64)) % np.uint64(int(P31))).astype(np.uint32)


def np_cw_hash(x: np.ndarray, coeffs: np.ndarray) -> np.ndarray:
    p = np.uint64(int(P31))
    x64 = x.astype(np.uint64)
    c = coeffs.astype(np.uint64)
    h = np.broadcast_to(c[..., 3], np.broadcast_shapes(x64.shape, c[..., 3].shape)).copy()
    for i in (2, 1, 0):
        h = (h * x64 + c[..., i]) % p
    return h.astype(np.uint32)
