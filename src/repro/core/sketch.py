"""Fast-AGMS (Count-Sketch) self-join / join size sketches.

One sketch = (depth t, width w) int32 counters plus two 4-universal hash
families (bucket + sign), each taking the *pair* of fingerprint components as
its key.  Linear: merging two sketches of disjoint sub-streams is counter
addition -- this is what makes the distributed deferred-merge design work
(each data-parallel worker accumulates locally; `psum` at query time).

F2 (self-join size) estimate  = median over rows of  sum_j C[i,j]^2.
Inner product (join size)     = median over rows of  sum_j A[i,j]*B[i,j].

The pure-jnp update here is the reference implementation; the Pallas kernel
in :mod:`repro.kernels.sketch_update` computes the same counters with a
one-hot matmul on the MXU (no scatter).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from .hashing import cw_hash_pair, hash_bucket, hash_sign, random_field_elements


class SketchParams(NamedTuple):
    """Hash coefficients for a stack of sketches.

    bucket_coeffs / sign_coeffs: (..., t, 2, 4) uint32 field elements.
    A leading dimension stacks independent sketches (one per lattice level).
    """
    bucket_coeffs: jax.Array
    sign_coeffs: jax.Array

    @property
    def depth(self) -> int:
        return self.bucket_coeffs.shape[-3]


def make_sketch_params(rng: np.random.Generator, depth: int, *, stack: tuple = ()) -> SketchParams:
    shape = tuple(stack) + (depth, 2, 4)
    return SketchParams(
        bucket_coeffs=jnp.asarray(random_field_elements(rng, shape)),
        sign_coeffs=jnp.asarray(random_field_elements(rng, shape)),
    )


def empty_counters(depth: int, width: int, *, stack: tuple = ()) -> jax.Array:
    assert width & (width - 1) == 0, "sketch width must be a power of two"
    return jnp.zeros(tuple(stack) + (depth, width), dtype=jnp.int32)


def sketch_buckets_signs(fp1, fp2, params: SketchParams, width: int):
    """Hash keys for all rows: returns buckets (t, N) int32, signs (t, N) int32."""
    t = params.depth
    fp1 = fp1.reshape(-1)
    fp2 = fp2.reshape(-1)
    hb = cw_hash_pair(fp1[None, :], fp2[None, :], params.bucket_coeffs[:, None, :, :])
    hs = cw_hash_pair(fp1[None, :], fp2[None, :], params.sign_coeffs[:, None, :, :])
    del t
    return hash_bucket(hb, width), hash_sign(hs)


def sketch_update(counters, fp1, fp2, params: SketchParams, weights=None):
    """Insert a batch of keys into one sketch (reference implementation).

    counters: (t, w) int32.  fp1/fp2: any shape (flattened).  weights:
    broadcastable int32 (0 masks an element out, matching the stochastic
    rounding of the projection sample).
    """
    t, w = counters.shape
    buckets, signs = sketch_buckets_signs(fp1, fp2, params, w)   # (t, N)
    if weights is not None:
        signs = signs * jnp.broadcast_to(weights.reshape(-1)[None, :], signs.shape).astype(jnp.int32)

    def row_update(row, b, s):
        return row.at[b].add(s)

    return jax.vmap(row_update)(counters, buckets, signs)


def estimate_f2(counters) -> jax.Array:
    """Median-of-rows second-moment estimate.  counters: (..., t, w)."""
    sq = jnp.sum(counters.astype(jnp.float32) ** 2, axis=-1)
    return jnp.median(sq, axis=-1)


def estimate_inner(counters_a, counters_b) -> jax.Array:
    """Median-of-rows inner-product (join size) estimate."""
    prod = jnp.sum(counters_a.astype(jnp.float32) * counters_b.astype(jnp.float32), axis=-1)
    return jnp.median(prod, axis=-1)


def np_estimate_f2_exact(counters: np.ndarray) -> np.ndarray:
    """int64-exact F2 (offline/oracle path; jnp uses f32 on-device)."""
    sq = (counters.astype(np.int64) ** 2).sum(axis=-1)
    return np.median(sq, axis=-1)


def np_estimate_inner_exact(counters_a: np.ndarray,
                            counters_b: np.ndarray) -> np.ndarray:
    """int64-exact inner-product (join size) estimate, the oracle the fused
    query kernel is tested against.  counters: (..., t, w)."""
    prod = (counters_a.astype(np.int64) * counters_b.astype(np.int64)).sum(axis=-1)
    return np.median(prod, axis=-1)


def merge(counters_a, counters_b):
    """Sketch linearity: union of sub-streams = counter addition."""
    return counters_a + counters_b
