"""SJPC -- Similarity Self-Join Pair Count (the paper's Algorithm 1).

One-pass, sublinear-space estimation of g_s = #{record pairs at least
s-similar} for a stream of d-column records:

  Step 1  per record, per level k in [s, d]: sample ~r*C(d,k) column
          combinations, fingerprint each projected sub-value, insert into
          the level's Fast-AGMS sketch.
  Step 2  Y_k = sketch F2 estimate of the level-k sub-value stream.
  Step 3  invert the lattice system (Eq. 4):
              X_k = (Y_k - r*C(d,k)*n) / r^2  -  sum_{j>k} C(j,k) X_j
          and return sum_k X_k (+ n for self-pairs -> g_s).

State is a pytree of int32 counters (levels, t, w) -- linear, so
data-parallel shards merge by addition (``jax.lax.psum``) and merging can be
deferred arbitrarily.  ``update`` is pure jnp (jit/shard_map-safe); the
Pallas-accelerated path swaps in kernels.ops.sketch_update_fused.

The similarity *join* estimator (paper §6, Eq. 7) works on two streams
sketched with the *same* hash parameters; Y_k is then the sketch inner
product and the inversion drops the self-pair term.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from . import projections as proj
from . import sketch as sk
from .fingerprint import make_fingerprint_bases, subvalue_fingerprints
from .hashing import cw_hash_pair, hash_bucket, hash_sign


@dataclasses.dataclass(frozen=True)
class SJPCConfig:
    """Static configuration (hashable; safe to close over in jit)."""
    d: int                  # record dimensionality (number of columns)
    s: int                  # similarity threshold (count of equal columns)
    ratio: float = 0.5      # projection sampling ratio r
    width: int = 1024       # sketch width w (counters per row, pow2)
    depth: int = 3          # sketch depth t (median of t estimates)
    seed: int = 0x5A5A

    def __post_init__(self):
        assert 1 <= self.s <= self.d, "need 1 <= s <= d"
        assert 0 < self.ratio <= 1.0
        assert self.width & (self.width - 1) == 0

    @property
    def num_levels(self) -> int:
        return self.d - self.s + 1

    def level_k(self, idx: int) -> int:
        return self.s + idx

    @property
    def counters_bytes(self) -> int:
        return self.num_levels * self.depth * self.width * 4


class SJPCParams(NamedTuple):
    """Hash/fingerprint randomness (arrays; checkpointed with the state)."""
    bucket_coeffs: jax.Array   # (levels, t, 2, 4) uint32
    sign_coeffs: jax.Array     # (levels, t, 2, 4) uint32
    fp_bases: jax.Array        # (2,) uint32


class SJPCState(NamedTuple):
    """Linear sketch state.  counters: (levels, t, w) int32; n: records seen."""
    counters: jax.Array
    n: jax.Array               # float32 scalar (exact for n < 2^24; int path below)
    step: jax.Array            # int32 PRNG folding counter


def init(cfg: SJPCConfig) -> tuple[SJPCParams, SJPCState]:
    rng = np.random.default_rng(cfg.seed)
    params = sk.make_sketch_params(rng, cfg.depth, stack=(cfg.num_levels,))
    fp_bases = make_fingerprint_bases(rng)
    state = SJPCState(
        counters=sk.empty_counters(cfg.depth, cfg.width, stack=(cfg.num_levels,)),
        n=jnp.zeros((), jnp.float32),
        step=jnp.zeros((), jnp.int32),
    )
    return SJPCParams(params.bucket_coeffs, params.sign_coeffs, jnp.asarray(fp_bases)), state


def _level_tables(cfg: SJPCConfig):
    return proj.lattice(cfg.d, cfg.s)


def update(cfg: SJPCConfig, params: SJPCParams, state: SJPCState, values,
           key: jax.Array | None = None, *, update_fn=None,
           row_mask: jax.Array | None = None) -> SJPCState:
    """Absorb a batch of records.  values: (B, d) uint32/int32.

    ``update_fn(counters, fp1, fp2, level_params, weights) -> counters`` lets
    callers swap the reference jnp update for the Pallas kernel; default is
    the reference.

    ``row_mask`` ((B,) int32/bool, optional) marks valid rows; rows with mask
    0 contribute nothing to the counters or to ``n``.  This is what lets the
    service ingest pipeline pad per-tenant batches to a shared static shape
    and still produce counters identical to an unpadded per-stream update.
    """
    values = jnp.asarray(values).astype(jnp.uint32)
    B = values.shape[0]
    if key is None:
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0xC0FFEE), state.step)
    update_fn = update_fn or sk.sketch_update
    if row_mask is not None:
        row_mask = jnp.asarray(row_mask).astype(jnp.int32).reshape(B)

    counters = state.counters
    new_counters = []
    for idx, level in enumerate(_level_tables(cfg)):
        lkey = jax.random.fold_in(key, idx)
        weights = proj.sample_combo_weights(lkey, B, level.num, cfg.ratio)
        if row_mask is not None:
            weights = weights * row_mask[:, None]
        fp1, fp2 = subvalue_fingerprints(
            values, jnp.asarray(level.masks), jnp.asarray(level.ids), params.fp_bases)
        level_params = sk.SketchParams(params.bucket_coeffs[idx], params.sign_coeffs[idx])
        new_counters.append(update_fn(counters[idx], fp1, fp2, level_params, weights))
    n_new = jnp.float32(B) if row_mask is None else row_mask.sum().astype(jnp.float32)
    # step counts rounds that CARRIED data: a fully-masked (padding-only)
    # round is a content no-op and consumes no randomness, so it must not
    # advance the replay/bootstrap coordinate either -- a stream riding
    # along fully masked in a busy cohort stays bit-identical to a solo
    # replay of its own record rounds (ingest.py's determinism contract)
    step_inc = (jnp.int32(1) if row_mask is None
                else (n_new > 0).astype(jnp.int32))
    return SJPCState(
        counters=jnp.stack(new_counters),
        n=state.n + n_new,
        step=state.step + step_inc,
    )


def _sample_level_weights(cfg: SJPCConfig, key: jax.Array, batch: int,
                          row_mask: jax.Array | None):
    """Per-level (B, C(d,k)) sampling weights, exactly as ``update`` draws
    them (same fold-in order, same uniforms) -- the fused paths reuse this so
    they stay bit-identical to the reference path under a shared key."""
    weights = []
    for idx, level in enumerate(_level_tables(cfg)):
        lkey = jax.random.fold_in(key, idx)
        w = proj.sample_combo_weights(lkey, batch, level.num, cfg.ratio)
        if row_mask is not None:
            w = w * row_mask[:, None]
        weights.append(w)
    return weights


def update_fused(cfg: SJPCConfig, params: SJPCParams, state: SJPCState, values,
                 key: jax.Array | None = None, *,
                 row_mask: jax.Array | None = None,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None) -> SJPCState:
    """``update``, but as the fused ingest hot path.

    Same contract and **bit-identical counters** as :func:`update` given the
    same ``key`` (asserted in tests/test_fused_ingest.py); the difference is
    execution shape.  On TPU backends (or ``use_pallas=True``) the whole
    record batch runs through the fused Pallas kernel -- fingerprints
    produced in VMEM feed the one-hot MXU contraction directly, one launch
    for every lattice level.  Elsewhere it runs the fused pure-jnp
    formulation: ONE masked-Horner fingerprint pass over the concatenated
    combination table and ONE scatter into the flattened (L, t, w) counter
    block (per-combination hash coefficients gathered by level), which
    replaces the per-level chain of 2L+L dispatching ops of the reference
    path with 3 large ones.
    """
    values = jnp.asarray(values).astype(jnp.uint32)
    B = values.shape[0]
    if key is None:
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0xC0FFEE), state.step)
    if row_mask is not None:
        row_mask = jnp.asarray(row_mask).astype(jnp.int32).reshape(B)
    level_weights = _sample_level_weights(cfg, key, B, row_mask)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    if use_pallas:
        from repro.kernels.fused_ingest import fused_ingest_pallas
        pad = proj.padded_lattice(cfg.d, cfg.s)
        wpad = jnp.stack(
            [jnp.pad(w, ((0, 0), (0, pad.m_max - w.shape[1])))
             for w in level_weights], axis=1)                    # (B, L, m_max)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        counters = fused_ingest_pallas(
            state.counters, values, jnp.asarray(pad.masks),
            jnp.asarray(pad.ids), params.fp_bases,
            params.bucket_coeffs, params.sign_coeffs, wpad,
            interpret=interpret)
    else:
        cat = proj.concat_lattice(cfg.d, cfg.s)
        t, w = cfg.depth, cfg.width
        fp1, fp2 = subvalue_fingerprints(
            values, jnp.asarray(cat.masks), jnp.asarray(cat.ids),
            params.fp_bases)                                     # (B, m_total)
        wcat = jnp.concatenate(level_weights, axis=1)            # (B, m_total)
        level_of = jnp.asarray(cat.level_of)                     # (m_total,)
        # per-combination coefficients, depth-major for broadcasting:
        # (t, 1, m_total, 2, 4) against fp (B, m_total) -> hashes (t, B, m_total)
        bcoef = jnp.moveaxis(params.bucket_coeffs[level_of], 1, 0)[:, None]
        scoef = jnp.moveaxis(params.sign_coeffs[level_of], 1, 0)[:, None]
        bucket = hash_bucket(cw_hash_pair(fp1, fp2, bcoef), w)
        sign = hash_sign(cw_hash_pair(fp1, fp2, scoef)) * wcat[None]
        plane = level_of[None, None, :] * t + jnp.arange(t, dtype=jnp.int32)[:, None, None]
        counters = (state.counters.reshape(-1)
                    .at[plane * w + bucket].add(sign)
                    .reshape(state.counters.shape))

    n_new = jnp.float32(B) if row_mask is None else row_mask.sum().astype(jnp.float32)
    # data-carrying rounds only (see `update`): padding-only rounds must not
    # advance the replay coordinate
    step_inc = (jnp.int32(1) if row_mask is None
                else (n_new > 0).astype(jnp.int32))
    return SJPCState(counters=counters, n=state.n + n_new,
                     step=state.step + step_inc)


def merge(a: SJPCState, b: SJPCState) -> SJPCState:
    """Linearity: sketches of disjoint sub-streams add.

    ``step`` feeds ``jax.random.fold_in`` to derive per-batch sampling keys,
    so the merged step must be a value no shard has already folded in.
    ``maximum`` is wrong there: two shards merged at equal step k
    would hand the merged sketch step k -- the exact fold-in key a shard that
    keeps ingesting would use for its own next batch, correlating the
    supposedly independent projection samples (and, under tree merges,
    replaying keys the shards already consumed).  The *sum* of the step
    counters dominates every step either side has folded in, so post-merge
    updates draw fresh keys.  Shards that keep ingesting concurrently after
    a merge (forked lineages) should pass explicit ``key``s to ``update``
    instead of relying on the step counter.
    """
    return SJPCState(a.counters + b.counters, a.n + b.n, a.step + b.step)


def subtract(a: SJPCState, b: SJPCState) -> SJPCState:
    """Linearity, the other direction: remove the sub-stream ``b`` sketched
    into ``a`` (sliding-window expiry; ``b`` must be a sub-stream of ``a``).

    ``step`` keeps ``a.step``: expiry removes old *data*, not PRNG history --
    the keys ``b`` consumed were consumed, and reusing them would correlate
    a re-ingest of the expired epoch with live data.
    """
    return SJPCState(a.counters - b.counters, a.n - b.n, a.step)


def all_reduce(state: SJPCState, axis_names) -> SJPCState:
    """Merge device-local sketches across mesh axes (inside shard_map/pjit)."""
    return SJPCState(
        counters=jax.lax.psum(state.counters, axis_names),
        n=jax.lax.psum(state.n, axis_names),
        step=state.step,
    )


_SHARD_SALT = 0x5A4D


class ShardedIngest:
    """Device-sharded ingest executor with deferred merges.

    Exploits sketch linearity for data parallelism: each record micro-batch
    is split across ``num_shards`` shards, every shard folds its slice into a
    shard-local *delta* sketch, and no cross-shard communication happens on
    the ingest path at all.  ``merged()`` pays the single cross-device
    reduction (``lax.psum`` semantics, executed as one sum over the shard
    axis) for however many micro-batches were absorbed since construction --
    N micro-batches cost one reduction, not N.

    When the runtime exposes at least ``num_shards`` devices the per-shard
    update runs inside :func:`repro.compat.shard_map` over a 1-D 'shards'
    mesh with the delta states and record slices sharded on the leading
    axis; with fewer devices the identical computation runs as a ``vmap``
    over the shard axis (bit-identical counters -- the update is integer
    arithmetic, so tests exercise either path interchangeably).

    Per-shard sampling keys are ``fold_in(batch_key, shard)``; replaying the
    same slices with the same keys through plain :func:`update` rebuilds any
    shard bit-exactly (the conformance contract, see tests).
    """

    def __init__(self, cfg: SJPCConfig, params: SJPCParams,
                 state: SJPCState | None = None, *, num_shards: int | None = None,
                 use_fused: bool = True, use_pallas: bool | None = None,
                 interpret: bool | None = None, devices=None):
        devices = list(devices if devices is not None else jax.local_devices())
        self.num_shards = int(num_shards or len(devices))
        assert self.num_shards >= 1
        self.cfg, self.params = cfg, params
        self.base = state if state is not None else init(cfg)[1]
        self.use_fused = use_fused
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.micro_batches = 0
        self.merges = 0

        self._mesh = None
        if self.num_shards > 1 and len(devices) >= self.num_shards:
            from jax.sharding import Mesh
            self._mesh = Mesh(np.asarray(devices[:self.num_shards]), ("shards",))
        self.deltas = self._zero_deltas()
        self._step_fn = self._build_step_fn()

    @property
    def mapped(self) -> bool:
        """True when shard updates run under shard_map on a device mesh
        (False: single-device vmap with identical numbers)."""
        return self._mesh is not None

    def _zero_deltas(self) -> SJPCState:
        zeros = SJPCState(
            counters=jnp.zeros((self.num_shards,) + tuple(self.base.counters.shape),
                               jnp.int32),
            n=jnp.zeros((self.num_shards,), jnp.float32),
            step=jnp.zeros((self.num_shards,), jnp.int32))
        if self._mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            shard = NamedSharding(self._mesh, P("shards"))
            zeros = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, shard), zeros)
        return zeros

    def reset(self, base: SJPCState | None = None) -> None:
        """Drop accumulated deltas (and optionally rebase), keeping the
        compiled step function -- unlike constructing a fresh executor."""
        if base is not None:
            self.base = base
        self.deltas = self._zero_deltas()
        self.micro_batches = 0

    # ------------------------------------------------------------------
    def _build_step_fn(self):
        cfg, params = self.cfg, self.params
        update_one = functools.partial(
            update_fused if self.use_fused else update, cfg, params)
        kwargs = ({"use_pallas": self.use_pallas, "interpret": self.interpret}
                  if self.use_fused else {})

        def shard_step(delta, values, row_mask, key):
            return update_one(delta, values, key=key, row_mask=row_mask, **kwargs)

        if self._mesh is None:
            def step(deltas, values, row_mask, keys):
                return jax.vmap(shard_step)(deltas, values, row_mask, keys)
            return jax.jit(step)

        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map

        def local(deltas, values, row_mask, keys):
            # local views carry a leading shard axis of size 1
            st = shard_step(
                SJPCState(deltas.counters[0], deltas.n[0], deltas.step[0]),
                values[0], row_mask[0], keys[0])
            return SJPCState(st.counters[None], st.n[None], st.step[None])

        step = shard_map(local, mesh=self._mesh,
                         in_specs=(P("shards"), P("shards"), P("shards"),
                                   P("shards")),
                         out_specs=P("shards"), check_rep=False)
        return jax.jit(step)

    # ------------------------------------------------------------------
    def ingest(self, values, key: jax.Array | None = None,
               row_mask=None) -> None:
        """Absorb one micro-batch: split across shards, update shard-local
        deltas, defer the merge.  values (B, d); rows pad to a shard
        multiple with mask 0."""
        values = np.ascontiguousarray(np.asarray(values, dtype=np.uint32))
        B = values.shape[0]
        if key is None:
            key = jax.random.fold_in(
                jax.random.PRNGKey(self.cfg.seed ^ _SHARD_SALT),
                self.micro_batches)
        mask = (np.ones((B,), np.int32) if row_mask is None
                else np.asarray(row_mask, np.int32).reshape(B))
        pad = (-B) % self.num_shards
        if pad:
            values = np.pad(values, ((0, pad), (0, 0)))
            mask = np.pad(mask, (0, pad))
        per = values.shape[0] // self.num_shards
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(self.num_shards))
        self.deltas = self._step_fn(
            self.deltas,
            jnp.asarray(values.reshape(self.num_shards, per, self.cfg.d)),
            jnp.asarray(mask.reshape(self.num_shards, per)), keys)
        self.micro_batches += 1

    def merged(self) -> SJPCState:
        """The single deferred cross-shard reduction: base + sum of deltas.

        ``step`` follows :func:`merge` semantics (sum over shards) so
        post-merge updates can never replay a shard's consumed fold-in keys.
        """
        self.merges += 1
        return SJPCState(
            counters=self.base.counters + self.deltas.counters.sum(axis=0),
            n=self.base.n + self.deltas.n.sum(),
            step=self.base.step + self.deltas.step.sum(),
        )

    def shard_key(self, micro_batch: int, shard: int) -> jax.Array:
        """The sampling key shard ``shard`` folded in for micro-batch
        ``micro_batch`` (the offline-replay coordinate)."""
        base = jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed ^ _SHARD_SALT), micro_batch)
        return jax.random.fold_in(base, shard)


# ---------------------------------------------------------------------------
# Step 2+3: estimation (host-side numpy; cheap, exact in float64)
# ---------------------------------------------------------------------------

def level_f2(state: SJPCState) -> np.ndarray:
    """Y_k for k = s..d, int64-exact median-of-rows F2."""
    counters = np.asarray(jax.device_get(state.counters))
    return sk.np_estimate_f2_exact(counters).astype(np.float64)


def f2_to_pair_count(d: int, s: int, n: float, r: float, y: Sequence[float],
                     *, clamp: bool = True) -> np.ndarray:
    """Procedure f2toPairCnt of Algorithm 1 (Eq. 4 inversion).

    ``y[i]`` is the level-(s+i) self-join size estimate.  Returns X[s..d]
    (estimated #pairs exactly k-similar, ordered-pair convention).

    NOTE (paper erratum): Algorithm 1 line 34 subtracts ``r^2 C(j,k) X[j]``
    from the *r^2-scaled* accumulator (division by r^2 happens only at line
    38), which applies the r^2 correction twice and biases estimates upward
    for r < 1.  Multiplying Eq. 4 through by r^2 shows the scaled recursion
    must subtract ``C(j,k) X_scaled[j]`` -- that is what Lemma 4 proves and
    what we implement (the two coincide at r = 1; verified unbiased in
    tests/test_sjpc_estimator.py).
    """
    X = np.zeros(d + 1, dtype=np.float64)     # r^2-scaled accumulators
    for k in range(d, s - 1, -1):
        acc = float(y[k - s]) - math.comb(d, k) * r * n
        for j in range(k + 1, d + 1):
            acc -= math.comb(j, k) * X[j]
        if clamp:
            acc = max(acc, 0.0)
        X[k] = acc
    X = X / (r * r)
    return X[s:]


class SJPCEstimate(NamedTuple):
    x: np.ndarray          # X[s..d]: per-level k-similar pair estimates
    pairs: float           # sum_k X_k (similar pairs, ordered, excl. self)
    g_s: float             # pairs + n (the paper's g_s, Eq. 2)
    y: np.ndarray          # raw level F2 estimates (diagnostics)
    n: float


def estimate(cfg: SJPCConfig, state: SJPCState, *, clamp: bool = True) -> SJPCEstimate:
    y = level_f2(state)
    n = float(jax.device_get(state.n))
    x = f2_to_pair_count(cfg.d, cfg.s, n, cfg.ratio, y, clamp=clamp)
    pairs = float(x.sum())
    return SJPCEstimate(x=x, pairs=pairs, g_s=pairs + n, y=y, n=n)


# ---------------------------------------------------------------------------
# Similarity join (two streams; paper §6)
# ---------------------------------------------------------------------------

def join_level_inner(state_a: SJPCState, state_b: SJPCState) -> np.ndarray:
    ca = np.asarray(jax.device_get(state_a.counters))
    cb = np.asarray(jax.device_get(state_b.counters))
    return sk.np_estimate_inner_exact(ca, cb).astype(np.float64)


def inner_to_join_count(d: int, s: int, r: float, y: Sequence[float],
                        *, clamp: bool = True) -> np.ndarray:
    """Eq. 7: X_k = Y_k / r^2 - sum_{j>k} C(j,k) X_j (no self-pair term)."""
    X = np.zeros(d + 1, dtype=np.float64)
    for k in range(d, s - 1, -1):
        acc = float(y[k - s]) / (r * r)
        for j in range(k + 1, d + 1):
            acc -= math.comb(j, k) * X[j]
        if clamp:
            acc = max(acc, 0.0)
        X[k] = acc
    return X[s:]


def estimate_join(cfg: SJPCConfig, state_a: SJPCState, state_b: SJPCState,
                  *, clamp: bool = True) -> SJPCEstimate:
    """Similarity join size of two streams sketched with identical params."""
    y = join_level_inner(state_a, state_b)
    x = inner_to_join_count(cfg.d, cfg.s, cfg.ratio, y, clamp=clamp)
    pairs = float(x.sum())
    return SJPCEstimate(x=x, pairs=pairs, g_s=pairs, y=y,
                        n=float(jax.device_get(state_a.n)))


# ---------------------------------------------------------------------------
# Batched estimation: every (stream, threshold) cell from ONE compiled call
# ---------------------------------------------------------------------------

class SJPCBatchEstimate(NamedTuple):
    """Estimates for N same-config sketches at EVERY threshold k = s..d.

    Column i answers threshold k = s + i; ``g[:, i]`` is the suffix sum
    ``x[:, i:].sum(axis=1)`` (+ n for self-joins), so one batch holds the
    full all-thresholds table of every stream.
    """
    x: np.ndarray              # (N, L) per-level k-similar pair estimates
    g: np.ndarray              # (N, L) g_k per threshold (join: join size)
    y: np.ndarray              # (N, L) raw level F2 / inner estimates
    n: np.ndarray              # (N,) records; joins: (N, 2) per side
    stderr: np.ndarray         # (N, L) absolute 1-sigma bound (Theorem 2)
    stderr_offline: np.ndarray  # (N, L) sampling-only bound (Theorem 1)


@functools.partial(jax.jit, static_argnames=("cfg", "clamp", "join",
                                             "use_pallas", "interpret"))
def _estimate_batch_core(cfg: SJPCConfig, counters_a, counters_b, n, *,
                         clamp: bool, join: bool, use_pallas, interpret):
    """The fused query dispatch: stacked (N, L, t, w) counters -> per-stream
    (y, x, g) arrays, one compiled call.

    The per-level Python loops of the reference path (``level_f2`` +
    ``f2_to_pair_count`` / ``inner_to_join_count``) become: one fused moment
    launch over every (stream, level, depth-row), a median over the depth
    axis, and the Eq. 4 / Eq. 7 recursion unrolled over the L static levels
    (vectorized over streams).  f32 is exact while intermediates stay
    exact-integer (< 2^24) -- true for the tested magnitudes; conformance vs
    the float64 numpy oracle is asserted to 1e-6 beyond that
    (tests/test_fused_query.py).
    """
    from repro.kernels.ops import fused_query
    d, s, r = cfg.d, cfg.s, cfg.ratio
    moments = fused_query(counters_a, counters_b, use_pallas=use_pallas,
                          interpret=interpret)             # (N, L, t)
    y = jnp.median(moments, axis=-1)                       # (N, L)

    # Eq. 4 (self; r^2-scaled accumulators, one division at the end) or
    # Eq. 7 (join) -- identical recursion orders to the numpy reference.
    X: dict[int, jax.Array] = {}
    for k in range(d, s - 1, -1):
        if join:
            acc = y[:, k - s] / jnp.float32(r * r)
        else:
            acc = y[:, k - s] - jnp.float32(math.comb(d, k) * r) * n
        for j in range(k + 1, d + 1):
            acc = acc - jnp.float32(math.comb(j, k)) * X[j]
        if clamp:
            acc = jnp.maximum(acc, 0.0)
        X[k] = acc
    x = jnp.stack([X[k] for k in range(s, d + 1)], axis=1)  # (N, L)
    if not join:
        x = x / jnp.float32(r * r)
    g = jnp.cumsum(x[:, ::-1], axis=1)[:, ::-1]             # suffix sums
    if not join:
        g = g + n[:, None]
    return y, x, g


def _batch_bounds(cfg: SJPCConfig, n: np.ndarray,
                  g: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized Theorem 1/2 plug-in bounds, float64, same op order as the
    scalar ``offline_variance_bound`` / ``online_variance_bound`` so the
    batched stderr matches the per-stream reference bit for bit.
    n (N,), g (N, L) -> (online, offline) absolute 1-sigma bounds (N, L)."""
    d, r, w = cfg.d, cfg.ratio, cfg.width
    lead = np.array([math.comb(d, k) ** 2 / r * math.comb(2 * (d - k), d - k)
                     for k in range(cfg.s, d + 1)], dtype=np.float64)
    g = np.asarray(g, np.float64)
    n = np.asarray(n, np.float64).reshape(-1, 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        off = np.sqrt(lead[None, :] / g) * g
        on = np.sqrt(lead[None, :] * ((1 + 2 / w) / g
                                      + (2 / w) * (1 + n / (r * g)) ** 2)) * g
    pos = g > 0
    return np.where(pos, on, 0.0), np.where(pos, off, 0.0)


def _stack_counters(counters) -> jax.Array:
    counters = jnp.asarray(counters)
    assert counters.ndim == 4, \
        f"expected stacked (N, levels, t, w) counters; got {counters.shape}"
    return counters


def estimate_batch(cfg: SJPCConfig, counters, n, *, clamp: bool = True,
                   use_pallas: bool | None = None,
                   interpret: bool | None = None) -> SJPCBatchEstimate:
    """Self-join estimates for N stacked sketches, all thresholds at once.

    counters: (N, levels, t, w) int32 (stacked ``SJPCState.counters`` of
    streams sharing one config/params draw); n: (N,) records per stream.
    """
    counters = _stack_counters(counters)
    n = jnp.asarray(n, jnp.float32).reshape(counters.shape[0])
    y, x, g = _estimate_batch_core(cfg, counters, counters, n, clamp=clamp,
                                   join=False, use_pallas=use_pallas,
                                   interpret=interpret)
    y, x, g, n = (np.asarray(jax.device_get(a), np.float64)
                  for a in (y, x, g, n))
    on, off = _batch_bounds(cfg, n, g)
    return SJPCBatchEstimate(x=x, g=g, y=y, n=n, stderr=on, stderr_offline=off)


def estimate_join_batch(cfg: SJPCConfig, counters_a, counters_b, n_a, n_b, *,
                        clamp: bool = True, use_pallas: bool | None = None,
                        interpret: bool | None = None) -> SJPCBatchEstimate:
    """Join sizes for N stacked sketch PAIRS (identical hash params per
    pair), all thresholds at once.  Error bars follow the reference proxy
    (DESIGN.md §10.4): the self-join bound at n = max(n_a, n_b) with
    max(estimate, 1) plugged in."""
    counters_a = _stack_counters(counters_a)
    counters_b = _stack_counters(counters_b)
    N = counters_a.shape[0]
    n_a = jnp.asarray(n_a, jnp.float32).reshape(N)
    n_b = jnp.asarray(n_b, jnp.float32).reshape(N)
    y, x, g = _estimate_batch_core(cfg, counters_a, counters_b, n_a,
                                   clamp=clamp, join=True,
                                   use_pallas=use_pallas, interpret=interpret)
    y, x, g, n_a, n_b = (np.asarray(jax.device_get(a), np.float64)
                         for a in (y, x, g, n_a, n_b))
    on, off = _batch_bounds(cfg, np.maximum(n_a, n_b), np.maximum(g, 1.0))
    return SJPCBatchEstimate(x=x, g=g, y=y, n=np.stack([n_a, n_b], axis=1),
                             stderr=on, stderr_offline=off)


# ---------------------------------------------------------------------------
# Analytical bounds (Theorems 1-3) -- used in tests and EXPERIMENTS.md
# ---------------------------------------------------------------------------

def offline_variance_bound(d: int, s: int, r: float, g_s: float) -> float:
    """Theorem 1: var(G_s / g_s) <= C(d,s)^2 (1/r) C(2(d-s), d-s) / g_s."""
    return math.comb(d, s) ** 2 / r * math.comb(2 * (d - s), d - s) / g_s


def online_variance_bound(d: int, s: int, r: float, w: int, n: float, g_s: float) -> float:
    """Theorem 2 (depth-1 sketch)."""
    lead = math.comb(d, s) ** 2 / r * math.comb(2 * (d - s), d - s)
    return lead * ((1 + 2 / w) / g_s + (2 / w) * (1 + n / (r * g_s)) ** 2)
