"""repro.core -- the paper's contribution: one-pass similarity (self-)join
size estimation over d-column record streams (SJPC, Rafiei & Deng 2018)."""

from .sjpc import (            # noqa: F401
    SJPCConfig, SJPCParams, SJPCState, SJPCEstimate,
    init, update, merge, all_reduce, estimate, estimate_join,
    f2_to_pair_count, inner_to_join_count, level_f2,
    offline_variance_bound, online_variance_bound,
)
from .sketch import (          # noqa: F401
    SketchParams, make_sketch_params, empty_counters, sketch_update,
    estimate_f2, estimate_inner,
)
from .exact import (           # noqa: F401
    exact_pair_counts, exact_level_join_sizes, brute_force_pair_counts,
    exact_g, brute_force_join_counts, exact_join_g,
)
from .baselines import (       # noqa: F401
    random_sampling_g, random_sampling_pair_counts, lsh_ss_g,
    sample_size_for_bytes,
)
from .projections import lattice, level_combinations, comb  # noqa: F401
