"""The projection lattice and per-record combination sampling (paper §3.2).

Level k of the lattice is the set of C(d, k) column combinations.  Each
record emits, per level, a uniform random subset of its combinations of
expected size r * C(d, k) -- "sampling from the space of projections".
Algorithm 1 lines 8-12: the non-integer sample size is rounded
stochastically; selection is uniform without replacement.

TPU adaptation: rather than materializing a ragged per-record list of
selected combinations (gather-heavy), we fingerprint *all* C(d, k)
combinations densely and carry a (batch, M) {0,1} **weight matrix** into the
sketch update (weight 0 = combination not sampled).  Selection of exactly
l_i = floor(rM) + Bernoulli(frac) combos per record is done by ranking i.i.d.
uniforms -- the top-l_i ranks form a uniform random l_i-subset.  Everything
is dense, static-shaped, and jit/Pallas friendly; the extra hashing for
masked-out combos is negligible next to model compute and beats gathers on
TPU by a wide margin.
"""
from __future__ import annotations

import itertools
import math
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


def comb(n: int, k: int) -> int:
    return math.comb(n, k)


class LevelCombos(NamedTuple):
    """Static combination table for one lattice level."""
    k: int
    masks: np.ndarray      # (M, d) uint32 in {0,1}
    ids: np.ndarray        # (M,) uint32 -- the column bitmask (globally unique)

    @property
    def num(self) -> int:
        return self.masks.shape[0]


def level_combinations(d: int, k: int) -> LevelCombos:
    masks = np.zeros((comb(d, k), d), dtype=np.uint32)
    ids = np.zeros((comb(d, k),), dtype=np.uint32)
    for i, cols in enumerate(itertools.combinations(range(d), k)):
        masks[i, list(cols)] = 1
        ids[i] = sum(1 << c for c in cols)
    return LevelCombos(k=k, masks=masks, ids=ids)


def lattice(d: int, s: int) -> list[LevelCombos]:
    """Levels s..d (the ones SJPC needs for threshold s)."""
    return [level_combinations(d, k) for k in range(s, d + 1)]


def sample_size_parts(num_combos: int, ratio: float) -> tuple[int, float]:
    """(floor, frac) of the stochastically rounded sample size r*M."""
    target = num_combos * ratio
    lo = int(math.floor(target + 1e-9))
    frac = target - lo
    if frac < 1e-9:
        frac = 0.0
    lo = min(lo, num_combos)
    return lo, frac


def sample_combo_weights(key: jax.Array, batch: int, num_combos: int, ratio: float):
    """(batch, M) {0,1} int32 weights: per-record uniform l_i-subset.

    l_i = floor(r*M) + Bernoulli(frac(r*M)) per record (Alg. 1 lines 9-11).
    ratio == 1 short-circuits to all-ones.
    """
    lo, frac = sample_size_parts(num_combos, ratio)
    if lo >= num_combos and frac == 0.0:
        return jnp.ones((batch, num_combos), dtype=jnp.int32)

    k_sel, k_round = jax.random.split(key)
    scores = jax.random.uniform(k_sel, (batch, num_combos))
    # rank of each combo among this record's scores (0 = largest)
    order = jnp.argsort(-scores, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    l_i = jnp.full((batch, 1), lo, dtype=jnp.int32)
    if frac > 0.0:
        l_i = l_i + (jax.random.uniform(k_round, (batch, 1)) < frac).astype(jnp.int32)
    return (ranks < l_i).astype(jnp.int32)
