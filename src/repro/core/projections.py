"""The projection lattice and per-record combination sampling (paper §3.2).

Level k of the lattice is the set of C(d, k) column combinations.  Each
record emits, per level, a uniform random subset of its combinations of
expected size r * C(d, k) -- "sampling from the space of projections".
Algorithm 1 lines 8-12: the non-integer sample size is rounded
stochastically; selection is uniform without replacement.

TPU adaptation: rather than materializing a ragged per-record list of
selected combinations (gather-heavy), we fingerprint *all* C(d, k)
combinations densely and carry a (batch, M) {0,1} **weight matrix** into the
sketch update (weight 0 = combination not sampled).  Selection of exactly
l_i = floor(rM) + Bernoulli(frac) combos per record is done by ranking i.i.d.
uniforms -- the top-l_i ranks form a uniform random l_i-subset.  Everything
is dense, static-shaped, and jit/Pallas friendly; the extra hashing for
masked-out combos is negligible next to model compute and beats gathers on
TPU by a wide margin.
"""
from __future__ import annotations

import functools
import itertools
import math
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


def comb(n: int, k: int) -> int:
    return math.comb(n, k)


class LevelCombos(NamedTuple):
    """Static combination table for one lattice level."""
    k: int
    masks: np.ndarray      # (M, d) uint32 in {0,1}
    ids: np.ndarray        # (M,) uint32 -- the column bitmask (globally unique)

    @property
    def num(self) -> int:
        return self.masks.shape[0]


def level_combinations(d: int, k: int) -> LevelCombos:
    masks = np.zeros((comb(d, k), d), dtype=np.uint32)
    ids = np.zeros((comb(d, k),), dtype=np.uint32)
    for i, cols in enumerate(itertools.combinations(range(d), k)):
        masks[i, list(cols)] = 1
        ids[i] = sum(1 << c for c in cols)
    return LevelCombos(k=k, masks=masks, ids=ids)


def lattice(d: int, s: int) -> list[LevelCombos]:
    """Levels s..d (the ones SJPC needs for threshold s)."""
    return [level_combinations(d, k) for k in range(s, d + 1)]


class PaddedLattice(NamedTuple):
    """All levels s..d stacked into one rectangular table.

    Every level is padded to ``m_max = max_k C(d, k)`` combinations so the
    whole lattice becomes dense (L, m_max, ...) arrays -- the layout the
    fused ingest kernel (one launch for every level) consumes.  Padded
    combination slots carry ``valid == 0``; the sampling step multiplies
    weights by ``valid`` so padded slots can never contribute to a sketch.
    """
    d: int
    s: int
    masks: np.ndarray      # (L, m_max, d) uint32 in {0,1}
    ids: np.ndarray        # (L, m_max) uint32 (0 in padded slots)
    valid: np.ndarray      # (L, m_max) uint32 in {0,1}
    nums: tuple            # true C(d, k) per level

    @property
    def num_levels(self) -> int:
        return self.masks.shape[0]

    @property
    def m_max(self) -> int:
        return self.masks.shape[1]


class ConcatLattice(NamedTuple):
    """All levels s..d concatenated along the combination axis (no padding).

    The fast pure-jnp fused update uses this layout: one masked-Horner
    fingerprint pass over all ``m_total = sum_k C(d, k)`` combinations and
    one flat scatter into the (L, t, w) counter block, with per-combination
    hash coefficients gathered via ``level_of``.
    """
    d: int
    s: int
    masks: np.ndarray      # (m_total, d) uint32 in {0,1}
    ids: np.ndarray        # (m_total,) uint32
    level_of: np.ndarray   # (m_total,) int32 level index (0 = level s)
    nums: tuple            # C(d, k) per level; offsets are cumulative

    @property
    def m_total(self) -> int:
        return self.masks.shape[0]


@functools.lru_cache(maxsize=None)
def concat_lattice(d: int, s: int) -> ConcatLattice:
    levels = lattice(d, s)
    masks = np.concatenate([lv.masks for lv in levels], axis=0)
    ids = np.concatenate([lv.ids for lv in levels], axis=0)
    level_of = np.concatenate(
        [np.full((lv.num,), i, dtype=np.int32) for i, lv in enumerate(levels)])
    return ConcatLattice(d=d, s=s, masks=masks, ids=ids, level_of=level_of,
                         nums=tuple(lv.num for lv in levels))


@functools.lru_cache(maxsize=None)
def padded_lattice(d: int, s: int) -> PaddedLattice:
    levels = lattice(d, s)
    m_max = max(lv.num for lv in levels)
    L = len(levels)
    masks = np.zeros((L, m_max, d), dtype=np.uint32)
    ids = np.zeros((L, m_max), dtype=np.uint32)
    valid = np.zeros((L, m_max), dtype=np.uint32)
    for i, lv in enumerate(levels):
        masks[i, :lv.num] = lv.masks
        ids[i, :lv.num] = lv.ids
        valid[i, :lv.num] = 1
    return PaddedLattice(d=d, s=s, masks=masks, ids=ids, valid=valid,
                         nums=tuple(lv.num for lv in levels))


def sample_size_parts(num_combos: int, ratio: float) -> tuple[int, float]:
    """(floor, frac) of the stochastically rounded sample size r*M."""
    target = num_combos * ratio
    lo = int(math.floor(target + 1e-9))
    frac = target - lo
    if frac < 1e-9:
        frac = 0.0
    lo = min(lo, num_combos)
    return lo, frac


# Below this combination count, descending ranks are computed by pairwise
# comparison counting (O(M^2) vectorized ops) instead of a double argsort
# (O(M log M) but ~6x slower in XLA:CPU at SJPC's practical M).  Both
# produce identical ranks (ties broken by index, matching stable argsort),
# so the switch never changes sampled weights.
_RANK_BY_COMPARISON_MAX_M = 64


def descending_ranks(scores: jax.Array) -> jax.Array:
    """Rank (0 = largest) of each entry along the last axis, ties by index.

    Bit-identical to ``argsort(argsort(-scores))`` with stable sorts:
    rank_j = #{k : s_k > s_j} + #{k < j : s_k == s_j}.
    """
    m = scores.shape[-1]
    if m > _RANK_BY_COMPARISON_MAX_M:
        return jnp.argsort(jnp.argsort(-scores, axis=-1), axis=-1).astype(jnp.int32)
    sk_ = scores[..., None, :]                  # k runs along the last axis
    sj = scores[..., :, None]
    earlier = jnp.tril(jnp.ones((m, m), jnp.int32), k=-1)   # [k < j]
    gt = (sk_ > sj).astype(jnp.int32)
    eq = (sk_ == sj).astype(jnp.int32)
    return jnp.sum(gt + eq * earlier, axis=-1).astype(jnp.int32)


def sample_combo_weights(key: jax.Array, batch: int, num_combos: int, ratio: float):
    """(batch, M) {0,1} int32 weights: per-record uniform l_i-subset.

    l_i = floor(r*M) + Bernoulli(frac(r*M)) per record (Alg. 1 lines 9-11).
    ratio == 1 short-circuits to all-ones.
    """
    lo, frac = sample_size_parts(num_combos, ratio)
    if lo >= num_combos and frac == 0.0:
        return jnp.ones((batch, num_combos), dtype=jnp.int32)

    k_sel, k_round = jax.random.split(key)
    scores = jax.random.uniform(k_sel, (batch, num_combos))
    # rank of each combo among this record's scores (0 = largest)
    ranks = descending_ranks(scores)
    l_i = jnp.full((batch, 1), lo, dtype=jnp.int32)
    if frac > 0.0:
        l_i = l_i + (jax.random.uniform(k_round, (batch, 1)) < frac).astype(jnp.int32)
    return (ranks < l_i).astype(jnp.int32)
