"""Assigned architecture configs (public-literature specs, verbatim) and the
input-shape pool.  ``get(name)`` returns the full ArchConfig; ``reduced(name)``
returns a CPU-smoke-sized config of the same family (same layer pattern, MoE
structure, GQA ratio -- tiny dims).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

from .jamba_1_5_large_398b import CONFIG as jamba_1_5_large_398b
from .dbrx_132b import CONFIG as dbrx_132b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .internlm2_20b import CONFIG as internlm2_20b
from .deepseek_coder_33b import CONFIG as deepseek_coder_33b
from .qwen2_7b import CONFIG as qwen2_7b
from .qwen2_5_3b import CONFIG as qwen2_5_3b
from .chameleon_34b import CONFIG as chameleon_34b
from .mamba2_370m import CONFIG as mamba2_370m
from .sjpc_paper import PAPER_DEFAULTS

REGISTRY: dict[str, ArchConfig] = {
    c.name: c for c in [
        jamba_1_5_large_398b, dbrx_132b, deepseek_moe_16b,
        seamless_m4t_large_v2, internlm2_20b, deepseek_coder_33b,
        qwen2_7b, qwen2_5_3b, chameleon_34b, mamba2_370m,
    ]
}

ARCH_NAMES = list(REGISTRY)


def get(name: str) -> ArchConfig:
    return REGISTRY[name]


# ---------------------------------------------------------------------------
# Input shapes (assigned pool): every cell = (arch x shape)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int           # sequence length (cache length for decode)
    batch: int         # global batch


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k needs a sub-quadratic path (SSM/hybrid only)."""
    if shape == "long_500k":
        return cfg.supports_long_context()
    return True


def cells(arch_names=None) -> list[tuple[str, str]]:
    """All runnable (arch, shape) dry-run cells."""
    names = arch_names or ARCH_NAMES
    out = []
    for a in names:
        for s in SHAPES:
            if applicable(REGISTRY[a], s):
                out.append((a, s))
    return out


# ---------------------------------------------------------------------------
# Reduced smoke configs (CPU tests): same family shape, tiny dims
# ---------------------------------------------------------------------------

def reduced(name: str) -> ArchConfig:
    cfg = REGISTRY[name]
    period = cfg.period
    layers = max(2 * period, 2)
    # keep one full period (+ leading dense layer if any)
    if cfg.leading_dense_layers:
        layers = period + cfg.leading_dense_layers
    kw = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=layers,
        d_model=64,
        num_heads=0 if cfg.attention_free else 4,
        num_kv_heads=0 if cfg.attention_free else max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        d_ff=0 if cfg.d_ff == 0 else 128,
        dense_ff=0 if cfg.dense_ff == 0 else 160,
        vocab_size=256,
        head_dim=16,
        num_experts=min(cfg.num_experts, 8),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        # drop-free capacity in smoke configs: keeps batched dispatch ==
        # per-token decode dispatch (capacity drops are exercised in
        # tests/test_moe_dispatch.py instead)
        capacity_factor=float(min(cfg.num_experts, 8)) if cfg.num_experts else 1.25,
        moe_period=cfg.moe_period,
        moe_offset=cfg.moe_offset,
        leading_dense_layers=cfg.leading_dense_layers,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_conv=cfg.ssm_conv,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_expand=cfg.ssm_expand,
        ssm_groups=cfg.ssm_groups,
        layer_pattern=cfg.layer_pattern,
        encoder_layers=2 if cfg.is_encdec else 0,
        qkv_bias=cfg.qkv_bias,
        tie_embeddings=cfg.tie_embeddings,
        frontend=cfg.frontend,
    )
    return ArchConfig(**kw)
