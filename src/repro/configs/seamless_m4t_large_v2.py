"""SeamlessM4T-large v2 text backbone.  [arXiv:2308.11596; hf]

Encoder-decoder, 24+24 layers; the speech/text modality frontend is a stub
(input_specs supplies precomputed frame embeddings (B, S_src, d_model)).
MHA (16 heads, head_dim 64).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    frontend="audio",
    rope_theta=10_000.0,
)
