"""DeepSeek-MoE 16B.  [arXiv:2401.06066; hf]

2 shared + 64 routed experts (top-6), fine-grained (expert d_ff=1408);
first layer is a dense MLP (d_ff 10944); MHA (kv == heads == 16).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    dense_ff=10944,
    vocab_size=102400,
    head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    leading_dense_layers=1,
    rope_theta=10_000.0,
)
