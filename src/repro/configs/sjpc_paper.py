"""Paper-default SJPC parameters (§7 experimental setup).

DBLPtitles setting: d=6 super-shingles, online sketches w=1000 (we round to
the pow2 1024), depth t=3, sampling ratio r=0.5, thresholds s=3..6.
"""
from repro.core.sjpc import SJPCConfig

PAPER_DEFAULTS = SJPCConfig(d=6, s=3, ratio=0.5, width=1024, depth=3)
