"""DeepSeek-Coder-33B.  [arXiv:2401.14196; hf] -- llama-arch, GQA kv=8.

56 query heads pad to 64 for TP=16 (zero-init pad heads; waste reported in
the roofline MODEL_FLOPS/HLO ratio).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    head_dim=128,
    rope_theta=100_000.0,
)
