"""Jamba-1.5-Large (398B).  [arXiv:2403.19887 / 2408.12570; hf]

Mamba+attention 1:7 interleave (attention at position 4 of each 8-layer
period, matching attn_layer_period=8 / attn_layer_offset=4), MoE 16e top-2
on every other layer (expert_layer_period=2, offset=1).  The Mamba mixers
are modeled with the SSD (Mamba2) formulation -- state 64, head 64 --
DESIGN.md §8 records this adaptation.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    num_experts=16,
    num_experts_per_tok=2,
    moe_period=2,
    moe_offset=1,
    layer_pattern="MMMMAMMM",
    ssm_state=64,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=8,
    rope_theta=1_000_000.0,
)
