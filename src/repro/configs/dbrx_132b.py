"""DBRX (132B total).  [hf:databricks/dbrx-base; unverified]

16 experts top-4 fine-grained MoE on every layer, GQA kv=8.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    num_experts=16,
    num_experts_per_tok=4,
    rope_theta=500_000.0,
)
