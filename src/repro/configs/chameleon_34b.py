"""Chameleon-34B.  [arXiv:2405.09818; unverified]

Early-fusion VLM: VQ image tokens are ordinary vocabulary ids, so the
backbone is a plain dense decoder; the image tokenizer is a frontend stub.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    frontend="vision",
    rope_theta=10_000.0,
)
