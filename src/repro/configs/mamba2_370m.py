"""Mamba2-370M.  [arXiv:2405.21060; unverified]

Attention-free SSD: 48 layers, d_model 1024, expand 2 (d_inner 2048),
head 64 (32 heads), state 128.  No FFN (d_ff = 0).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
)
