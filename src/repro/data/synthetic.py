"""Seeded synthetic generators matching the paper's evaluation datasets.

DBLP/YFCC are not redistributable here; these generators reproduce the
*statistics the estimator sees*: column cardinalities, duplicate/similarity
structure, and skew profiles (DESIGN.md §8).  Each returns (records, meta)
where records is an (n, d) uint32 matrix of column-value ids.

- ``dblp_like``: columns with very different cardinalities (title >> year),
  plus planted near-duplicate pairs -- the DBLP5/DBLP6 analogue.
- ``shingle_records``: documents as d super-shingle fingerprints with a
  configurable duplication profile -- the DBLPtitles analogue.
- ``near_uniform_40_60`` / ``skewed``: the §7.5 running-time datasets
  (40% unique / 60% in 4-similar pairs; 20-80 and 10-90 skew).
- ``yfcc_like``: 5 columns shaped like (userid, date, device, lat, lon).
"""
from __future__ import annotations

import numpy as np


def _rng(seed):
    return np.random.default_rng(seed)


def planted_cluster_records(n: int, d: int, rng: np.random.Generator,
                            clusters) -> np.ndarray:
    """Uniform noise + planted near-duplicate clusters.

    ``clusters`` is a list of (k, size, count): plant ``count`` clusters of
    ``size`` records agreeing on ``k`` columns -- the quadratic
    duplicate-group structure of the paper's DBLP data (g_s >> n, the
    regime where small samples fail; Figs. 4/8).  The one workload
    generator shared by the paper-accuracy regression suite, the
    ``equal_space`` benchmark, and examples/equal_space_serving.py.
    """
    recs = rng.integers(0, 1 << 30, size=(n, d), dtype=np.uint32)
    pos = n - 1
    for k, size, count in clusters:
        for _ in range(count):
            src = rng.integers(0, n // 4)
            cols = rng.choice(d, size=k, replace=False)
            for _ in range(size - 1):
                recs[pos, cols] = recs[src, cols]
                pos -= 1
    return recs


def dblp_like(n: int, *, d: int = 5, seed: int = 0,
              cardinalities=None, dup_fraction: float = 0.1,
              dup_columns: int | None = None):
    """Records with per-column cardinalities + planted near-duplicates.

    ``dup_fraction`` of records are near-copies of earlier records agreeing
    on ``dup_columns`` (default d-1) columns.
    """
    rng = _rng(seed)
    if cardinalities is None:
        # title-like, author-like, then increasingly low-cardinality fields
        cardinalities = [max(2, int(n * f)) for f in
                         (0.99, 0.8, 0.002, 0.006, 0.0025, 0.013)][:d]
        while len(cardinalities) < d:
            cardinalities.append(max(2, n // 100))
    recs = np.stack([rng.integers(0, c, size=n, dtype=np.uint32)
                     for c in cardinalities], axis=1)
    n_dup = int(n * dup_fraction)
    if n_dup:
        dup_cols = dup_columns if dup_columns is not None else d - 1
        src = rng.integers(0, n - n_dup, size=n_dup)
        dst = np.arange(n - n_dup, n)
        recs[dst] = recs[src]
        # perturb (d - dup_cols) random columns so pairs are dup_cols-similar
        for row, s in zip(dst, src):
            cols = rng.choice(d, size=d - dup_cols, replace=False)
            for c in cols:
                recs[row, c] = rng.integers(0, cardinalities[c], dtype=np.uint32)
    return recs


def shingle_records(n_docs: int, *, d: int = 6, seed: int = 1,
                    dup_profile=((2, 0.02), (4, 0.01), (6, 0.005)),
                    group: int = 4):
    """Documents as d super-shingles; dup_profile plants (k_similar, frac).

    Near-duplicates come in GROUPS of ``group`` rows sharing k columns (a
    group of g rows contributes g*(g-1) ordered k-similar pairs) -- matching
    the quadratic duplicate-cluster structure of the paper's DBLP data,
    where g_s >> n.  ``frac`` is the fraction of rows consumed by groups at
    that level.
    """
    rng = _rng(seed)
    recs = rng.integers(0, 1 << 30, size=(n_docs, d), dtype=np.uint32)
    pos = n_docs - 1
    for k, frac in dup_profile:
        rows = int(n_docs * frac)
        n_groups = max(rows // max(group - 1, 1), 1)
        for _ in range(n_groups):
            src = rng.integers(0, n_docs // 2)
            cols = rng.choice(d, size=k, replace=False)
            for _ in range(group - 1):
                if pos <= n_docs // 2:
                    break
                recs[pos, cols] = recs[src, cols]
                pos -= 1
    return recs


def near_uniform_40_60(n: int, *, d: int = 5, seed: int = 2):
    """40% unique records; 60% form 4-similar pairs (§7.5)."""
    rng = _rng(seed)
    recs = rng.integers(0, 1 << 30, size=(n, d), dtype=np.uint32)
    n_pair = int(n * 0.6) // 2
    for i in range(n_pair):
        a, b = 2 * i, 2 * i + 1
        recs[b] = recs[a]
        c = rng.integers(0, d)
        recs[b, c] = rng.integers(0, 1 << 30, dtype=np.uint32)
    perm = rng.permutation(n)
    return recs[perm]


def skewed(n: int, *, d: int = 5, frac_unique: float = 0.2,
           group: int = 16, seed: int = 3):
    """frac_unique records unique; rest in groups of ``group`` 4-similar
    records (20-80: frac_unique=0.2; 10-90: 0.1)."""
    rng = _rng(seed)
    recs = rng.integers(0, 1 << 30, size=(n, d), dtype=np.uint32)
    n_grouped = int(n * (1 - frac_unique))
    n_groups = n_grouped // group
    pos = int(n * frac_unique)
    for _ in range(n_groups):
        base = recs[rng.integers(0, max(pos, 1))]
        c = rng.integers(0, d)      # one varying column per group ->
        for j in range(group):      # members are pairwise (d-1)-similar
            if pos >= n:
                break
            recs[pos] = base
            recs[pos, c] = rng.integers(0, 1 << 30, dtype=np.uint32)
            pos += 1
    perm = rng.permutation(n)
    return recs[perm]


def yfcc_like(n: int, *, seed: int = 4):
    """5 columns: userid, date, device, lat, lon (YFCC-shaped skew)."""
    rng = _rng(seed)
    users = (rng.zipf(1.5, size=n) % max(n // 50, 2)).astype(np.uint32)
    dates = rng.integers(0, 4000, size=n, dtype=np.uint32)
    devices = (rng.zipf(1.3, size=n) % 5000).astype(np.uint32)
    lat = rng.integers(0, 180_000, size=n, dtype=np.uint32)
    lon = rng.integers(0, 360_000, size=n, dtype=np.uint32)
    return np.stack([users, dates, devices, lat, lon], axis=1)


def zipf_tokens(rng: np.random.Generator, batch: int, seq: int, vocab: int,
                *, a: float = 1.2, dup_fraction: float = 0.05):
    """LM token batches with a zipfian unigram + near-duplicate sequences."""
    toks = (rng.zipf(a, size=(batch, seq)) % vocab).astype(np.int32)
    n_dup = int(batch * dup_fraction)
    if n_dup and batch > 1:
        src = rng.integers(0, batch, size=n_dup)
        dst = rng.integers(0, batch, size=n_dup)
        toks[dst] = toks[src]
        # small perturbation: a few token flips
        for r in dst:
            idx = rng.integers(0, seq, size=max(seq // 100, 1))
            toks[r, idx] = (rng.zipf(a, size=idx.shape[0]) % vocab).astype(np.int32)
    return toks
