from .synthetic import (dblp_like, shingle_records, near_uniform_40_60,
                        skewed, yfcc_like, zipf_tokens)
from .recordize import records_from_tokens
from .loader import token_batches, sharded_put
