"""Host-side streaming loader: seeded token batches placed onto the mesh.

``token_batches`` is an infinite iterator of {tokens, labels} numpy batches
(labels = tokens shifted left, last position masked via label -1 -> masked
in loss by the driver).  ``sharded_put`` places a host batch as a global
array with the given sharding (single-process: device_put with
NamedSharding; the API shape matches multi-host
``jax.make_array_from_process_local_data``).
"""
from __future__ import annotations

import numpy as np
import jax

from .synthetic import zipf_tokens


def token_batches(batch: int, seq: int, vocab: int, *, seed: int = 0,
                  dup_fraction: float = 0.05):
    rng = np.random.default_rng(seed)
    while True:
        toks = zipf_tokens(rng, batch, seq + 1, vocab, dup_fraction=dup_fraction)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def sharded_put(batch: dict, sharding=None) -> dict:
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
