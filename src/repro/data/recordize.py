"""Token sequences -> d-column super-shingle records (the paper's
DBLPtitles construction applied to the LM data stream).

Each training sequence is split into ``d`` equal spans; every span is
reduced to one uint32 column value with a polynomial fingerprint over the
token ids (mod 2^31-1, same field as the sketch hashing).  Two sequences
that share >= s spans verbatim are s-similar records -- exactly the
near-duplicate signal the SJPC stream monitor estimates.

Pure jnp: rides inside train_step under jit/shard_map.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.hashing import P31, addmod_p31, mulmod_p31, reduce_p31

SHINGLE_BASE = np.uint32(1_000_003)


def records_from_tokens(tokens, d: int):
    """tokens (B, S) int32 -> records (B, d) uint32.

    S need not divide d; the tail tokens fold into the last span.
    """
    b, s = tokens.shape
    span = s // d
    vals = reduce_p31(tokens.astype(jnp.uint32) + jnp.uint32(1))
    cols = []
    for i in range(d):
        lo = i * span
        hi = (i + 1) * span if i < d - 1 else s
        h = jnp.zeros((b,), jnp.uint32)
        for j in range(lo, hi):
            h = addmod_p31(mulmod_p31(h, SHINGLE_BASE), vals[:, j])
        cols.append(h)
    return jnp.stack(cols, axis=1)


def np_records_from_tokens(tokens: np.ndarray, d: int) -> np.ndarray:
    """NumPy oracle (tests)."""
    p = np.uint64(int(P31))
    b, s = tokens.shape
    span = s // d
    vals = (tokens.astype(np.uint64) + 1) % p
    out = np.zeros((b, d), dtype=np.uint32)
    for i in range(d):
        lo, hi = i * span, ((i + 1) * span if i < d - 1 else s)
        h = np.zeros((b,), np.uint64)
        for j in range(lo, hi):
            h = (h * np.uint64(int(SHINGLE_BASE)) + vals[:, j]) % p
        out[:, i] = h.astype(np.uint32)
    return out
