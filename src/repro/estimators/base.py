"""The Estimator protocol: every similarity-join size estimator -- the
paper's SJPC *and* its competitors -- behind one streaming, service-grade
interface (DESIGN.md §13).

An :class:`Estimator` instance is the per-hash-group engine for one
estimator family: it owns the static configuration (dimensionality d,
sketch threshold s, memory budget, hash/PRNG seeds) and operates on
immutable per-stream **states** (pytrees of jax arrays, so they stack,
ship across devices, and ride the service's batched ingest dispatch
unchanged).  The protocol:

  init(sid)                  fresh per-stream state (sid tags provenance
                             for estimators whose subtract is tag-based)
  ingest_rounds(...)         ALL coalesced rounds of a flush for ALL
                             streams of a cohort in one jit'd dispatch --
                             states stacked on a leading stream axis,
                             records (R, S, B, d), masks (R, S, B),
                             per-(round, stream) PRNG keys (R, S)
  merge / subtract           the window algebra: merge combines disjoint
                             sub-streams, subtract removes a previously
                             merged component (sliding-window expiry).
                             ``linear=True`` estimators do both exactly by
                             counter arithmetic; sampling estimators merge
                             by deterministic weighted union and subtract
                             by provenance tag (exact for the epoch states
                             the window hands them).
  memory_bytes()             the per-stream state footprint -- the paper's
                             equal-space comparison axis (Fig. 8)
  estimate_batch(states)     every (stream, threshold) estimate of a
                             stacked cohort from one dispatch
  estimate_ref(state)        the per-stream host-numpy oracle the batched
                             path is held to (<= 1e-6, tests)

The registry maps estimator kind names ("sjpc", "reservoir", "lsh_ss") to
factories taking the group's ``SJPCConfig`` -- so competitors derive their
space budget FROM the sketch they are compared against, and an equal-space
side-by-side deployment is the default, not a benchmark contrivance.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


class EstimateTable(NamedTuple):
    """Estimates for N same-config streams at EVERY threshold k = s..d.

    Shapes mirror :class:`repro.core.sjpc.SJPCBatchEstimate` (column i
    answers threshold k = s + i).  ``stderr_kind`` names the uncertainty
    method behind the stderr columns -- "analytic" (the paper's Theorem
    1/2 bounds), "bootstrap" / "bootstrap_stratified" (the resampling
    bars of :mod:`repro.estimators.uncertainty`), or "none" (disabled /
    unknown; columns are zero) -- so the service can surface per-kind
    confidence intervals through one contract (DESIGN.md §14).
    """
    x: np.ndarray              # (N, L) per-level k-similar pair estimates
    g: np.ndarray              # (N, L) g_k per threshold
    y: np.ndarray              # (N, L) raw level diagnostics (estimator-specific)
    n: np.ndarray              # (N,) records in each stream's window
    stderr: np.ndarray         # (N, L) absolute 1-sigma bound (0 = unknown)
    stderr_offline: np.ndarray  # (N, L) sampling-only bound (0 = unknown)
    stderr_kind: str = "none"  # uncertainty method behind the bars


class Estimator:
    """Abstract base; subclasses set ``kind`` and the capability flags."""

    kind: str = "abstract"
    linear: bool = False       # exact merge/subtract by state arithmetic
    supports_join: bool = False  # two-stream §6 join estimates

    # subclasses must define: d, s, seed attributes (ints)

    @property
    def num_levels(self) -> int:
        return self.d - self.s + 1

    @property
    def thresholds(self) -> range:
        return range(self.s, self.d + 1)

    @property
    def ingest_seed(self) -> int:
        """Seed of the per-(stream, round) ingest key grid (see
        service.ingest.ingest_key_grid)."""
        return self.seed ^ 0x5E41CE

    # -- protocol ------------------------------------------------------
    def init(self, sid: int = 0):
        raise NotImplementedError

    def ingest_rounds(self, states, values, row_mask, keys):
        """states: pytree stacked on a leading S axis; values (R, S, B, d)
        uint32; row_mask (R, S, B) int32; keys (R, S) PRNG keys.  Returns
        the updated stacked states.  One jit'd dispatch per call."""
        raise NotImplementedError

    def merge(self, a, b):
        raise NotImplementedError

    def subtract(self, a, b):
        raise NotImplementedError

    def memory_bytes(self) -> int:
        raise NotImplementedError

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        """Stacked states (leading N axis) -> the full (N, L) table.
        ``use_pallas``/``interpret`` are optional dispatch hints for
        kernel-backed estimators (None = the instance's own default)."""
        raise NotImplementedError

    def estimate_ref(self, state, *, clamp: bool = True) -> EstimateTable:
        """Single-state host-numpy reference (N=1 table); the conformance
        oracle for ``estimate_batch`` and the ``use_fused_query=False``
        service path.  Default: the batched path on a singleton stack."""
        return self.estimate_batch(stack_states([state]), clamp=clamp)

    # -- generic helpers ----------------------------------------------
    def state_n(self, state) -> float:
        return float(np.asarray(jax.device_get(state.n)))


# ---------------------------------------------------------------------------
# State stacking: pytree states <-> batched (leading-axis) cohorts
# ---------------------------------------------------------------------------

def stack_states(states):
    """Stack same-shape state pytrees along a new leading axis.

    On CPU backends the leaves are stacked host-side (np.stack over the
    zero-copy views, ~5x cheaper than N expand+concat XLA dispatches -- the
    same trade query._stack_states makes); on TPU they stay on device.
    """
    if jax.default_backend() == "tpu":
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    return jax.tree_util.tree_map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]), *states)


def index_state(stacked, i: int):
    """The i-th state of a stacked cohort."""
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def zeros_like_stack(state, count: int):
    """A (count, ...) stacked pytree of zeros shaped like ``state``."""
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((count,) + tuple(jnp.shape(x)), x.dtype), state)


def scan_rounds(ingest_one: Callable, states, values, row_mask, keys):
    """Generic (R rounds x S streams) ingest dispatch: ``lax.scan`` over
    the round axis, ``vmap`` over the stream axis -- the execution shape
    service.ingest.multi_round_update gave SJPC, for any estimator whose
    single-stream update is ``ingest_one(state, values, mask, key)``."""
    def body(carry, rnd):
        vals, mask, ks = rnd
        return jax.vmap(ingest_one)(carry, vals, mask, ks), None

    carry, _ = jax.lax.scan(body, states, (values, row_mask, keys))
    return carry


# ---------------------------------------------------------------------------
# Sample-merge helper: deterministic weighted union of two uniform samples
# ---------------------------------------------------------------------------

def priority_merge_keys(items, tags, weight, salt: int):
    """Selection keys for merging uniform samples (A-ES weighted draw).

    Each retained sample item represents ``weight`` = n/m population
    records; a uniform sample of the merged population keeps items with
    probability proportional to represented mass.  A-ES realizes that as
    top-k over keys u^(1/w) -- computed here as log(u)/w (monotone
    equivalent, and f32-stable near 0 where u^(1/w) saturates at 1).
    ``u`` is a hash of (slot index, item, tag, salt), NOT a PRNG draw, so
    the merge is deterministic and symmetric: merge(a, b) selects the
    same multiset as merge(b, a).  The slot index MUST be in the hash:
    keyed on content alone, duplicate items (one epoch's worth of equal
    pair-sim values, a cluster of identical records) would share one key
    and survive or vanish as a block under top_k instead of
    proportionally.  Invalid slots (tag < 0) get -inf keys.
    """
    slot = jnp.arange(items.shape[0], dtype=jnp.uint32)
    h = (jnp.uint32(salt) ^ tags.astype(jnp.uint32)) \
        + slot * jnp.uint32(0x9E3779B9)
    for c in range(items.shape[-1]):
        h = (h * jnp.uint32(0x9E3779B1)) ^ items[..., c].astype(jnp.uint32)
    h = h * jnp.uint32(0x85EBCA77)
    h ^= h >> 15
    u = (h.astype(jnp.float32) + 1.0) / 4294967296.0       # (0, 1]
    key = jnp.log(u) / jnp.maximum(weight, 1e-9)
    return jnp.where(tags >= 0, key, -jnp.inf)


def merge_tagged_samples(items_a, tags_a, n_a, items_b, tags_b, n_b,
                         capacity: int, salt: int):
    """Merge two tagged fixed-capacity uniform samples into one of
    ``capacity`` slots: pool both, keep the top-``capacity`` priority keys
    (weighted by represented population, see :func:`priority_merge_keys`).
    Returns (items, tags) with empty slots tagged -1.  ``capacity`` may
    exceed the pooled slot count (the window's backing-epoch refill folds
    into an *expanded* total); the shortfall is padded with empty slots.
    """
    m_a = jnp.sum((tags_a >= 0).astype(jnp.float32))
    m_b = jnp.sum((tags_b >= 0).astype(jnp.float32))
    w_a = jnp.asarray(n_a, jnp.float32) / jnp.maximum(m_a, 1.0)
    w_b = jnp.asarray(n_b, jnp.float32) / jnp.maximum(m_b, 1.0)
    items = jnp.concatenate([items_a, items_b], axis=0)
    tags = jnp.concatenate([tags_a, tags_b], axis=0)
    keys = jnp.concatenate([
        priority_merge_keys(items_a, tags_a, w_a, salt),
        priority_merge_keys(items_b, tags_b, w_b, salt)], axis=0)
    k = min(capacity, items.shape[0])
    _, top = jax.lax.top_k(keys, k)
    sel_valid = jnp.take(tags, top) >= 0
    out_items = jnp.take(items, top, axis=0)
    out_tags = jnp.where(sel_valid, jnp.take(tags, top), -1)
    if k < capacity:
        pad = capacity - k
        out_items = jnp.concatenate(
            [out_items, jnp.zeros((pad,) + out_items.shape[1:],
                                  out_items.dtype)], axis=0)
        out_tags = jnp.concatenate(
            [out_tags, jnp.full((pad,), -1, out_tags.dtype)], axis=0)
    return out_items, out_tags


# ---------------------------------------------------------------------------
# Spec registry: ONE declarative record per estimator kind (DESIGN.md §19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EstimatorSpec:
    """Everything any layer needs to know about an estimator kind.

    One registration feeds every consumer: the factory (``make``), the
    state NamedTuple class (the distributed wire codec), the window
    strategy (``linear``: delta-ring vs slot-fold, and with it the wire
    delta mode), join gating (``join_capable``), the uncertainty story
    (``stderr_kind``), the planner's fusion-signature contribution
    (``fusion``), and the accuracy auditor's exact-replay oracle
    (``exact_oracle``).

    Capability fields default to ``None`` = "resolve from the instance
    attribute" (``est.linear`` / ``est.supports_join`` / a served table's
    ``stderr_kind``), so legacy ``register(kind, factory)`` calls keep
    working unchanged; :func:`spec_of` performs that resolution.

      factory(sjpc_cfg, *, params=None, estimator_cfg=None, opts=None)
      fusion(est) -> hashable        planner fusion-signature config part
      exact_oracle(query_kind, records) -> (s -> float)  exact g replay
    """
    kind: str
    factory: Callable | None = None
    state_cls: type | None = None
    linear: bool | None = None
    join_capable: bool | None = None
    stderr_kind: str | None = None
    fusion: Callable | None = None
    exact_oracle: Callable | None = None
    registrant: str = "?"

    @property
    def wire_mode(self) -> str:
        """The distributed delta mode this kind ships (DESIGN.md §18.2):
        linear kinds send per-epoch counter increments (``"merge"``),
        sample kinds replace their open slot (``"replace"``)."""
        return "merge" if self.linear else "replace"


_REGISTRY: dict[str, EstimatorSpec] = {}


def _callable_id(fn):
    """Identity of a callable that survives module re-import: the same
    source definition re-executed (importlib.reload of a plugin module)
    produces a new function object but the same (module, qualname)."""
    if fn is None:
        return None
    return (getattr(fn, "__module__", None),
            getattr(fn, "__qualname__", repr(fn)))


def _cls_id(cls):
    if cls is None:
        return None
    return (getattr(cls, "__module__", None),
            getattr(cls, "__qualname__", cls.__name__),
            tuple(getattr(cls, "_fields", ())))


def _spec_signature(sp: EstimatorSpec):
    """The comparison key for idempotent re-registration: identical specs
    (same definitions, even across a module reload) are a no-op; anything
    else is a conflict."""
    return (sp.kind, _callable_id(sp.factory), _cls_id(sp.state_cls),
            sp.linear, sp.join_capable, sp.stderr_kind,
            _callable_id(sp.fusion), _callable_id(sp.exact_oracle))


def register_spec(spec: EstimatorSpec) -> EstimatorSpec:
    """Register (or idempotently re-register) a kind's spec.

    Identical re-registration -- same kind, same factory/state-class
    definitions, same capability fields -- is a no-op, so plugin modules
    survive being imported twice (or reloaded).  A *conflicting*
    re-registration raises, naming both registrants.  A spec may also
    *complete* a partial prior registration: a state-class-only spec (the
    wire codec's channel) merges with a later factory registration for
    the same kind, and vice versa.
    """
    prev = _REGISTRY.get(spec.kind)
    if prev is None:
        _REGISTRY[spec.kind] = spec
        return spec
    if _spec_signature(prev) == _spec_signature(spec):
        # Idempotent -- but ADOPT the newcomer: after importlib.reload the
        # re-executed module's class/function objects are the live ones
        # (the module dict is updated in place, so factories registered
        # earlier already resolve names against the NEW definitions).
        # Keeping the stale objects would make decode-by-kind hand back a
        # class that `is not` the one fresh states carry.
        _REGISTRY[spec.kind] = spec
        return spec
    merged = _merge_specs(prev, spec)
    if merged is None:
        raise ValueError(
            f"estimator kind {spec.kind!r} already registered by "
            f"{prev.registrant} with a conflicting spec; refused "
            f"re-registration from {spec.registrant}")
    _REGISTRY[spec.kind] = merged
    return merged


def _merge_specs(prev: EstimatorSpec, new: EstimatorSpec):
    """Fill ``None`` fields of ``prev`` from ``new`` (and vice versa);
    ``None`` if any concrete field disagrees (a genuine conflict)."""
    updates = {}
    for f in ("factory", "state_cls", "linear", "join_capable",
              "stderr_kind", "fusion", "exact_oracle"):
        a, b = getattr(prev, f), getattr(new, f)
        if a is None and b is not None:
            updates[f] = b
        elif a is not None and b is not None:
            ident = _cls_id if f == "state_cls" else (
                _callable_id if callable(a) else (lambda x: x))
            if ident(a) != ident(b):
                return None
    return dataclasses.replace(prev, **updates) if updates else prev


def register(kind: str, factory: Callable, *, state_cls: type | None = None,
             linear: bool | None = None, join_capable: bool | None = None,
             stderr_kind: str | None = None, fusion: Callable | None = None,
             exact_oracle: Callable | None = None) -> EstimatorSpec:
    """Register an estimator kind (declaratively, once, for every layer).

    ``factory(sjpc_cfg, params=None, estimator_cfg=None, opts=None)``
    -> Estimator.  ``estimator_cfg`` overrides the kind's derived config;
    ``opts`` carries construction kwargs (dispatch flags etc.).  The
    keyword fields populate the kind's :class:`EstimatorSpec`; omitted
    ones resolve from the instance (see :func:`spec_of`), so the legacy
    two-argument form keeps working.  Identical re-registration is a
    no-op; a conflicting one raises, naming both registrants.
    """
    return register_spec(EstimatorSpec(
        kind=kind, factory=factory, state_cls=state_cls, linear=linear,
        join_capable=join_capable, stderr_kind=stderr_kind, fusion=fusion,
        exact_oracle=exact_oracle,
        registrant=getattr(factory, "__module__", "?")))


def register_state_type(kind: str, cls: type) -> None:
    """Register the state NamedTuple class for ``kind`` (the wire codec's
    decode channel).  Merges into the kind's spec: idempotent for the
    same class, conflict (naming both registrants) otherwise."""
    prev = _REGISTRY.get(kind)
    if prev is not None and prev.state_cls is not None \
            and _cls_id(prev.state_cls) != _cls_id(cls):
        raise ValueError(
            f"state type for kind {kind!r} already registered as "
            f"{prev.state_cls.__name__} (by {prev.registrant}), not "
            f"{cls.__name__} (from {getattr(cls, '__module__', '?')})")
    register_spec(EstimatorSpec(
        kind=kind, state_cls=cls,
        registrant=getattr(cls, "__module__", "?")))


def spec(kind: str) -> EstimatorSpec:
    """The registered spec for ``kind`` (KeyError if unknown)."""
    if kind not in _REGISTRY:
        raise KeyError(
            f"unknown estimator kind {kind!r}; available: {available()}")
    return _REGISTRY[kind]


def spec_of(est: Estimator) -> EstimatorSpec:
    """The RESOLVED spec for an estimator instance: registered fields win;
    ``None`` capability fields fall back to the instance attributes.  For
    instances of unregistered kinds (ad-hoc subclasses in tests) this
    synthesizes a spec entirely from the instance."""
    kind = getattr(est, "kind", "abstract")
    sp = _REGISTRY.get(kind)
    if sp is None:
        sp = EstimatorSpec(kind=kind, registrant=type(est).__module__)
    updates = {}
    if sp.linear is None:
        updates["linear"] = bool(getattr(est, "linear", False))
    if sp.join_capable is None:
        updates["join_capable"] = bool(getattr(est, "supports_join", False))
    return dataclasses.replace(sp, **updates) if updates else sp


def state_type(kind: str) -> type:
    """The registered state NamedTuple class for ``kind`` (the wire
    codec's container type; KeyError if none registered)."""
    sp = _REGISTRY.get(kind)
    if sp is None or sp.state_cls is None:
        raise KeyError(
            f"no state type registered for estimator kind {kind!r}; "
            f"register_state_type() it (plugins: import the plugin module "
            f"on the decoding side too)")
    return sp.state_cls


def available() -> list[str]:
    """Kinds that can be instantiated (state-type-only registrations --
    a decode-side wire channel without a factory -- are excluded)."""
    return sorted(k for k, sp in _REGISTRY.items() if sp.factory is not None)


def make(kind: str, sjpc_cfg, *, params=None, estimator_cfg=None,
         opts=None) -> Estimator:
    """Instantiate an estimator for a hash group.

    ``sjpc_cfg`` is the group's :class:`~repro.core.sjpc.SJPCConfig`; it
    defines (d, s, seed) for every kind and the byte budget competitors
    match (equal space by construction).  ``params`` carries the group's
    shared hash randomness (SJPC only).  ``estimator_cfg`` overrides the
    derived per-kind config; ``opts`` carries construction kwargs (the
    service's dispatch flags).  The service registry caches one instance
    per (group, kind) so a group's streams share one engine and its jit
    caches.
    """
    sp = _REGISTRY.get(kind)
    if sp is None or sp.factory is None:
        raise KeyError(
            f"unknown estimator kind {kind!r}; available: {available()}")
    return sp.factory(sjpc_cfg, params=params,
                      estimator_cfg=estimator_cfg, opts=opts)


def load_plugins(modules=None) -> list[str]:
    """Import plugin modules for their registration side effect.

    ``modules`` is an iterable of module names; default is the
    ``REPRO_PLUGINS`` environment variable (comma-separated), so services
    and benchmarks pick up plugin kinds without code changes.  Importing
    an already-imported module is a no-op (and re-registration of an
    identical spec is too), so this is safe to call repeatedly.
    """
    import importlib
    import os
    if modules is None:
        raw = os.environ.get("REPRO_PLUGINS", "")
        modules = [m for m in (p.strip() for p in raw.split(",")) if m]
    loaded = []
    for name in modules:
        importlib.import_module(name)
        loaded.append(name)
    return loaded


# ---------------------------------------------------------------------------
# Shared exact-replay oracle for pairwise-similarity kinds
# ---------------------------------------------------------------------------

def pairwise_exact_oracle(query_kind: str, records):
    """The exact g replay shared by every kind that estimates the paper's
    pairwise-similarity counts (DESIGN.md §15.4): given the mirrored
    record batches of a query's streams, return ``g(s)`` -- the exact
    number of candidate pairs at threshold ``s``.

    ``records`` is a tuple of per-stream ``(n, d)`` uint32 arrays: one
    entry for self-join queries, two for §6 joins.  Kinds whose estimand
    is NOT this g (a distinct counter, say) register their own oracle --
    or ``None``, which the auditor surfaces as a reason-labeled skip.
    """
    from repro.core import exact
    if query_kind == "join":
        a, b = records
        counts = np.asarray(exact.brute_force_join_counts(a, b))
        return lambda s: float(counts[s:].sum())
    recs = records[0]
    x = np.asarray(exact.exact_pair_counts(recs))
    n = recs.shape[0]
    return lambda s: float(x[s:].sum() + n)
