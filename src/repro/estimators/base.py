"""The Estimator protocol: every similarity-join size estimator -- the
paper's SJPC *and* its competitors -- behind one streaming, service-grade
interface (DESIGN.md §13).

An :class:`Estimator` instance is the per-hash-group engine for one
estimator family: it owns the static configuration (dimensionality d,
sketch threshold s, memory budget, hash/PRNG seeds) and operates on
immutable per-stream **states** (pytrees of jax arrays, so they stack,
ship across devices, and ride the service's batched ingest dispatch
unchanged).  The protocol:

  init(sid)                  fresh per-stream state (sid tags provenance
                             for estimators whose subtract is tag-based)
  ingest_rounds(...)         ALL coalesced rounds of a flush for ALL
                             streams of a cohort in one jit'd dispatch --
                             states stacked on a leading stream axis,
                             records (R, S, B, d), masks (R, S, B),
                             per-(round, stream) PRNG keys (R, S)
  merge / subtract           the window algebra: merge combines disjoint
                             sub-streams, subtract removes a previously
                             merged component (sliding-window expiry).
                             ``linear=True`` estimators do both exactly by
                             counter arithmetic; sampling estimators merge
                             by deterministic weighted union and subtract
                             by provenance tag (exact for the epoch states
                             the window hands them).
  memory_bytes()             the per-stream state footprint -- the paper's
                             equal-space comparison axis (Fig. 8)
  estimate_batch(states)     every (stream, threshold) estimate of a
                             stacked cohort from one dispatch
  estimate_ref(state)        the per-stream host-numpy oracle the batched
                             path is held to (<= 1e-6, tests)

The registry maps estimator kind names ("sjpc", "reservoir", "lsh_ss") to
factories taking the group's ``SJPCConfig`` -- so competitors derive their
space budget FROM the sketch they are compared against, and an equal-space
side-by-side deployment is the default, not a benchmark contrivance.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


class EstimateTable(NamedTuple):
    """Estimates for N same-config streams at EVERY threshold k = s..d.

    Shapes mirror :class:`repro.core.sjpc.SJPCBatchEstimate` (column i
    answers threshold k = s + i).  ``stderr_kind`` names the uncertainty
    method behind the stderr columns -- "analytic" (the paper's Theorem
    1/2 bounds), "bootstrap" / "bootstrap_stratified" (the resampling
    bars of :mod:`repro.estimators.uncertainty`), or "none" (disabled /
    unknown; columns are zero) -- so the service can surface per-kind
    confidence intervals through one contract (DESIGN.md §14).
    """
    x: np.ndarray              # (N, L) per-level k-similar pair estimates
    g: np.ndarray              # (N, L) g_k per threshold
    y: np.ndarray              # (N, L) raw level diagnostics (estimator-specific)
    n: np.ndarray              # (N,) records in each stream's window
    stderr: np.ndarray         # (N, L) absolute 1-sigma bound (0 = unknown)
    stderr_offline: np.ndarray  # (N, L) sampling-only bound (0 = unknown)
    stderr_kind: str = "none"  # uncertainty method behind the bars


class Estimator:
    """Abstract base; subclasses set ``kind`` and the capability flags."""

    kind: str = "abstract"
    linear: bool = False       # exact merge/subtract by state arithmetic
    supports_join: bool = False  # two-stream §6 join estimates

    # subclasses must define: d, s, seed attributes (ints)

    @property
    def num_levels(self) -> int:
        return self.d - self.s + 1

    @property
    def thresholds(self) -> range:
        return range(self.s, self.d + 1)

    @property
    def ingest_seed(self) -> int:
        """Seed of the per-(stream, round) ingest key grid (see
        service.ingest.ingest_key_grid)."""
        return self.seed ^ 0x5E41CE

    # -- protocol ------------------------------------------------------
    def init(self, sid: int = 0):
        raise NotImplementedError

    def ingest_rounds(self, states, values, row_mask, keys):
        """states: pytree stacked on a leading S axis; values (R, S, B, d)
        uint32; row_mask (R, S, B) int32; keys (R, S) PRNG keys.  Returns
        the updated stacked states.  One jit'd dispatch per call."""
        raise NotImplementedError

    def merge(self, a, b):
        raise NotImplementedError

    def subtract(self, a, b):
        raise NotImplementedError

    def memory_bytes(self) -> int:
        raise NotImplementedError

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        """Stacked states (leading N axis) -> the full (N, L) table.
        ``use_pallas``/``interpret`` are optional dispatch hints for
        kernel-backed estimators (None = the instance's own default)."""
        raise NotImplementedError

    def estimate_ref(self, state, *, clamp: bool = True) -> EstimateTable:
        """Single-state host-numpy reference (N=1 table); the conformance
        oracle for ``estimate_batch`` and the ``use_fused_query=False``
        service path.  Default: the batched path on a singleton stack."""
        return self.estimate_batch(stack_states([state]), clamp=clamp)

    # -- generic helpers ----------------------------------------------
    def state_n(self, state) -> float:
        return float(np.asarray(jax.device_get(state.n)))


# ---------------------------------------------------------------------------
# State stacking: pytree states <-> batched (leading-axis) cohorts
# ---------------------------------------------------------------------------

def stack_states(states):
    """Stack same-shape state pytrees along a new leading axis.

    On CPU backends the leaves are stacked host-side (np.stack over the
    zero-copy views, ~5x cheaper than N expand+concat XLA dispatches -- the
    same trade query._stack_states makes); on TPU they stay on device.
    """
    if jax.default_backend() == "tpu":
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    return jax.tree_util.tree_map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]), *states)


def index_state(stacked, i: int):
    """The i-th state of a stacked cohort."""
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def zeros_like_stack(state, count: int):
    """A (count, ...) stacked pytree of zeros shaped like ``state``."""
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((count,) + tuple(jnp.shape(x)), x.dtype), state)


def scan_rounds(ingest_one: Callable, states, values, row_mask, keys):
    """Generic (R rounds x S streams) ingest dispatch: ``lax.scan`` over
    the round axis, ``vmap`` over the stream axis -- the execution shape
    service.ingest.multi_round_update gave SJPC, for any estimator whose
    single-stream update is ``ingest_one(state, values, mask, key)``."""
    def body(carry, rnd):
        vals, mask, ks = rnd
        return jax.vmap(ingest_one)(carry, vals, mask, ks), None

    carry, _ = jax.lax.scan(body, states, (values, row_mask, keys))
    return carry


# ---------------------------------------------------------------------------
# Sample-merge helper: deterministic weighted union of two uniform samples
# ---------------------------------------------------------------------------

def priority_merge_keys(items, tags, weight, salt: int):
    """Selection keys for merging uniform samples (A-ES weighted draw).

    Each retained sample item represents ``weight`` = n/m population
    records; a uniform sample of the merged population keeps items with
    probability proportional to represented mass.  A-ES realizes that as
    top-k over keys u^(1/w) -- computed here as log(u)/w (monotone
    equivalent, and f32-stable near 0 where u^(1/w) saturates at 1).
    ``u`` is a hash of (slot index, item, tag, salt), NOT a PRNG draw, so
    the merge is deterministic and symmetric: merge(a, b) selects the
    same multiset as merge(b, a).  The slot index MUST be in the hash:
    keyed on content alone, duplicate items (one epoch's worth of equal
    pair-sim values, a cluster of identical records) would share one key
    and survive or vanish as a block under top_k instead of
    proportionally.  Invalid slots (tag < 0) get -inf keys.
    """
    slot = jnp.arange(items.shape[0], dtype=jnp.uint32)
    h = (jnp.uint32(salt) ^ tags.astype(jnp.uint32)) \
        + slot * jnp.uint32(0x9E3779B9)
    for c in range(items.shape[-1]):
        h = (h * jnp.uint32(0x9E3779B1)) ^ items[..., c].astype(jnp.uint32)
    h = h * jnp.uint32(0x85EBCA77)
    h ^= h >> 15
    u = (h.astype(jnp.float32) + 1.0) / 4294967296.0       # (0, 1]
    key = jnp.log(u) / jnp.maximum(weight, 1e-9)
    return jnp.where(tags >= 0, key, -jnp.inf)


def merge_tagged_samples(items_a, tags_a, n_a, items_b, tags_b, n_b,
                         capacity: int, salt: int):
    """Merge two tagged fixed-capacity uniform samples into one of
    ``capacity`` slots: pool both, keep the top-``capacity`` priority keys
    (weighted by represented population, see :func:`priority_merge_keys`).
    Returns (items, tags) with empty slots tagged -1.  ``capacity`` may
    exceed the pooled slot count (the window's backing-epoch refill folds
    into an *expanded* total); the shortfall is padded with empty slots.
    """
    m_a = jnp.sum((tags_a >= 0).astype(jnp.float32))
    m_b = jnp.sum((tags_b >= 0).astype(jnp.float32))
    w_a = jnp.asarray(n_a, jnp.float32) / jnp.maximum(m_a, 1.0)
    w_b = jnp.asarray(n_b, jnp.float32) / jnp.maximum(m_b, 1.0)
    items = jnp.concatenate([items_a, items_b], axis=0)
    tags = jnp.concatenate([tags_a, tags_b], axis=0)
    keys = jnp.concatenate([
        priority_merge_keys(items_a, tags_a, w_a, salt),
        priority_merge_keys(items_b, tags_b, w_b, salt)], axis=0)
    k = min(capacity, items.shape[0])
    _, top = jax.lax.top_k(keys, k)
    sel_valid = jnp.take(tags, top) >= 0
    out_items = jnp.take(items, top, axis=0)
    out_tags = jnp.where(sel_valid, jnp.take(tags, top), -1)
    if k < capacity:
        pad = capacity - k
        out_items = jnp.concatenate(
            [out_items, jnp.zeros((pad,) + out_items.shape[1:],
                                  out_items.dtype)], axis=0)
        out_tags = jnp.concatenate(
            [out_tags, jnp.full((pad,), -1, out_tags.dtype)], axis=0)
    return out_items, out_tags


# ---------------------------------------------------------------------------
# Registry: estimator kinds -> factories over the group's SJPCConfig
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable] = {}


def register(kind: str, factory: Callable) -> None:
    """factory(sjpc_cfg, params=None, estimator_cfg=None, opts=None)
    -> Estimator.  ``estimator_cfg`` overrides the kind's derived config;
    ``opts`` carries construction kwargs (dispatch flags etc.)."""
    if kind in _REGISTRY:
        raise ValueError(f"estimator kind {kind!r} already registered")
    _REGISTRY[kind] = factory


def available() -> list[str]:
    return sorted(_REGISTRY)


def make(kind: str, sjpc_cfg, *, params=None, estimator_cfg=None,
         opts=None) -> Estimator:
    """Instantiate an estimator for a hash group.

    ``sjpc_cfg`` is the group's :class:`~repro.core.sjpc.SJPCConfig`; it
    defines (d, s, seed) for every kind and the byte budget competitors
    match (equal space by construction).  ``params`` carries the group's
    shared hash randomness (SJPC only).  ``estimator_cfg`` overrides the
    derived per-kind config; ``opts`` carries construction kwargs (the
    service's dispatch flags).  The service registry caches one instance
    per (group, kind) so a group's streams share one engine and its jit
    caches.
    """
    if kind not in _REGISTRY:
        raise KeyError(
            f"unknown estimator kind {kind!r}; available: {available()}")
    return _REGISTRY[kind](sjpc_cfg, params=params,
                           estimator_cfg=estimator_cfg, opts=opts)
