"""Streaming LSH-SS behind the Estimator protocol.

The paper's stratified competitor (§2.3, Lee et al. [17], arXiv:1104.3212)
is multi-pass offline: build LSH buckets over the values of a random
column subset, then sample same-bucket ("high") and cross-bucket ("low")
pairs and scale each stratum's similar fraction.  The one-pass variant
served here maintains every ingredient online:

  * a **bucket-count sketch**: one hashed counter per LSH bucket (the
    values of the ``num_hash_cols`` chosen columns, avalanche-hashed into
    ``num_buckets`` slots).  sum c_b(c_b - 1) estimates the same-stratum
    ordered-pair count; hash collisions merge buckets, biasing the split
    conservatively toward the same stratum (documented, bounded by the
    load factor).  Linear, so merge/subtract are exact counter arithmetic.
  * a **record reservoir** (Algorithm R, with each record's bucket id):
    the online pair generator.  Every arriving record g is paired with one
    uniform *earlier* record: a uniform rank u in [0, g) resolves to the
    in-batch record when it falls inside the current round, else to a
    uniform stored reservoir slot (the reservoir is itself a uniform
    sample of the past).  The pair is a same- or cross-stratum candidate
    by bucket equality.  Pairing only against the stored reservoir -- the
    pre-fix behavior -- silently dropped every within-round pair, biasing
    the stratum fractions low whenever similar records arrive together.
  * two **stratified pair reservoirs**: per stratum, Algorithm R over its
    candidate pairs, storing only the pair's match count (int) -- the
    similar fraction of each stratum at query time is a mask-and-count.

Estimates: g_s = p1 * same_pairs + p2 * cross_pairs + n, exactly the
offline formula (core/baselines.py:lsh_ss_g) with every term read from
the online state.  No analytical error bound exists (the paper proves
none for LSH-SS); the served stderr is the *stratified bootstrap* of
estimators/uncertainty.py (resample each stratum's pair reservoir, scale
by the near-exact linear stratum totals; stderr_kind
"bootstrap_stratified").

Sample-state algebra follows estimators.reservoir: provenance-tagged
slots, deterministic weighted union on merge, tag-drop on subtract; the
bucket counts merge/subtract linearly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sjpc import SJPCConfig

from . import uncertainty
from .base import (EstimateTable, Estimator, merge_tagged_samples,
                   pairwise_exact_oracle, register, scan_rounds)
from .reservoir import reservoir_accept

_MERGE_SALT = 0x15A55B01


@dataclasses.dataclass(frozen=True)
class LSHSSConfig:
    d: int                     # record dimensionality
    s: int                     # lowest queryable threshold
    num_hash_cols: int = 1     # LSH column-subset size c, 1 <= c <= d
    num_buckets: int = 1024    # hashed bucket counters (power of two)
    record_capacity: int = 256   # record reservoir slots
    pair_capacity: int = 256     # pair reservoir slots per stratum
    seed: int = 0x5A5A

    def __post_init__(self):
        if not 1 <= self.s <= self.d:
            raise ValueError(f"need 1 <= s={self.s} <= d={self.d}")
        if not 1 <= self.num_hash_cols <= self.d:
            raise ValueError(
                f"num_hash_cols={self.num_hash_cols} outside [1, d={self.d}]"
                " (the paper's LSH-SS hashes a random column subset)")
        if self.num_buckets & (self.num_buckets - 1):
            raise ValueError("num_buckets must be a power of two")
        assert self.record_capacity >= 1 and self.pair_capacity >= 1


class LSHSSState(NamedTuple):
    counts: jax.Array        # (Bh,) int32 records per hashed bucket
    rec_items: jax.Array     # (R, d) uint32 record reservoir
    rec_bucket: jax.Array    # (R,) int32 bucket id of each stored record
    rec_tags: jax.Array      # (R,) int32 provenance; -1 = empty
    same_sim: jax.Array      # (M,) int32 match counts, same-bucket stratum
    same_tags: jax.Array     # (M,) int32
    same_seen: jax.Array     # int32 same-stratum candidates seen
    cross_sim: jax.Array     # (M,) int32 match counts, cross-bucket stratum
    cross_tags: jax.Array    # (M,) int32
    cross_seen: jax.Array    # int32
    n: jax.Array             # int32 records seen (exact: Algorithm R needs
    #   true arrival indices -- see estimators.reservoir.ReservoirState.n)
    sid: jax.Array           # int32 provenance tag for insertions
    step: jax.Array          # int32


class LSHSSEstimator(Estimator):
    kind = "lsh_ss"
    linear = False
    supports_join = False

    def __init__(self, cfg: LSHSSConfig, *,
                 bootstrap_replicates: int = uncertainty.DEFAULT_REPLICATES):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed ^ 0x15AC01)
        self.cols = np.sort(rng.choice(cfg.d, size=cfg.num_hash_cols,
                                       replace=False))
        # stratified bootstrap error bars (0 disables -> stderr_kind "none")
        if bootstrap_replicates == 1:
            raise ValueError("bootstrap_replicates must be 0 (disabled) "
                             "or >= 2 (a std needs two replicates)")
        self.bootstrap = int(bootstrap_replicates)
        self._rounds_fn = jax.jit(
            functools.partial(scan_rounds, self._ingest_one))

    @property
    def d(self) -> int:
        return self.cfg.d

    @property
    def s(self) -> int:
        return self.cfg.s

    @property
    def seed(self) -> int:
        return self.cfg.seed

    def memory_bytes(self) -> int:
        c = self.cfg
        return (c.num_buckets * 4 + c.record_capacity * (c.d + 2) * 4
                + 2 * c.pair_capacity * 8)

    # ------------------------------------------------------------------
    def _bucket(self, values) -> jax.Array:
        """Avalanche hash of the chosen columns' values -> bucket id."""
        h = jnp.full(values.shape[:-1], 0x811C9DC5, jnp.uint32) \
            ^ jnp.uint32(self.cfg.seed)
        for c in self.cols:
            h = (h * jnp.uint32(0x01000193)) \
                ^ (values[..., int(c)].astype(jnp.uint32)
                   + jnp.uint32(0x9E3779B1))
        h ^= h >> 15
        h = h * jnp.uint32(0x85EBCA77)
        h ^= h >> 13
        return (h & jnp.uint32(self.cfg.num_buckets - 1)).astype(jnp.int32)

    def init(self, sid: int = 0) -> LSHSSState:
        c = self.cfg
        return LSHSSState(
            counts=jnp.zeros((c.num_buckets,), jnp.int32),
            rec_items=jnp.zeros((c.record_capacity, c.d), jnp.uint32),
            rec_bucket=jnp.zeros((c.record_capacity,), jnp.int32),
            rec_tags=jnp.full((c.record_capacity,), -1, jnp.int32),
            same_sim=jnp.zeros((c.pair_capacity,), jnp.int32),
            same_tags=jnp.full((c.pair_capacity,), -1, jnp.int32),
            same_seen=jnp.zeros((), jnp.int32),
            cross_sim=jnp.zeros((c.pair_capacity,), jnp.int32),
            cross_tags=jnp.full((c.pair_capacity,), -1, jnp.int32),
            cross_seen=jnp.zeros((), jnp.int32),
            n=jnp.zeros((), jnp.int32),
            sid=jnp.asarray(sid, jnp.int32),
            step=jnp.zeros((), jnp.int32))

    def _ingest_one(self, state: LSHSSState, values, mask,
                    key) -> LSHSSState:
        cfg = self.cfg
        values = values.astype(jnp.uint32)
        mask = mask.astype(jnp.int32)
        maskb = mask != 0
        bucket = self._bucket(values)                       # (B,)
        counts = state.counts.at[jnp.where(maskb, bucket, 0)] \
            .add(jnp.where(maskb, 1, 0))

        kp, kq, ks, kc, kr = jax.random.split(key, 5)
        # pair one candidate per arriving record with a uniform EARLIER
        # record: arrival g draws a uniform rank u in [0, g); ranks inside
        # the current round resolve to the in-batch record directly, ranks
        # before it to a uniform reservoir slot (the reservoir is a uniform
        # sample of the past, so the partner stays ~uniform).  The old
        # reservoir-only draw skipped every within-round pair, which
        # silently biased the stratum fractions low on workloads whose
        # similar records arrive close together (planted clusters, bursts)
        # -- the dominant term of the equal_space LSH-SS error.
        B = mask.shape[0]
        pos = jnp.cumsum(mask) - 1                          # candidate index
        gidx = state.n + pos                                # global arrival
        u = jax.random.randint(kp, mask.shape, 0, jnp.maximum(gidx, 1))
        within = maskb & (u >= state.n)
        # pre-round ranks: while the reservoir is warming up (n < R) its
        # slots are filled sequentially, so rank u lives at slot u exactly
        # -- a fresh uniform slot draw there would drop candidates landing
        # on still-empty slots, thinning pre-round pairs relative to
        # within-round ones.  Once full, every uniform slot is valid.
        slot_draw = jax.random.randint(kq, mask.shape, 0,
                                       cfg.record_capacity)
        warmup = state.n < cfg.record_capacity
        slot = jnp.where(warmup,
                         jnp.clip(u, 0, cfg.record_capacity - 1), slot_draw)
        row_of = jnp.zeros((B + 1,), jnp.int32) \
            .at[jnp.where(maskb, pos, B)].set(jnp.arange(B, dtype=jnp.int32))
        in_row = jnp.take(row_of, jnp.clip(u - state.n, 0, B))
        p_items = jnp.where(within[:, None],
                            jnp.take(values, in_row, axis=0),
                            jnp.take(state.rec_items, slot, axis=0))
        p_bucket = jnp.where(within, jnp.take(bucket, in_row),
                             jnp.take(state.rec_bucket, slot))
        p_ok = (gidx > 0) & jnp.where(
            within, True, jnp.take(state.rec_tags, slot) >= 0)
        p_sim = jnp.sum((values == p_items).astype(jnp.int32), axis=1)
        p_same = p_bucket == bucket

        def pair_reservoir(k, cand, sims, tags, seen, sim_vals):
            win, src, seen_new = reservoir_accept(
                k, seen, cand.astype(jnp.int32), cfg.pair_capacity)
            return (jnp.where(win, jnp.take(sim_vals, src), sims),
                    jnp.where(win, state.sid, tags),
                    seen_new)

        same_sim, same_tags, same_seen = pair_reservoir(
            ks, maskb & p_ok & p_same, state.same_sim, state.same_tags,
            state.same_seen, p_sim)
        cross_sim, cross_tags, cross_seen = pair_reservoir(
            kc, maskb & p_ok & ~p_same, state.cross_sim, state.cross_tags,
            state.cross_seen, p_sim)

        win, src, n_new = reservoir_accept(
            kr, state.n, mask, cfg.record_capacity)
        taken = jnp.take(values, src, axis=0)
        return LSHSSState(
            counts=counts,
            rec_items=jnp.where(win[:, None], taken, state.rec_items),
            rec_bucket=jnp.where(win, jnp.take(bucket, src),
                                 state.rec_bucket),
            rec_tags=jnp.where(win, state.sid, state.rec_tags),
            same_sim=same_sim, same_tags=same_tags, same_seen=same_seen,
            cross_sim=cross_sim, cross_tags=cross_tags,
            cross_seen=cross_seen,
            n=n_new, sid=state.sid,
            # data-carrying rounds only (see reservoir._ingest_one): padding
            # rounds must not advance the bootstrap/replay coordinate
            step=state.step + (jnp.sum(mask) > 0).astype(jnp.int32))

    def ingest_rounds(self, states, values, row_mask, keys):
        return self._rounds_fn(states, jnp.asarray(values),
                               jnp.asarray(row_mask), keys)

    # -- algebra -------------------------------------------------------
    def _merge_sample(self, items_a, tags_a, n_a, items_b, tags_b, n_b,
                      capacity):
        return merge_tagged_samples(items_a, tags_a, n_a, items_b, tags_b,
                                    n_b, capacity,
                                    _MERGE_SALT ^ self.cfg.seed)

    def refill_capacity(self, backing: int) -> tuple[int, int]:
        """(record, pair) fold capacities with ``backing`` half-capacity
        backing epochs (window refill, DESIGN.md §14.2)."""
        c = self.cfg
        return (c.record_capacity + backing * (c.record_capacity // 2),
                c.pair_capacity + backing * (c.pair_capacity // 2))

    def merge(self, a: LSHSSState, b: LSHSSState, *,
              backing: int = 0) -> LSHSSState:
        cfg = self.cfg
        rec_cap, pair_cap = self.refill_capacity(backing)
        # record reservoir: carry the bucket id as an extra merged column
        rec_a = jnp.concatenate(
            [a.rec_items, a.rec_bucket.astype(jnp.uint32)[:, None]], axis=1)
        rec_b = jnp.concatenate(
            [b.rec_items, b.rec_bucket.astype(jnp.uint32)[:, None]], axis=1)
        rec, rec_tags = self._merge_sample(rec_a, a.rec_tags, a.n,
                                           rec_b, b.rec_tags, b.n,
                                           rec_cap)
        same, same_tags = self._merge_sample(
            a.same_sim.astype(jnp.uint32)[:, None], a.same_tags, a.same_seen,
            b.same_sim.astype(jnp.uint32)[:, None], b.same_tags, b.same_seen,
            pair_cap)
        cross, cross_tags = self._merge_sample(
            a.cross_sim.astype(jnp.uint32)[:, None], a.cross_tags,
            a.cross_seen,
            b.cross_sim.astype(jnp.uint32)[:, None], b.cross_tags,
            b.cross_seen, pair_cap)
        return LSHSSState(
            counts=a.counts + b.counts,
            rec_items=rec[:, :cfg.d],
            rec_bucket=rec[:, cfg.d].astype(jnp.int32),
            rec_tags=rec_tags,
            same_sim=same[:, 0].astype(jnp.int32), same_tags=same_tags,
            same_seen=a.same_seen + b.same_seen,
            cross_sim=cross[:, 0].astype(jnp.int32), cross_tags=cross_tags,
            cross_seen=a.cross_seen + b.cross_seen,
            n=a.n + b.n, sid=jnp.maximum(a.sid, b.sid),
            step=a.step + b.step)

    def subtract(self, a: LSHSSState, b: LSHSSState) -> LSHSSState:
        drop = b.sid
        return LSHSSState(
            counts=a.counts - b.counts,
            rec_items=a.rec_items, rec_bucket=a.rec_bucket,
            rec_tags=jnp.where(a.rec_tags == drop, -1, a.rec_tags),
            same_sim=a.same_sim,
            same_tags=jnp.where(a.same_tags == drop, -1, a.same_tags),
            same_seen=jnp.maximum(a.same_seen - b.same_seen, 0),
            cross_sim=a.cross_sim,
            cross_tags=jnp.where(a.cross_tags == drop, -1, a.cross_tags),
            cross_seen=jnp.maximum(a.cross_seen - b.cross_seen, 0),
            n=jnp.maximum(a.n - b.n, 0), sid=a.sid, step=a.step)

    # -- estimation ----------------------------------------------------
    def _stderr(self, same_sim, same_tags, same_seen, cross_sim, cross_tags,
                cross_seen, same_pairs, cross_pairs, n, step):
        """(N, L) stratified-bootstrap stderr, or zeros when disabled."""
        if not self.bootstrap:
            return np.zeros((np.asarray(n).shape[0], self.num_levels))
        return uncertainty.stratified_bootstrap_stderr(
            same_sim, same_tags >= 0, same_seen,
            cross_sim, cross_tags >= 0, cross_seen,
            same_pairs, cross_pairs, d=self.d, s=self.s,
            seed=self.cfg.seed, n=n, step=step,
            replicates=self.bootstrap)

    def _table(self, counts, same_sim, same_tags, same_seen, cross_sim,
               cross_tags, cross_seen, n, step) -> EstimateTable:
        """Vectorized numpy: stratum totals from the bucket counts, per-
        stratum level fractions from the pair reservoirs, Eq. of §2.3.
        Error bars: the stratified bootstrap of DESIGN.md §14 (the bucket
        totals are linear and near-exact; the pair-reservoir fractions
        carry the sampling randomness)."""
        counts = counts.astype(np.float64)
        same_pairs = (counts * (counts - 1)).sum(axis=-1)       # ordered
        total = n * (n - 1)
        cross_pairs = np.maximum(total - same_pairs, 0.0)
        levels = np.arange(self.d + 1)

        def level_fracs(sim, tags):
            ok = tags >= 0
            m = ok.sum(axis=-1).astype(np.float64)
            hits = ((sim[..., None] == levels) & ok[..., None]) \
                .sum(axis=-2).astype(np.float64)                # (N, d+1)
            with np.errstate(divide="ignore", invalid="ignore"):
                return np.where(m[:, None] > 0, hits / m[:, None], 0.0), hits

        f1, y1 = level_fracs(same_sim, same_tags)
        f2, _ = level_fracs(cross_sim, cross_tags)
        x_full = f1 * same_pairs[:, None] + f2 * cross_pairs[:, None]
        x = x_full[:, self.s:]
        g = np.cumsum(x[:, ::-1], axis=1)[:, ::-1] + n[:, None]
        stderr = self._stderr(same_sim, same_tags, same_seen, cross_sim,
                              cross_tags, cross_seen, same_pairs,
                              cross_pairs, n, step)
        return EstimateTable(x=x, g=g, y=y1[:, self.s:], n=n,
                             stderr=stderr, stderr_offline=stderr,
                             stderr_kind=("bootstrap_stratified"
                                          if self.bootstrap else "none"))

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        del clamp, use_pallas, interpret           # pure host-numpy math
        get = lambda a: np.asarray(jax.device_get(a))
        return self._table(get(states.counts), get(states.same_sim),
                           get(states.same_tags), get(states.same_seen),
                           get(states.cross_sim), get(states.cross_tags),
                           get(states.cross_seen),
                           get(states.n).astype(np.float64),
                           get(states.step))

    def estimate_ref(self, state: LSHSSState, *,
                     clamp: bool = True) -> EstimateTable:
        """Scalar python-loop oracle for the batched numpy path (the
        stderr column reuses the shared stratified bootstrap, whose
        per-stream PRNG makes batch == ref by construction)."""
        del clamp
        get = lambda a: np.asarray(jax.device_get(a))
        counts = get(state.counts).astype(np.int64)
        n = float(get(state.n))
        same_pairs = float((counts * (counts - 1)).sum())
        cross_pairs = max(n * (n - 1) - same_pairs, 0.0)
        x = np.zeros(self.d + 1)
        y = np.zeros(self.d + 1)
        for sim, tags, pairs, record_y in (
                (get(state.same_sim), get(state.same_tags), same_pairs, True),
                (get(state.cross_sim), get(state.cross_tags), cross_pairs,
                 False)):
            ok = tags >= 0
            m = int(ok.sum())
            for k in range(self.d + 1):
                hits = int(((sim == k) & ok).sum())
                if record_y:
                    y[k] = hits
                if m > 0:
                    x[k] += hits / m * pairs
        xs = x[self.s:]
        g = np.array([xs[i:].sum() + n for i in range(self.num_levels)])
        stderr = self._stderr(
            get(state.same_sim)[None], get(state.same_tags)[None],
            get(state.same_seen)[None], get(state.cross_sim)[None],
            get(state.cross_tags)[None], get(state.cross_seen)[None],
            np.array([same_pairs]), np.array([cross_pairs]),
            np.array([n]), get(state.step)[None])
        return EstimateTable(x=xs[None], g=g[None], y=y[self.s:][None],
                             n=np.array([n]), stderr=stderr,
                             stderr_offline=stderr,
                             stderr_kind=("bootstrap_stratified"
                                          if self.bootstrap else "none"))


def derive_config(sjpc_cfg: SJPCConfig, *, num_hash_cols: int = 1) -> LSHSSConfig:
    """Split the group's SJPC byte budget across the three structures:
    ~half to the record reservoir, ~quarter to the pair reservoirs,
    the rest to bucket counters (capped at 1024 buckets)."""
    budget = sjpc_cfg.counters_bytes
    d = sjpc_cfg.d
    num_buckets = 1024
    while num_buckets * 4 > max(budget // 4, 64):
        num_buckets //= 2
    record_capacity = max(1, (budget // 2) // ((d + 2) * 4))
    pair_capacity = max(1, (budget // 4) // (2 * 8))
    return LSHSSConfig(d=d, s=sjpc_cfg.s, num_hash_cols=num_hash_cols,
                       num_buckets=max(num_buckets, 16),
                       record_capacity=record_capacity,
                       pair_capacity=pair_capacity, seed=sjpc_cfg.seed)


def _factory(sjpc_cfg: SJPCConfig, *, params=None, estimator_cfg=None,
             opts=None):
    del params                # no shared hash randomness
    if estimator_cfg is None:
        estimator_cfg = derive_config(sjpc_cfg)
    return LSHSSEstimator(estimator_cfg, **(dict(opts) if opts else {}))


register("lsh_ss", _factory, state_cls=LSHSSState, linear=False,
         join_capable=False, stderr_kind="bootstrap_stratified",
         exact_oracle=pairwise_exact_oracle)
