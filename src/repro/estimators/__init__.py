"""repro.estimators -- every similarity-join size estimator behind one
streaming protocol (DESIGN.md §13).

Importing this package registers the built-in kinds:

  "sjpc"       the paper's sketch estimator (Algorithm 1); linear,
               joinable, analytical error bounds -- the reference
               implementation (estimators/sjpc_backend.py)
  "reservoir"  one-pass uniform record sampling (§2.1 / Fig. 8), queried
               through the fused all-pairs kernel (estimators/reservoir.py)
  "lsh_ss"     one-pass stratified LSH sampling (§2.3), bucket-count
               sketch + online pair reservoirs (estimators/lsh_ss.py)

``make(kind, sjpc_cfg)`` derives each competitor's configuration from the
group's SJPCConfig, so all kinds are equal-space by construction.
"""
from .base import (EstimateTable, Estimator, EstimatorSpec, available,
                   index_state, load_plugins, make, pairwise_exact_oracle,
                   register, register_spec, register_state_type, scan_rounds,
                   spec, spec_of, stack_states, state_type, zeros_like_stack)
from .lsh_ss import LSHSSConfig, LSHSSEstimator, derive_config
from .reservoir import ReservoirConfig, ReservoirEstimator, capacity_for_bytes
from .sjpc_backend import SJPCEstimator

__all__ = [
    "EstimateTable", "Estimator", "EstimatorSpec", "LSHSSConfig",
    "LSHSSEstimator", "ReservoirConfig", "ReservoirEstimator",
    "SJPCEstimator", "available", "capacity_for_bytes", "derive_config",
    "index_state", "load_plugins", "make", "pairwise_exact_oracle",
    "register", "register_spec", "register_state_type", "scan_rounds",
    "spec", "spec_of", "stack_states", "state_type", "zeros_like_stack",
]
