"""Streaming uniform record sampling behind the Estimator protocol.

The paper's one-pass competitor (§2.1, Fig. 8): keep R records chosen
uniformly without replacement from the stream (Vitter's Algorithm R), and
estimate x[k] as the sample's all-pairs similarity histogram scaled by
n(n-1)/(m(m-1)).  PRs 0-3 carried this only as an offline batch function
(``baselines.random_sampling_pair_counts``); this module is the *served*
version: state is a fixed-shape pytree, ingest is one jit'd vectorized
dispatch per flush, and the query hot path -- previously O(R^2 d) host
numpy -- is the fused all-pairs kernel (kernels/fused_pairs.py).

Vectorized Algorithm R: record with global arrival index g (0-based) is
accepted with probability min(1, R/(g+1)) into a uniform random slot;
within a batch all accept/slot draws are independent given the starting
count, so the whole batch resolves in one pass -- per slot, the *latest*
accepted candidate wins (a scatter-max over arrival order), which is
exactly sequential processing.  Distributional equivalence to offline
uniform sampling is pinned statistically in tests/test_estimators.py.

Epoch algebra: inserted items are tagged with the state's ``sid``
(provenance).  ``merge`` is the deterministic weighted union of
base.merge_tagged_samples (``backing > 0`` folds into an expanded total
-- the window's backing-epoch refill, DESIGN.md §14.2); ``subtract(a,
b)`` drops a's items tagged with b's sid -- exact for the per-epoch
states the sliding window hands it (dropping one component of a uniform
sample of a union leaves a uniform sample of the rest), at the honest
streaming cost that expired slots cannot be refilled from data the
sample never kept.

Error bars: the bootstrap-with-Serfling stderr of
estimators/uncertainty.py (stderr_kind "bootstrap"), with every
replicate histogram riding the fused kernel's N axis in one launch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import exact
from repro.core.sjpc import SJPCConfig

from . import uncertainty
from .base import (EstimateTable, Estimator, merge_tagged_samples,
                   pairwise_exact_oracle, register, scan_rounds)

_MERGE_SALT = 0x7E5E4B01


@dataclasses.dataclass(frozen=True)
class ReservoirConfig:
    d: int                   # record dimensionality
    s: int                   # lowest queryable threshold
    capacity: int            # reservoir slots R
    seed: int = 0x5A5A

    def __post_init__(self):
        assert 1 <= self.s <= self.d, "need 1 <= s <= d"
        assert self.capacity >= 1, "reservoir needs at least one slot"


class ReservoirState(NamedTuple):
    items: jax.Array         # (R, d) uint32 stored records
    tags: jax.Array          # (R,) int32 provenance sid; -1 = empty slot
    n: jax.Array             # int32 records seen.  Exact integer on
    #   purpose: Algorithm R's acceptance probability R/(g+1) needs the
    #   true arrival index (a float32 n freezes at 2^24 and would skew
    #   retention toward recent records); int32 is exact to 2^31.
    sid: jax.Array           # int32 provenance tag for new insertions
    step: jax.Array          # int32 PRNG folding counter


def reservoir_accept(key, n0, mask, capacity: int):
    """One batch of vectorized Algorithm R bookkeeping.

    mask (B,) int32 marks candidate rows; ``n0`` (int32 scalar) is the
    stream count before the batch.  Returns (win (R,) bool, src (R,)
    int32 batch row feeding each winning slot, n_new): per slot the
    latest accepted candidate wins, which is bit-equivalent to processing
    the batch sequentially.  Shared by the record reservoir here and the
    stratified pair reservoirs of estimators.lsh_ss.
    """
    B = mask.shape[0]
    maskb = mask != 0
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1       # index among candidates
    gidx = n0 + pos                                    # global arrival index
    ku, ks = jax.random.split(key)
    # acceptance w.p. capacity/(gidx+1), decided on INTEGERS: draw a
    # uniform arrival rank r in [0, gidx] and accept iff r < capacity.
    # The float form u * (gidx+1) < capacity loses exactness once gidx
    # crosses 2^24 (f32 rounds adjacent arrival indices together, skewing
    # retention on long streams -- the drift the int32 ``n`` comment
    # guards against); the integer draw is exact to the int32 range.
    rank = jax.random.randint(ku, (B,), 0, jnp.maximum(gidx + 1, 1))
    rand_slot = jax.random.randint(ks, (B,), 0, capacity)
    accept = maskb & ((gidx < capacity) | (rank < capacity))
    slot = jnp.where(gidx < capacity, jnp.clip(gidx, 0, capacity - 1),
                     rand_slot)
    order = jnp.where(accept, pos, -1)
    best = jnp.full((capacity,), -1, jnp.int32).at[slot].max(order)
    # map winning candidate index -> batch row (candidate indices are
    # unique among masked rows; masked-out rows scatter into the spare
    # B-th slot that is never read)
    row_of = jnp.zeros((B + 1,), jnp.int32) \
        .at[jnp.where(maskb, pos, B)].set(jnp.arange(B, dtype=jnp.int32))
    win = best >= 0
    src = jnp.take(row_of, jnp.clip(best, 0, B))
    return win, src, n0 + jnp.sum(mask.astype(jnp.int32))


class ReservoirEstimator(Estimator):
    kind = "reservoir"
    linear = False
    supports_join = False

    def __init__(self, cfg: ReservoirConfig, *,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 bootstrap_replicates: int = uncertainty.DEFAULT_REPLICATES,
                 bootstrap_item_cap: int = uncertainty.DEFAULT_ITEM_CAP):
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.interpret = interpret
        # bootstrap error bars (0 replicates disables -> stderr_kind
        # "none"); a capacity-1 reservoir can never hold a pair, so its
        # bars would be identically zero -- disable rather than mislabel
        if bootstrap_replicates == 1:
            raise ValueError("bootstrap_replicates must be 0 (disabled) "
                             "or >= 2 (a std needs two replicates)")
        self.bootstrap = (int(bootstrap_replicates) if cfg.capacity >= 2
                          else 0)
        self.bootstrap_cap = int(bootstrap_item_cap)
        self._rounds_fn = jax.jit(
            functools.partial(scan_rounds, self._ingest_one))

    @property
    def d(self) -> int:
        return self.cfg.d

    @property
    def s(self) -> int:
        return self.cfg.s

    @property
    def seed(self) -> int:
        return self.cfg.seed

    def memory_bytes(self) -> int:
        # items + tags; n/sid/step are O(1) scalars
        return self.cfg.capacity * (self.cfg.d + 1) * 4

    # -- protocol ------------------------------------------------------
    def init(self, sid: int = 0) -> ReservoirState:
        R, d = self.cfg.capacity, self.cfg.d
        return ReservoirState(
            items=jnp.zeros((R, d), jnp.uint32),
            tags=jnp.full((R,), -1, jnp.int32),
            n=jnp.zeros((), jnp.int32),
            sid=jnp.asarray(sid, jnp.int32),
            step=jnp.zeros((), jnp.int32))

    def _ingest_one(self, state: ReservoirState, values, mask,
                    key) -> ReservoirState:
        values = values.astype(jnp.uint32)
        win, src, n_new = reservoir_accept(
            key, state.n, mask.astype(jnp.int32), self.cfg.capacity)
        taken = jnp.take(values, src, axis=0)
        # step (the bootstrap_key coordinate) advances only on rounds that
        # carried data: a fully-masked padding round is a content no-op and
        # must leave the state -- bars included -- bit-identical to a solo
        # replay without it (ingest.py's determinism contract)
        carried = (jnp.sum(mask.astype(jnp.int32)) > 0).astype(jnp.int32)
        return ReservoirState(
            items=jnp.where(win[:, None], taken, state.items),
            tags=jnp.where(win, state.sid, state.tags),
            n=n_new,
            sid=state.sid,
            step=state.step + carried)

    def ingest_rounds(self, states, values, row_mask, keys):
        return self._rounds_fn(states, jnp.asarray(values),
                               jnp.asarray(row_mask), keys)

    def refill_capacity(self, backing: int) -> int:
        """Fold capacity with ``backing`` half-capacity backing epochs
        (the window refill of DESIGN.md §14.2): cap + backing * cap//2."""
        return self.cfg.capacity + backing * (self.cfg.capacity // 2)

    def merge(self, a: ReservoirState, b: ReservoirState, *,
              backing: int = 0) -> ReservoirState:
        """Deterministic weighted union.  ``backing > 0`` merges into an
        *expanded* sample of ``refill_capacity(backing)`` slots -- the
        window's backing-epoch refill fold; the inputs may be any mix of
        base-capacity epoch states and already-expanded totals."""
        items, tags = merge_tagged_samples(
            a.items, a.tags, a.n, b.items, b.tags, b.n,
            self.refill_capacity(backing), _MERGE_SALT ^ self.cfg.seed)
        return ReservoirState(items=items, tags=tags, n=a.n + b.n,
                              sid=jnp.maximum(a.sid, b.sid),
                              step=a.step + b.step)

    def subtract(self, a: ReservoirState, b: ReservoirState) -> ReservoirState:
        keep = a.tags != b.sid
        return ReservoirState(
            items=a.items,
            tags=jnp.where(keep, a.tags, -1),
            n=jnp.maximum(a.n - b.n, 0),
            sid=a.sid, step=a.step)

    # -- estimation ----------------------------------------------------
    def _table(self, hist: np.ndarray, n: np.ndarray, m: np.ndarray,
               stderr: np.ndarray | None = None) -> EstimateTable:
        """hist (N, d+1) float64 sample pair counts -> the (N, L) table.
        Scale n(n-1)/(m(m-1)); m < 2 yields the zero histogram (the
        empty-stream guard of baselines.random_sampling_pair_counts)."""
        x_full = hist * uncertainty.pair_scale(n, m)[:, None]  # (N, d+1)
        x = x_full[:, self.s:]
        g = np.cumsum(x[:, ::-1], axis=1)[:, ::-1] + n[:, None]
        if stderr is None:
            stderr = np.zeros_like(x)
        # the reservoir is a pure sampling estimator: the online and the
        # sampling-only (offline) bars coincide
        return EstimateTable(x=x, g=g, y=hist[:, self.s:], n=n,
                             stderr=stderr, stderr_offline=stderr,
                             stderr_kind=("bootstrap" if self.bootstrap
                                          else "none"))

    def _bootstrap_stderr(self, items, valid, n, step, *, use_pallas,
                          interpret, pair_fn=None) -> np.ndarray | None:
        """(N, L) bootstrap-with-Serfling stderr of the g table, or None
        when disabled (bootstrap_replicates=0)."""
        if not self.bootstrap:
            return None
        keys = uncertainty.bootstrap_key(self.cfg.seed, n, step)
        return uncertainty.bootstrap_pair_stderr(
            items, valid, np.asarray(jax.device_get(n), np.float64),
            keys=keys, s=self.s, replicates=self.bootstrap,
            item_cap=self.bootstrap_cap, use_pallas=use_pallas,
            interpret=interpret, pair_fn=pair_fn)

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        del clamp                                  # counts are >= 0 already
        from repro.kernels.ops import fused_pairs
        use_pallas = self.use_pallas if use_pallas is None else use_pallas
        interpret = self.interpret if interpret is None else interpret
        # device arrays flow straight into the kernel (no host round-trip
        # re-uploading the sample per query); only the small outputs --
        # histogram, valid counts, n -- are fetched
        valid = (jnp.asarray(states.tags) >= 0).astype(jnp.int32)
        hist = np.asarray(jax.device_get(fused_pairs(
            states.items, valid, use_pallas=use_pallas, interpret=interpret,
        ))).astype(np.float64)
        n = np.asarray(jax.device_get(states.n), np.float64)
        m = np.asarray(jax.device_get(valid.sum(axis=1)), np.float64)
        stderr = self._bootstrap_stderr(states.items, valid, states.n,
                                        states.step, use_pallas=use_pallas,
                                        interpret=interpret)
        return self._table(hist, n, m, stderr)

    def estimate_ref(self, state: ReservoirState, *,
                     clamp: bool = True) -> EstimateTable:
        """O(m^2 d) numpy oracle: brute-force histogram of the valid
        sample (core.exact), then the identical scaling.  The bootstrap
        stderr re-draws the same replicate indices (same per-state keys)
        but bins them through the numpy oracle."""
        del clamp
        tags = np.asarray(jax.device_get(state.tags))
        valid = (tags >= 0).astype(np.int32)
        items = np.asarray(jax.device_get(state.items))
        hist = (exact.brute_force_pair_counts(items[tags >= 0])
                if items[tags >= 0].shape[0] else np.zeros(self.d + 1))
        n = np.array([self.state_n(state)], np.float64)

        def pair_fn(it, va):
            it, va = np.asarray(it), np.asarray(va)
            lead = it.shape[:-2]
            flat_it = it.reshape((-1,) + it.shape[-2:])
            flat_va = va.reshape((-1, va.shape[-1]))
            out = np.stack([exact.brute_force_pair_counts(r[v != 0])
                            if (v != 0).sum() else np.zeros(self.d + 1)
                            for r, v in zip(flat_it, flat_va)])
            return out.reshape(lead + (self.d + 1,))

        stderr = self._bootstrap_stderr(
            items[None], valid[None], jnp.asarray(state.n)[None],
            jnp.asarray(state.step)[None], use_pallas=False,
            interpret=None, pair_fn=pair_fn)
        return self._table(hist[None], n,
                           np.array([float(valid.sum())], np.float64),
                           stderr)


def capacity_for_bytes(sjpc_cfg: SJPCConfig) -> int:
    """The Fig. 8 equal-space rule, served: the records (plus provenance
    tag) storable in the byte budget of the group's SJPC counters."""
    return max(1, sjpc_cfg.counters_bytes // ((sjpc_cfg.d + 1) * 4))


def _factory(sjpc_cfg: SJPCConfig, *, params=None, estimator_cfg=None,
             opts=None):
    del params                               # no shared hash randomness
    if estimator_cfg is None:
        estimator_cfg = ReservoirConfig(
            d=sjpc_cfg.d, s=sjpc_cfg.s, capacity=capacity_for_bytes(sjpc_cfg),
            seed=sjpc_cfg.seed)
    return ReservoirEstimator(estimator_cfg, **(dict(opts) if opts else {}))


register("reservoir", _factory, state_cls=ReservoirState, linear=False,
         join_capable=False, stderr_kind="bootstrap",
         exact_oracle=pairwise_exact_oracle)
