"""SJPC behind the Estimator protocol: a thin adapter over core/sjpc.py.

Nothing numerical lives here -- every path delegates to the PR 1-3 code
(``sjpc.update_fused`` / ``ShardedIngest`` semantics via the service's
``multi_round_update`` scan, ``sjpc.estimate_batch``, the Theorem 1/2
bounds), so the fused ingest/query conformance suites keep pinning the
exact same functions.  The adapter's job is shape only: expose those
functions with the protocol signatures the generalized service layers
(registry/window/ingest/query) dispatch over, alongside the reservoir and
LSH-SS competitors.
"""
from __future__ import annotations

import numpy as np

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCParams, SJPCState

from .base import (EstimateTable, Estimator, pairwise_exact_oracle, register,
                   stack_states)


class SJPCEstimator(Estimator):
    """The paper's estimator (Algorithm 1) as the protocol's reference
    implementation: linear (merge/subtract are exact counter arithmetic),
    joinable (§6 inner products), with analytical error bounds."""

    kind = "sjpc"
    linear = True
    supports_join = True

    def __init__(self, cfg: SJPCConfig, params: SJPCParams | None = None, *,
                 use_fused: bool = True, use_pallas: bool | None = None,
                 interpret: bool | None = None, shards: int = 1):
        self.cfg = cfg
        self.params = params if params is not None else sjpc.init(cfg)[0]
        self.use_fused = use_fused
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.shards = shards

    # -- static properties --------------------------------------------
    @property
    def d(self) -> int:
        return self.cfg.d

    @property
    def s(self) -> int:
        return self.cfg.s

    @property
    def seed(self) -> int:
        return self.cfg.seed

    def memory_bytes(self) -> int:
        return self.cfg.counters_bytes

    # -- protocol ------------------------------------------------------
    def init(self, sid: int = 0) -> SJPCState:
        del sid                      # linear subtract needs no provenance
        return sjpc.init(self.cfg)[1]

    def ingest_rounds(self, states, values, row_mask, keys):
        # the PR 2 fused scan'd dispatch, verbatim (lazy import: service
        # imports estimators at registry time, so the module edge must
        # point service -> estimators at import and back only at runtime)
        from repro.service.ingest import multi_round_update
        counters, n, steps = multi_round_update(
            self.cfg, self.params, states.counters, states.n, states.step,
            values, row_mask, keys, use_pallas=self.use_pallas,
            interpret=self.interpret, use_fused=self.use_fused,
            shards=self.shards)
        return SJPCState(counters=counters, n=n, step=steps)

    def merge(self, a: SJPCState, b: SJPCState) -> SJPCState:
        return sjpc.merge(a, b)

    def subtract(self, a: SJPCState, b: SJPCState) -> SJPCState:
        return sjpc.subtract(a, b)

    def estimate_batch(self, states, *, clamp: bool = True,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> EstimateTable:
        be = sjpc.estimate_batch(
            self.cfg, states.counters, states.n, clamp=clamp,
            use_pallas=self.use_pallas if use_pallas is None else use_pallas,
            interpret=self.interpret if interpret is None else interpret)
        return EstimateTable(*be, stderr_kind="analytic")

    def estimate_ref(self, state: SJPCState, *,
                     clamp: bool = True) -> EstimateTable:
        """The PR 1 per-stream oracle: int64-exact F2, float64 inversion,
        scalar Theorem 1/2 bounds -- identical op order to the path the
        reference query engine served before the protocol refactor."""
        cfg = self.cfg
        y = sjpc.level_f2(state)
        n = self.state_n(state)
        x = sjpc.f2_to_pair_count(cfg.d, cfg.s, n, cfg.ratio, y, clamp=clamp)
        L = cfg.num_levels
        g = np.array([x[i:].sum() + n for i in range(L)], np.float64)
        on = np.zeros(L)
        off = np.zeros(L)
        for i, s in enumerate(self.thresholds):
            if g[i] > 0:
                off[i] = np.sqrt(sjpc.offline_variance_bound(
                    cfg.d, s, cfg.ratio, g[i])) * g[i]
                on[i] = np.sqrt(sjpc.online_variance_bound(
                    cfg.d, s, cfg.ratio, cfg.width, n, g[i])) * g[i]
        return EstimateTable(x=x[None], g=g[None], y=np.asarray(y)[None],
                             n=np.array([n]), stderr=on[None],
                             stderr_offline=off[None],
                             stderr_kind="analytic")

    # -- join (SJPC-only capability) ----------------------------------
    def estimate_join_batch(self, states_a, states_b, *, clamp: bool = True,
                            use_pallas: bool | None = None,
                            interpret: bool | None = None) -> EstimateTable:
        be = sjpc.estimate_join_batch(
            self.cfg, states_a.counters, states_b.counters,
            states_a.n, states_b.n, clamp=clamp,
            use_pallas=self.use_pallas if use_pallas is None else use_pallas,
            interpret=self.interpret if interpret is None else interpret)
        return EstimateTable(*be, stderr_kind="analytic")

    def estimate_join_ref(self, state_a, state_b, *,
                          clamp: bool = True) -> EstimateTable:
        """Per-pair oracle: int64-exact inner products + float64 inversion,
        with the reference proxy error bars (self-join bound at
        n = max(n_a, n_b), g = max(estimate, 1); DESIGN.md §10.4)."""
        cfg = self.cfg
        y = sjpc.join_level_inner(state_a, state_b)
        x = sjpc.inner_to_join_count(cfg.d, cfg.s, cfg.ratio, y, clamp=clamp)
        L = cfg.num_levels
        g = np.array([x[i:].sum() for i in range(L)], np.float64)
        n_a, n_b = self.state_n(state_a), self.state_n(state_b)
        n = max(n_a, n_b)
        on = np.zeros(L)
        off = np.zeros(L)
        for i, s in enumerate(self.thresholds):
            gp = max(g[i], 1.0)
            off[i] = np.sqrt(sjpc.offline_variance_bound(
                cfg.d, s, cfg.ratio, gp)) * gp
            on[i] = np.sqrt(sjpc.online_variance_bound(
                cfg.d, s, cfg.ratio, cfg.width, n, gp)) * gp
        return EstimateTable(x=x[None], g=g[None], y=np.asarray(y)[None],
                             n=np.array([[n_a, n_b]]), stderr=on[None],
                             stderr_offline=off[None],
                             stderr_kind="analytic")


def _factory(sjpc_cfg, *, params=None, estimator_cfg=None, opts=None):
    # SJPC has no separate config (it IS the group's SJPCConfig); both
    # channels carry construction kwargs, explicit estimator_cfg winning
    kwargs = {**(dict(opts) if opts else {}),
              **(dict(estimator_cfg) if estimator_cfg else {})}
    return SJPCEstimator(sjpc_cfg, params, **kwargs)


register("sjpc", _factory, state_cls=SJPCState, linear=True,
         join_capable=True, stderr_kind="analytic",
         exact_oracle=pairwise_exact_oracle)


__all__ = ["SJPCEstimator", "stack_states"]
