"""Calibrated error bars for sample-kind estimators (DESIGN.md §14).

The paper's accuracy story (Thms. 1/2, Figs. 4/8) is about *bounded*
error, yet until this module the service hard-zeroed ``stderr`` for every
sample-kind estimator -- a correctness bug in the served confidence, not a
missing feature.  The remedy is the standard one for sampling estimators
with no closed-form bound (Efron bootstrap, plus Serfling's
without-replacement correction):

  * **Bootstrap over the retained sample** (reservoir): resample the valid
    sample B times with replacement, recompute the scaled pair-count table
    per replicate, and report the replicate standard deviation.  All B
    histograms ride the existing fused all-pairs kernel's N dimension in
    ONE launch (``kernels.ops.fused_pairs`` accepts stacked leading dims),
    so the error bar costs one extra kernel call, not B.

  * **m-out-of-m cap**: at service-scale reservoirs (R ~ thousands) a full
    resample would multiply the O(R^2 d) pair reduction by B.  Replicates
    are capped at ``item_cap`` items and the replicate std is rescaled by
    sqrt(b / m) -- the m-out-of-n bootstrap correction for a degree-2
    U-statistic whose leading variance term is O(1/m).

  * **Serfling finite-population correction**: the reservoir samples
    *without replacement* from the n-record window, so the iid bootstrap
    overstates the variance by the factor Serfling's inequality removes;
    every stderr is scaled by sqrt(max(1 - (m-1)/n, 0)).

  * **Stratified bootstrap** (LSH-SS): the estimate is
    f1·same_pairs + f2·cross_pairs + n with the stratum totals read from
    *linear* (near-exact) bucket counters and the fractions from two
    fixed-capacity pair reservoirs.  Each stratum's reservoir is resampled
    independently; the per-stratum replicate deviations are scaled by that
    stratum's pair mass and Serfling factor (population = candidates seen),
    then combined -- a stratified bootstrap of exactly the random part of
    the estimator.

Every path is deterministic given the estimator seed and the state's
(n, step) coordinates: snapshots of an unchanged window report identical
error bars, so the query engine's version-keyed cache stays coherent.

``EstimateTable.stderr_kind`` names the method ("analytic" for SJPC's
Theorem 1/2 bounds, "bootstrap" / "bootstrap_stratified" here, "none"
when disabled) so ``service.query`` can surface per-kind confidence
intervals through one uniform contract.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs.metrics import default_registry

DEFAULT_REPLICATES = 32     # bootstrap resamples B
DEFAULT_ITEM_CAP = 256      # m-out-of-m cap b per replicate

_BOOT_SALT = 0xB0075  # PRNG domain separator vs ingest / merge salts


def serfling_factor(n, m):
    """Serfling's without-replacement variance factor, as a std multiplier.

    For a size-m uniform sample drawn without replacement from an
    n-record population, Serfling's inequality tightens the iid
    (with-replacement) bound by (1 - (m-1)/n); the matching stderr
    correction is its square root.  Degenerate windows (n <= 1 or an
    exhausted population) clamp to [0, 1].
    """
    n = np.asarray(n, np.float64)
    m = np.asarray(m, np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        f = np.where(n > 0, 1.0 - (m - 1.0) / np.maximum(n, 1.0), 1.0)
    return np.sqrt(np.clip(f, 0.0, 1.0))


def bootstrap_key(seed: int, n, step):
    """Per-stream PRNG keys for bootstrap resampling: deterministic in the
    estimator seed and the state's (n, step) coordinates, so an unchanged
    window always reports the same error bar.  n (N,), step (N,) ->
    (N,) keys."""
    base = jax.random.PRNGKey(np.uint32(seed) ^ np.uint32(_BOOT_SALT))

    def one(n_i, step_i):
        return jax.random.fold_in(jax.random.fold_in(base, n_i), step_i)

    return jax.vmap(one)(jnp.asarray(n, jnp.int32),
                         jnp.asarray(step, jnp.int32))


def resample_valid_slots(keys, valid, replicates: int, item_cap: int):
    """Bootstrap slot indices over the valid entries of fixed-shape samples.

    valid (N, R) bool/int -> (idx (N, B, b) int32, rep_valid (N, B, b)
    int32, b_sizes (N,) int32) with b = min(item_cap, R): ``idx`` draws
    uniformly *with replacement* from each stream's valid slots (columns
    past ``b_i = min(m_i, item_cap)`` are masked out by ``rep_valid``, as
    are whole streams with m < 2 -- no pairs, no bootstrap).  Everything
    stays a device computation: the caller can gather items and feed the
    (N*B, b, d) stack straight through the fused all-pairs kernel.
    """
    valid = jnp.asarray(valid) != 0
    N, R = valid.shape
    b = min(item_cap, R)
    m = jnp.sum(valid.astype(jnp.int32), axis=1)              # (N,)
    # valid slot ids first, in slot order: argsort of ~valid is stable
    order = jnp.argsort(~valid, axis=1).astype(jnp.int32)      # (N, R)

    def draw(key, m_i):
        return jax.random.randint(key, (replicates, b), 0,
                                  jnp.maximum(m_i, 1))

    r = jax.vmap(draw)(keys, m)                                # (N, B, b)
    idx = jnp.take_along_axis(order[:, None, :], r, axis=2)
    b_sizes = jnp.minimum(m, b)
    col = jnp.arange(b, dtype=jnp.int32)
    rep_valid = jnp.broadcast_to(
        (col[None, None, :] < b_sizes[:, None, None])
        & (m[:, None, None] >= 2), (N, replicates, b)).astype(jnp.int32)
    return idx, rep_valid, b_sizes


def pair_scale(n, m):
    """n(n-1) / (m(m-1)) with the m < 2 guard -> the zero table."""
    n = np.asarray(n, np.float64)
    m = np.asarray(m, np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(m >= 2, n * (n - 1.0)
                        / np.maximum(m * (m - 1.0), 1.0), 0.0)


def suffix_stderr_from_reps(x_reps: np.ndarray) -> np.ndarray:
    """Replicate per-level tables (N, B, L) -> stderr of the suffix-sum
    g table (N, L): std (ddof=1) of the per-replicate suffix sums.  (The
    additive +n of g is deterministic and drops out of the deviation.)"""
    g_reps = np.cumsum(x_reps[:, :, ::-1], axis=2)[:, :, ::-1]
    return g_reps.std(axis=1, ddof=1)


def bootstrap_pair_stderr(items, valid, n, *, keys, s: int,
                          replicates: int = DEFAULT_REPLICATES,
                          item_cap: int = DEFAULT_ITEM_CAP,
                          use_pallas=None, interpret=None,
                          pair_fn=None) -> np.ndarray:
    """Bootstrap stderr of a scaled all-pairs suffix table (reservoir).

    items (N, R, d) stored samples, valid (N, R), n (N,) float window
    counts; returns (N, L) absolute stderr for g_k, k = s..d, already
    rescaled by the m-out-of-m cap (sqrt(b/m)) and the Serfling factor.
    ``pair_fn(items, valid)`` computes stacked pair histograms (defaults
    to the fused kernel; tests inject the numpy oracle).
    """
    if pair_fn is None:
        from repro.kernels.ops import fused_pairs

        def pair_fn(it, va):
            return fused_pairs(it, va, use_pallas=use_pallas,
                               interpret=interpret)

    items = jnp.asarray(items)
    N, R, d = items.shape
    L = d - s + 1
    m = np.asarray(jax.device_get(jnp.sum(jnp.asarray(valid) != 0, axis=1)),
                   np.float64)
    if replicates < 2 or R < 2:
        return np.zeros((N, L))
    reg = default_registry()
    if reg.enabled:
        reg.inc("bootstrap_replicates_total", N * replicates,
                method="bootstrap")
    idx, rep_valid, b_sizes = resample_valid_slots(
        keys, valid, replicates, item_cap)
    # gather replicate items on device; ONE fused launch over the stacked
    # (N, B) leading dims computes every replicate histogram
    rep_items = jnp.take_along_axis(items[:, None, :, :],
                                    idx[:, :, :, None], axis=2)
    hists = np.asarray(jax.device_get(pair_fn(rep_items, rep_valid)),
                       np.float64)                        # (N, B, d+1)

    n = np.asarray(n, np.float64)
    b_sizes = np.asarray(jax.device_get(b_sizes), np.float64)
    scale_b = pair_scale(n, b_sizes)                          # (N,)
    x_reps = hists[:, :, s:] * scale_b[:, None, None]         # (N, B, L)
    stderr = suffix_stderr_from_reps(x_reps)
    # m-out-of-m cap rescale (U-stat leading variance is O(1/m)) and the
    # Serfling without-replacement correction
    with np.errstate(divide="ignore", invalid="ignore"):
        cap_scale = np.where(m >= 2, np.sqrt(
            np.minimum(b_sizes, m) / np.maximum(m, 1.0)), 0.0)
    return stderr * (cap_scale * serfling_factor(n, m))[:, None]


def _resample_fracs(sim, valid, levels, rng, replicates: int):
    """Bayesian-bootstrap level-fraction replicates of ONE stream's
    stratum reservoir: sim (M,) int match counts, valid (M,) ->
    ((B, d+1) replicate fractions, m).

    Replicates draw f* ~ Dirichlet(hits + 1/2) -- the Rubin bootstrap
    under the Jeffreys prior -- rather than the empirical multinomial.
    The smoothing matters: rare levels (one cross-stratum hit scales to
    ~n^2/M pairs) are zero in a third of reservoirs, and the empirical
    bootstrap then reports *zero* spread for mass it simply failed to
    see, collapsing the error bar exactly where it is needed most.  The
    Jeffreys pseudo-count keeps a half-hit of spread at every level, at
    the cost of a slightly conservative bar on well-observed ones.
    m == 0 gives all-zero fractions (the stratum contributes nothing).
    """
    vals = np.asarray(sim)[np.asarray(valid) != 0]
    m = vals.shape[0]
    if m == 0:
        return np.zeros((replicates, levels.shape[0])), 0.0
    hits = (vals[:, None] == levels).sum(axis=0)
    return rng.dirichlet(hits + 0.5, size=replicates), float(m)


def stratified_bootstrap_stderr(same_sim, same_valid, same_seen,
                                cross_sim, cross_valid, cross_seen,
                                same_pairs, cross_pairs, *, d: int, s: int,
                                seed: int, n, step,
                                replicates: int = DEFAULT_REPLICATES
                                ) -> np.ndarray:
    """Stratified bootstrap stderr for the LSH-SS g table (N, L).

    Each stratum's pair reservoir is resampled independently; its centered
    replicate fraction deviations are scaled by the stratum's (linear,
    near-exact) pair mass and its Serfling factor (population = candidates
    seen), then combined per replicate -- bootstrapping exactly the random
    part of x = f1*same_pairs + f2*cross_pairs.
    """
    same_pairs = np.asarray(same_pairs, np.float64)
    cross_pairs = np.asarray(cross_pairs, np.float64)
    if replicates < 2:
        raise ValueError("stratified bootstrap needs >= 2 replicates")
    levels = np.arange(d + 1)
    N = same_pairs.shape[0]
    reg = default_registry()
    if reg.enabled:
        reg.inc("bootstrap_replicates_total", N * replicates,
                method="bootstrap_stratified")
    n_i = np.asarray(n, np.int64).reshape(N)
    step_i = np.asarray(step, np.int64).reshape(N)
    seen_s = np.asarray(same_seen, np.float64).reshape(N)
    seen_c = np.asarray(cross_seen, np.float64).reshape(N)
    x_dev = np.zeros((N, replicates, d + 1))
    for i in range(N):
        # per-stream rng keyed on (seed, n, step): a stream's error bar is
        # independent of its position in a stacked cohort (batch == ref)
        rng = np.random.default_rng(np.random.SeedSequence(
            [int(np.uint32(seed) ^ np.uint32(_BOOT_SALT)),
             int(n_i[i]) & 0xFFFFFFFF, int(step_i[i]) & 0xFFFFFFFF]))
        for sim, valid, seen, pairs in (
                (np.asarray(same_sim)[i], np.asarray(same_valid)[i],
                 seen_s[i], same_pairs[i]),
                (np.asarray(cross_sim)[i], np.asarray(cross_valid)[i],
                 seen_c[i], cross_pairs[i])):
            f, m = _resample_fracs(sim, valid, levels, rng, replicates)
            dev = f - f.mean(axis=0, keepdims=True)            # (B, d+1)
            x_dev[i] += dev * (pairs * serfling_factor(seen, m))
    return suffix_stderr_from_reps(x_dev[:, :, s:])
