"""Backend bootstrapping for examples, benchmarks, and the service
(DESIGN.md §17.4).

One module owns the "pick the fastest backend and configure XLA for it"
idiom (the bayespec ``set_platform`` + olmax XLA-env recipes from
SNIPPETS.md), so call sites stop hand-rolling environment mutation:

  * :func:`set_platform` -- pin jax to cpu/gpu/tpu and (for GPU) install
    the Triton-fusion / latency-hiding XLA flags.  Only effective before
    the jax backend initializes, like every jax platform knob.
  * :func:`bootstrap` -- the ``ServiceConfig.platform="auto"`` entry:
    ``"auto"`` keeps whatever backend jax already picked (jax prefers
    accelerators on its own; we only *report* it), any concrete name pins
    it via :func:`set_platform`.
  * :func:`force_host_device_count` / :func:`subprocess_env` -- the
    forced-multi-device idiom: N XLA host devices on CPU for shard_map
    testing/benchmarking, either in-process (before jax init) or as an
    environment for a child process (how benchmarks/run.py executes its
    executor rows).
"""
from __future__ import annotations

import os

# <https://jax.readthedocs.io/en/latest/gpu_performance_tips.html>
GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def _append_xla_flags(flags: str, env: dict | None = None) -> None:
    target = os.environ if env is None else env
    existing = target.get("XLA_FLAGS", "")
    parts = [p for p in existing.split() if p]
    for flag in flags.split():
        if flag not in parts:
            parts.append(flag)
    target["XLA_FLAGS"] = " ".join(parts)


def set_platform(platform: str) -> None:
    """Pin jax to ``cpu`` / ``gpu`` / ``tpu``.  Takes effect only before
    the first jax computation initializes the backend; on GPU also
    installs the Triton-fusion XLA flags (idempotent append)."""
    if platform == "gpu":
        _append_xla_flags(GPU_XLA_FLAGS)
    import jax
    jax.config.update("jax_platform_name", platform)


def current() -> str:
    """The backend jax actually resolved (initializes it if needed)."""
    import jax
    return jax.default_backend()


def bootstrap(platform: str = "auto") -> str:
    """Resolve a ``ServiceConfig.platform`` value and return the active
    backend name.  ``"auto"`` trusts jax's own accelerator preference
    (tpu > gpu > cpu) and just reports the outcome; a concrete name pins
    it.  Safe to call more than once with the same value."""
    if platform and platform != "auto":
        set_platform(platform)
    return current()


def force_host_device_count(n: int, env: dict | None = None) -> None:
    """Ask XLA for ``n`` host (CPU) devices -- the laptop-scale stand-in
    for a multi-device mesh (ROADMAP shard benchmarks).  Mutates
    ``os.environ`` (must run before jax init) or, given ``env``, a child
    process environment."""
    _append_xla_flags(f"{_HOST_COUNT_FLAG}={n}", env)


def subprocess_env(n_devices: int, base: dict | None = None) -> dict:
    """A copy of the environment with ``n_devices`` forced host devices:
    the benchmarks' subprocess idiom (the parent process has usually
    already initialized a single-device backend, so the flag can only
    apply in a child)."""
    env = dict(os.environ if base is None else base)
    force_host_device_count(n_devices, env)
    return env
