"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state is a pytree mirroring params; moments are fp32.  The
optimizer is expressed as an (init, update) pair so train_step can swap in
Q8Adam (int8 moments) without structural changes.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable        # params -> opt_state
    update: Callable      # (grads, opt_state, params) -> (new_params, new_state, stats)


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def make_adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
               weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    """lr_fn: step (int32 array) -> learning rate scalar."""

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(zeros, params),
                          v=jax.tree_util.tree_map(zeros, params))

    def update(grads, state: AdamWState, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        leaves, treedef = jax.tree_util.tree_flatten(params)
        gl = treedef.flatten_up_to(grads)
        ml = treedef.flatten_up_to(state.m)
        vl = treedef.flatten_up_to(state.v)
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(leaves, gl, ml, vl):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if p.ndim > 1:                       # no decay on norms/biases
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p.append((p - lr * delta).astype(p.dtype))
            new_m.append(m)
            new_v.append(v)
        return (treedef.unflatten(new_p),
                AdamWState(step, treedef.unflatten(new_m),
                           treedef.unflatten(new_v)),
                {"grad_norm": gnorm, "lr": lr})

    return Optimizer(init=init, update=update)
