from .adamw import make_adamw
from .q8adam import make_q8adam
from .schedules import warmup_cosine
from .compression import compress_int8, decompress_int8
