"""Q8Adam under shard_map: fully-local int8 moment update (ZeRO-style).

Each device dequantizes / updates / requantizes only ITS shard of every
parameter: zero collectives inside the optimizer (gradients are already
reduced by the backward pass; global-norm clipping happens outside).  The
int8 codes live as (total_shards * nblk_local, 256) arrays with dim0 sharded
across the whole mesh -- 2.03 B/param of optimizer HBM regardless of
topology, which is what fits jamba-398B training on one 256-chip pod.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.compat import shard_map

from .adamw import Optimizer, clip_by_global_norm
from .q8adam import quantize, dequantize, quantize_v, dequantize_v, QTensor


class Q8State(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def _all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def state_pspecs(mesh, param_pspecs):
    """PartitionSpec tree for the Q8 state mirroring a param pspec tree."""
    qspec = QTensor(codes=PartitionSpec(_all_axes(mesh), None),
                    scales=PartitionSpec(_all_axes(mesh), None))
    is_ps = lambda x: isinstance(x, PartitionSpec)
    return Q8State(
        step=PartitionSpec(),
        m=jax.tree_util.tree_map(lambda _: qspec, param_pspecs, is_leaf=is_ps),
        v=jax.tree_util.tree_map(lambda _: qspec, param_pspecs, is_leaf=is_ps))


def make_q8adam_sharded(mesh, lr_fn, param_pspecs, *, b1=0.9, b2=0.95,
                        eps=1e-8, weight_decay=0.1, clip_norm=1.0,
                        seed=23) -> Optimizer:
    axes = _all_axes(mesh)
    sspecs = state_pspecs(mesh, param_pspecs)
    smap = functools.partial(shard_map, mesh=mesh, check_vma=False)

    def local_init(params):
        qm = lambda p: quantize(jnp.zeros(p.shape, jnp.float32))
        qv = lambda p: quantize_v(jnp.zeros(p.shape, jnp.float32))
        return Q8State(step=jnp.zeros((), jnp.int32),
                       m=jax.tree_util.tree_map(qm, params),
                       v=jax.tree_util.tree_map(qv, params))

    def init(params):
        return smap(local_init, in_specs=(param_pspecs,), out_specs=sspecs)(params)

    def local_update(grads, state, params, lr, rkey):
        step = state.step + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        gl = treedef.flatten_up_to(grads)
        ml = treedef.flatten_up_to(state.m)
        vl = treedef.flatten_up_to(state.v)
        new_p, new_m, new_v = [], [], []
        for i, (p, g, mq, vq) in enumerate(zip(leaves, gl, ml, vl)):
            g = g.astype(jnp.float32)
            m = b1 * dequantize(mq, p.shape) + (1 - b1) * g
            v = b2 * dequantize_v(vq, p.shape) + (1 - b2) * g * g
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if p.ndim > 1:
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p.append((p - lr * delta).astype(p.dtype))
            new_m.append(quantize(m, jax.random.fold_in(rkey, 2 * i)))
            new_v.append(quantize_v(v, jax.random.fold_in(rkey, 2 * i + 1)))
        return (treedef.unflatten(new_p),
                Q8State(step, treedef.unflatten(new_m), treedef.unflatten(new_v)))

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state.step + 1)
        rkey = jax.random.fold_in(jax.random.PRNGKey(seed), state.step)
        new_params, new_state = smap(
            local_update,
            in_specs=(param_pspecs, sspecs, param_pspecs,
                      PartitionSpec(), PartitionSpec()),
            out_specs=(param_pspecs, sspecs),
        )(grads, state, params, lr, rkey)
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)
