"""LR schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup_steps, warm, cos)
    return fn


def constant(lr: float):
    def fn(step):
        return jnp.full((), lr, jnp.float32)
    return fn
