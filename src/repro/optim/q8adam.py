"""Q8Adam: AdamW with block-wise int8 moments + stochastic rounding.

Moment tensors dominate optimizer HBM (8 B/param fp32).  Q8Adam stores both
moments as int8 codes with one fp32 abs-max scale per 256-element block
(~2.03 B/param), making jamba-398B training state fit a single 256-chip pod
(EXPERIMENTS.md §Dry-run).  Stochastic rounding keeps the quantizer unbiased
so the Adam trajectory stays close to fp32 (validated in tests against
AdamW on a quadratic bowl).

Layout: every moment is flattened, padded to a block multiple, and stored as
{codes int8 (nblocks, 256), scales fp32 (nblocks, 1)}.  Dequant -> update ->
requant happens inside the fused train step; only int8 + scales persist.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .adamw import Optimizer, clip_by_global_norm

BLOCK = 256


class QTensor(NamedTuple):
    codes: jax.Array       # (nblocks, BLOCK) int8
    scales: jax.Array      # (nblocks, 1) float32
    # static shape info rides in the pytree as an aux leaf-free wrapper:
    # original shape is recovered from the paired param.


def _pad_len(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def quantize(x, key=None):
    """fp32 tensor -> QTensor, linear symmetric map (for the FIRST moment;
    stochastic rounding when key is given)."""
    flat = x.reshape(-1)
    pad = _pad_len(flat.shape[0]) - flat.shape[0]
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scales = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scales = jnp.maximum(scales, 1e-12)
    q = blocks / scales
    if key is not None:
        q = jnp.floor(q + jax.random.uniform(key, q.shape))
    else:
        q = jnp.round(q)
    return QTensor(codes=jnp.clip(q, -127, 127).astype(jnp.int8), scales=scales)


def dequantize(qt: QTensor, shape) -> jax.Array:
    flat = (qt.codes.astype(jnp.float32) * qt.scales).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


# -- second moment: nonlinear (power) map -----------------------------------
# A linear int8 map is catastrophic for v: within-block dynamic range easily
# exceeds 127x, small entries round to 0, and v sits under a sqrt in the
# denominator -> step explosion.  The quartic map q = 255*(v/max)^(1/4)
# spends its resolution near zero (relative error ~4/q), the same idea as
# bitsandbytes' dynamic map.  Codes are stored in the int8 field as q-128.

V_POWER = 4.0


def quantize_v(x, key=None):
    """Nonnegative tensor -> QTensor with the power map."""
    flat = jnp.maximum(x.reshape(-1), 0.0)
    pad = _pad_len(flat.shape[0]) - flat.shape[0]
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scales = jnp.maximum(jnp.max(blocks, axis=1, keepdims=True), 1e-30)
    t = (blocks / scales) ** (1.0 / V_POWER) * 255.0
    if key is not None:
        t = jnp.floor(t + jax.random.uniform(key, t.shape))
    else:
        t = jnp.round(t)
    codes = (jnp.clip(t, 0, 255) - 128.0).astype(jnp.int8)
    return QTensor(codes=codes, scales=scales)


def dequantize_v(qt: QTensor, shape) -> jax.Array:
    t = (qt.codes.astype(jnp.float32) + 128.0) / 255.0
    flat = (qt.scales * t ** V_POWER).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def make_q8adam(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                weight_decay: float = 0.1, clip_norm: float = 1.0,
                seed: int = 17) -> Optimizer:

    class Q8State(NamedTuple):
        step: jax.Array
        m: dict
        v: dict

    def init(params):
        qm = lambda p: quantize(jnp.zeros_like(p, jnp.float32))
        qv = lambda p: quantize_v(jnp.zeros_like(p, jnp.float32))
        return Q8State(step=jnp.zeros((), jnp.int32),
                       m=jax.tree_util.tree_map(qm, params),
                       v=jax.tree_util.tree_map(qv, params))

    def update(grads, state: Q8State, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        base = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        leaves, treedef = jax.tree_util.tree_flatten(params)
        gleaves = treedef.flatten_up_to(grads)
        mleaves = treedef.flatten_up_to(state.m)
        vleaves = treedef.flatten_up_to(state.v)

        new_p, new_m, new_v = [], [], []
        for i, (p, g, mq, vq) in enumerate(zip(leaves, gleaves, mleaves, vleaves)):
            g = g.astype(jnp.float32)
            m = b1 * dequantize(mq, p.shape) + (1 - b1) * g
            v = b2 * dequantize_v(vq, p.shape) + (1 - b2) * g * g
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if p.ndim > 1:
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p.append((p - lr * delta).astype(p.dtype))
            km = jax.random.fold_in(base, 2 * i)
            kv = jax.random.fold_in(base, 2 * i + 1)
            new_m.append(quantize(m, km))
            new_v.append(quantize_v(v, kv))

        return (treedef.unflatten(new_p),
                Q8State(step, treedef.unflatten(new_m), treedef.unflatten(new_v)),
                {"grad_norm": gnorm, "lr": lr})

    return Optimizer(init=init, update=update)
