"""Int8 gradient compression with error feedback.

For cross-pod data parallelism the gradient all-reduce over the slow
inter-pod links can dominate (§Roofline collective term).  Compressing the
*pod-axis* reduction payload to int8 (per-block abs-max scaling) cuts those
bytes 4x; the residual (quantization error) is fed back into the next step's
gradient so the scheme stays convergent (error-feedback SGD).

Usage inside train_step (see launch/train.py):

    g_q, scales = compress_int8(g + err)
    err = (g + err) - decompress_int8(g_q, scales, g.shape)
    g = psum(decompress...)   # or all-reduce the int8 payload via shard_map
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0,
                         1e-12)
    codes = jnp.clip(jnp.round(blocks / scales), -127, 127).astype(jnp.int8)
    return codes, scales


def decompress_int8(codes, scales, shape):
    flat = (codes.astype(jnp.float32) * scales).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_mean(x, axis_name):
    """Error-free int8-payload mean over a mesh axis (inside shard_map).

    Quantize locally, psum the int8 codes as int32 (sum of codes is exact),
    psum the scales, dequantize with the summed scale estimate.  The scale
    sum makes this an upper-bound reconstruction; error feedback at the
    caller absorbs the difference.
    """
    codes, scales = compress_int8(x)
    csum = jax.lax.psum(codes.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scales, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    avg_scale = ssum / n
    flat = (csum.astype(jnp.float32) * avg_scale / n).reshape(-1)
    size = 1
    for s in x.shape:
        size *= s
    return flat[:size].reshape(x.shape)
