"""Accuracy telemetry: sampled exact replay of queried windows
(DESIGN.md §15.4).

The paper's Figs. 4/8 accuracy story -- relative error and bound coverage
per estimator -- is pinned offline by tests and benchmarks, but a served
system should *measure* it on live traffic: drifting workloads (skew,
cluster structure, window churn) move the error in ways a seeded
regression suite cannot see.  The :class:`AccuracyAuditor` turns that
story into a live signal:

* **Mirror** (opt-in, the memory cost of auditing): ``record`` keeps the
  raw record batches of each stream's live window, rotated in lockstep
  with the window's epochs, so the auditor can reconstruct exactly the
  data behind any snapshot.
* **Sampled replay**: at rate ``rate`` per polled query, the mirrored
  window is pushed through the estimator kind's declared
  ``exact_oracle`` (``EstimatorSpec``, DESIGN.md §19; for the pairwise
  kinds that is ``core/exact.py``'s O(2^d n) group-by oracle -- exact,
  not an estimate) and compared to the served
  :class:`~repro.service.query.QueryResult`:

    ``accuracy_rel_err{kind,s}``        histogram of |est - g|/max(g, 1)
    ``accuracy_audits_total{kind}``     audited query count
    ``accuracy_ci_covered_total{kind}`` audits whose 95% CI covered g

  CI-coverage over time *is* the served calibration curve: for
  "analytic" bars it should sit at/above 95% (the bounds are
  conservative), for bootstrap bars near it (DESIGN.md §14 pins the
  floors offline).
* **Honesty guards**: streams fed by ``ingest_state_delta`` (no raw
  records to mirror) are marked unauditable; windows whose mirrored
  record count disagrees with the served ``n`` (a mirror bug, never
  silent), windows above ``max_records`` (the exact oracle is
  quadratic in lattice width, not free), and kinds whose spec declares
  no exact oracle (a plugin estimating something the replay cannot
  check) skip with a reason-labeled ``accuracy_audit_skipped_total``
  counter instead of lying.

Sampling uses a dedicated seeded generator, so audit cost is
deterministic per workload and replayable in tests (rate=1 audits
everything).
"""
from __future__ import annotations

import numpy as np

from .metrics import MetricsRegistry


class AccuracyAuditor:
    def __init__(self, registry: MetricsRegistry, *, rate: float,
                 max_records: int = 65536, seed: int = 0xA0D17):
        assert 0.0 <= rate <= 1.0, f"audit rate must be in [0, 1]: {rate}"
        self.registry = registry
        self.rate = rate
        self.max_records = max_records
        self._rng = np.random.default_rng(seed)
        # per stream: list of epochs (open epoch last), each a list of
        # record batches -- the window mirror
        self._epochs: dict[str, list[list[np.ndarray]]] = {}
        self._window: dict[str, int | None] = {}
        self._blocked: set[str] = set()

    # -- mirror maintenance (driven by the service) ---------------------
    def record(self, name: str, records: np.ndarray,
               window_epochs: int | None) -> None:
        """Mirror one ingested batch into ``name``'s open epoch."""
        self._window[name] = window_epochs
        eps = self._epochs.setdefault(name, [[]])
        eps[-1].append(np.asarray(records))

    def advance_epoch(self, name: str) -> None:
        """Rotate the mirror with the stream's window: open a new epoch,
        drop epochs the ring expired (the window keeps the open epoch
        plus window_epochs - 1 closed ones)."""
        eps = self._epochs.setdefault(name, [[]])
        eps.append([])
        w = self._window.get(name)
        if w is not None and len(eps) > w:
            del eps[:len(eps) - w]

    def mark_unauditable(self, name: str) -> None:
        """Streams ingesting pre-sketched state deltas carry no raw
        records; exact replay is impossible and must say so."""
        self._blocked.add(name)

    def live_records(self, name: str) -> np.ndarray | None:
        batches = [b for ep in self._epochs.get(name, []) for b in ep]
        if not batches:
            return None
        return np.concatenate(batches)

    # -- audit ----------------------------------------------------------
    def _skip(self, reason: str) -> None:
        self.registry.inc("accuracy_audit_skipped_total", reason=reason)

    def _mirror(self, name: str, n_served: float) -> np.ndarray | None:
        if name in self._blocked:
            self._skip("state_delta_stream")
            return None
        recs = self.live_records(name)
        if recs is None:
            self._skip("no_mirror")
            return None
        if recs.shape[0] > self.max_records:
            self._skip("window_too_large")
            return None
        if recs.shape[0] != int(round(n_served)):
            # the served window and the mirror disagree -- audit would
            # compare against the wrong population; fail loudly in the
            # metrics rather than emit a bogus rel-err
            self._skip("mirror_mismatch")
            return None
        return recs

    def _observe(self, result, g_exact: float, kind: str) -> None:
        rel = abs(result.estimate - g_exact) / max(g_exact, 1.0)
        self.registry.observe("accuracy_rel_err", rel, kind=kind,
                              s=str(result.s))
        self.registry.inc("accuracy_audits_total", kind=kind)
        lo, hi = result.ci(1.96)
        if lo <= g_exact <= hi:
            self.registry.inc("accuracy_ci_covered_total", kind=kind)

    def _oracle_for(self, kind: str):
        """The estimator kind's exact-replay oracle from its spec
        (DESIGN.md §19); ``None`` when the kind declares none (or is
        unregistered) -- the audit skips with a reason instead of
        replaying an estimand the kind does not estimate."""
        from repro import estimators
        try:
            return estimators.spec(kind).exact_oracle
        except KeyError:
            return None

    def maybe_audit(self, result, kind: str) -> bool:
        """Sampled audit of one served result: a QueryResult or an
        all-thresholds dict (one replay covers every threshold).  Returns
        whether an audit ran (tests drive this with rate=1)."""
        if self.rate <= 0.0 or self._rng.random() >= self.rate:
            return False
        results = list(result.values()) if isinstance(result, dict) \
            else [result]
        if not results:
            return False
        r0 = results[0]
        oracle = self._oracle_for(kind)
        if oracle is None:
            self._skip("no_exact_oracle")
            return False
        records = []
        for i, name in enumerate(r0.streams):
            recs = self._mirror(name, r0.n[i])
            if recs is None:
                return False
            records.append(recs)
        # one exact replay answers every threshold of the dict
        g_of_s = oracle(r0.kind, tuple(records))
        for r in results:
            self._observe(r, g_of_s(r.s), kind)
        return True
