"""Metrics core: labeled counters, gauges, and fixed-bucket latency
histograms in an injectable :class:`MetricsRegistry` (DESIGN.md §15).

The service's visibility story before this module was a handful of ad-hoc
untyped dicts (``EstimationService.stats``, ``IngestPipeline.stats``) --
no labels, no latency distributions, no way to ask "what is tenant A's
queue depth" or "what fraction of polls were pure cache hits".  This
registry is the typed replacement every layer (service, kernels,
estimators) emits into:

* **Counters** -- monotone totals (``inc``): records ingested, cache
  hits/misses, kernel dispatches per path, bootstrap replicates.
* **Gauges** -- last-written values (``set``; ``set_max`` keeps the
  high-water mark): per-group queue depth, per-stream live epochs and
  memory bytes.
* **Histograms** -- fixed log-spaced buckets (``observe``) with
  p50/p95/p99 read-out: ingest/flush/snapshot latencies (device-time
  semantics via obs.trace spans) and the sampled accuracy rel-err
  distribution.

Every series is keyed by (family name, sorted label items); families are
created on first write, so instrumentation sites never pre-declare.

**Disabled-mode contract**: every mutator begins with a single
``enabled`` check and returns immediately -- one attribute load and a
branch, no allocation, no locking -- so instrumented hot paths run at
reference speed when observability is off (the overhead guard in
tests/test_obs.py pins enabled-vs-disabled ingest throughput within 5%).

One process-global default registry (:func:`default_registry`) serves
call sites with no service handle (kernel dispatch counters, bootstrap
replicate counts); the service injects its own or shares the default.
Exports: :meth:`MetricsRegistry.collect` (plain dict, for tests and
results.json) and :meth:`MetricsRegistry.to_prometheus` (text format,
served by ``EstimationService.metrics_report``).
"""
from __future__ import annotations

import math
import threading

# Log-spaced latency buckets (seconds): 10us .. 10s, ~2.5x steps.  The
# same geometry works for the accuracy auditor's relative errors (ratios
# in [0, ~10]); +inf is implicit (the overflow bucket).
DEFAULT_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0)


def _labelkey(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _parse_labels(s: str) -> tuple:
    """Inverse of :func:`_fmt_labels` for the collect() label strings
    (``_`` = no labels).  Label values never contain quotes or commas in
    this codebase (stream/group/kind names), so a split suffices."""
    if s in ("", "_"):
        return ()
    if not (s.startswith("{") and s.endswith("}")):
        raise ValueError(f"unparseable label string {s!r}")
    out = []
    for part in s[1:-1].split(","):
        k, _, v = part.partition("=")
        out.append((k, v.strip('"')))
    return tuple(out)


class Histogram:
    """Fixed-bucket histogram: counts per upper bound + sum + count."""

    __slots__ = ("bounds", "counts", "overflow", "total", "count")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        self.bounds = tuple(bounds)
        self.counts = [0] * len(self.bounds)
        self.overflow = 0
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.total += value
        self.count += 1
        for i, b in enumerate(self.bounds):
            if value <= b:
                self.counts[i] += 1
                return
        self.overflow += 1

    def quantile(self, q: float) -> float:
        """Bucket-resolved quantile: the upper bound of the bucket holding
        the q-th observation (0 when empty; the last finite bound for
        overflow mass) -- the standard Prometheus-style read-out, biased
        at most one bucket width."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return self.bounds[i]
        return self.bounds[-1]


class MetricsRegistry:
    """Process-local metric store.  Injectable (the service takes one);
    :func:`default_registry` is the shared fallback for module-level
    instrumentation (kernel dispatch counts, bootstrap replicates)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, dict[tuple, float]] = {}
        self._gauges: dict[str, dict[tuple, float]] = {}
        self._hists: dict[str, dict[tuple, Histogram]] = {}

    # -- mutators (each starts with the one-branch disabled check) ------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        if not self.enabled:
            return
        key = _labelkey(labels)
        with self._lock:
            fam = self._counters.setdefault(name, {})
            fam[key] = fam.get(key, 0.0) + value

    def set(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._gauges.setdefault(name, {})[_labelkey(labels)] = float(value)

    def set_max(self, name: str, value: float, **labels) -> None:
        """Gauge that only moves up: high-water marks (peak queue depth)."""
        if not self.enabled:
            return
        key = _labelkey(labels)
        with self._lock:
            fam = self._gauges.setdefault(name, {})
            fam[key] = max(fam.get(key, -math.inf), float(value))

    def observe(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        key = _labelkey(labels)
        with self._lock:
            fam = self._hists.setdefault(name, {})
            h = fam.get(key)
            if h is None:
                h = fam[key] = Histogram()
            h.observe(value)

    # -- readers (always live; a disabled registry just stays empty) ----
    def counter(self, name: str, **labels) -> float:
        return self._counters.get(name, {}).get(_labelkey(labels), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter family over all label sets."""
        return sum(self._counters.get(name, {}).values())

    def gauge(self, name: str, **labels) -> float | None:
        return self._gauges.get(name, {}).get(_labelkey(labels))

    def histogram(self, name: str, **labels) -> Histogram | None:
        return self._hists.get(name, {}).get(_labelkey(labels))

    def quantile(self, name: str, q: float, **labels) -> float:
        h = self.histogram(name, **labels)
        return h.quantile(q) if h is not None else 0.0

    def series(self, name: str) -> dict[tuple, float]:
        """Every (labelkey -> value) of a counter or gauge family."""
        if name in self._counters:
            return dict(self._counters[name])
        return dict(self._gauges.get(name, {}))

    def collect(self) -> dict:
        """Plain-dict snapshot: {family: {label-string: value}}; histograms
        flatten to count/sum/p50/p95/p99 (the benchmark emit format)."""
        out: dict = {}
        with self._lock:
            for name, fam in self._counters.items():
                out[name] = {_fmt_labels(k) or "_": v for k, v in fam.items()}
            for name, fam in self._gauges.items():
                out[name] = {_fmt_labels(k) or "_": v for k, v in fam.items()}
            for name, fam in self._hists.items():
                out[name] = {
                    _fmt_labels(k) or "_": {
                        "count": h.count, "sum": h.total,
                        "p50": h.quantile(0.50), "p95": h.quantile(0.95),
                        "p99": h.quantile(0.99)}
                    for k, h in fam.items()}
        return out

    def absorb(self, collected: dict, **labels) -> None:
        """Fold another registry's :meth:`collect` snapshot into this one
        (the coordinator's per-worker ``metrics_report`` aggregation,
        DESIGN.md §18).  ``labels`` are appended to every absorbed series
        (``worker="2"``), so re-absorbing a newer snapshot from the same
        source *overwrites* rather than double-counts: every absorbed
        value lands as a gauge (scrape semantics -- the worker's counters
        stay cumulative on the worker).  Flattened histograms land as
        ``name:count/sum/p50/p95/p99`` gauges."""
        if not self.enabled:
            return
        extra = _labelkey(labels)
        with self._lock:
            for name, fam in collected.items():
                if not isinstance(fam, dict):
                    continue
                for labelstr, value in fam.items():
                    key = tuple(sorted(_parse_labels(labelstr) + extra))
                    if isinstance(value, dict):     # flattened histogram
                        for stat, v in value.items():
                            self._gauges.setdefault(
                                f"{name}:{stat}", {})[key] = float(v)
                    else:
                        self._gauges.setdefault(name, {})[key] = float(value)

    def to_prometheus(self) -> str:
        """The Prometheus text exposition format (counters get a _total
        suffix if they lack one; histograms emit cumulative _bucket /
        _sum / _count series)."""
        lines: list[str] = []
        with self._lock:
            for name in sorted(self._counters):
                lines.append(f"# TYPE {name} counter")
                for key, v in sorted(self._counters[name].items()):
                    lines.append(f"{name}{_fmt_labels(key)} {v:g}")
            for name in sorted(self._gauges):
                lines.append(f"# TYPE {name} gauge")
                for key, v in sorted(self._gauges[name].items()):
                    lines.append(f"{name}{_fmt_labels(key)} {v:g}")
            for name in sorted(self._hists):
                lines.append(f"# TYPE {name} histogram")
                for key, h in sorted(self._hists[name].items()):
                    cum = 0
                    for bound, c in zip(h.bounds, h.counts):
                        cum += c
                        lk = _fmt_labels(key + (("le", f"{bound:g}"),))
                        lines.append(f"{name}_bucket{lk} {cum}")
                    lk = _fmt_labels(key + (("le", "+Inf"),))
                    lines.append(f"{name}_bucket{lk} {h.count}")
                    lines.append(f"{name}_sum{_fmt_labels(key)} {h.total:g}")
                    lines.append(f"{name}_count{_fmt_labels(key)} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_DEFAULT = MetricsRegistry(enabled=True)
NULL_REGISTRY = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    """The process-global registry (kernel/estimator instrumentation and
    the service's default sink)."""
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the previous."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, reg
    return prev
