"""Nested spans with wall *and* device time, JSON-lines trace events, and
optional XLA profile annotation (DESIGN.md §15).

The failure mode this module exists for: jax dispatch is asynchronous, so
``t1 - t0`` around a jit'd call times the *enqueue*, not the compute --
exactly the bug that made ``EstimationService.stats["flush_s"]`` report
near-zero.  A :class:`Span` records two durations:

  ``dispatch_s``   t(body exit) - t(enter): host time to build and
                   enqueue the work (plus any synchronous host compute)
  ``total_s``      the same interval measured after
                   ``jax.block_until_ready`` on every array the body
                   registered via :meth:`Span.sync` -- device-inclusive
                   time, the number a latency SLO is about

so dispatch vs compute is never conflated again: a span whose body does
no device work has ``total_s == dispatch_s``; a span closing over a jit'd
launch shows the gap explicitly.

Spans nest (a thread-local stack); each close emits one JSON-lines event
``{"name", "path", "ts", "dispatch_ms", "total_ms", "depth", ...attrs}``
to the configured sink (a path or file-like) and into a bounded
in-memory ring (:attr:`Tracer.events`) for tests and examples.  With
``annotate=True`` every span body additionally runs inside
``jax.profiler.TraceAnnotation(path)``, so service stages appear as
named regions in XLA device profiles.

Spans observe their ``total_s`` into a :class:`MetricsRegistry` latency
histogram when given one (``histogram=``), which is how every
``*_seconds`` histogram in the service carries device-time semantics.

Disabled tracers hand out one shared no-op span -- no allocation, no
clock reads -- honoring the obs-off overhead contract.
"""
from __future__ import annotations

import collections
import json
import threading
import time

from .metrics import MetricsRegistry

_EVENT_RING = 1024           # in-memory events kept per tracer


class Span:
    """One timed region.  Use via ``Tracer.span`` (context manager)."""

    __slots__ = ("name", "path", "attrs", "_tracer", "_registry",
                 "_histogram", "_labels", "_sync", "_t0", "_ts",
                 "dispatch_s", "total_s", "_annotation")

    def __init__(self, tracer: "Tracer", registry: MetricsRegistry,
                 name: str, path: str, histogram: str | None, labels: dict,
                 attrs: dict):
        self.name = name
        self.path = path
        self.attrs = attrs
        self._tracer = tracer
        self._registry = registry
        self._histogram = histogram
        self._labels = labels
        self._sync: list = []
        self._annotation = None

    def sync(self, *arrays) -> None:
        """Register jax outputs to ``block_until_ready`` before the clock
        stops: the span's ``total_s`` then covers their device compute
        (pytrees welcome; None leaves are ignored)."""
        self._sync.extend(a for a in arrays if a is not None)

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    # -- context manager ------------------------------------------------
    def __enter__(self):
        self._tracer._stack().append(self.name)
        if self._tracer.annotate:
            import jax
            self._annotation = jax.profiler.TraceAnnotation(self.path)
            self._annotation.__enter__()
        self._ts = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dispatch_s = time.perf_counter() - self._t0
        if self._sync and exc_type is None:
            import jax
            jax.block_until_ready(self._sync)
        self.total_s = time.perf_counter() - self._t0
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        stack = self._tracer._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        if exc_type is None:
            self._tracer._emit(self)
            if self._histogram:
                self._registry.observe(
                    self._histogram, self.total_s, **self._labels)
        return False


class _NullSpan:
    """Shared do-nothing span for disabled tracers."""

    dispatch_s = 0.0
    total_s = 0.0
    attrs: dict = {}

    def sync(self, *arrays) -> None:
        pass

    def set(self, **attrs) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + JSON-lines event sink.

    ``sink`` is a filesystem path (opened append, line-buffered on first
    event) or any object with ``write``.  ``registry`` receives the
    ``histogram=`` observations of spans (defaults to a throwaway
    disabled registry; the service injects its own)."""

    def __init__(self, *, sink=None, enabled: bool = True,
                 annotate: bool = False,
                 registry: MetricsRegistry | None = None):
        self.enabled = enabled
        self.annotate = annotate
        self.registry = registry if registry is not None else \
            MetricsRegistry(enabled=False)
        self.events: collections.deque = collections.deque(maxlen=_EVENT_RING)
        self._sink_path = sink if isinstance(sink, str) else None
        self._sink = sink if (sink is not None
                              and not isinstance(sink, str)) else None
        self._local = threading.local()
        self._lock = threading.Lock()

    def _stack(self) -> list:
        if not hasattr(self._local, "stack"):
            self._local.stack = []
        return self._local.stack

    def span(self, name: str, *, histogram: str | None = None,
             labels: dict | None = None,
             registry: MetricsRegistry | None = None, **attrs):
        """Open a nested span.  ``histogram``/``labels`` route the span's
        device-inclusive duration into ``registry`` (default: the
        tracer's own); ``attrs`` ride the trace event verbatim."""
        if not self.enabled:
            return NULL_SPAN
        path = "/".join(self._stack() + [name])
        return Span(self, registry if registry is not None else self.registry,
                    name, path, histogram, labels or {}, attrs)

    def _emit(self, span: Span) -> None:
        event = {"name": span.name, "path": span.path,
                 "ts": round(span._ts, 6),
                 "dispatch_ms": round(1e3 * span.dispatch_s, 4),
                 "total_ms": round(1e3 * span.total_s, 4),
                 "depth": span.path.count("/")}
        event.update(span.attrs)
        self.events.append(event)
        with self._lock:
            if self._sink is None and self._sink_path is not None:
                self._sink = open(self._sink_path, "a", buffering=1)
            if self._sink is not None:
                self._sink.write(json.dumps(event, default=str) + "\n")

    def close(self) -> None:
        with self._lock:
            if self._sink is not None and self._sink_path is not None:
                self._sink.close()
                self._sink = None


NULL_TRACER = Tracer(enabled=False)
_DEFAULT = Tracer()


def default_tracer() -> Tracer:
    return _DEFAULT


def set_default_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-global tracer (tests); returns the previous."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, tracer
    return prev
