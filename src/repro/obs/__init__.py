"""repro.obs -- structured observability for the estimation service
(DESIGN.md §15).

Three parts, composable and individually injectable:

  metrics.py   labeled counters / gauges / fixed-bucket latency
               histograms in a :class:`MetricsRegistry`; process-global
               default + Prometheus text / plain-dict export
  trace.py     nested :class:`Tracer` spans with wall *and* device time
               (``Span.sync`` blocks on registered jax outputs before the
               clock stops), JSON-lines events, optional
               ``jax.profiler.TraceAnnotation`` bracketing
  accuracy.py  :class:`AccuracyAuditor` -- opt-in sampled replay of
               queried windows through ``core/exact.py``, serving live
               rel-err and CI-coverage counters per estimator kind

:class:`Observability` bundles a registry + tracer (+ optional auditor)
for the service layers; ``Observability.disabled()`` is the shared no-op
bundle honoring the near-zero-overhead-when-off contract.
"""
from __future__ import annotations

import dataclasses

from .accuracy import AccuracyAuditor
from .metrics import (DEFAULT_BUCKETS, Histogram, MetricsRegistry,
                      NULL_REGISTRY, default_registry, set_default_registry)
from .trace import (NULL_SPAN, NULL_TRACER, Span, Tracer, default_tracer,
                    set_default_tracer)


@dataclasses.dataclass
class Observability:
    """The bundle the service threads through its layers."""

    metrics: MetricsRegistry
    tracer: Tracer
    auditor: AccuracyAuditor | None = None

    def span(self, name: str, *, histogram: str | None = None,
             labels: dict | None = None, **attrs):
        """A tracer span whose ``histogram=`` observation lands in THIS
        bundle's registry (device-time semantics, see trace.Span)."""
        return self.tracer.span(name, histogram=histogram, labels=labels,
                                registry=self.metrics, **attrs)

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled or self.tracer.enabled

    @classmethod
    def default(cls) -> "Observability":
        return cls(metrics=default_registry(), tracer=default_tracer())

    @classmethod
    def disabled(cls) -> "Observability":
        return _DISABLED


_DISABLED = Observability(metrics=NULL_REGISTRY, tracer=NULL_TRACER)

__all__ = [
    "AccuracyAuditor", "DEFAULT_BUCKETS", "Histogram", "MetricsRegistry",
    "NULL_REGISTRY", "NULL_SPAN", "NULL_TRACER", "Observability", "Span",
    "Tracer", "default_registry", "default_tracer", "set_default_registry",
    "set_default_tracer",
]
