"""Version compatibility shims for the jax API surface.

The repo targets the jax that ships in the container (0.4.x today) while
using the modern spellings where they exist:

- ``shard_map``: ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
  (0.4.x), and the replication-check kwarg renamed check_rep -> check_vma.
- ``set_mesh``: ``jax.set_mesh`` / ``jax.sharding.use_mesh`` context manager;
  on 0.4.x the ``Mesh`` object is itself the context manager that installs
  the ambient mesh ``with_sharding_constraint`` resolves bare
  ``PartitionSpec``s against.

Keep this module dependency-free (stdlib + jax only) -- it is imported by
optim, launch, and service.
"""
from __future__ import annotations

import contextlib
import functools

import jax

try:                                        # jax >= 0.5 style
    _shard_map = jax.shard_map              # type: ignore[attr-defined]
    _CHECK_KWARG = "check_vma"
except AttributeError:                      # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"


@functools.wraps(_shard_map)
def shard_map(f=None, /, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg normalized.

    Accepts either ``check_vma`` or ``check_rep`` and forwards whichever
    name the installed jax understands.  Usable directly or via
    ``functools.partial`` exactly like the real function.
    """
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None:
        kwargs[_CHECK_KWARG] = check
    if f is None:
        return functools.partial(shard_map, **kwargs)
    return _shard_map(f, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh when the
    installed jax supports one; a no-op context otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)           # type: ignore[attr-defined]
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    if hasattr(mesh, "__enter__"):          # jax 0.4.x: Mesh is a context mgr
        return mesh
    return contextlib.nullcontext(mesh)
