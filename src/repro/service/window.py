"""Sliding-window estimator state: per-epoch ring with expiry.

Generalized over the :class:`repro.estimators.Estimator` protocol.  Two
window strategies, chosen by the kind's declarative spec
(``EstimatorSpec.linear``, DESIGN.md §19):

**Linear estimators** (SJPC): expiry-by-subtraction, exactly the PR 1
design.  Keep the cumulative state of the live window (``total``) plus
per-epoch *delta* states in a ring of ``window_epochs`` slots (stacked
pytree leaves); when an epoch rotates past the window edge its delta is
subtracted from ``total`` and the slot is recycled.  Queries read
``total`` directly.  Invariants (asserted in tests/test_service.py):

  W1  total == sum of the live ring slots, bit-exactly, at all times.
  W2  after any number of rotations, total == a fresh sketch built from
      only the live epochs' batches (same per-batch keys) -- expiry by
      subtraction is exact, not approximate.
  W3  total.n >= 0 and (clamp=True) estimates stay non-negative.

**Sample estimators** (reservoir, lsh_ss): a uniform sample cannot be
"un-sampled" by arithmetic, so each epoch is sketched into its own ring
slot (states init'd with ``sid = epoch`` for provenance) and ``total`` is
the estimator's merge-fold over the live slots, recomputed when an epoch
expires.  Ingest targets the *open slot* (see :meth:`ingest_base`), and a
commit that changes it refreshes the fold -- O(window) merges per flush,
far off the per-record hot path.  Expired epochs drop whole slots, so
expiry is exact in n and provenance; the honest streaming cost is that a
merged sample cannot refill slots from data it never kept.

``backing_epochs = K`` (sample windows only) bounds the fold's
compression loss: the W live slots together retain up to W x capacity
records, but the plain fold shrinks them to ONE base-capacity total --
after every expiry the served sample is 1/W of what the window actually
kept.  With K backing epochs each sample structure folds at capacity
cap + K * cap//2 (K half-capacity backing slots), so ``_refold`` refills
the total from kept per-epoch data instead of discarding it; the
effective sample size a query sees grows by the same factor, and the
bootstrap error bars of DESIGN.md §14 shrink accordingly.

``window_epochs=None`` means an unbounded (whole-stream) window for
either strategy -- no ring, nothing expires, ingest goes straight into
``total``.

The open (current) epoch accumulates at ring position ``pos``;
``advance_epoch`` closes it.  ``version`` bumps whenever ``total``
changes (ingest commits; rotations that expire data) and is the query
engine's cache key -- a rotation that leaves ``total`` untouched must not
invalidate standing-query caches.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.estimators import Estimator, index_state, spec_of
from repro.obs import Observability


class WindowedSketch:
    """Mutable host-side wrapper around the (device-resident) window state.

    All arrays stay jnp; mutation here is per-epoch bookkeeping, far off
    the ingest hot path (which batches through service.ingest -> one jit'd
    multi-stream dispatch per estimator cohort and then calls
    :meth:`absorb_delta` once)."""

    def __init__(self, estimator: Estimator, init_state,
                 window_epochs: int | None = None,
                 backing_epochs: int = 0,
                 obs: Observability | None = None, name: str = ""):
        assert window_epochs is None or window_epochs >= 1
        self.obs = obs if obs is not None else Observability.disabled()
        self.name = name                     # metric label (stream name)
        self.estimator = estimator
        # the kind's declarative spec (DESIGN.md §19) drives the window
        # strategy: ``spec.linear`` picks delta-ring vs slot-fold, and
        # ``spec.wire_mode`` the distributed delta mode
        self.spec = spec_of(estimator)
        self.cfg = getattr(estimator, "cfg", None)
        self.window_epochs = window_epochs
        self.backing_epochs = int(backing_epochs)
        if self.backing_epochs:
            if self.spec.linear:
                raise ValueError(
                    "backing_epochs is a sample-window refill; linear "
                    f"estimators ({estimator.kind!r}) expire exactly by "
                    "subtraction and have nothing to refill")
            if window_epochs is None:
                raise ValueError(
                    "backing_epochs needs a bounded window (unbounded "
                    "sample windows never expire, so never shrink)")
        self.total = init_state
        self.epoch = 0                      # index of the open epoch
        self.version = 0                    # bumped whenever ``total`` changes
        # delta-export bookkeeping (distributed/, DESIGN.md §18): the
        # version last shipped and, for linear windows, the open epoch's
        # content at that point (the shipped baseline the next delta is
        # taken against).  Reset on advance_epoch -- exports are
        # per-open-epoch, never cumulative, because expiry subtraction
        # runs independently (and identically) on worker and replica rings
        self._shipped_version = 0
        self._shipped_base = None
        if window_epochs is None:
            return
        if self.spec.linear:
            # ring of per-epoch DELTA states, stacked pytree leaves
            self._ring = jax.tree_util.tree_map(
                lambda x: jnp.zeros((window_epochs,) + tuple(jnp.shape(x)),
                                    x.dtype), init_state)
        else:
            # ring of per-epoch STATES; slot sid = epoch for provenance
            self._slots: list = [None] * window_epochs
            self._slots[0] = init_state
            if self.backing_epochs:
                # ``total`` folds at expanded capacity from version 0 so
                # its pytree shape never changes across rotations
                self._refold()
        self._pos = 0                       # slot of the open epoch
        self._live = 1                      # live epochs incl. the open one

    # ------------------------------------------------------------------
    def ingest_base(self):
        """The state the ingest pipeline should update: the cumulative
        window for linear estimators (and unbounded windows), the open
        epoch's own state for windowed sample estimators."""
        if self.window_epochs is not None and not self.spec.linear:
            return self._slots[self._pos]
        return self.total

    def absorb_delta(self, new_state) -> None:
        """Commit the post-ingest state for :meth:`ingest_base`.  Linear:
        the delta vs the previous total is credited to the open epoch's
        ring slot.  Sample: the open slot is replaced and the live-window
        fold refreshed."""
        base = self.ingest_base()
        new_leaves = jax.tree_util.tree_leaves(new_state)
        base_leaves = jax.tree_util.tree_leaves(base)
        if new_state is base or (
                len(new_leaves) == len(base_leaves)
                and all(a is b for a, b in zip(new_leaves, base_leaves))):
            # no-op flush: nothing changed, keep the version.  The leaf
            # check hardens the identity test against pipelines that
            # re-wrap unchanged leaves in a new pytree container -- an
            # equal-but-new state must not thrash version-keyed caches
            return
        self.version += 1
        if self.window_epochs is None or self.spec.linear:
            if self.window_epochs is not None:
                delta = self.estimator.subtract(new_state, self.total)
                self._ring = jax.tree_util.tree_map(
                    lambda ring, d: ring.at[self._pos].add(d),
                    self._ring, delta)
            self.total = new_state
        else:
            self._slots[self._pos] = new_state
            self._refold()

    def _refold(self) -> None:
        """total = merge-fold of the live ring slots (sample windows).

        With ``backing_epochs = K`` the fold runs at *expanded* capacity
        (each sample structure gains K half-capacity backing slots, see
        ``Estimator.refill_capacity``): instead of compressing the W kept
        per-epoch samples down to one base-capacity state, the refold
        refills the expanded total from the data the slots kept -- so an
        expiry no longer shrinks the served sample to 1/W of what the
        window retains (DESIGN.md §14.2)."""
        self.obs.metrics.inc("window_refolds_total", stream=self.name,
                             refill=str(bool(self.backing_epochs)))
        live = [s for s in self._slots if s is not None]
        K = self.backing_epochs
        if K and len(live) == 1:
            # singleton fold still expands (stable total shape): merge
            # with an empty state of the same kind
            live = live + [self.estimator.init(sid=0)]
        total = live[0]
        for s in live[1:]:
            total = (self.estimator.merge(total, s, backing=K) if K
                     else self.estimator.merge(total, s))
        self.total = total

    # -- delta export (the multi-host protocol, DESIGN.md §18) ----------
    def export_delta(self):
        """What a worker ships for this stream since its last export:
        ``None`` when nothing changed (the caller sends the zero-byte
        heartbeat), else ``(mode, state)``:

        * linear windows -> ``("merge", delta)``: the leaf-wise difference
          of the open epoch's accumulated state against the shipped
          baseline (raw counter arrays; the replica applies it through the
          estimator's merge, crediting its own open ring slot);
        * sample windows -> ``("replace", state)``: the open slot's full
          state (provenance tags included) -- a uniform sample has no
          arithmetic delta, so the replica replaces its slot and refolds.

        Epoch alignment is the caller's contract: the coordinator exports
        from every worker BEFORE broadcasting advance_epoch, so a slot is
        fully mirrored when it closes (advance_epoch resets the baseline).
        """
        if self.version == self._shipped_version:
            return None
        self._shipped_version = self.version
        if not self.spec.linear:
            return (self.spec.wire_mode, self.ingest_base())
        acc = (self.total if self.window_epochs is None
               else index_state(self._ring, self._pos))
        base = self._shipped_base
        self._shipped_base = acc
        delta = acc if base is None else jax.tree_util.tree_map(
            lambda a, b: jnp.asarray(a) - jnp.asarray(b), acc, base)
        if "step" in getattr(delta, "_fields", ()):
            # ``step`` is worker-local PRNG history (fold-in position), not
            # window data; a replica never ingests records, so it has no
            # PRNG position to advance.  Shipping zero keeps the replica a
            # pure data mirror: counters and n bit-equal, step pinned at 0
            delta = delta._replace(step=jnp.zeros_like(delta.step))
        return ("merge", delta)

    def advance_epoch(self) -> None:
        """Close the open epoch.  If the ring is full, the oldest epoch
        expires: subtracted from ``total`` (linear) or dropped from the
        fold (sample)."""
        self.epoch += 1
        if self.window_epochs is None:
            return
        self._pos = (self._pos + 1) % self.window_epochs
        expiring = self._live >= self.window_epochs
        if not expiring:
            self._live += 1
        with self.obs.span("window.rotate",
                           histogram="window_rotate_seconds",
                           labels={"stream": self.name},
                           stream=self.name, expiring=expiring) as sp:
            if self.spec.linear:
                if expiring:
                    # the slot we are about to reuse holds the expiring
                    # epoch; version bumps only here -- a rotation that
                    # leaves ``total`` untouched must not invalidate
                    # version-keyed query caches
                    expired = self._with_total_step(
                        index_state(self._ring, self._pos))
                    self.total = self.estimator.subtract(self.total, expired)
                    self.version += 1
                self._ring = jax.tree_util.tree_map(
                    lambda ring: ring.at[self._pos].set(
                        jnp.zeros_like(ring[self._pos])), self._ring)
            else:
                self._slots[self._pos] = self.estimator.init(sid=self.epoch)
                if expiring:
                    self._refold()
                    self.version += 1
            sp.sync(*jax.tree_util.tree_leaves(self.total))
        # re-arm the export baseline for the new open epoch.  Rotation is
        # driven in lockstep by the coordinator (export-before-advance),
        # so the version bump an expiry causes must not read as "new data
        # to ship" -- an idle worker stays heartbeat-only across
        # rotations.  Unbounded windows never take this path: their
        # exports stay cumulative against the standing baseline.
        self._shipped_base = None
        self._shipped_version = self.version
        m = self.obs.metrics
        if m.enabled:
            m.inc("window_rotations_total", stream=self.name)
            if expiring:
                m.inc("window_expirations_total", stream=self.name)
            self._export_gauges()

    def _export_gauges(self) -> None:
        """Refresh the per-stream window gauges (live ring slots, version,
        refill depth) -- called on rotation and by metrics_report()."""
        m = self.obs.metrics
        if not m.enabled:
            return
        m.set("window_live_epochs", self.live_epochs, stream=self.name)
        m.set("window_version", self.version, stream=self.name)
        if self.backing_epochs:
            m.set("window_backing_epochs", self.backing_epochs,
                  stream=self.name)

    def _with_total_step(self, state):
        """Epoch deltas carry no meaningful PRNG position: expiry removes
        old *data*, not PRNG history (see sjpc.subtract), so reconstructed
        ring states borrow the cumulative state's step."""
        if "step" not in getattr(state, "_fields", ()):
            return state
        return state._replace(step=self.total.step)

    # ------------------------------------------------------------------
    def window_state(self):
        """The state of exactly the live window (linear W1: == ring sum)."""
        return self.total

    def n_live(self) -> float:
        """Host-side record count of the live window, cached per version so
        snapshot construction does not pay one device_get per stream."""
        if getattr(self, "_n_cache_version", None) != self.version:
            self._n_cache = float(np.asarray(self.total.n))
            self._n_cache_version = self.version
        return self._n_cache

    @property
    def live_epochs(self) -> int:
        return self._live if self.window_epochs is not None else self.epoch + 1

    def ring_sum(self):
        """Recompute total from the ring (diagnostics / invariant W1;
        linear estimators only -- sample windows fold via merge)."""
        assert self.window_epochs is not None, "unbounded window has no ring"
        assert self.spec.linear, "sample windows have no delta ring"
        return self._with_total_step(
            jax.tree_util.tree_map(lambda x: x.sum(axis=0), self._ring))

    def memory_bytes(self) -> int:
        base = self.estimator.memory_bytes()
        if self.window_epochs is None:
            return base
        # backing-epoch refill: the expanded total carries K extra
        # half-capacity backing slots per sample structure
        return (base * (1 + self.window_epochs)
                + self.backing_epochs * (base // 2))
