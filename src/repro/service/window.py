"""Sliding-window sketch state: a ring buffer of per-epoch deltas.

The SJPC sketch is linear, so time-windowed semantics cost one subtraction:
keep the cumulative counters of the live window (``total``) plus the
per-epoch *deltas* in a ring of ``window_epochs`` slots; when an epoch
rotates past the window edge its delta is subtracted from ``total`` and the
slot is recycled.  Space overhead is O(window/epoch) sketch copies; queries
read ``total`` directly -- no per-query summation over epochs.

Invariants (asserted in tests/test_service.py):

  W1  total == sum of the live ring slots, bit-exactly, at all times.
  W2  after any number of rotations, total == a fresh sketch built from
      only the live epochs' batches (same per-batch keys) -- expiry by
      subtraction is exact, not approximate.
  W3  total.n >= 0 and (clamp=True) estimates stay non-negative.

The open (current) epoch accumulates in slot ``pos``; ``advance_epoch``
closes it.  ``window_epochs=None`` means an unbounded (whole-stream) window
-- no ring is kept and nothing ever expires, which degenerates to the
original whole-stream monitor semantics.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCState


class WindowedSketch:
    """Mutable host-side wrapper around the (device-resident) window state.

    All arrays stay jnp; mutation here is per-epoch bookkeeping, far off the
    ingest hot path (which batches through service.ingest -> one jit'd
    multi-stream dispatch and then calls :meth:`absorb_delta` once).
    """

    def __init__(self, cfg: SJPCConfig, init_state: SJPCState,
                 window_epochs: int | None = None):
        assert window_epochs is None or window_epochs >= 1
        self.cfg = cfg
        self.window_epochs = window_epochs
        self.total = init_state
        self.epoch = 0                      # index of the open epoch
        self.version = 0                    # bumped whenever ``total`` changes
        if window_epochs is not None:
            shape = (window_epochs,) + tuple(init_state.counters.shape)
            self._ring_counters = jnp.zeros(shape, jnp.int32)
            self._ring_n = jnp.zeros((window_epochs,), jnp.float32)
            self._pos = 0                   # slot of the open epoch
            self._live = 1                  # live epochs incl. the open one

    # ------------------------------------------------------------------
    def absorb_delta(self, new_state: SJPCState) -> None:
        """Commit the post-ingest cumulative state; the delta vs the previous
        total is credited to the open epoch's ring slot."""
        if new_state is self.total:
            return          # no-op flush: nothing changed, keep the version
        self.version += 1
        if self.window_epochs is not None:
            d_counters = new_state.counters - self.total.counters
            d_n = new_state.n - self.total.n
            self._ring_counters = self._ring_counters.at[self._pos].add(d_counters)
            self._ring_n = self._ring_n.at[self._pos].add(d_n)
        self.total = new_state

    def advance_epoch(self) -> None:
        """Close the open epoch.  If the ring is full, the oldest epoch's
        delta is subtracted from ``total`` (expiry-by-subtraction)."""
        self.epoch += 1
        if self.window_epochs is None:
            return
        self._pos = (self._pos + 1) % self.window_epochs
        if self._live < self.window_epochs:
            self._live += 1
        else:
            # the slot we are about to reuse holds the expiring epoch;
            # version bumps only here -- a rotation that leaves ``total``
            # untouched must not invalidate version-keyed query caches
            expired = SJPCState(counters=self._ring_counters[self._pos],
                                n=self._ring_n[self._pos],
                                step=self.total.step)
            self.total = sjpc.subtract(self.total, expired)
            self.version += 1
        self._ring_counters = self._ring_counters.at[self._pos].set(0)
        self._ring_n = self._ring_n.at[self._pos].set(0.0)

    # ------------------------------------------------------------------
    def window_state(self) -> SJPCState:
        """The SJPC state of exactly the live window (W1: == ring sum)."""
        return self.total

    def n_live(self) -> float:
        """Host-side record count of the live window, cached per version so
        snapshot construction does not pay one device_get per stream."""
        if getattr(self, "_n_cache_version", None) != self.version:
            self._n_cache = float(np.asarray(self.total.n))
            self._n_cache_version = self.version
        return self._n_cache

    @property
    def live_epochs(self) -> int:
        return self._live if self.window_epochs is not None else self.epoch + 1

    def ring_sum(self) -> SJPCState:
        """Recompute total from the ring (diagnostics / invariant W1)."""
        assert self.window_epochs is not None, "unbounded window has no ring"
        return SJPCState(counters=self._ring_counters.sum(axis=0),
                         n=self._ring_n.sum(),
                         step=self.total.step)

    def memory_bytes(self) -> int:
        base = self.cfg.counters_bytes
        if self.window_epochs is None:
            return base
        return base * (1 + self.window_epochs)
