"""Query engine: self-join / join / all-thresholds estimates from a snapshot.

Queries never touch live ingest state: the engine materializes a
:class:`Snapshot` -- each stream's windowed ``SJPCState`` pulled at one
instant -- and answers any number of queries from it.  That is what makes
*batched continuous queries* cheap: the expensive parts (device->host
counter pull, the int64-exact level F2 pass) are computed once per stream
per snapshot and memoized; every additional query against the same snapshot
is a lattice inversion over d-s+1 numbers.

Error bars come from the paper's analytical bounds: Theorem 1 (projection
sampling alone) and Theorem 2 (sampling + sketching, width w) bound
var(G_s / g_s), so ``sqrt(bound)`` is a relative standard-deviation bound.
The true g_s is unknown at query time, so the estimate is plugged in --
standard practice, conservative when the estimate is low, and reported as
an explicit ``stderr`` field rather than silently folded in.  For join
queries the self-join bound with n = max(n_a, n_b) is used as a proxy (the
paper proves no join-specific bound; DESIGN.md §10.4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import numpy as np

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCState

from .registry import StreamRegistry


class QueryResult(NamedTuple):
    kind: str                  # "self_join" | "join" | "all_thresholds"
    streams: tuple             # 1 or 2 stream names
    s: int                     # threshold the estimate answers
    estimate: float            # g_s (self-join) or join size
    stderr: float              # absolute 1-sigma bound (online, Theorem 2)
    stderr_offline: float      # absolute 1-sigma bound (sampling only, Thm 1)
    per_level: np.ndarray      # X_k for k = s..d
    n: tuple                   # records in the window, per stream
    window_epochs: tuple       # live epochs per stream (coverage metadata)


def _stderr(cfg: SJPCConfig, s: int, n: float, g: float) -> tuple[float, float]:
    """(online, offline) absolute 1-sigma bounds at plug-in g."""
    if g <= 0:
        return 0.0, 0.0
    off = math.sqrt(sjpc.offline_variance_bound(cfg.d, s, cfg.ratio, g)) * g
    on = math.sqrt(sjpc.online_variance_bound(
        cfg.d, s, cfg.ratio, cfg.width, n, g)) * g
    return on, off


@dataclasses.dataclass(frozen=True)
class _StreamView:
    name: str
    cfg: SJPCConfig
    state: SJPCState
    n: float
    live_epochs: int
    window_epochs: int | None


class Snapshot:
    """Immutable view of every stream's window at one instant."""

    def __init__(self, views: dict[str, _StreamView],
                 registry: StreamRegistry):
        self._views = views
        self._registry = registry
        self._f2_cache: dict[str, np.ndarray] = {}

    def _view(self, name: str) -> _StreamView:
        if name not in self._views:
            raise KeyError(f"stream {name!r} not in snapshot")
        return self._views[name]

    def _level_f2(self, name: str) -> np.ndarray:
        if name not in self._f2_cache:
            self._f2_cache[name] = sjpc.level_f2(self._view(name).state)
        return self._f2_cache[name]

    # ------------------------------------------------------------------
    def self_join(self, name: str, s: int | None = None, *,
                  clamp: bool = True) -> QueryResult:
        """Windowed g_s for ``name`` (s defaults to, and must be >=, cfg.s)."""
        v = self._view(name)
        s = v.cfg.s if s is None else s
        if not v.cfg.s <= s <= v.cfg.d:
            raise ValueError(f"s={s} outside sketched range "
                             f"[{v.cfg.s}, {v.cfg.d}] of {name!r}")
        y = self._level_f2(name)
        x = sjpc.f2_to_pair_count(v.cfg.d, v.cfg.s, v.n, v.cfg.ratio, y,
                                  clamp=clamp)
        xs = x[s - v.cfg.s:]
        g = float(xs.sum()) + v.n
        on, off = _stderr(v.cfg, s, v.n, g)
        return QueryResult("self_join", (name,), s, g, on, off, xs,
                           (v.n,), (v.live_epochs,))

    def join(self, a: str, b: str, s: int | None = None, *,
             clamp: bool = True) -> QueryResult:
        """Windowed similarity-join size of two same-group streams (§6)."""
        self._registry.require_joinable(a, b)
        va, vb = self._view(a), self._view(b)
        cfg = va.cfg
        s = cfg.s if s is None else s
        if not cfg.s <= s <= cfg.d:
            raise ValueError(f"s={s} outside sketched range [{cfg.s}, {cfg.d}]")
        y = sjpc.join_level_inner(va.state, vb.state)
        x = sjpc.inner_to_join_count(cfg.d, cfg.s, cfg.ratio, y, clamp=clamp)
        xs = x[s - cfg.s:]
        j = float(xs.sum())
        on, off = _stderr(cfg, s, max(va.n, vb.n), max(j, 1.0))
        return QueryResult("join", (a, b), s, j, on, off, xs,
                           (va.n, vb.n), (va.live_epochs, vb.live_epochs))

    def all_thresholds(self, name: str, *, clamp: bool = True) -> dict[int, QueryResult]:
        """g_k for every k in [cfg.s, d] -- one inversion, d-s+1 results."""
        v = self._view(name)
        return {k: self.self_join(name, k, clamp=clamp)
                for k in range(v.cfg.s, v.cfg.d + 1)}

    def streams(self) -> list[str]:
        return list(self._views)


@dataclasses.dataclass(frozen=True)
class ContinuousQuery:
    """A standing query evaluated against each snapshot (``service.poll``)."""
    name: str
    kind: str                       # "self_join" | "join" | "all_thresholds"
    streams: tuple                  # (a,) or (a, b)
    s: int | None = None

    def evaluate(self, snap: Snapshot):
        if self.kind == "self_join":
            return snap.self_join(self.streams[0], self.s)
        if self.kind == "join":
            return snap.join(self.streams[0], self.streams[1], self.s)
        if self.kind == "all_thresholds":
            return snap.all_thresholds(self.streams[0])
        raise ValueError(f"unknown query kind {self.kind!r}")


class QueryEngine:
    def __init__(self, registry: StreamRegistry):
        self._registry = registry

    def snapshot(self, names: list[str] | None = None) -> Snapshot:
        entries = (self._registry.streams() if names is None
                   else [self._registry.stream(n) for n in names])
        views = {}
        for e in entries:
            st = e.window.window_state()
            views[e.name] = _StreamView(
                name=e.name, cfg=self._registry.group(e.group_id).cfg,
                state=st, n=float(np.asarray(st.n)),
                live_epochs=e.window.live_epochs,
                window_epochs=e.window.window_epochs)
        return Snapshot(views, self._registry)
