"""Query engine: self-join / join / all-thresholds estimates from a snapshot.

Queries never touch live ingest state: the engine materializes a
:class:`Snapshot` -- each stream's windowed ``SJPCState`` pulled at one
instant -- and answers any number of queries from it.

The default query path is the **fused batched engine** (DESIGN.md §12):
all streams of a hash group are stacked into one (N, levels, t, w) counter
tensor and ``sjpc.estimate_batch`` answers every (stream, threshold) cell
-- level moments, depth medians, the Eq. 4 inversion, and the suffix-sum
g_k table -- from ONE compiled call (a Pallas launch on TPU, the fused jnp
reduction elsewhere).  Join queries batch the same way through
``sjpc.estimate_join_batch``; ``Snapshot.prefetch`` lets ``service.poll``
answer every registered join pair of a group in one additional call.  The
PR 1 per-stream numpy path (int64-exact F2 + float64 inversion per stream)
is kept verbatim behind ``use_fused_query=False`` as the conformance
oracle; tests/test_fused_query.py holds the two within 1e-6.

Results are memoized in a cache shared across snapshots of one
:class:`QueryEngine`, keyed by each stream's **window version** (bumped by
`WindowedSketch` on every ingest commit and epoch rotation) -- so standing
queries over an unchanged window are pure lookups, and a snapshot taken
across an expiry boundary can never be served a stale entry (the cache-key
regression test in tests/test_service.py pins this).

Error bars come from the paper's analytical bounds: Theorem 1 (projection
sampling alone) and Theorem 2 (sampling + sketching, width w) bound
var(G_s / g_s), so ``sqrt(bound)`` is a relative standard-deviation bound.
The true g_s is unknown at query time, so the estimate is plugged in --
standard practice, conservative when the estimate is low, and reported as
an explicit ``stderr`` field rather than silently folded in.  For join
queries the self-join bound with n = max(n_a, n_b) is used as a proxy (the
paper proves no join-specific bound; DESIGN.md §10.4).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import NamedTuple

import numpy as np
import jax

from repro.core.sjpc import SJPCConfig
from repro.estimators import Estimator, stack_states
from repro.obs import Observability

from .registry import StreamRegistry

_CACHE_MAX_ENTRIES = 4096      # shared-cache bound; LRU-evicted beyond


class QueryResult(NamedTuple):
    kind: str                  # "self_join" | "join" | "all_thresholds"
    streams: tuple             # 1 or 2 stream names
    s: int                     # threshold the estimate answers
    estimate: float            # g_s (self-join) or join size
    stderr: float              # absolute 1-sigma bound/estimate (online)
    stderr_offline: float      # absolute 1-sigma, sampling-only variant
    per_level: np.ndarray      # X_k for k = s..d
    n: tuple                   # records in the window, per stream
    window_epochs: tuple       # live epochs per stream (coverage metadata)
    stderr_kind: str = "none"  # uncertainty method behind stderr:
    #   "analytic" (Thm 1/2 bounds), "bootstrap", "bootstrap_stratified",
    #   or "none" (no bars available; stderr is 0)
    stale: bool = False        # True when admission control served the last
    #   cached result instead of fresh device work (DESIGN.md §16.3)

    def ci(self, z: float = 1.96) -> tuple:
        """The +/- z-sigma confidence interval, floored at 0 (both g_s
        and join sizes are non-negative counts).  The default z is the
        normal 95% quantile; for "analytic" kinds the bounds are
        conservative, so coverage is >= the nominal level."""
        return (max(self.estimate - z * self.stderr, 0.0),
                self.estimate + z * self.stderr)


@dataclasses.dataclass(frozen=True)
class _StreamView:
    name: str
    cfg: SJPCConfig            # the group's config (thresholds, join params)
    state: object              # the stream's windowed estimator state
    estimator: Estimator       # the stream's protocol engine
    kind: str                  # estimator kind (batch cohort key)
    n: float
    live_epochs: int
    window_epochs: int | None
    group_id: str
    version: int               # window version at snapshot time (cache key)
    shape_sig: tuple = ()      # state leaf shapes: same-estimator streams
    #   with different window geometry (backing-epoch refill expands the
    #   sample-window total) must batch in separate stacks


class Snapshot:
    """Immutable view of every stream's window at one instant.

    ``cache`` is shared across the owning engine's snapshots; every entry's
    key embeds the (name, version) pairs it was computed from, so entries
    survive exactly as long as the underlying windows are unchanged.
    """

    def __init__(self, views: dict[str, _StreamView],
                 registry: StreamRegistry, *,
                 use_fused_query: bool = True,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 cache: dict | None = None,
                 obs: Observability | None = None):
        self._views = views
        self._registry = registry
        self._use_fused = use_fused_query
        self._use_pallas = use_pallas
        self._interpret = interpret
        self._cache = {} if cache is None else cache
        self._local: dict = {}     # per-snapshot memo of shared-cache hits
        self._obs = obs if obs is not None else Observability.disabled()

    def _count_cache(self, hit: bool, group: str, kind: str, op: str) -> None:
        """Version-keyed cache accounting: a *miss* is a serve that had to
        recompute; everything else -- per-snapshot memo hits, shared-cache
        hits across snapshots, idle ride-along tenants whose versions kept
        a cohort key stable -- is a *hit*."""
        m = self._obs.metrics
        if m.enabled:
            m.inc("query_cache_hits_total" if hit
                  else "query_cache_misses_total",
                  group=group, kind=kind, op=op)

    def _view(self, name: str) -> _StreamView:
        if name not in self._views:
            raise KeyError(f"stream {name!r} not in snapshot")
        return self._views[name]

    def _cache_get(self, key):
        """Shared-cache read that refreshes LRU recency (the engine evicts
        least-recently-used entries, so every hit must count as use)."""
        cache = self._cache
        if isinstance(cache, collections.OrderedDict):
            cache.move_to_end(key)
        return cache[key]

    # -- fused batched path --------------------------------------------
    def _cohort_views(self, group_id: str, eid: int,
                      shape_sig: tuple) -> list[_StreamView]:
        # cohorts key on the estimator INSTANCE (id), not the kind: a
        # same-kind stream with an explicit estimator_cfg override has its
        # own engine (and possibly state shapes) and must batch separately.
        # The shape signature further splits same-engine streams whose
        # window geometry differs (a backing-epoch refill total is wider
        # than an unexpanded one; stacking them would shape-mismatch)
        return [v for v in self._views.values()
                if v.group_id == group_id and id(v.estimator) == eid
                and v.shape_sig == shape_sig]

    def _self_batch(self, view: _StreamView, clamp: bool):
        """The one batched call answering every (stream, threshold) cell of
        a hash group's estimator cohort; memoized by the member windows'
        versions (shared engine cache) and per-snapshot (versions are fixed
        within one snapshot, so repeated queries skip rebuilding the
        version key)."""
        group_id, eid = view.group_id, id(view.estimator)
        local_key = (group_id, eid, view.shape_sig, clamp)
        if local_key in self._local:
            self._count_cache(True, group_id, view.kind, "self")
            return self._local[local_key]
        views = self._cohort_views(group_id, eid, view.shape_sig)
        key = self._self_key(views, clamp)
        hit = key in self._cache
        self._count_cache(hit, group_id, views[0].kind, "self")
        if not hit:
            with self._obs.span("query.self_batch",
                                histogram="query_batch_seconds",
                                labels={"group": group_id,
                                        "kind": views[0].kind, "op": "self"},
                                group=group_id, kind=views[0].kind,
                                streams=len(views)) as sp:
                est = views[0].estimator.estimate_batch(
                    stack_states([v.state for v in views]), clamp=clamp,
                    use_pallas=self._use_pallas, interpret=self._interpret)
                sp.sync(*jax.tree_util.tree_leaves(est))
            self._cache[key] = ({v.name: i for i, v in enumerate(views)}, est)
        self._local[local_key] = self._cache_get(key)
        return self._local[local_key]

    @staticmethod
    def _self_key(views: list[_StreamView], clamp: bool) -> tuple:
        """The shared-cache key of one group cohort's batched self table."""
        return ("self", views[0].group_id, views[0].kind, clamp,
                tuple((v.name, v.version) for v in views))

    def fused_self_batch(self, cohorts: list[list[_StreamView]],
                         clamp: bool = True) -> int:
        """ONE ``estimate_batch`` launch answering several group cohorts at
        once (the planner's cross-group fusion, DESIGN.md §16.1).  Every
        cohort must share the fusion signature -- same estimator kind,
        derived config, and state shapes -- so their states stack along one
        stream axis; the result unstacks back into the per-cohort cache
        entries ``_self_batch`` reads, byte-for-byte the entries the
        unfused path would have written (row slices of one batch).
        """
        todo = [c for c in cohorts if self._self_key(c, clamp)
                not in self._cache]
        if not todo:
            return 0
        views = [v for c in todo for v in c]
        kind = views[0].kind
        for c in todo:           # the per-cohort miss the unfused path counts
            self._count_cache(False, c[0].group_id, kind, "self")
        gids = sorted({c[0].group_id for c in todo})
        with self._obs.span("query.self_batch",
                            histogram="query_batch_seconds",
                            labels={"group": "+".join(gids), "kind": kind,
                                    "op": "self"},
                            group="+".join(gids), kind=kind,
                            streams=len(views), cohorts=len(todo)) as sp:
            est = views[0].estimator.estimate_batch(
                stack_states([v.state for v in views]), clamp=clamp,
                use_pallas=self._use_pallas, interpret=self._interpret)
            sp.sync(*jax.tree_util.tree_leaves(est))
        lo = 0
        for c in todo:
            hi = lo + len(c)
            sub = type(est)(*(a[lo:hi] if isinstance(a, (np.ndarray,
                                                         jax.Array))
                              else a for a in est))
            self._cache[self._self_key(c, clamp)] = (
                {v.name: i for i, v in enumerate(c)}, sub)
            lo = hi
        return len(todo)

    def _join_batch(self, pairs: list[tuple[str, str]], clamp: bool) -> None:
        """Answer many join pairs of one group in a single compiled call,
        filling the per-pair cache entries ``prefetch``/``join`` read."""
        views_a = [self._view(a) for a, _ in pairs]
        views_b = [self._view(b) for _, b in pairs]
        gid, kind = views_a[0].group_id, views_a[0].kind
        with self._obs.span("query.join_batch",
                            histogram="query_batch_seconds",
                            labels={"group": gid, "kind": kind, "op": "join"},
                            group=gid, kind=kind, pairs=len(pairs)) as sp:
            est = views_a[0].estimator.estimate_join_batch(
                stack_states([v.state for v in views_a]),
                stack_states([v.state for v in views_b]),
                clamp=clamp, use_pallas=self._use_pallas,
                interpret=self._interpret)
            sp.sync(*jax.tree_util.tree_leaves(est))
        for i, (va, vb) in enumerate(zip(views_a, views_b)):
            k = ("join", va.name, va.version, vb.name, vb.version, clamp)
            # slice array fields to the pair's row; scalar metadata
            # (stderr_kind) passes through unsliced
            self._cache[k] = type(est)(*(a[i:i + 1] if isinstance(
                a, (np.ndarray, jax.Array)) else a for a in est))

    def prefetch(self, queries, *, clamp: bool = True) -> None:
        """Warm the cache for a batch of :class:`ContinuousQuery` -- one
        ``estimate_batch`` per touched group plus one ``estimate_join_batch``
        per group with join pairs (instead of one call per query)."""
        if not self._use_fused:
            return
        m = self._obs.metrics
        if m.enabled and queries:
            m.inc("query_prefetch_queries_total", value=float(len(queries)))
        # join pairs bucket like the self path splits cohorts: by estimator
        # INSTANCE and state shapes, not group alone -- a group mixing
        # estimator_cfg-overridden streams or backing-epoch geometries must
        # not stack mismatched states into one estimate_join_batch launch
        join_pairs: dict[tuple, list[tuple[str, str]]] = {}
        for q in queries:
            if q.kind == "join":
                a, b = q.streams
                self._registry.require_joinable(a, b)
                va, vb = self._view(a), self._view(b)
                k = ("join", a, va.version, b, vb.version, clamp)
                if k not in self._cache:
                    bucket = (va.group_id, id(va.estimator),
                              id(vb.estimator), va.shape_sig, vb.shape_sig)
                    join_pairs.setdefault(bucket, []).append((a, b))
            else:
                self._self_batch(self._view(q.streams[0]), clamp)
        for bucket, pairs in join_pairs.items():
            pairs = sorted(set(pairs))
            if m.enabled:
                m.inc("query_prefetch_join_pairs_total",
                      value=float(len(pairs)), group=bucket[0])
            self._join_batch(pairs, clamp)

    # -- per-stream reference oracle -----------------------------------
    def _ref_table(self, name: str, clamp: bool):
        """The estimator's per-stream host oracle (SJPC: int64-exact F2 +
        float64 inversion -- the PR 1 path), memoized by window version."""
        v = self._view(name)
        key = ("ref", name, v.version, clamp)
        hit = key in self._cache
        self._count_cache(hit, v.group_id, v.kind, "ref")
        if not hit:
            self._cache[key] = v.estimator.estimate_ref(v.state, clamp=clamp)
        return self._cache_get(key)

    # ------------------------------------------------------------------
    def self_join(self, name: str, s: int | None = None, *,
                  clamp: bool = True) -> QueryResult:
        """Windowed g_s for ``name`` (s defaults to, and must be >=, cfg.s)."""
        v = self._view(name)
        s = v.cfg.s if s is None else s
        if not v.cfg.s <= s <= v.cfg.d:
            raise ValueError(f"s={s} outside sketched range "
                             f"[{v.cfg.s}, {v.cfg.d}] of {name!r}")
        li = s - v.cfg.s
        if self._use_fused:
            index, est = self._self_batch(v, clamp)
            i = index[name]
        else:
            est = self._ref_table(name, clamp)
            i = 0
        g = float(est.g[i, li])
        on, off = float(est.stderr[i, li]), float(est.stderr_offline[i, li])
        xs = est.x[i, li:]
        return QueryResult("self_join", (name,), s, g, on, off, xs,
                           (v.n,), (v.live_epochs,), est.stderr_kind)

    def join(self, a: str, b: str, s: int | None = None, *,
             clamp: bool = True) -> QueryResult:
        """Windowed similarity-join size of two same-group streams (§6)."""
        self._registry.require_joinable(a, b)
        va, vb = self._view(a), self._view(b)
        cfg = va.cfg
        s = cfg.s if s is None else s
        if not cfg.s <= s <= cfg.d:
            raise ValueError(f"s={s} outside sketched range [{cfg.s}, {cfg.d}]")
        li = s - cfg.s
        if self._use_fused:
            k = ("join", a, va.version, b, vb.version, clamp)
            hit = k in self._cache
            self._count_cache(hit, va.group_id, va.kind, "join")
            if not hit:
                self._join_batch([(a, b)], clamp)
            est = self._cache_get(k)
        else:
            k = ("join_ref", a, va.version, b, vb.version, clamp)
            hit = k in self._cache
            self._count_cache(hit, va.group_id, va.kind, "join")
            if not hit:
                self._cache[k] = va.estimator.estimate_join_ref(
                    va.state, vb.state, clamp=clamp)
            est = self._cache_get(k)
        j = float(est.g[0, li])
        on, off = float(est.stderr[0, li]), float(est.stderr_offline[0, li])
        xs = est.x[0, li:]
        return QueryResult("join", (a, b), s, j, on, off, xs,
                           (va.n, vb.n), (va.live_epochs, vb.live_epochs),
                           est.stderr_kind)

    def all_thresholds(self, name: str, *, clamp: bool = True) -> dict[int, QueryResult]:
        """g_k for every k in [cfg.s, d] -- one batch lookup, d-s+1 results."""
        v = self._view(name)
        return {k: self.self_join(name, k, clamp=clamp)
                for k in range(v.cfg.s, v.cfg.d + 1)}

    def streams(self) -> list[str]:
        return list(self._views)


@dataclasses.dataclass(frozen=True)
class ContinuousQuery:
    """A standing query evaluated against each snapshot (``service.poll``)."""
    name: str
    kind: str                       # "self_join" | "join" | "all_thresholds"
    streams: tuple                  # (a,) or (a, b)
    s: int | None = None
    priority: int = 1               # planner scheduling class; LOWER value is
    #   served first and throttled last (0 = most critical)
    tenant: str | None = None       # admission-control budget account;
    #   defaults to the first stream name (one tenant per stream)

    @property
    def tenant_id(self) -> str:
        return self.tenant if self.tenant is not None else self.streams[0]

    def evaluate(self, snap: Snapshot):
        if self.kind == "self_join":
            return snap.self_join(self.streams[0], self.s)
        if self.kind == "join":
            return snap.join(self.streams[0], self.streams[1], self.s)
        if self.kind == "all_thresholds":
            return snap.all_thresholds(self.streams[0])
        raise ValueError(f"unknown query kind {self.kind!r}")


class QueryEngine:
    def __init__(self, registry: StreamRegistry, *,
                 use_fused_query: bool = True,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 cache_max_entries: int | None = None,
                 obs: Observability | None = None):
        self._registry = registry
        self.use_fused_query = use_fused_query
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self._cache_max = (_CACHE_MAX_ENTRIES if cache_max_entries is None
                           else cache_max_entries)
        self.obs = obs if obs is not None else Observability.disabled()

    def snapshot(self, names: list[str] | None = None) -> Snapshot:
        entries = (self._registry.streams() if names is None
                   else [self._registry.stream(n) for n in names])
        # LRU eviction: drop only the least-recently-used entries down to
        # the bound (every read refreshes recency via Snapshot._cache_get),
        # so one overflowing snapshot can never cold-start hot standing
        # queries the way a wholesale clear() did
        evicted = 0
        while len(self._cache) > self._cache_max:
            self._cache.popitem(last=False)
            evicted += 1
        if evicted:
            self.obs.metrics.inc("query_cache_evictions_total",
                                 value=float(evicted))
        with self.obs.span("query.snapshot",
                           histogram="query_snapshot_seconds",
                           streams=len(entries)):
            views = {}
            for e in entries:
                st = e.window.window_state()
                views[e.name] = _StreamView(
                    name=e.name, cfg=self._registry.group(e.group_id).cfg,
                    state=st, estimator=e.estimator, kind=e.estimator_kind,
                    n=e.window.n_live(),
                    live_epochs=e.window.live_epochs,
                    window_epochs=e.window.window_epochs,
                    group_id=e.group_id, version=e.window.version,
                    shape_sig=tuple(tuple(np.shape(leaf)) for leaf in
                                    jax.tree_util.tree_leaves(st)))
        if self.obs.metrics.enabled:
            self.obs.metrics.set("query_cache_entries", float(len(self._cache)))
        return Snapshot(views, self._registry,
                        use_fused_query=self.use_fused_query,
                        use_pallas=self.use_pallas, interpret=self.interpret,
                        cache=self._cache, obs=self.obs)
