"""Batched ingest: double-buffered submission, fixed-shape coalescing, and
ONE jit'd device dispatch per estimator cohort per flush.

Why batch across tenants: each tenant's trickle of records is far too small
to saturate a device, and per-tenant dispatches pay per-call overhead S
times.  The pipeline stacks every stream of a hash group along a leading
axis -- states (S, ...) pytrees, records (R, S, B, d), row masks
(R, S, B), per-(round, stream) PRNG keys (R, S) -- and consumes ALL R
coalesced rounds of a flush in one ``Estimator.ingest_rounds`` dispatch
per **estimator cohort** (streams of one kind; DESIGN.md §13.4).  A group
whose streams all run SJPC -- the default -- is exactly one ``lax.scan``
inside one jit (:func:`multi_round_update`), vmapping the single-stream
update over the stream axis, bit-identical to the pre-protocol pipeline.  The inner update is the **fused** ingest path by default
(``sjpc.update_fused``: fingerprint -> multi-level sketch in one kernel
launch on TPU, the fused-scatter formulation elsewhere); the original
per-level ``sjpc.update`` stays available behind ``use_fused=False`` as the
conformance oracle -- both produce bit-identical counters for the same keys
(tests/test_fused_ingest.py, tests/test_service.py).

Sharding: with ``shards > 1`` every round's per-stream rows are split across
a leading shard axis and folded into shard-local *delta* sketches inside the
scan -- no cross-shard reduction per round.  The deltas merge once per flush
after the scan (``sjpc.merge`` semantics: counters add, steps sum), so R
micro-batch rounds cost ONE cross-device reduction (merge deferral).  Arrays
carrying the shard axis may be laid out across a device mesh; the shard-axis
``sum`` is then the deferred ``psum``.  Per-shard keys are
``fold_in(round_key, shard)``; ``shards=1`` (the default) uses the round key
directly and is bit-compatible with the PR 1 single-device pipeline.

Shapes are static: records are coalesced into rounds of exactly
``batch_rows`` rows per stream, the tail round padded with zero rows that
carry row_mask 0 (contributing nothing to counters or n -- see
``sjpc.update``).  jit compiles once per (R, S, batch_rows) and reuses the
executable across flushes of the same shape.

Double buffering: ``submit`` appends to the *front* buffer while ``flush``
drains the *back* buffer; the buffers swap at flush start.  In-process this
models (and under an async caller provides) ingest that never blocks on a
device dispatch in flight.

Determinism: the sampling key for stream u's i-th consumed round is
``ingest_key(cfg, uid, i)`` -- a pure function, so any window can be
re-built offline bit-exactly by replaying the same record rounds with the
same keys (tests/test_service.py does exactly this).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCParams, SJPCState
from repro.estimators import index_state, stack_states
from repro.kernels.ops import make_sjpc_update_fn
from repro.obs import Observability

from .registry import HashGroup, StreamEntry

_INGEST_SALT = 0x5E41CE
_EMPTY = np.zeros((0,))          # shape probe for absent pending entries


def ingest_key(cfg: SJPCConfig, uid: int, round_idx: int) -> jax.Array:
    """The PRNG key stream u folds into its round_idx-th ingest round."""
    base = jax.random.PRNGKey(cfg.seed ^ _INGEST_SALT)
    return jax.random.fold_in(jax.random.fold_in(base, uid), round_idx)


@jax.jit
def ingest_key_grid(seed, uids, round_idx) -> jax.Array:
    """Vectorized :func:`ingest_key`: uids (S,), round_idx (R, S) ->
    keys (R, S).  Bit-identical to the scalar function (fold_in is
    elementwise deterministic under vmap); one dispatch instead of R*S."""
    base = jax.random.PRNGKey(seed)

    def one(uid, ridx):
        return jax.random.fold_in(jax.random.fold_in(base, uid), ridx)

    return jax.vmap(jax.vmap(one))(
        jnp.broadcast_to(uids[None, :], round_idx.shape), round_idx)


def _one_stream(cfg, params, use_fused, use_pallas, interpret,
                c, n_s, step_s, vals, mask, key):
    st = SJPCState(c, n_s, step_s)
    if use_fused:
        st = sjpc.update_fused(cfg, params, st, vals, key=key, row_mask=mask,
                               use_pallas=use_pallas, interpret=interpret)
    else:
        st = sjpc.update(cfg, params, st, vals, key=key, row_mask=mask,
                         update_fn=make_sjpc_update_fn(use_pallas=use_pallas,
                                                       interpret=interpret))
    return st.counters, st.n, st.step


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas", "interpret",
                                             "use_fused"))
def multi_stream_update(cfg, params, counters, n, steps, values, row_mask,
                        keys, *, use_pallas=None, interpret=None,
                        use_fused=False):
    """One device dispatch updating every stream of a group (single round).

    counters (S, L, t, w) int32; n (S,) f32; steps (S,) int32;
    values (S, B, d) uint32; row_mask (S, B) int32; keys (S,) PRNG keys.
    Returns the updated (counters, n, steps).
    """
    one = functools.partial(_one_stream, cfg, params, use_fused, use_pallas,
                            interpret)
    return jax.vmap(one)(counters, n, steps, values, row_mask, keys)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas", "interpret",
                                             "use_fused", "shards"))
def multi_round_update(cfg, params, counters, n, steps, values, row_mask,
                       keys, *, use_pallas=None, interpret=None,
                       use_fused=True, shards=1):
    """ALL rounds of a flush in one dispatch: ``lax.scan`` over the round
    axis of values (R, S, B, d) / row_mask (R, S, B) / keys (R, S).

    With ``shards > 1`` each round splits its B rows into ``shards`` slices
    folded into shard-local delta sketches (keys ``fold_in(key, shard)``);
    the single cross-shard merge happens after the scan -- R rounds, one
    reduction.  Requires B % shards == 0 (the pipeline enforces it).
    """
    one = functools.partial(_one_stream, cfg, params, use_fused, use_pallas,
                            interpret)

    if shards == 1:
        def body(carry, rnd):
            vals, mask, ks = rnd
            return jax.vmap(one)(*carry, vals, mask, ks), None

        carry, _ = jax.lax.scan(body, (counters, n, steps),
                                (values, row_mask, keys))
        return carry

    R, S, B, d = values.shape
    assert B % shards == 0
    per = B // shards
    # (R, S, B, ...) -> (R, shards, S, per, ...): shard-major so the scan
    # body vmaps (shards, S) and the shard axis can live on a device mesh.
    vals_sh = values.reshape(R, S, shards, per, d).swapaxes(1, 2)
    mask_sh = row_mask.reshape(R, S, shards, per).swapaxes(1, 2)
    fold = jax.vmap(jax.vmap(jax.random.fold_in, in_axes=(0, None)),
                    in_axes=(0, None))
    keys_sh = jnp.stack([fold(keys, j) for j in range(shards)], axis=1)

    zeros = (jnp.zeros((shards,) + counters.shape, counters.dtype),
             jnp.zeros((shards,) + n.shape, n.dtype),
             jnp.zeros((shards,) + steps.shape, steps.dtype))

    def body(carry, rnd):
        vals, mask, ks = rnd
        return jax.vmap(jax.vmap(one))(*carry, vals, mask, ks), None

    (dc, dn, dstep), _ = jax.lax.scan(body, zeros,
                                      (vals_sh, mask_sh, keys_sh))
    # the deferred merge: ONE reduction over the shard axis for all R rounds
    return (counters + dc.sum(axis=0), n + dn.sum(axis=0),
            steps + dstep.sum(axis=0))


class IngestPipeline:
    """Per-group ingest front end.  Not thread-safe by itself; the service
    serializes submit/flush (the double buffer is about device overlap and
    fixed-shape coalescing, not about lock-free concurrency)."""

    def __init__(self, group: HashGroup, *, batch_rows: int = 256,
                 use_pallas: bool | None = None, interpret: bool | None = None,
                 use_fused: bool = True, shards: int = 1,
                 obs: Observability | None = None):
        assert batch_rows >= 1 and shards >= 1
        assert batch_rows % shards == 0, \
            f"batch_rows={batch_rows} must be divisible by shards={shards}"
        self.group = group
        self.batch_rows = batch_rows
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.use_fused = use_fused
        self.shards = shards
        self.obs = obs if obs is not None else Observability.disabled()
        self._front: dict[str, list[np.ndarray]] = {}
        self._front_rows = 0                 # queue depth, kept incrementally
        self._back: dict[str, list[np.ndarray]] = {}
        self.stats = {"submitted_records": 0, "flushes": 0, "rounds": 0,
                      "dispatches": 0, "padded_rows": 0, "dispatch_rows": 0}

    # ------------------------------------------------------------------
    def submit(self, name: str, records) -> int:
        """Queue records ((n, d) integer array) for ``name``; returns n."""
        records = np.ascontiguousarray(np.asarray(records, dtype=np.uint32))
        if records.ndim != 2 or records.shape[1] != self.group.cfg.d:
            raise ValueError(
                f"records must be (n, d={self.group.cfg.d}); got {records.shape}")
        self._front.setdefault(name, []).append(records)
        self._front_rows += records.shape[0]
        self.stats["submitted_records"] += records.shape[0]
        m = self.obs.metrics
        if m.enabled:
            gid = self.group.group_id
            m.inc("ingest_submitted_records_total", records.shape[0],
                  group=gid)
            m.set("ingest_pending_rows", self._front_rows, group=gid)
            m.set_max("ingest_pending_rows_peak", self._front_rows, group=gid)
        return records.shape[0]

    def pending_rows(self) -> int:
        return self._front_rows

    # ------------------------------------------------------------------
    def flush(self, entries: list[StreamEntry]) -> dict:
        """Drain the queued records of ``entries`` (all streams of this
        group, in uid order) and return each stream's new ingest state
        (cumulative window for linear estimators, open-epoch state for
        windowed sample estimators -- whatever ``window.ingest_base``
        hands out).

        Streams dispatch in **estimator cohorts**: every stream of one
        estimator kind shares one batched ``ingest_rounds`` call (static S
        per cohort for jit shape stability); streams with no remaining
        records ride along fully masked.  An all-SJPC group is exactly the
        PR 2 single-dispatch path, bit for bit.  ``entry.flushes`` counts
        the rounds that carried the stream's OWN rows, and is the replay
        coordinate for :func:`ingest_key` -- cohort rounds that existed only
        for a busier cohort-mate are fully masked here, consume none of this
        stream's randomness, and do not advance it.
        """
        self._front, self._back = self._back, self._front
        self._front_rows = 0
        pending = {name: (np.concatenate(chunks) if chunks else
                          np.zeros((0, self.group.cfg.d), np.uint32))
                   for name, chunks in self._back.items()}
        self._back = {}
        if self.obs.metrics.enabled:
            self.obs.metrics.set("ingest_pending_rows", 0,
                                 group=self.group.group_id)

        entries = sorted(entries, key=lambda e: e.uid)
        out = {e.name: e.window.ingest_base() for e in entries}
        # cohorts key on the estimator INSTANCE: streams of one kind but
        # with an explicit estimator_cfg override are distinct cohorts
        # (different state shapes / seeds must not share a dispatch)
        cohorts: dict[int, list[StreamEntry]] = {}
        for e in entries:
            cohorts.setdefault(id(e.estimator), []).append(e)
        self.stats["flushes"] += 1
        for cohort in cohorts.values():
            self._flush_cohort(cohort, pending, out)
        return out

    def _flush_cohort(self, entries: list[StreamEntry], pending: dict,
                      out: dict) -> None:
        B, cfg = self.batch_rows, self.group.cfg
        est = entries[0].estimator
        counts = [pending.get(e.name, np.zeros((0, cfg.d), np.uint32)).shape[0]
                  for e in entries]
        rounds = max((-(-c // B) for c in counts if c), default=0)
        if rounds == 0:
            return

        S = len(entries)
        values = np.zeros((rounds, S, B, cfg.d), np.uint32)
        mask = np.zeros((rounds, S, B), np.int32)
        round_idx = np.zeros((rounds, S), np.int32)
        for i, e in enumerate(entries):
            rows = pending.get(e.name, np.zeros((0, cfg.d), np.uint32))
            for r in range(rounds):
                chunk = rows[r * B:(r + 1) * B]
                values[r, i, :chunk.shape[0]] = chunk
                mask[r, i, :chunk.shape[0]] = 1
                self.stats["padded_rows"] += B - chunk.shape[0]
            # streams with no pending records ride along fully masked (the
            # cohort's S stays jit-shape-stable) but neither consume round
            # keys nor commit the ride-along state below: their window
            # content is unchanged, and committing the step-only bump
            # would spuriously bump the version and thrash version-keyed
            # query caches.  Each stream's replay coordinate advances only
            # by the rounds that carried ITS rows (r_i = ceil(c_i / B)) --
            # trailing rounds that exist only for a busier cohort-mate are
            # fully masked for this stream, consume no randomness, and must
            # not shift its key stream, or the window content would depend
            # on co-tenants' backlog sizes and the offline replay contract
            # (module docstring) would break
            round_idx[:, i] = e.flushes + np.arange(rounds)
            if rows.shape[0]:
                e.flushes += -(-rows.shape[0] // B)
                e.records += int(rows.shape[0])

        gid, kind = self.group.group_id, entries[0].estimator_kind
        with self.obs.span("ingest.flush_cohort",
                           histogram="ingest_flush_seconds",
                           labels={"group": gid, "kind": kind},
                           group=gid, kind=kind, streams=S,
                           rounds=rounds) as sp:
            keys = ingest_key_grid(
                jnp.uint32(est.ingest_seed),
                jnp.asarray([e.uid for e in entries], jnp.int32),
                jnp.asarray(round_idx))
            states = stack_states([out[e.name] for e in entries])
            states = est.ingest_rounds(states, jnp.asarray(values),
                                       jnp.asarray(mask), keys)
            # device-time semantics: the span blocks on the dispatched
            # states before its clock stops (trace events show dispatch
            # vs compute separately)
            sp.sync(*jax.tree_util.tree_leaves(states))
        self.stats["rounds"] += rounds
        self.stats["dispatches"] += 1
        self.stats["dispatch_rows"] += S * B * rounds
        m = self.obs.metrics
        if m.enabled:
            m.inc("ingest_dispatches_total", group=gid, kind=kind)
            m.inc("ingest_rounds_total", rounds, group=gid, kind=kind)
            m.inc("ingest_dispatch_rows_total", S * B * rounds,
                  group=gid, kind=kind)
        for i, e in enumerate(entries):
            if pending.get(e.name, _EMPTY).shape[0]:
                out[e.name] = index_state(states, i)
