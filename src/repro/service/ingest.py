"""Batched ingest: double-buffered submission, fixed-shape coalescing, and
ONE jit'd multi-stream sketch update per dispatch.

Why batch across tenants: each tenant's trickle of records is far too small
to saturate a device, and per-tenant dispatches pay per-call overhead S
times.  Instead the pipeline stacks every stream of a hash group along a
leading axis -- counters (S, levels, t, w), records (S, B, d), row masks
(S, B), per-stream PRNG keys (S, 2) -- and vmaps the single-stream
``sjpc.update`` over that axis inside one jit.  The inner update is the
same code the offline estimator uses (and dispatches to the fused Pallas
``sketch_update`` kernel on TPU backends), so one device program serves all
tenants per round.

Shapes are static: records are coalesced into rounds of exactly
``batch_rows`` rows per stream, the tail round padded with zero rows that
carry row_mask 0 (contributing nothing to counters or n -- see
``sjpc.update``).  jit therefore compiles once per (S, batch_rows) and
every subsequent flush reuses the executable.

Double buffering: ``submit`` appends to the *front* buffer while ``flush``
drains the *back* buffer; the buffers swap at flush start.  In-process this
models (and under an async caller provides) ingest that never blocks on a
device dispatch in flight.

Determinism: the sampling key for stream u's i-th consumed round is
``ingest_key(cfg, uid, i)`` -- a pure function, so any window can be
re-built offline bit-exactly by replaying the same record rounds with the
same keys (tests/test_service.py does exactly this).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCParams, SJPCState
from repro.kernels.ops import make_sjpc_update_fn

from .registry import HashGroup, StreamEntry

_INGEST_SALT = 0x5E41CE


def ingest_key(cfg: SJPCConfig, uid: int, round_idx: int) -> jax.Array:
    """The PRNG key stream u folds into its round_idx-th ingest round."""
    base = jax.random.PRNGKey(cfg.seed ^ _INGEST_SALT)
    return jax.random.fold_in(jax.random.fold_in(base, uid), round_idx)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas", "interpret"))
def multi_stream_update(cfg: SJPCConfig, params: SJPCParams, counters, n,
                        steps, values, row_mask, keys, *, use_pallas=None,
                        interpret=None):
    """One device dispatch updating every stream of a group.

    counters (S, L, t, w) int32; n (S,) f32; steps (S,) int32;
    values (S, B, d) uint32; row_mask (S, B) int32; keys (S,) PRNG keys.
    Returns the updated (counters, n, steps).
    """
    update_fn = make_sjpc_update_fn(use_pallas=use_pallas, interpret=interpret)

    def one(c, n_s, step_s, vals, mask, key):
        st = sjpc.update(cfg, params, SJPCState(c, n_s, step_s), vals,
                         key=key, row_mask=mask, update_fn=update_fn)
        return st.counters, st.n, st.step

    return jax.vmap(one)(counters, n, steps, values, row_mask, keys)


class IngestPipeline:
    """Per-group ingest front end.  Not thread-safe by itself; the service
    serializes submit/flush (the double buffer is about device overlap and
    fixed-shape coalescing, not about lock-free concurrency)."""

    def __init__(self, group: HashGroup, *, batch_rows: int = 256,
                 use_pallas: bool | None = None, interpret: bool | None = None):
        assert batch_rows >= 1
        self.group = group
        self.batch_rows = batch_rows
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._front: dict[str, list[np.ndarray]] = {}
        self._back: dict[str, list[np.ndarray]] = {}
        self.stats = {"submitted_records": 0, "flushes": 0, "rounds": 0,
                      "padded_rows": 0, "dispatch_rows": 0}

    # ------------------------------------------------------------------
    def submit(self, name: str, records) -> int:
        """Queue records ((n, d) integer array) for ``name``; returns n."""
        records = np.ascontiguousarray(np.asarray(records, dtype=np.uint32))
        if records.ndim != 2 or records.shape[1] != self.group.cfg.d:
            raise ValueError(
                f"records must be (n, d={self.group.cfg.d}); got {records.shape}")
        self._front.setdefault(name, []).append(records)
        self.stats["submitted_records"] += records.shape[0]
        return records.shape[0]

    def pending_rows(self) -> int:
        return sum(r.shape[0] for chunks in self._front.values() for r in chunks)

    # ------------------------------------------------------------------
    def flush(self, entries: list[StreamEntry]) -> dict[str, SJPCState]:
        """Drain the queued records of ``entries`` (all streams of this
        group, in uid order) and return each stream's new cumulative state.

        Every stream participates in every round (static S for jit shape
        stability); streams with no remaining records ride along fully
        masked.  ``entry.flushes`` counts *rounds* consumed, and is the
        replay coordinate for :func:`ingest_key`.
        """
        self._front, self._back = self._back, self._front
        pending = {name: (np.concatenate(chunks) if chunks else
                          np.zeros((0, self.group.cfg.d), np.uint32))
                   for name, chunks in self._back.items()}
        self._back = {}

        entries = sorted(entries, key=lambda e: e.uid)
        B, cfg = self.batch_rows, self.group.cfg
        counts = [pending.get(e.name, np.zeros((0, cfg.d), np.uint32)).shape[0]
                  for e in entries]
        rounds = max((-(-c // B) for c in counts if c), default=0)
        out = {e.name: e.window.total for e in entries}
        if rounds == 0:
            self.stats["flushes"] += 1
            return out

        counters = jnp.stack([out[e.name].counters for e in entries])
        n = jnp.stack([out[e.name].n for e in entries])
        steps = jnp.stack([out[e.name].step for e in entries])
        for r in range(rounds):
            values = np.zeros((len(entries), B, cfg.d), np.uint32)
            mask = np.zeros((len(entries), B), np.int32)
            keys = []
            for i, e in enumerate(entries):
                rows = pending.get(e.name,
                                   np.zeros((0, cfg.d), np.uint32))[r * B:(r + 1) * B]
                values[i, :rows.shape[0]] = rows
                mask[i, :rows.shape[0]] = 1
                keys.append(ingest_key(cfg, e.uid, e.flushes))
                e.flushes += 1
                e.records += int(rows.shape[0])
                self.stats["padded_rows"] += B - rows.shape[0]
            counters, n, steps = multi_stream_update(
                cfg, self.group.params, counters, n, steps,
                jnp.asarray(values), jnp.asarray(mask), jnp.stack(keys),
                use_pallas=self.use_pallas, interpret=self.interpret)
            self.stats["rounds"] += 1
            self.stats["dispatch_rows"] += len(entries) * B
        self.stats["flushes"] += 1
        for i, e in enumerate(entries):
            out[e.name] = SJPCState(counters[i], n[i], steps[i])
        return out
