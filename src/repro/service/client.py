"""Service client for the training-loop stream monitor.

Turns the whole-stream ``sketchstream.monitor`` into a tenant of the
estimation service: each ``publish`` takes the monitor's current cumulative
state, derives the *delta* since the previous publish by linearity
(new - old is exactly the sketch of the records seen in between), ingests
that delta into the stream's open epoch, and closes the epoch.  The window
then answers "how much near-duplication in the last K publish intervals"
-- the time-windowed continuous query the whole-stream monitor cannot.

The stream's hash group is created from the monitor's own SJPCConfig, so a
second monitored corpus (e.g. eval) published into the same group supports
the §6 contamination join, windowed.
"""
from __future__ import annotations

from repro.core import sjpc
from repro.sketchstream.monitor import MonitorState, SketchMonitorConfig, merge_monitor

from .query import QueryResult
from .service import EstimationService


class MonitorServiceClient:
    def __init__(self, service: EstimationService, stream: str,
                 monitor_cfg: SketchMonitorConfig, *, group_id: str | None = None,
                 window_epochs=None):
        self.service = service
        self.stream = stream
        self.monitor_cfg = monitor_cfg
        gid = group_id or f"monitor/{monitor_cfg.seed:#x}"
        existing = {g.group_id: g for g in service.registry.groups()}
        if gid not in existing:
            service.create_group(gid, monitor_cfg.sjpc)
        elif existing[gid].cfg != monitor_cfg.sjpc:
            # same params draw (seed) does NOT imply the same lattice: merging
            # deltas sketched under a different config silently corrupts the
            # group, so refuse rather than reuse
            raise ValueError(
                f"group {gid!r} exists with config {existing[gid].cfg}, "
                f"incompatible with this monitor's {monitor_cfg.sjpc}; pass "
                "an explicit group_id")
        self.group_id = gid
        kw = {} if window_epochs is None else {"window_epochs": window_epochs}
        service.create_stream(stream, gid, **kw)
        self._last: sjpc.SJPCState | None = None

    # ------------------------------------------------------------------
    def publish(self, monitor_state: MonitorState) -> None:
        """Ingest the monitor's progress since the last publish as one epoch."""
        merged = merge_monitor(monitor_state)
        delta = merged if self._last is None else sjpc.subtract(merged, self._last)
        self.service.ingest_state_delta(self.stream, delta)
        self.service.advance_epoch(self.stream)
        self._last = merged

    def resync(self, monitor_state: MonitorState) -> None:
        """Re-base the delta after a checkpoint restore: the monitor rolled
        back, so the next publish must cover only post-restore progress.
        Batches replayed between the restore point and the last publish were
        already ingested into earlier epochs; they age out with the window
        (expiry-by-subtraction), so the windowed estimate self-heals."""
        self._last = merge_monitor(monitor_state)

    def query(self, *, clamp: bool = True) -> dict[int, QueryResult]:
        """Windowed g_k (+ error bars) for every monitored threshold.

        Served by the fused batched query engine (DESIGN.md §12): the whole
        all-thresholds table comes out of one compiled call, cached by
        window version until the next publish changes the window."""
        return self.service.snapshot([self.stream]).all_thresholds(
            self.stream, clamp=clamp)

    def metrics_report(self) -> str:
        """The owning service's Prometheus text dump (DESIGN.md §15) --
        the training driver scrapes its monitor tenant like any other."""
        return self.service.metrics_report()

    def log_entry(self, step: int) -> dict:
        """A flat dict for the driver's sketch log: g_k +/- stderr per k."""
        res = self.query()
        entry = {"step": step,
                 "window_epochs": self.service.registry.stream(
                     self.stream).window.window_epochs}
        for k, r in res.items():
            entry[k] = r.estimate
            entry[f"stderr_{k}"] = r.stderr
        return entry
