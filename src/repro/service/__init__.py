"""repro.service -- multi-tenant streaming estimation service.

Sliding-window SJPC sketches behind a registry of named streams, batched
single-dispatch ingest, and a snapshot query engine with analytical error
bars.  See DESIGN.md §10 for the architecture and invariants.
"""
from .client import MonitorServiceClient
from .ingest import (IngestPipeline, ingest_key, ingest_key_grid,
                     multi_round_update, multi_stream_update)
from .planner import PlannerConfig, QueryPlanner
from .query import ContinuousQuery, QueryEngine, QueryResult, Snapshot
from .registry import HashGroup, StreamEntry, StreamRegistry
from .service import EstimationService, ServiceConfig
from .window import WindowedSketch

__all__ = [
    "ContinuousQuery", "EstimationService", "HashGroup", "IngestPipeline",
    "MonitorServiceClient", "PlannerConfig", "QueryEngine", "QueryPlanner",
    "QueryResult", "ServiceConfig", "Snapshot", "StreamEntry",
    "StreamRegistry", "WindowedSketch", "ingest_key", "ingest_key_grid",
    "multi_round_update", "multi_stream_update",
]
