"""Query planner + admission control for the continuous-query path.

``service.poll`` batches device work per (group, estimator instance,
state-shape) cohort -- one ``estimate_batch`` per hash group.  At planner
scale (thousands of standing queries over dozens of groups) that still
means one launch per group per poll, and every tenant's query is equal.
The :class:`QueryPlanner` sits between ``poll()`` and the snapshot engine
(DESIGN.md §16) and adds three things:

**Cross-group cohort fusion (§16.1).**  Group cohorts whose *fusion
signature* matches -- same estimator kind, same derived estimator config
(which pins the counter geometry: levels, depth t, width w -- and the
seed), and same state leaf shapes -- stack along one stream axis into ONE
``estimate_batch`` launch; the result unstacks back into the per-group
cache entries the unfused path would have written.  All batched estimate
paths are row-independent (moments, depth medians, the Eq. 4 inversion are
per-stream reductions; bootstrap bars are position-independent by
construction, DESIGN.md §14.1), so fused results equal unfused results --
tests/test_planner.py holds them within 1e-6 for every kind.

**Priority scheduling + admission control (§16.3).**  Each
:class:`~repro.service.query.ContinuousQuery` carries a ``priority`` class
(lower = more critical) and a ``tenant`` budget account (default: its
first stream).  Per tenant, a token bucket refills every poll; queries are
charged in priority order, and a tenant over budget is served its *last
fresh* result marked ``stale=True`` -- no new device work, no audit --
with ``admission_rejections_total{tenant}`` counting every throttled
serve.  A query that has never produced a result is admitted regardless
(there is nothing to serve stale).  Fused launches run in priority order:
a launch's priority is the most critical admitted query that needs it.

**Plan caching (§16.2).**  The fusion plan -- signature -> member cohorts,
query -> cohort/pair wiring -- is a pure function of the registry topology
and the registered queries, so it is computed once and reused across polls
(``planner_plans_built_total`` / ``planner_plan_reuse_total``).  It is
invalidated by ``create_stream``/``create_group`` (the registry's topology
``version``), ``register_continuous`` (the service's query version), and
estimator-cfg changes (registration-time, hence covered); a per-poll
validation pass additionally rebuilds when any covered stream's state
shapes drift (backing-epoch refill widens sample windows mid-life).
"""
from __future__ import annotations

import dataclasses
import time

from repro import estimators
from repro.obs import Observability

from .query import ContinuousQuery, QueryResult, Snapshot
from .registry import StreamRegistry


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    fuse_groups: bool = True         # cross-group cohort fusion (§16.1)
    tenant_budget: float | None = None   # default tokens refilled per poll
    #   per tenant (None = unlimited: admission control off unless a
    #   per-tenant budget is set)
    tenant_budgets: tuple = ()       # ((tenant, refill), ...) overrides
    tenant_burst: float | None = None    # bucket capacity (None = refill)
    coalesce_window: float = 0.0     # seconds (§16.6): a cohort/pair whose
    #   launch completed within this window serves the SAME result to the
    #   next poll even if window versions moved -- back-to-back sub-second
    #   polls reuse the in-flight launch instead of recomputing.  0 = off
    #   (every version bump recomputes; the pre-coalescing behavior)


class _Bucket:
    """Per-tenant token bucket: ``refill`` tokens per poll, capped at
    ``burst``; one admitted query costs one token."""

    __slots__ = ("refill", "burst", "tokens")

    def __init__(self, refill: float, burst: float | None):
        self.refill = float(refill)
        self.burst = float(refill if burst is None else burst)
        self.tokens = self.burst         # start full: first poll is served

    def tick(self) -> None:
        self.tokens = min(self.tokens + self.refill, self.burst)

    def take(self) -> bool:
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclasses.dataclass
class _Plan:
    key: tuple                       # (registry.version, queries_version)
    stream_sigs: dict                # name -> (id(estimator), shape_sig)
    self_launches: list              # [[cohort_key, ...], ...] one fused
    #   launch per inner list; cohort_key = (group_id, eid, shape_sig)
    query_cohort: dict               # query name -> cohort_key (self kinds)
    join_launches: list              # [[(a, b), ...], ...] fused join buckets
    query_pair: dict                 # query name -> (a, b) (join kind)


class QueryPlanner:
    def __init__(self, registry: StreamRegistry, cfg: PlannerConfig | None
                 = None, *, obs: Observability | None = None):
        self.registry = registry
        self.cfg = cfg or PlannerConfig()
        self.obs = obs if obs is not None else Observability.disabled()
        self._plan: _Plan | None = None
        self._queries_version = 0
        self._budgets: dict[str, float | None] = dict(
            self.cfg.tenant_budgets)
        self._buckets: dict[str, _Bucket] = {}
        self._last: dict[str, object] = {}   # query name -> last fresh result
        # launch coalescing (§16.6): ("self", ck) / ("join", pair) -> the
        # (timestamp, cache key) of the last *fresh* launch.  Aliased
        # serves keep the original record, so a real launch happens at
        # least once per coalesce window
        self._coalesce: dict = {}
        self._now = time.monotonic           # injectable clock (tests)

    # -- registration-side invalidation --------------------------------
    def invalidate_queries(self) -> None:
        """Called by ``register_continuous``: the query set is part of the
        plan key (join pairs and needed cohorts change with it)."""
        self._queries_version += 1

    def set_tenant_budget(self, tenant: str, refill: float | None, *,
                          burst: float | None = None) -> None:
        """Set (or clear, with ``refill=None``) one tenant's per-poll query
        budget at runtime; takes effect at the next poll."""
        self._budgets[tenant] = refill
        self._buckets.pop(tenant, None)
        if refill is not None:
            self._buckets[tenant] = _Bucket(refill, burst
                                            if burst is not None
                                            else self.cfg.tenant_burst)

    # -- planning ------------------------------------------------------
    def _fusion_sig(self, view) -> tuple:
        """Cohorts fuse iff this matches: estimator kind, the derived
        config (geometry AND seed -- groups with equal SJPCConfig draw
        identical hash params, and sample kinds' bootstrap keys derive
        from the cfg seed), and the state leaf shapes.  Only the group's
        *cached* kind instance is eligible: its numerics are a pure
        function of the config, whereas an ``estimator_cfg``-overridden
        instance may carry construction kwargs the config cannot see, so
        it falls back to instance identity (fused only with itself)."""
        est = view.estimator
        group = self.registry.group(view.group_id)
        if group.cached_estimator(view.kind) is not est:
            cfg = id(est)
        else:
            # the kind's spec may contribute its own fusion key
            # (``EstimatorSpec.fusion``, DESIGN.md §19); the default is
            # the instance's derived config
            fusion = estimators.spec_of(est).fusion
            cfg = fusion(est) if fusion is not None \
                else getattr(est, "cfg", None)
            try:
                hash(cfg)
            except TypeError:
                cfg = id(est)
        return (view.kind, cfg, view.shape_sig)

    def _build_plan(self, snap: Snapshot,
                    queries: dict[str, ContinuousQuery]) -> _Plan:
        stream_sigs: dict = {}
        cohort_of: dict = {}         # cohort_key -> fusion sig
        query_cohort: dict = {}
        join_buckets: dict = {}      # fused-join sig -> [(a, b), ...]
        query_pair: dict = {}
        for name, q in queries.items():
            if q.kind == "join":
                a, b = q.streams
                self.registry.require_joinable(a, b)
                va, vb = snap._view(a), snap._view(b)
                for v in (va, vb):
                    stream_sigs[v.name] = (id(v.estimator), v.shape_sig)
                sig = ((self._fusion_sig(va), self._fusion_sig(vb))
                       if self.cfg.fuse_groups
                       else (va.group_id, id(va.estimator),
                             id(vb.estimator), va.shape_sig, vb.shape_sig))
                pair = (a, b)
                if pair not in query_pair.values():
                    join_buckets.setdefault(sig, []).append(pair)
                query_pair[name] = pair
            else:
                v = snap._view(q.streams[0])
                stream_sigs[v.name] = (id(v.estimator), v.shape_sig)
                ck = (v.group_id, id(v.estimator), v.shape_sig)
                cohort_of[ck] = (self._fusion_sig(v) if self.cfg.fuse_groups
                                 else ck)
                query_cohort[name] = ck
        by_sig: dict = {}
        for ck, sig in cohort_of.items():
            by_sig.setdefault(sig, []).append(ck)
        plan = _Plan(key=(self.registry.version, self._queries_version),
                     stream_sigs=stream_sigs,
                     self_launches=list(by_sig.values()),
                     query_cohort=query_cohort,
                     join_launches=[sorted(set(p))
                                    for p in join_buckets.values()],
                     query_pair=query_pair)
        m = self.obs.metrics
        if m.enabled:
            m.inc("planner_plans_built_total")
        return plan

    def _plan_for(self, snap: Snapshot,
                  queries: dict[str, ContinuousQuery]) -> _Plan:
        key = (self.registry.version, self._queries_version)
        plan = self._plan
        if plan is not None and plan.key == key:
            # shape drift (backing-epoch refill) changes cohort membership
            # without touching the topology version -- validate per poll
            for name, (eid, sig) in plan.stream_sigs.items():
                v = snap._views.get(name)
                if v is None or id(v.estimator) != eid or v.shape_sig != sig:
                    plan = None
                    break
        else:
            plan = None
        if plan is None:
            plan = self._build_plan(snap, queries)
            self._plan = plan
        elif self.obs.metrics.enabled:
            self.obs.metrics.inc("planner_plan_reuse_total")
        return plan

    # -- admission -----------------------------------------------------
    def _admit(self, queries: dict[str, ContinuousQuery]) -> set:
        """Charge each tenant's bucket in priority order; return the names
        throttled this poll (served stale)."""
        throttled: set = set()
        default = self.cfg.tenant_budget
        if default is None and not self._budgets:
            return throttled
        per_tenant: dict[str, list] = {}
        for idx, (name, q) in enumerate(queries.items()):
            per_tenant.setdefault(q.tenant_id, []).append((q.priority, idx,
                                                           name))
        m = self.obs.metrics
        for tenant, qs in per_tenant.items():
            refill = self._budgets.get(tenant, default)
            if refill is None:
                continue
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = self._buckets[tenant] = _Bucket(
                    refill, self.cfg.tenant_burst)
            else:
                bucket.tick()
            if m.enabled:
                m.set("admission_tokens", bucket.tokens, tenant=tenant)
            for _, _, name in sorted(qs):
                if not bucket.take() and name in self._last:
                    # over budget AND a previous fresh result exists to
                    # serve; a never-served query is admitted regardless
                    throttled.add(name)
                    if m.enabled:
                        m.inc("admission_rejections_total", tenant=tenant)
        return throttled

    @staticmethod
    def _stale(result):
        if isinstance(result, QueryResult):
            return result._replace(stale=True)
        return {k: r._replace(stale=True) for k, r in result.items()}

    # -- launch coalescing (§16.6) -------------------------------------
    @staticmethod
    def _launch_key(snap: Snapshot, op: str, member) -> tuple:
        """The version-embedding cache key ``member`` resolves to in this
        snapshot (the key the fused launch would fill)."""
        if op == "self":
            return snap._self_key(snap._cohort_views(*member), True)
        a, b = member
        return ("join", a, snap._view(a).version,
                b, snap._view(b).version, True)

    def _apply_coalescing(self, snap: Snapshot, cohort_prio: dict,
                          pair_prio: dict) -> list:
        """Alias cache entries for launches whose previous fresh result is
        younger than the coalesce window: the new version key points at
        the last launch's entry, so the launch loop skips the cohort/pair
        entirely.  Returns the members that still need fresh launches (the
        records to stamp afterwards)."""
        win = self.cfg.coalesce_window
        m = self.obs.metrics
        fresh = []
        now = self._now() if win > 0.0 else 0.0
        for op, prio in (("self", cohort_prio), ("join", pair_prio)):
            for member in prio:
                key = self._launch_key(snap, op, member)
                if key in snap._cache:
                    continue
                rec = self._coalesce.get((op, member)) if win > 0.0 else None
                if (rec is not None and now - rec[0] <= win
                        and rec[1] in snap._cache and rec[1] != key):
                    # within the window: serve the in-flight result under
                    # the new version key (no device work; the entry ages
                    # out when the ORIGINAL launch leaves the window)
                    snap._cache[key] = snap._cache_get(rec[1])
                    if m.enabled:
                        m.inc("planner_coalesced_launches_total", op=op)
                else:
                    fresh.append((op, member, key))
        return fresh

    def _stamp_coalescing(self, fresh: list) -> None:
        if self.cfg.coalesce_window <= 0.0:
            return
        now = self._now()
        for op, member, key in fresh:
            self._coalesce[(op, member)] = (now, key)

    # -- the poll body -------------------------------------------------
    def poll(self, snap: Snapshot,
             queries: dict[str, ContinuousQuery]) -> dict:
        """Evaluate the standing queries against ``snap`` through the plan:
        admission first, then the fused launches (priority order, skipping
        work no admitted query needs), then per-query evaluation -- cache
        hits for admitted queries, last-fresh ``stale=True`` results for
        throttled ones."""
        throttled = self._admit(queries)
        plan = self._plan_for(snap, queries)
        m = self.obs.metrics
        if snap._use_fused:
            # priority of each cohort/pair = most critical admitted query
            # needing it; untouched launches are skipped entirely
            cohort_prio: dict = {}
            pair_prio: dict = {}
            for name, q in queries.items():
                if name in throttled:
                    continue
                if q.kind == "join":
                    pair = plan.query_pair[name]
                    pair_prio[pair] = min(pair_prio.get(pair, q.priority),
                                          q.priority)
                else:
                    ck = plan.query_cohort[name]
                    cohort_prio[ck] = min(cohort_prio.get(ck, q.priority),
                                          q.priority)
            fresh = self._apply_coalescing(snap, cohort_prio, pair_prio)
            launches = [(min(cohort_prio[ck] for ck in cks), "self", cks)
                        for cks in plan.self_launches
                        if any(ck in cohort_prio for ck in cks)]
            launches += [(min(pair_prio[p] for p in ps), "join", ps)
                         for ps in plan.join_launches
                         if any(p in pair_prio for p in ps)]
            launches.sort(key=lambda t: t[0])
            for _, op, members in launches:
                if op == "self":
                    done = snap.fused_self_batch(
                        [snap._cohort_views(*ck) for ck in members
                         if ck in cohort_prio])
                    if done and m.enabled:
                        m.inc("planner_fused_launches_total", op="self")
                        m.inc("planner_fused_cohorts_total",
                              value=float(done), op="self")
                else:
                    pairs = [p for p in members if p in pair_prio
                             and ("join", p[0], snap._view(p[0]).version,
                                  p[1], snap._view(p[1]).version, True)
                             not in snap._cache]
                    if pairs:
                        if m.enabled:
                            m.inc("planner_fused_launches_total", op="join")
                            m.inc("planner_fused_cohorts_total",
                                  value=float(len(pairs)), op="join")
                        snap._join_batch(pairs, True)
            self._stamp_coalescing(fresh)
        out = {}
        for name, q in queries.items():
            if name in throttled:
                out[name] = self._stale(self._last[name])
            else:
                out[name] = self._last[name] = q.evaluate(snap)
        return out
