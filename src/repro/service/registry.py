"""Sketch registry: many named streams (tenants), grouped by shared hashes.

A **hash group** owns one ``SJPCConfig`` and one draw of ``SJPCParams``
(bucket/sign hash coefficients + fingerprint bases).  Every SJPC stream
registered into the group sketches with those exact parameters, which is
the paper's §6 precondition: the similarity-*join* estimator is the sketch
inner product, and inner products are only meaningful between sketches
built with identical hash functions.  Streams in different groups can use
different configs (dimensionality, threshold, width, ...) but are not
pairwise joinable -- the registry enforces this at query time.

Per-stream **estimator choice** (DESIGN.md §13): each stream picks an
estimator kind from :mod:`repro.estimators` ("sjpc" by default); the
group's ``SJPCConfig`` seeds every kind's derived configuration, so a
reservoir or LSH-SS stream created next to an SJPC stream is equal-space
with it by construction.  One estimator instance per (group, kind) is
cached on the group, so cohort streams share jit caches and hash params.

Each stream carries its own :class:`~repro.service.window.WindowedSketch`,
so tenants in one group may still have different window lengths.
"""
from __future__ import annotations

import dataclasses

from repro import estimators as est_mod
from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCParams
from repro.estimators import Estimator

from repro.obs import Observability

from .window import WindowedSketch


@dataclasses.dataclass(frozen=True)
class HashGroup:
    group_id: str
    cfg: SJPCConfig
    params: SJPCParams
    # per-kind construction overrides (e.g. the service's fused/pallas
    # flags for "sjpc") and the per-kind instance cache
    estimator_opts: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _estimators: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def estimator(self, kind: str = "sjpc",
                  estimator_cfg=None) -> Estimator:
        """The group's shared estimator instance for ``kind`` (constructed
        on first use; an explicit ``estimator_cfg`` bypasses the cache).
        ``estimator_opts[kind]`` (the service's dispatch flags) apply
        either way."""
        if estimator_cfg is not None:
            return est_mod.make(kind, self.cfg, params=self.params,
                                estimator_cfg=estimator_cfg,
                                opts=self.estimator_opts.get(kind))
        if kind not in self._estimators:
            self._estimators[kind] = est_mod.make(
                kind, self.cfg, params=self.params,
                opts=self.estimator_opts.get(kind))
        return self._estimators[kind]

    def cached_estimator(self, kind: str) -> Estimator | None:
        """The group's cfg-derived instance for ``kind`` if one has been
        constructed -- the planner's cross-group fusion eligibility test
        (an ``estimator_cfg``-overridden stream's instance is never this
        one, so it never fuses across groups)."""
        return self._estimators.get(kind)


@dataclasses.dataclass
class StreamEntry:
    name: str
    group_id: str
    uid: int                        # dense per-registry id (keys, stacking order)
    window: WindowedSketch
    estimator_kind: str = "sjpc"
    flushes: int = 0                # ingest flushes consumed (PRNG folding)
    records: int = 0                # total records ever ingested

    @property
    def estimator(self) -> Estimator:
        return self.window.estimator


class StreamRegistry:
    def __init__(self, obs: Observability | None = None):
        self.obs = obs if obs is not None else Observability.disabled()
        self._groups: dict[str, HashGroup] = {}
        self._streams: dict[str, StreamEntry] = {}
        self._next_uid = 0
        # topology version: bumped on every group/stream registration.
        # Cohort membership -- which streams stack into which batched
        # launch -- is a pure function of the registered streams, so this
        # is the invalidation key for the query planner's cached fusion
        # plan (planner.py; estimator-cfg choices happen at registration
        # too, so they are covered)
        self.version = 0

    # ------------------------------------------------------------------
    def create_group(self, group_id: str, cfg: SJPCConfig, *,
                     estimator_opts: dict | None = None) -> HashGroup:
        if group_id in self._groups:
            raise ValueError(f"group {group_id!r} already exists")
        params, _ = sjpc.init(cfg)
        group = HashGroup(group_id=group_id, cfg=cfg, params=params,
                          estimator_opts=dict(estimator_opts or {}))
        self._groups[group_id] = group
        self.version += 1
        return group

    def register(self, name: str, group_id: str,
                 window_epochs: int | None = None, *,
                 estimator: str = "sjpc",
                 estimator_cfg=None,
                 backing_epochs: int = 0,
                 uid: int | None = None) -> StreamEntry:
        """``uid`` pins the stream's per-registry id instead of taking the
        next dense one.  The uid keys the per-(stream, round) ingest PRNG
        grid (``ingest.ingest_key``), so a distributed worker that pins
        its tenants' *global* uids sketches bit-identically to a
        single-process run over the same stream -- the replica-vs-oracle
        contract of DESIGN.md §18.  Pinned uids must be unique; the dense
        counter skips past them."""
        if name in self._streams:
            raise ValueError(f"stream {name!r} already registered")
        if uid is None:
            uid = self._next_uid
        elif any(e.uid == uid for e in self._streams.values()):
            raise ValueError(f"uid {uid} already taken (pinned uids must "
                             "be unique per registry)")
        group = self.group(group_id)
        est = group.estimator(estimator, estimator_cfg)
        entry = StreamEntry(
            name=name, group_id=group_id, uid=uid,
            window=WindowedSketch(est, est.init(sid=0), window_epochs,
                                  backing_epochs=backing_epochs,
                                  obs=self.obs, name=name),
            estimator_kind=estimator)
        self._next_uid = max(self._next_uid, uid) + 1
        self._streams[name] = entry
        self.version += 1
        return entry

    # ------------------------------------------------------------------
    def group(self, group_id: str) -> HashGroup:
        if group_id not in self._groups:
            raise KeyError(f"unknown group {group_id!r}")
        return self._groups[group_id]

    def stream(self, name: str) -> StreamEntry:
        if name not in self._streams:
            raise KeyError(f"unknown stream {name!r}")
        return self._streams[name]

    def group_of(self, name: str) -> HashGroup:
        return self.group(self.stream(name).group_id)

    def streams(self, group_id: str | None = None) -> list[StreamEntry]:
        entries = list(self._streams.values())
        if group_id is not None:
            entries = [e for e in entries if e.group_id == group_id]
        return entries

    def groups(self) -> list[HashGroup]:
        return list(self._groups.values())

    def joinable(self, a: str, b: str) -> bool:
        """Two streams support the §6 join estimator iff they share hashes
        AND both run a kind whose spec declares ``join_capable``
        (DESIGN.md §19; built in: SJPC)."""
        ea, eb = self.stream(a), self.stream(b)
        return (ea.group_id == eb.group_id
                and ea.estimator_kind == eb.estimator_kind
                and est_mod.spec_of(ea.estimator).join_capable)

    def require_joinable(self, a: str, b: str) -> HashGroup:
        ea, eb = self.stream(a), self.stream(b)
        if ea.group_id != eb.group_id:
            raise ValueError(
                f"streams {a!r} ({ea.group_id}) and {b!r} "
                f"({eb.group_id}) are in different hash groups; "
                "the join estimator needs identical hash params (paper §6)")
        if not self.joinable(a, b):
            raise ValueError(
                f"streams {a!r} ({ea.estimator_kind}) and {b!r} "
                f"({eb.estimator_kind}) must both run a join-capable "
                "estimator kind to answer §6 join queries")
        return self.group_of(a)
