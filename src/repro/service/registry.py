"""Sketch registry: many named streams (tenants), grouped by shared hashes.

A **hash group** owns one ``SJPCConfig`` and one draw of ``SJPCParams``
(bucket/sign hash coefficients + fingerprint bases).  Every stream
registered into the group sketches with those exact parameters, which is
the paper's §6 precondition: the similarity-*join* estimator is the sketch
inner product, and inner products are only meaningful between sketches
built with identical hash functions.  Streams in different groups can use
different configs (dimensionality, threshold, width, ...) but are not
pairwise joinable -- the registry enforces this at query time.

Each stream carries its own :class:`~repro.service.window.WindowedSketch`,
so tenants in one group may still have different window lengths.
"""
from __future__ import annotations

import dataclasses

from repro.core import sjpc
from repro.core.sjpc import SJPCConfig, SJPCParams

from .window import WindowedSketch


@dataclasses.dataclass(frozen=True)
class HashGroup:
    group_id: str
    cfg: SJPCConfig
    params: SJPCParams


@dataclasses.dataclass
class StreamEntry:
    name: str
    group_id: str
    uid: int                        # dense per-registry id (keys, stacking order)
    window: WindowedSketch
    flushes: int = 0                # ingest flushes consumed (PRNG folding)
    records: int = 0                # total records ever ingested


class StreamRegistry:
    def __init__(self):
        self._groups: dict[str, HashGroup] = {}
        self._streams: dict[str, StreamEntry] = {}
        self._next_uid = 0

    # ------------------------------------------------------------------
    def create_group(self, group_id: str, cfg: SJPCConfig) -> HashGroup:
        if group_id in self._groups:
            raise ValueError(f"group {group_id!r} already exists")
        params, _ = sjpc.init(cfg)
        group = HashGroup(group_id=group_id, cfg=cfg, params=params)
        self._groups[group_id] = group
        return group

    def register(self, name: str, group_id: str,
                 window_epochs: int | None = None) -> StreamEntry:
        if name in self._streams:
            raise ValueError(f"stream {name!r} already registered")
        group = self.group(group_id)
        _, state = sjpc.init(group.cfg)     # zero counters, fresh step
        entry = StreamEntry(
            name=name, group_id=group_id, uid=self._next_uid,
            window=WindowedSketch(group.cfg, state, window_epochs))
        self._next_uid += 1
        self._streams[name] = entry
        return entry

    # ------------------------------------------------------------------
    def group(self, group_id: str) -> HashGroup:
        if group_id not in self._groups:
            raise KeyError(f"unknown group {group_id!r}")
        return self._groups[group_id]

    def stream(self, name: str) -> StreamEntry:
        if name not in self._streams:
            raise KeyError(f"unknown stream {name!r}")
        return self._streams[name]

    def group_of(self, name: str) -> HashGroup:
        return self.group(self.stream(name).group_id)

    def streams(self, group_id: str | None = None) -> list[StreamEntry]:
        entries = list(self._streams.values())
        if group_id is not None:
            entries = [e for e in entries if e.group_id == group_id]
        return entries

    def groups(self) -> list[HashGroup]:
        return list(self._groups.values())

    def joinable(self, a: str, b: str) -> bool:
        """Two streams support the §6 join estimator iff they share hashes."""
        return self.stream(a).group_id == self.stream(b).group_id

    def require_joinable(self, a: str, b: str) -> HashGroup:
        if not self.joinable(a, b):
            raise ValueError(
                f"streams {a!r} ({self.stream(a).group_id}) and {b!r} "
                f"({self.stream(b).group_id}) are in different hash groups; "
                "the join estimator needs identical hash params (paper §6)")
        return self.group_of(a)
