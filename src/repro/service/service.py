"""`EstimationService`: the multi-tenant streaming estimation front end.

Composes the subsystem (DESIGN.md §10):

  registry.py   named streams grouped by shared hash params (join-ability)
  window.py     per-stream sliding windows; expiry = counter subtraction
  ingest.py     double-buffered, fixed-shape, single-dispatch batched ingest
  query.py      snapshot-based queries with analytical error bars

Lifecycle:

    svc = EstimationService()
    svc.create_group("g", SJPCConfig(d=6, s=4, width=2048, depth=3))
    svc.create_stream("tenant-a", "g", window_epochs=8)
    svc.ingest("tenant-a", records)        # buffered (numpy in, no device work)
    svc.flush()                            # one jit'd dispatch per group round
    svc.advance_epoch()                    # close the epoch on every window
    r = svc.snapshot().self_join("tenant-a")   # estimate +/- r.stderr

``ingest`` is deliberately device-free so tenant request handling stays
cheap; all device work happens in ``flush`` (and is shared across tenants).
``poll()`` evaluates the registered continuous queries against one shared
snapshot -- the batched continuous-query path.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro import estimators
from repro import platform as repro_platform
from repro.core.sjpc import SJPCConfig, SJPCState
from repro.obs import (AccuracyAuditor, Observability, Tracer,
                       default_registry, default_tracer)

from .ingest import IngestPipeline
from .planner import PlannerConfig, QueryPlanner
from .query import ContinuousQuery, QueryEngine, QueryResult, Snapshot
from .registry import HashGroup, StreamEntry, StreamRegistry


_DEFAULT_WINDOW = object()       # "use ServiceConfig.window_epochs" sentinel


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    platform: str = "auto"           # backend bootstrap (repro.platform):
                                     # "auto" = trust jax's accelerator
                                     # preference; "cpu"/"gpu"/"tpu" pins it
                                     # (effective only before jax init)
    batch_rows: int = 256            # ingest round size per stream
    window_epochs: int | None = 8    # default; per-stream override at create
    auto_flush_rows: int | None = None   # flush() when a group's backlog hits this
    use_pallas: bool | None = None   # None = auto (Pallas on TPU)
    interpret: bool | None = None    # forwarded to the Pallas path
    use_fused: bool = True           # fused ingest path; False = reference oracle
    shards: int = 1                  # data-parallel ingest shards per round
    use_fused_query: bool = True     # batched query engine; False = per-stream
                                     # numpy oracle (DESIGN.md §12)
    estimator: str = "sjpc"          # default estimator kind for new streams
                                     # (any repro.estimators kind; per-stream
                                     # override at create_stream)
    backing_epochs: int = 0          # default sample-window refill depth K
                                     # (DESIGN.md §14.2; per-stream override
                                     # at create_stream; sample kinds only)
    observe: bool = True             # metrics + spans (DESIGN.md §15); False =
                                     # shared no-op bundle, reference-speed paths
    audit_rate: float = 0.0          # sampled exact-replay accuracy telemetry
                                     # (0 = off; 1 = audit every polled query)
    audit_max_records: int = 65536   # audit skip threshold (exact oracle cost)
    trace_sink: object = None        # JSON-lines span sink: path or file-like
    trace_annotate: bool = False     # bracket spans in jax.profiler annotations
    use_planner: bool = True         # plan poll() through the query planner
                                     # (cross-group fusion + admission,
                                     # DESIGN.md §16); False = the PR 3
                                     # per-group prefetch path
    planner: PlannerConfig = PlannerConfig()   # fusion/budget knobs


class EstimationService:
    def __init__(self, cfg: ServiceConfig = ServiceConfig(), *,
                 obs: Observability | None = None):
        self.cfg = cfg
        self.platform = repro_platform.bootstrap(cfg.platform)
        if obs is None:
            obs = self._build_obs(cfg)
        if cfg.audit_rate > 0.0 and obs.auditor is None:
            obs = dataclasses.replace(obs, auditor=AccuracyAuditor(
                obs.metrics, rate=cfg.audit_rate,
                max_records=cfg.audit_max_records))
        self.obs = obs
        self.registry = StreamRegistry(obs=self.obs)
        self.engine = QueryEngine(self.registry,
                                  use_fused_query=cfg.use_fused_query,
                                  use_pallas=cfg.use_pallas,
                                  interpret=cfg.interpret,
                                  obs=self.obs)
        self._pipelines: dict[str, IngestPipeline] = {}
        self._continuous: dict[str, ContinuousQuery] = {}
        self.planner = (QueryPlanner(self.registry, cfg.planner,
                                     obs=self.obs)
                        if cfg.use_planner else None)
        self.stats = {"ingested_records": 0, "flush_s": 0.0, "epochs": 0,
                      "snapshots": 0, "polls": 0}

    @staticmethod
    def _build_obs(cfg: ServiceConfig) -> Observability:
        """Default bundle: the process-global registry/tracer, a private
        tracer only when the config asks for a sink or profiler
        annotations (so two services never interleave one file)."""
        if not cfg.observe:
            return Observability.disabled()
        metrics = default_registry()
        if cfg.trace_sink is not None or cfg.trace_annotate:
            tracer = Tracer(sink=cfg.trace_sink, annotate=cfg.trace_annotate,
                            registry=metrics)
        else:
            tracer = default_tracer()
        return Observability(metrics=metrics, tracer=tracer)

    # -- provisioning ---------------------------------------------------
    def create_group(self, group_id: str, cfg: SJPCConfig) -> HashGroup:
        group = self.registry.create_group(
            group_id, cfg,
            estimator_opts={
                "sjpc": {"use_fused": self.cfg.use_fused,
                         "use_pallas": self.cfg.use_pallas,
                         "interpret": self.cfg.interpret,
                         "shards": self.cfg.shards},
                "reservoir": {"use_pallas": self.cfg.use_pallas,
                              "interpret": self.cfg.interpret},
            })
        self._pipelines[group_id] = IngestPipeline(
            group, batch_rows=self.cfg.batch_rows,
            use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret,
            use_fused=self.cfg.use_fused, shards=self.cfg.shards,
            obs=self.obs)
        return group

    def create_stream(self, name: str, group_id: str,
                      window_epochs=_DEFAULT_WINDOW, *,
                      estimator: str | None = None,
                      estimator_cfg=None,
                      backing_epochs: int | None = None,
                      uid: int | None = None) -> StreamEntry:
        """Register a stream.  ``estimator`` picks the protocol kind
        ("sjpc" | "reservoir" | "lsh_ss", default from ServiceConfig);
        competitors derive an equal-space config from the group's
        SJPCConfig unless ``estimator_cfg`` overrides it.
        ``backing_epochs`` enables the sample-window refill fold for
        windowed sample estimators (default from ServiceConfig; linear
        kinds reject it -- their expiry is exact already).  ``uid`` pins
        the stream's registry id (distributed workers pin global tenant
        uids so their ingest PRNG grid matches a single-process run --
        see StreamRegistry.register)."""
        if window_epochs is _DEFAULT_WINDOW:
            window_epochs = self.cfg.window_epochs
        kind = estimator or self.cfg.estimator
        if backing_epochs is None:
            backing = self.cfg.backing_epochs
            # the config-level default applies only where it is meaningful
            # (bounded sample windows); explicit arguments stay strict.
            # ``linear`` is a kind-level capability, read from the spec
            # (the group's cached instance resolves legacy registrations)
            if (estimators.spec_of(
                    self.registry.group(group_id).estimator(kind)).linear
                    or window_epochs is None):
                backing = 0
        else:
            backing = backing_epochs
        entry = self.registry.register(
            name, group_id, window_epochs, estimator=kind,
            estimator_cfg=estimator_cfg, backing_epochs=backing, uid=uid)
        if self.obs.metrics.enabled:
            self.obs.metrics.set("estimator_memory_bytes",
                                 float(entry.window.memory_bytes()),
                                 stream=name, kind=kind)
            entry.window._export_gauges()
        return entry

    # -- ingest ---------------------------------------------------------
    def ingest(self, name: str, records) -> int:
        """Buffer records for ``name``; device work is deferred to flush."""
        entry = self.registry.stream(name)
        pipe = self._pipelines[entry.group_id]
        n = pipe.submit(name, records)
        self.stats["ingested_records"] += n
        if self.obs.auditor is not None:
            self.obs.auditor.record(name, records,
                                    entry.window.window_epochs)
        if (self.cfg.auto_flush_rows is not None
                and pipe.pending_rows() >= self.cfg.auto_flush_rows):
            self._flush_group(entry.group_id)
        return n

    def ingest_state_delta(self, name: str, delta: SJPCState) -> None:
        """Absorb an externally-sketched delta (e.g. the training monitor's
        counters since its last publish) into ``name``'s open epoch.  The
        delta must have been sketched with this stream's group params (and
        the stream must run a linear estimator kind -- sample estimators
        cannot absorb foreign states)."""
        entry = self.registry.stream(name)
        est = entry.estimator
        if not estimators.spec_of(est).linear:
            raise ValueError(
                f"stream {name!r} runs non-linear estimator "
                f"{entry.estimator_kind!r}; external state deltas need a "
                "linear (mergeable-by-arithmetic) estimator")
        entry.window.absorb_delta(est.merge(entry.window.ingest_base(), delta))
        if self.obs.auditor is not None:
            self.obs.auditor.mark_unauditable(name)
        self.obs.metrics.inc("ingest_state_deltas_total", stream=name)

    # -- multi-host delta exchange (distributed/, DESIGN.md §18) --------
    def export_deltas(self) -> list:
        """Every stream's unshipped window delta since the last export
        (flushing first so the exports reflect all buffered records):
        ``[(name, kind, epoch, window_version, mode, state), ...]``.
        Streams with nothing new are skipped entirely -- an idle service
        returns ``[]`` and its worker ships the zero-byte heartbeat."""
        self.flush()
        out = []
        for e in self.registry.streams():
            d = e.window.export_delta()
            if d is None:
                continue
            mode, state = d
            out.append((e.name, e.estimator_kind, e.window.epoch,
                        e.window.version, mode, state))
            self.obs.metrics.inc("delta_exports_total", stream=e.name,
                                 mode=mode)
        return out

    def apply_remote_delta(self, name: str, mode: str, state) -> None:
        """Replica-side application of one exported delta.  ``"merge"``
        folds a linear counter delta into the open epoch via the existing
        merge algebra (exactly :meth:`ingest_state_delta`); ``"replace"``
        installs a sample kind's open-slot state and refolds.  Epoch
        alignment (apply-before-advance) is the coordinator's contract."""
        entry = self.registry.stream(name)
        if mode == "merge":
            self.ingest_state_delta(name, state)
            return
        if mode != "replace":
            raise ValueError(f"unknown delta mode {mode!r}")
        if estimators.spec_of(entry.estimator).linear:
            raise ValueError(
                f"stream {name!r} runs linear estimator "
                f"{entry.estimator_kind!r}; replace-mode deltas are the "
                "sample-window protocol (linear kinds merge)")
        entry.window.absorb_delta(state)
        if self.obs.auditor is not None:
            self.obs.auditor.mark_unauditable(name)
        self.obs.metrics.inc("ingest_state_deltas_total", stream=name)

    def _flush_group(self, group_id: str) -> None:
        pipe = self._pipelines[group_id]
        entries = self.registry.streams(group_id)
        with self.obs.span("service.flush", histogram="service_flush_seconds",
                           labels={"group": group_id},
                           group=group_id, streams=len(entries)):
            t0 = time.perf_counter()
            new_states = pipe.flush(entries)
            for e in entries:
                e.window.absorb_delta(new_states[e.name])
            # jax dispatch is asynchronous: without blocking on the
            # committed windows this timed the *enqueue* and reported
            # near-zero.  flush_s is device-inclusive wall time, obs on
            # or off (the span's histogram inherits the same interval)
            jax.block_until_ready(
                [jax.tree_util.tree_leaves(e.window.total) for e in entries])
            self.stats["flush_s"] += time.perf_counter() - t0

    def flush(self) -> None:
        """Drain every group's ingest buffer into the windows."""
        for group_id in list(self._pipelines):
            self._flush_group(group_id)

    # -- windowing ------------------------------------------------------
    def advance_epoch(self, name: str | None = None) -> None:
        """Close the open epoch (flushing first so the epoch boundary is
        exact); expired epochs are subtracted out of their windows."""
        self.flush()
        entries = (self.registry.streams() if name is None
                   else [self.registry.stream(name)])
        for e in entries:
            e.window.advance_epoch()
            if self.obs.auditor is not None:
                self.obs.auditor.advance_epoch(e.name)
        self.stats["epochs"] += 1
        self.obs.metrics.inc("service_epochs_total")

    # -- queries --------------------------------------------------------
    def snapshot(self, names: list[str] | None = None) -> Snapshot:
        self.flush()
        self.stats["snapshots"] += 1
        return self.engine.snapshot(names)

    def register_continuous(self, query: ContinuousQuery) -> None:
        if query.name in self._continuous:
            raise ValueError(f"continuous query {query.name!r} already exists")
        # validate eagerly: unknown streams / non-joinable pairs fail here,
        # not at poll time
        for s in query.streams:
            self.registry.stream(s)
        if query.kind == "join":
            self.registry.require_joinable(*query.streams)
        self._continuous[query.name] = query
        if self.planner is not None:
            self.planner.invalidate_queries()

    def set_tenant_budget(self, tenant: str, refill: float | None, *,
                          burst: float | None = None) -> None:
        """Set (or clear) one tenant's per-poll standing-query budget; see
        :meth:`QueryPlanner.set_tenant_budget`.  Requires the planner."""
        if self.planner is None:
            raise ValueError("admission control needs use_planner=True")
        self.planner.set_tenant_budget(tenant, refill, burst=burst)

    def poll(self) -> dict[str, QueryResult | dict[int, QueryResult]]:
        """Evaluate every continuous query against ONE shared snapshot.

        With the planner (the default) the device work is scheduled through
        the cached fusion plan: matching cohorts across hash groups share
        one ``estimate_batch`` launch, launches run in priority order, and
        over-budget tenants are served their last fresh result with
        ``stale=True`` (DESIGN.md §16).  With ``use_planner=False`` the
        PR 3 path prefetches one batch per touched group instead.  Either
        way the individual ``evaluate`` calls are pure cache lookups.
        """
        with self.obs.span("service.poll", histogram="service_poll_seconds",
                           queries=len(self._continuous)):
            snap = self.snapshot()
            if self.planner is not None:
                out = self.planner.poll(snap, self._continuous)
            else:
                snap.prefetch(self._continuous.values())
                out = {name: q.evaluate(snap)
                       for name, q in self._continuous.items()}
            self.stats["polls"] += 1
        if self.obs.auditor is not None:
            for q in self._continuous.values():
                res = out[q.name]
                if (res.stale if isinstance(res, QueryResult)
                        else any(r.stale for r in res.values())):
                    continue          # already audited when it was fresh
                kind = self.registry.stream(q.streams[0]).estimator_kind
                self.obs.auditor.maybe_audit(res, kind)
        return out

    # -- introspection --------------------------------------------------
    def describe(self) -> dict:
        groups = {}
        for g in self.registry.groups():
            pipe = self._pipelines[g.group_id]
            groups[g.group_id] = {
                "cfg": dataclasses.asdict(g.cfg),
                "streams": {e.name: {"records": e.records,
                                     "estimator": e.estimator_kind,
                                     "window_epochs": e.window.window_epochs,
                                     "live_epochs": e.window.live_epochs,
                                     "memory_bytes": e.window.memory_bytes()}
                            for e in self.registry.streams(g.group_id)},
                "ingest": dict(pipe.stats),
            }
        return {"groups": groups, "continuous": list(self._continuous),
                **self.stats}

    def refresh_gauges(self) -> None:
        """Recompute the derived / point-in-time gauges (memory bytes,
        window geometry, queue depth, per-(group, kind) cache hit
        ratios) so an export reflects *now*, not the last mutation."""
        m = self.obs.metrics
        if not m.enabled:
            return
        for e in self.registry.streams():
            m.set("estimator_memory_bytes", float(e.window.memory_bytes()),
                  stream=e.name, kind=e.estimator_kind)
            e.window._export_gauges()
        for group_id, pipe in self._pipelines.items():
            m.set("ingest_pending_rows", float(pipe.pending_rows()),
                  group=group_id)
        hits = m.series("query_cache_hits_total")
        misses = m.series("query_cache_misses_total")
        for key in sorted(set(hits) | set(misses)):
            h, miss = hits.get(key, 0.0), misses.get(key, 0.0)
            if h + miss > 0:
                m.set("query_cache_hit_ratio", h / (h + miss),
                      **dict(key))

    def metrics_report(self) -> str:
        """The service's metric state in the Prometheus text exposition
        format (derived gauges refreshed first).  ``obs.metrics.collect()``
        is the plain-dict equivalent for programmatic readers."""
        self.refresh_gauges()
        return self.obs.metrics.to_prometheus()
