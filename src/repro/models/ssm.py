"""Mamba2 / SSD (state-space duality) mixer: chunked scan + recurrent decode.

The SSD recurrence per head (state N, head dim P):

    h_t = exp(a_t) * h_{t-1} + dt_t * (B_t outer x_t)        a_t = -exp(A_log)*dt_t
    y_t = C_t . h_t + D * x_t

Train/prefill uses the chunked form: a ``lax.scan`` over length-L chunks
carries the (B, H, N, P) inter-chunk state; within a chunk the quadratic
"attention-like" form computes intra-chunk contributions with the decay mask
exp(cum[i] - cum[j]).  Memory is O(B * L * H * (L + N + P)) per step
independent of sequence length -- this is what makes ``long_500k`` run.

Decode is the O(1) recurrent step (plus a (k-1)-deep causal-conv state).

TP: heads shard over the `model` axis (every per-head tensor carries the
"ssm_heads" logical axis); B/C group projections are small and replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import Dims
from .layers import P, dense_init, zeros_init, ones_init

DEFAULT_CHUNK = 128


def init_mamba(key, dims: Dims) -> dict:
    cfg = dims.cfg
    d, g, n, kconv = cfg.d_model, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    h, p = dims.ssm_heads, cfg.ssm_head_dim
    ks = jax.random.split(key, 8)
    # A init in [1, 16] (mamba2 default): A_log = log(uniform)
    a_init = jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32))
    # dt bias ~ softplus^-1(uniform in [1e-3, 1e-1])
    dt0 = jnp.exp(jnp.linspace(np.log(1e-3), np.log(1e-1), h, dtype=jnp.float32))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "wz": dense_init(ks[0], (d, h, p), ("embed", "ssm_heads", "hd")),
        "wx": dense_init(ks[1], (d, h, p), ("embed", "ssm_heads", "hd")),
        "wB": dense_init(ks[2], (d, g, n), ("embed", "ssm_group", "state")),
        "wC": dense_init(ks[3], (d, g, n), ("embed", "ssm_group", "state")),
        "wdt": dense_init(ks[4], (d, h), ("embed", "ssm_heads")),
        "conv_x": dense_init(ks[5], (h, p, kconv), ("ssm_heads", "hd", "conv"),
                             scale=1.0 / np.sqrt(kconv)),
        "conv_bc": dense_init(ks[6], (2 * g * n, kconv), ("conv_ch", "conv"),
                              scale=1.0 / np.sqrt(kconv)),
        "A_log": P(a_init, ("ssm_heads",)),
        "dt_bias": P(dt_bias, ("ssm_heads",)),
        "D": ones_init((h,), ("ssm_heads",)),
        "norm": ones_init((h, p), ("ssm_heads", "hd")),
        "wo": dense_init(ks[7], (h, p, d), ("ssm_heads", "hd", "embed_out"),
                         scale=1.0 / np.sqrt(h * p)),
    }


def _causal_conv(seq, weight, *, state=None):
    """Depthwise causal conv along time.  seq (B, S, C), weight (C, K).

    state: optional (B, K-1, C) left context (decode/prefill chaining);
    zeros when None.  Returns (out (B, S, C), new_state (B, K-1, C)).
    """
    b, s, c = seq.shape
    k = weight.shape[-1]
    if state is None:
        state = jnp.zeros((b, k - 1, c), seq.dtype)
    full = jnp.concatenate([state, seq], axis=1)              # (B, S+K-1, C)
    out = jnp.zeros((b, s, c), jnp.float32)
    for i in range(k):                                        # K is 4: unrolled
        out = out + full[:, i:i + s, :].astype(jnp.float32) * weight[:, i].astype(jnp.float32)
    new_state = full[:, -(k - 1):, :] if k > 1 else jnp.zeros((b, 0, c), seq.dtype)
    return out.astype(seq.dtype), new_state


def _project(params, u, dims: Dims):
    """u (B, S, d) -> z, x, Bm, Cm, dt (pre-conv, pre-activation)."""
    z = jnp.einsum("bsd,dhp->bshp", u, params["wz"])
    x = jnp.einsum("bsd,dhp->bshp", u, params["wx"])
    bm = jnp.einsum("bsd,dgn->bsgn", u, params["wB"])
    cm = jnp.einsum("bsd,dgn->bsgn", u, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", u, params["wdt"])
    return z, x, bm, cm, dt


def _conv_split(params, x, bm, cm, conv_state=None):
    """Apply the causal convs; returns activated x, B, C and new conv states."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    xs = x.reshape(b, s, h * p)
    cw = params["conv_x"].reshape(h * p, -1)
    bc = jnp.concatenate([bm.reshape(b, s, g * n), cm.reshape(b, s, g * n)], axis=-1)
    st_x = None if conv_state is None else conv_state["x"]
    st_bc = None if conv_state is None else conv_state["bc"]
    xs, new_x = _causal_conv(xs, cw, state=st_x)
    bc, new_bc = _causal_conv(bc, params["conv_bc"], state=st_bc)
    xs = jax.nn.silu(xs).reshape(b, s, h, p)
    bc = jax.nn.silu(bc)
    bm = bc[..., :g * n].reshape(b, s, g, n)
    cm = bc[..., g * n:].reshape(b, s, g, n)
    return xs, bm, cm, {"x": new_x, "bc": new_bc}


def ssd_chunked(x, a, dt, bm, cm, *, chunk: int = DEFAULT_CHUNK, h0=None):
    """Chunked SSD.  x (B,S,H,P), a/dt (B,S,H), bm/cm (B,S,G,N).

    Returns (y (B,S,H,P) fp32, h_final (B,H,N,P) fp32).
    """
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    hg = h // g
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l

    xdt = (x.astype(jnp.float32) * dt[..., None])              # (B,S,H,P)
    # chunked views, scanned over axis 0
    xc = jnp.moveaxis(xdt.reshape(b, nc, l, h, p), 1, 0)
    ac = jnp.moveaxis(a.reshape(b, nc, l, h), 1, 0)
    bc_ = jnp.moveaxis(bm.astype(jnp.float32).reshape(b, nc, l, g, n), 1, 0)
    cc_ = jnp.moveaxis(cm.astype(jnp.float32).reshape(b, nc, l, g, n), 1, 0)

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def step2(hstate, inp):
        xk, ak, bk, ck = inp                # (B,L,H,P) (B,L,H) (B,L,G,N) x2
        cum = jnp.cumsum(ak, axis=1)        # inclusive (B,L,H)
        # ---- intra-chunk (quadratic in L) ----
        cb = jnp.einsum("bign,bjgn->bijg", ck, bk)             # (B,L,L,G)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
        w = jnp.where((ii >= jj)[None, :, :, None], decay, 0.0)        # (B,i,j,H)
        if g > 1:
            scores = jnp.repeat(cb, hg, axis=3)                # (B,i,j,H)
        else:
            scores = jnp.broadcast_to(cb, (b, l, l, h))
        scores = scores * w
        y = jnp.einsum("bijh,bjhp->bihp", scores, xk)
        # inter-chunk: y_i += exp(cum_i) * C_i . h_in
        ckh = _group_to_heads(ck, h)                           # (B,L,H,N)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum("bihn,bhnp->bihp", ckh, hstate)
        # state update
        last = cum[:, -1:, :]                                  # (B,1,H)
        wstate = jnp.exp(last - cum)                           # (B,L,H)
        bkh = _group_to_heads(bk, h)                           # (B,L,H,N)
        s_new = jnp.einsum("bjh,bjhn,bjhp->bhnp", wstate, bkh, xk)
        hstate = jnp.exp(last[:, 0, :])[:, :, None, None] * hstate + s_new
        return hstate, y

    h_final, ys = jax.lax.scan(step2, h0, (xc, ac, bc_, cc_))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, h_final


def _group_to_heads(t, h):
    """(B, L, G, N) -> (B, L, H, N) by repeating each group H/G times."""
    b, l, g, n = t.shape
    if g == h:
        return t
    return jnp.broadcast_to(t[:, :, :, None, :], (b, l, g, h // g, n)).reshape(b, l, h, n)


def mamba_block(params, u, dims: Dims, *, chunk: int = DEFAULT_CHUNK,
                conv_state=None, ssm_state=None):
    """Full-sequence mixer.  u (B, S, d) -> (out (B,S,d), new states)."""
    cfg = dims.cfg
    z, x, bm, cm, dt = _project(params, u, dims)
    x, bm, cm, new_conv = _conv_split(params, x, bm, cm, conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    a = -jnp.exp(params["A_log"]) * dt                                 # (B,S,H)
    y, h_final = ssd_chunked(x, a, dt, bm, cm, chunk=chunk, h0=ssm_state)
    y = y + params["D"][:, None] * x.astype(jnp.float32)
    y = _gated_norm(params["norm"], y, z, cfg.rms_eps)
    out = jnp.einsum("bshp,hpd->bsd", y.astype(u.dtype), params["wo"])
    return out, {"conv": new_conv, "ssm": h_final}


def _gated_norm(scale, y, z, eps):
    """RMSNorm(y * silu(z)) * scale -- mamba2's gated output norm (per head)."""
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * scale


def mamba_decode_step(params, u, dims: Dims, conv_state, ssm_state):
    """One-token recurrent step.  u (B, 1, d).

    conv_state: {"x": (B,K-1,H*P), "bc": (B,K-1,2GN)}; ssm_state (B,H,N,P).
    """
    cfg = dims.cfg
    z, x, bm, cm, dt = _project(params, u, dims)
    x, bm, cm, new_conv = _conv_split(params, x, bm, cm, conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,1,H)
    a = -jnp.exp(params["A_log"]) * dt
    h = dims.ssm_heads
    bkh = _group_to_heads(bm.astype(jnp.float32), h)[:, 0]             # (B,H,N)
    ckh = _group_to_heads(cm.astype(jnp.float32), h)[:, 0]
    xdt = x.astype(jnp.float32)[:, 0] * dt[:, 0][..., None]            # (B,H,P)
    ssm_state = (jnp.exp(a[:, 0])[..., None, None] * ssm_state
                 + bkh[..., None] * xdt[:, :, None, :])                # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", ckh, ssm_state)[:, None]           # (B,1,H,P)
    y = y + params["D"][:, None] * x.astype(jnp.float32)
    y = _gated_norm(params["norm"], y, z, cfg.rms_eps)
    out = jnp.einsum("bshp,hpd->bsd", y.astype(u.dtype), params["wo"])
    return out, {"conv": new_conv, "ssm": ssm_state}


def init_mamba_state(dims: Dims, batch: int, dtype=jnp.bfloat16):
    """Zero decode state for one mamba layer."""
    cfg = dims.cfg
    h, p = dims.ssm_heads, cfg.ssm_head_dim
    g, n, k = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": {"x": jnp.zeros((batch, k - 1, h * p), dtype),
                 "bc": jnp.zeros((batch, k - 1, 2 * g * n), dtype)},
        "ssm": jnp.zeros((batch, h, n, p), jnp.float32),
    }
