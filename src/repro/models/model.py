"""Arch-config -> init / forward / prefill / decode, with scan-over-layers.

Layers with identical structure are stacked and applied under ``lax.scan``
(one compiled body per *group* of equal layers; hybrid archs like jamba scan
over whole periods).  This keeps HLO size O(distinct layer kinds) instead of
O(num_layers) -- essential for 72-layer 398B dry-run compiles.

Public entry points (all pure; cfg/dims are static):

    init_params(key, cfg, dims)               -> P-tree
    forward(params, cfg, dims, tokens, ...)   -> (logits, aux)     [train]
    lm_loss(logits, labels, true_vocab)       -> scalar
    init_cache(cfg, dims, batch, max_len)     -> cache
    prefill(params, cfg, dims, tokens, ...)   -> (logits_last, cache)
    decode_step(params, cfg, dims, token, cache) -> (logits, cache)

Sharding: parameters carry logical axis names (see layers.P); activations
are annotated via the optional ``act_spec`` (a PartitionSpec for (B, S, d)
activations) so GSPMD propagation is pinned down at group boundaries.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ArchConfig, Dims, _layer_list
from . import blocks
from .layers import (P, is_p, add_leading_axis_name, init_embedding, embed,
                     init_rmsnorm, rmsnorm, mask_padded_vocab, dense_init)


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------

def layer_groups(cfg: ArchConfig) -> list[tuple[tuple, int]]:
    """[(period_specs, repeat_count)] -- consecutive equal periods merge."""
    specs = _layer_list(cfg)
    period = cfg.period
    assert len(specs) % period == 0
    periods = [tuple(specs[i * period:(i + 1) * period])
               for i in range(len(specs) // period)]
    groups: list[tuple[tuple, int]] = []
    for p in periods:
        if groups and groups[-1][0] == p:
            groups[-1] = (p, groups[-1][1] + 1)
        else:
            groups.append((p, 1))
    return groups


def _stack_init(key, count: int, init_one):
    """vmap an init function over ``count`` keys; tag the stacked axis."""
    keys = jax.random.split(key, count)
    stacked = jax.vmap(init_one)(keys)
    return add_leading_axis_name(stacked, "layers")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, dims: Dims) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], dims.vocab, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, dims.vocab),
                                       ("embed", "vocab"))
    groups = []
    for gi, (pspec, count) in enumerate(layer_groups(cfg)):
        gkey = jax.random.fold_in(keys[2], gi)

        def init_one(k, _pspec=pspec):
            ks = jax.random.split(k, len(_pspec))
            return tuple(blocks.init_layer(ks[i], dims, _pspec[i],
                                           cross=cfg.is_encdec)
                         for i in range(len(_pspec)))

        groups.append(_stack_init(gkey, count, init_one))
    params["groups"] = groups

    if cfg.is_encdec:
        def init_enc_layer(k):
            return (blocks.init_layer(k, dims, ("A", False), cross=False),)
        params["encoder"] = {
            "layers": _stack_init(keys[3], cfg.encoder_layers, init_enc_layer),
            "norm": init_rmsnorm(cfg.d_model),
        }
    return params


def param_count_tree(params) -> int:
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda p: p.value if is_p(p) else p, params,
                               is_leaf=is_p))
    return sum(int(l.size) for l in leaves)


# ---------------------------------------------------------------------------
# Forward (train / full-sequence)
# ---------------------------------------------------------------------------

def _maybe_constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def strip_p(tree):
    """P-tree -> plain array tree (no-op on already-plain trees).

    Apply functions take *plain* params; callers hold the logical-axes tree
    separately (layers.split_tree) for sharding.
    """
    return jax.tree_util.tree_map(lambda p: p.value if is_p(p) else p, tree,
                                  is_leaf=is_p)


def _cast(tree, dtype):
    tree = strip_p(tree)
    def f(x):
        if isinstance(x, jax.Array) and x.dtype == jnp.float32:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(f, tree)


def _zero_aux():
    return {"moe_lb_loss": jnp.zeros((), jnp.float32),
            "moe_z_loss": jnp.zeros((), jnp.float32)}


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(remat)


def _positions(tokens):
    b, s = tokens.shape[:2]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def _run_groups(params, cfg, dims, x, positions, *, causal, enc_mem, remat,
                ssm_chunk, act_spec, collect_cache=False, attn_chunk=2048,
                probs_dtype=jnp.float32):
    """Scan every layer group.  Returns (x, aux, caches|None)."""
    has_moe = cfg.num_experts > 0
    aux = _zero_aux() if has_moe else None
    caches = [] if collect_cache else None

    for (pspec, count), gparams in zip(layer_groups(cfg), params["groups"]):

        def body(carry, pslice, _pspec=pspec):
            x, aux = carry
            outs = []
            for i, spec in enumerate(_pspec):
                x, cache_out, aux = blocks.apply_layer(
                    pslice[i], x, dims, spec, positions=positions,
                    causal=causal, enc_mem=enc_mem, aux=aux,
                    ssm_chunk=ssm_chunk, attn_chunk=attn_chunk,
                    probs_dtype=probs_dtype)
                outs.append(cache_out)
            x = _maybe_constrain(x, act_spec)
            return (x, aux), (tuple(outs) if collect_cache else None)

        body = _remat_wrap(body, remat)
        (x, aux), ys = jax.lax.scan(body, (x, aux), gparams)
        if collect_cache:
            caches.append(ys)
    return x, aux, caches


def _encode(params, cfg, dims, enc_feats, *, remat, act_spec):
    """Encoder stack over precomputed frontend features (B, Ss, d)."""
    x = enc_feats
    positions = _positions(x)

    def body(carry, pslice):
        x, = carry
        x, _, _ = blocks.apply_layer(pslice[0], x, dims, ("A", False),
                                     positions=positions, causal=False,
                                     aux=None)
        return (_maybe_constrain(x, act_spec),), None

    body = _remat_wrap(body, remat)
    (x,), _ = jax.lax.scan(body, (x,), params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["norm"], x, cfg.rms_eps)


def forward(params, cfg: ArchConfig, dims: Dims, tokens, *, enc_feats=None,
            compute_dtype=jnp.bfloat16, remat: str = "full",
            ssm_chunk: int = 128, act_spec=None, logits_spec=None,
            attn_chunk: int = 2048, probs_dtype=jnp.float32):
    """Teacher-forced full-sequence forward.  tokens (B, S) int32.

    Returns (logits (B, S, vocab_padded) float32, aux dict).
    """
    wp = _cast(params, compute_dtype)
    x = embed(wp["embed"], tokens)
    x = _maybe_constrain(x, act_spec)
    enc_mem = None
    if cfg.is_encdec:
        assert enc_feats is not None, "encoder-decoder needs enc_feats"
        enc_mem = _encode(wp, cfg, dims, enc_feats.astype(compute_dtype),
                          remat=remat, act_spec=act_spec)
    x, aux, _ = _run_groups(wp, cfg, dims, x, _positions(tokens),
                            causal=True, enc_mem=enc_mem, remat=remat,
                            ssm_chunk=ssm_chunk, act_spec=act_spec,
                            attn_chunk=attn_chunk, probs_dtype=probs_dtype)
    x = rmsnorm(wp["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings:
        lg = jnp.einsum("bsd,vd->bsv", x, wp["embed"])
    else:
        lg = jnp.einsum("bsd,dv->bsv", x, wp["lm_head"])
    lg = _maybe_constrain(lg.astype(jnp.float32), logits_spec)
    return lg, (aux if aux is not None else _zero_aux())


def lm_loss(logits, labels, true_vocab: int, *, mask=None):
    """Cross entropy over the *unpadded* vocabulary (padded cols masked).

    The label term uses the one-hot-einsum form (not a gather) so it lowers
    to a local partial sum + small all-reduce when vocab is TP-sharded.
    """
    lg = mask_padded_vocab(logits, true_vocab)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
    num = jnp.einsum("bsv,bsv->bs", lg, onehot)
    nll = lse - num
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

class Cache(NamedTuple):
    """Decode state.  groups: per layer-group stacked per-layer caches."""
    groups: tuple
    lens: jax.Array            # (B,) tokens already in cache


def init_cache(cfg: ArchConfig, dims: Dims, batch: int, max_len: int,
               src_len: int = 0, dtype=jnp.bfloat16) -> Cache:
    groups = []
    for (pspec, count) in layer_groups(cfg):
        def one(_, _pspec=pspec):
            return tuple(blocks.init_layer_cache(dims, spec, batch, max_len,
                                                 src_len, dtype)
                         for spec in _pspec)
        stacked = jax.vmap(one)(jnp.arange(count))
        groups.append(stacked)
    return Cache(groups=tuple(groups), lens=jnp.zeros((batch,), jnp.int32))


def prefill(params, cfg: ArchConfig, dims: Dims, tokens, *, enc_feats=None,
            compute_dtype=jnp.bfloat16, ssm_chunk: int = 128, act_spec=None,
            attn_chunk: int = 2048):
    """Process a full prompt; returns (last-token logits, Cache).

    The returned attention caches have length = prompt length; the serving
    runtime re-bases them into a max_len cache (see launch/serve.py).
    """
    wp = _cast(params, compute_dtype)
    x = embed(wp["embed"], tokens)
    x = _maybe_constrain(x, act_spec)
    enc_mem = None
    if cfg.is_encdec:
        enc_mem = _encode(wp, cfg, dims, enc_feats.astype(compute_dtype),
                          remat="none", act_spec=act_spec)
    x, _, caches = _run_groups(wp, cfg, dims, x, _positions(tokens),
                               causal=True, enc_mem=enc_mem, remat="none",
                               ssm_chunk=ssm_chunk, act_spec=act_spec,
                               collect_cache=True, attn_chunk=attn_chunk)
    x = rmsnorm(wp["final_norm"], x[:, -1:], cfg.rms_eps)
    if cfg.tie_embeddings:
        lg = jnp.einsum("bsd,vd->bsv", x, wp["embed"])
    else:
        lg = jnp.einsum("bsd,dv->bsv", x, wp["lm_head"])
    b, s = tokens.shape
    cache = Cache(groups=tuple(caches),
                  lens=jnp.full((b,), s, jnp.int32))
    return lg.astype(jnp.float32), cache


def decode_step(params, cfg: ArchConfig, dims: Dims, token, cache: Cache, *,
                compute_dtype=jnp.bfloat16, act_spec=None):
    """One token for every sequence.  token (B, 1) int32 -> (logits, Cache)."""
    wp = _cast(params, compute_dtype)
    x = embed(wp["embed"], token)
    new_groups = []
    for (pspec, count), gparams, gcache in zip(layer_groups(cfg),
                                               wp["groups"], cache.groups):

        def body(carry, slices, _pspec=pspec):
            x, = carry
            pslice, cslice = slices
            new_c = []
            for i, spec in enumerate(_pspec):
                x, nc, _ = blocks.decode_layer(pslice[i], x, dims, spec,
                                               cslice[i], cache.lens, aux=None)
                new_c.append(nc)
            return (x,), tuple(new_c)

        (x,), new_cache = jax.lax.scan(body, (x,), (gparams, gcache))
        new_groups.append(new_cache)
    x = rmsnorm(wp["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings:
        lg = jnp.einsum("bsd,vd->bsv", x, wp["embed"])
    else:
        lg = jnp.einsum("bsd,dv->bsv", x, wp["lm_head"])
    return (lg.astype(jnp.float32),
            Cache(groups=tuple(new_groups), lens=cache.lens + 1))
