"""Mixture-of-Experts with GShard/Switch-style capacity dispatch.

Dispatch is the einsum formulation (one-hot dispatch/combine tensors over
token groups) -- the form XLA's SPMD partitioner understands natively: with
experts sharded over the `model` mesh axis and tokens over `data`, the
dispatch einsum lowers to the canonical all-to-all pair.  Group size is
fixed (GROUP = 1024 tokens) so the dispatch-tensor footprint stays
O(T * k * cf * d / E) regardless of batch (DESIGN.md §6).

Supports shared experts (DeepSeek-MoE: always-on experts added to the
routed output) and exposes the load-balancing + router-z auxiliary losses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import P, dense_init

GROUP = 1024


def init_moe(key, d: int, ff: int, num_experts: int, num_shared: int) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, num_experts), ("embed", "experts"),
                             scale=0.02),
        "w_gate": dense_init(ks[1], (num_experts, d, ff), ("experts", "embed", "expert_mlp")),
        "w_up": dense_init(ks[2], (num_experts, d, ff), ("experts", "embed", "expert_mlp")),
        "w_down": dense_init(ks[3], (num_experts, ff, d), ("experts", "expert_mlp", "embed_out")),
    }
    if num_shared:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, ff * num_shared)
    return p


def _dispatch_tensors(router_probs, top_k: int, capacity: int):
    """router_probs: (G, S, E) -> dispatch (G,S,E,C) bool-ish, combine f32.

    Sequential-choice position assignment (Switch Transformer): the k-th
    choice of every token is placed after all (k-1)-th choices so earlier
    choices win capacity.
    """
    g, s, e = router_probs.shape
    gates, idx = jax.lax.top_k(router_probs, top_k)              # (G,S,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((g, s, e, capacity), router_probs.dtype)
    combine = jnp.zeros((g, s, e, capacity), router_probs.dtype)
    # expert fill counts carried across the K sequential choices
    fill = jnp.zeros((g, e), jnp.int32)
    for k in range(top_k):
        onehot = jax.nn.one_hot(idx[:, :, k], e, dtype=jnp.int32)     # (G,S,E)
        # position of each token within its expert for this choice
        pos_in_e = jnp.cumsum(onehot, axis=1) - onehot + fill[:, None, :]
        pos = (pos_in_e * onehot).sum(-1)                             # (G,S)
        keep = pos < capacity
        pos_c = jnp.clip(pos, 0, capacity - 1)
        oh_cap = jax.nn.one_hot(pos_c, capacity, dtype=router_probs.dtype)
        sel = (onehot.astype(router_probs.dtype) * keep[..., None].astype(router_probs.dtype))
        dispatch = dispatch + sel[..., None] * oh_cap[:, :, None, :]
        combine = combine + (sel * gates[:, :, k:k + 1])[..., None] * oh_cap[:, :, None, :]
        fill = fill + onehot.sum(axis=1)
    return dispatch, combine, gates, idx


def moe_ffn(params, x, *, num_experts: int, top_k: int,
            capacity_factor: float, group: int = GROUP):
    """x: (B, S, d) -> (out (B, S, d), aux losses dict)."""
    b, s, d = x.shape
    t = b * s
    group = min(group, t)
    assert t % group == 0, (t, group)
    g = t // group
    xt = x.reshape(g, group, d)

    router_logits = jnp.einsum("gsd,de->gse", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    capacity = int(np.ceil(group * top_k * capacity_factor / num_experts))
    capacity = max(capacity, top_k)
    dispatch, combine, gates, idx = _dispatch_tensors(probs, top_k, capacity)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xt)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("egcd,edf->egcf", expert_in, params["w_up"])
    expert_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), expert_out)
    out = out.reshape(b, s, d)

    if "shared" in params:
        from .layers import mlp
        out = out + mlp(params["shared"], x)

    # aux: load-balance (Switch eq. 4-6) + router z-loss
    me = probs.mean(axis=(0, 1))                                  # (E,)
    one = jax.nn.one_hot(idx[..., 0], num_experts).mean(axis=(0, 1))
    lb_loss = num_experts * jnp.sum(me * one)
    z_loss = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    return out, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}
