"""Decoder/encoder layer blocks: mixer (attention | mamba) + FFN (dense | MoE)
with pre-norm residuals, plus the per-layer decode-step variants.

A layer's *spec* is ``(kind, moe)`` with kind in {'A', 'M'}; specs come from
``config._layer_list`` and drive both init (parameter structure) and apply.
Everything is shape-static so layers with equal specs stack under lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import Dims
from . import attention as attn
from . import ssm
from .layers import init_rmsnorm, rmsnorm, init_mlp, mlp
from .moe import init_moe, moe_ffn


def init_layer(key, dims: Dims, spec, *, cross: bool = False) -> dict:
    kind, moe = spec
    cfg = dims.cfg
    ks = jax.random.split(key, 4)
    p = {"mixer_norm": init_rmsnorm(cfg.d_model)}
    if kind == "A":
        p["attn"] = attn.init_attention(ks[0], dims)
    else:
        p["mamba"] = ssm.init_mamba(ks[0], dims)
    if cross:
        p["cross_norm"] = init_rmsnorm(cfg.d_model)
        p["cross"] = attn.init_attention(ks[1], dims, cross=True)
    if cfg.d_ff > 0:
        p["mlp_norm"] = init_rmsnorm(cfg.d_model)
        if moe:
            p["moe"] = init_moe(ks[2], cfg.d_model, cfg.d_ff,
                                cfg.num_experts, cfg.num_shared_experts)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.dense_ff or cfg.d_ff)
    return p


def _ffn(params, x, dims: Dims, aux):
    cfg = dims.cfg
    if "moe" in params:
        h, moe_aux = moe_ffn(params["moe"], rmsnorm(params["mlp_norm"], x, cfg.rms_eps),
                             num_experts=cfg.num_experts,
                             top_k=cfg.num_experts_per_tok,
                             capacity_factor=cfg.capacity_factor)
        aux = {k: aux.get(k, 0.0) + v for k, v in moe_aux.items()} if aux is not None else aux
        return x + h, aux
    if "mlp" in params:
        return x + mlp(params["mlp"], rmsnorm(params["mlp_norm"], x, cfg.rms_eps)), aux
    return x, aux


def apply_layer(params, x, dims: Dims, spec, *, positions, causal=True,
                enc_mem=None, aux=None, ssm_chunk: int = ssm.DEFAULT_CHUNK,
                attn_chunk: int = 2048, probs_dtype=jnp.float32):
    """Full-sequence layer (train / prefill).  Returns (x, cache_out, aux).

    cache_out carries whatever decode needs: attention K/V of this pass,
    mamba final states, cross-attention memory K/V.
    """
    kind, _ = spec
    cfg = dims.cfg
    cache_out = {}
    h = rmsnorm(params["mixer_norm"], x, cfg.rms_eps)
    if kind == "A":
        out, (k, v) = attn.attention_block(params["attn"], h, dims, positions,
                                           causal=causal, chunk=attn_chunk,
                                           probs_dtype=probs_dtype)
        cache_out["k"], cache_out["v"] = k, v
    else:
        out, states = ssm.mamba_block(params["mamba"], h, dims, chunk=ssm_chunk)
        cache_out["mamba"] = states
    x = x + out
    if "cross" in params:
        h = rmsnorm(params["cross_norm"], x, cfg.rms_eps)
        out, (mk, mv) = attn.attention_block(params["cross"], h, dims, positions,
                                             causal=False, kv_override=enc_mem,
                                             chunk=attn_chunk,
                                             probs_dtype=probs_dtype)
        cache_out["mk"], cache_out["mv"] = mk, mv
        x = x + out
    x, aux = _ffn(params, x, dims, aux)
    return x, cache_out, aux


def decode_layer(params, x, dims: Dims, spec, cache, lens, *, aux=None):
    """One-token layer step.  x (B,1,d); cache is this layer's state dict."""
    kind, _ = spec
    cfg = dims.cfg
    h = rmsnorm(params["mixer_norm"], x, cfg.rms_eps)
    new_cache = dict(cache)
    if kind == "A":
        out, ck, cv = attn.decode_attention_block(params["attn"], h, dims,
                                                  cache["k"], cache["v"], lens)
        new_cache["k"], new_cache["v"] = ck, cv
    else:
        out, st = ssm.mamba_decode_step(params["mamba"], h, dims,
                                        cache["mamba"]["conv"], cache["mamba"]["ssm"])
        new_cache["mamba"] = st
    x = x + out
    if "cross" in params:
        h = rmsnorm(params["cross_norm"], x, cfg.rms_eps)
        out = attn.decode_cross_attention_block(params["cross"], h, dims,
                                                cache["mk"], cache["mv"])
        x = x + out
    x, aux = _ffn(params, x, dims, aux)
    return x, new_cache, aux


def init_layer_cache(dims: Dims, spec, batch: int, max_len: int, src_len: int = 0,
                     dtype=jnp.bfloat16) -> dict:
    """Zero decode cache for one layer."""
    kind, _ = spec
    cfg = dims.cfg
    c = {}
    if kind == "A":
        c["k"] = jnp.zeros((batch, max_len, dims.kv_heads, cfg.head_dim), dtype)
        c["v"] = jnp.zeros((batch, max_len, dims.kv_heads, cfg.head_dim), dtype)
    else:
        c["mamba"] = ssm.init_mamba_state(dims, batch, dtype)
    if cfg.is_encdec and src_len > 0:
        c["mk"] = jnp.zeros((batch, src_len, dims.kv_heads, cfg.head_dim), dtype)
        c["mv"] = jnp.zeros((batch, src_len, dims.kv_heads, cfg.head_dim), dtype)
    return c
