"""Architecture configuration + TP-derived dimensions.

``ArchConfig`` carries the published architecture hyper-parameters verbatim
(the 10 assigned configs live in repro.configs).  ``Dims`` derives the
mesh-dependent padded dimensions: query heads are padded up to a multiple of
the tensor-parallel degree, KV heads are repeat-expanded when kv < tp, and
the vocabulary is padded to a multiple of 128 -- the standard divisibility
moves for a fixed (data, model) mesh; the resulting FLOP/byte overhead is
reported in the roofline's MODEL_FLOPS / HLO_FLOPs ratio (EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_period: int = 1         # a layer is MoE iff layer % moe_period == moe_offset
    moe_offset: int = 0
    leading_dense_layers: int = 0
    capacity_factor: float = 1.25
    dense_ff: int = 0           # d_ff for non-MoE layers when it differs (deepseek)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    # --- hybrid ---
    layer_pattern: str = ""     # one char per layer in a period: 'A' attn, 'M' mamba
    # --- enc-dec ---
    encoder_layers: int = 0     # > 0 => encoder-decoder
    # --- flags ---
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    rms_eps: float = 1e-5
    frontend: str = "none"      # 'audio'/'vision': inputs are precomputed embeddings
    # modality frontend stub: source features arrive as (B, S_src, d_model)

    # ------------------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def pattern(self) -> str:
        """Per-period layer pattern; uniform models are a period of 1."""
        if self.layer_pattern:
            return self.layer_pattern
        return "M" if self.attention_free else "A"

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period == 0, (self.name, self.num_layers, self.period)
        return self.num_layers // self.period

    def is_moe_layer(self, layer_in_period: int) -> bool:
        if self.num_experts == 0:
            return False
        return layer_in_period % self.moe_period == self.moe_offset

    # SSM derived sizes
    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def supports_long_context(self) -> bool:
        """Sub-quadratic path exists iff some layers are attention-free."""
        return "M" in self.pattern

    def param_count(self) -> int:
        """Exact parameter count of the unpadded architecture."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                         # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                     # lm head
        layers = _layer_list(self)
        for (kind, moe) in layers:
            n += d                                       # mixer norm
            if kind == "A":
                hd = self.head_dim
                n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                n += self.num_heads * hd * d
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * hd
            elif kind == "M":
                di, g, N, h = self.ssm_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
                n += d * (2 * di + 2 * g * N + h)        # in projections
                n += (di + 2 * g * N) * self.ssm_conv    # conv
                n += 3 * h + di                          # A_log, D, dt_bias, norm
                n += di * d                              # out proj
            if self.d_ff > 0:
                n += d                                   # mlp norm
                if moe:
                    fe = self.d_ff
                    n += d * self.num_experts            # router
                    n += self.num_experts * 3 * d * fe
                    n += self.num_shared_experts * 3 * d * fe
                else:
                    n += 3 * d * self.d_ff
        if self.is_encdec:
            # encoder layers: self-attn + mlp (+ cross-attn params in decoder
            # are already counted above? no -- add cross attn for decoder)
            hd = self.head_dim
            enc = self.encoder_layers * (
                2 * d + d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d + 3 * d * self.d_ff)
            cross = self.num_layers * (
                d + d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d)
            n += enc + cross
        n += d                                           # final norm
        return n

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k + shared only)."""
        if self.num_experts == 0:
            return self.param_count()
        d, fe = self.d_model, self.d_ff
        total = self.param_count()
        layers = _layer_list(self)
        n_moe = sum(1 for (_, moe) in layers if moe)
        inactive = n_moe * (self.num_experts - self.num_experts_per_tok) * 3 * d * fe
        return total - inactive


def _layer_list(cfg: ArchConfig) -> list[tuple[str, bool]]:
    """[(kind, is_moe)] for every decoder layer."""
    out = []
    for layer in range(cfg.num_layers):
        lp = layer % cfg.period
        kind = cfg.pattern[lp]
        moe = cfg.is_moe_layer(lp) and layer >= cfg.leading_dense_layers
        out.append((kind, moe))
    return out


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class Dims:
    """Mesh-derived dimensions (see module docstring)."""
    cfg: ArchConfig
    tp: int
    heads: int            # padded query heads
    kv_heads: int         # expanded kv heads
    vocab: int            # padded vocab
    ssm_heads: int

    @property
    def q_per_kv(self) -> int:
        return self.heads // self.kv_heads

    @property
    def attn_pad_waste(self) -> float:
        if self.cfg.num_heads == 0:
            return 0.0
        return self.heads / self.cfg.num_heads - 1.0


def compute_dims(cfg: ArchConfig, tp: int = 1) -> Dims:
    if cfg.attention_free:
        heads = kv = 0
    else:
        heads = pad_to(cfg.num_heads, tp)
        kv = cfg.num_kv_heads
        if kv < tp:
            assert tp % kv == 0 or kv % tp == 0
            kv = tp if tp % kv == 0 else kv
        # kv heads must also divide padded query heads evenly
        while heads % kv != 0:
            kv += 1
        assert heads % kv == 0
    vocab = pad_to(cfg.vocab_size, max(128, tp))
    ssm_heads = pad_to(cfg.ssm_heads, tp) if "M" in cfg.pattern else 0
    return Dims(cfg=cfg, tp=tp, heads=heads, kv_heads=kv, vocab=vocab,
                ssm_heads=ssm_heads)
