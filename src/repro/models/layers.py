"""Common layers: param plumbing with logical sharding axes, norms, MLP,
embeddings, RoPE.

Parameters are plain pytrees of arrays.  During init every leaf is built as
a ``P(value, axes)`` pair carrying *logical* axis names; ``split_tree``
separates the value tree (params) from the axes tree, and
:mod:`repro.launch.shardings` maps logical names -> mesh axes to produce
NamedShardings.  This is the t5x/MaxText "logical axis rules" pattern
without a framework dependency.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class P:
    """A parameter leaf: array value + *static* logical axis names.

    Registered as a pytree node with ``axes`` as aux data, so vmap / scan /
    jit treat it as a transparent array container (vmap over init stacks the
    value and leaves the axis names alone).
    """
    value: jax.Array
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def is_p(x) -> bool:
    return isinstance(x, P)


def split_tree(tree):
    """Tree of P -> (params tree, logical-axes tree)."""
    params = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_p)
    return params, axes


def add_leading_axis_name(tree, name: str):
    """Prefix every P's logical axes with ``name`` (stacked-layer params)."""
    return jax.tree_util.tree_map(
        lambda p: P(p.value, (name,) + tuple(p.axes)), tree, is_leaf=is_p)


def dense_init(key, shape, axes, scale=None, dtype=jnp.float32) -> P:
    """Truncated-normal fan-in init (LeCun-ish, matching common LM practice)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    return P(v, axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> P:
    return P(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> P:
    return P(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> P:
    return ones_init((d,), ("norm",))


def rmsnorm(scale, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int) -> P:
    return dense_init(key, (vocab, d), ("vocab", "embed"), scale=1.0)


def embed(table, token_ids):
    return jnp.take(table, token_ids, axis=0)


def logits(table_or_head, x, *, transpose: bool):
    """x (..., d) -> (..., vocab).  transpose=True for tied embeddings."""
    if transpose:
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)


def mask_padded_vocab(lg, true_vocab: int):
    """Padded vocabulary ids never win: set their logits to -inf."""
    v = lg.shape[-1]
    if v == true_vocab:
        return lg
    neg = jnp.finfo(lg.dtype).min
    col = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    return jnp.where(col >= true_vocab, neg, lg)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    exponent = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return jnp.asarray(1.0 / (theta ** exponent))     # (hd/2,)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), ("embed", "mlp")),
        "w_up": dense_init(k2, (d, ff), ("embed", "mlp")),
        "w_down": dense_init(k3, (ff, d), ("mlp", "embed_out")),
    }


def mlp(params, x):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, params["w_gate"]))
    h = h * jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", h, params["w_down"])
