"""GQA attention: full, chunked-flash (for long sequences), and KV-cache
decode.  Pure JAX; grouped query layout throughout (no KV head expansion --
KV heads stay a separate einsum dimension, which matters for both the 32k
prefill memory footprint and the sharded decode path).

Chunked-flash = lax.scan over (q-chunk x kv-chunk) tiles with the online
softmax recurrence (running max m, normalizer l, weighted accumulator) --
the standard memory-bounded attention for 32k+ sequences in pure jnp.  On
real TPU this is where a splash/flash Pallas kernel would slot in; the
paper's own kernels are the sketch path, so attention stays jnp (DESIGN.md
§3).  Causal masking is per-tile; fully-masked tiles are still computed
(static shapes) -- the ~2x FLOP overhead is visible in the roofline's
MODEL_FLOPS/HLO ratio and is attacked in the §Perf loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import Dims
from .layers import P, dense_init, zeros_init, apply_rope

NEG_INF = -1e30


def init_attention(key, dims: Dims, *, cross: bool = False) -> dict:
    cfg = dims.cfg
    d, h, kv, hd = cfg.d_model, dims.heads, dims.kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), ("embed", "heads", "hd")),
        "wk": dense_init(ks[1], (d, kv, hd), ("embed", "kv", "hd")),
        "wv": dense_init(ks[2], (d, kv, hd), ("embed", "kv", "hd")),
        "wo": dense_init(ks[3], (h, hd, d), ("heads", "hd", "embed_out"),
                         scale=1.0 / np.sqrt(h * hd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = zeros_init((h, hd), ("heads", "hd"))
        p["bk"] = zeros_init((kv, hd), ("kv", "hd"))
        p["bv"] = zeros_init((kv, hd), ("kv", "hd"))
    return p


def _project_q(params, x, positions, theta, *, rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    if rope:
        q = apply_rope(q, positions, theta)
    return q


def _project_kv(params, x, positions, theta, *, rope=True):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    if rope:
        k = apply_rope(k, positions, theta)
    return k, v


def _grouped(q, kv_heads):
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, hd)


def full_attention(q, k, v, *, causal: bool, q_offset=0, kv_valid=None,
                   probs_dtype=jnp.float32):
    """Dense attention.  q (B,Sq,H,hd); k,v (B,Skv,KV,hd).

    kv_valid: optional (B, Skv) bool mask of valid cache slots.
    q_offset: absolute position of q[:, 0] (for causal masking vs a cache).
    probs_dtype: bf16 halves the O(S^2) probability-matrix HBM traffic (the
    dominant memory term at 4k+ with materialized attention); softmax max/
    normalizer stay f32.
    """
    kv_h = k.shape[2]
    qg = _grouped(q, kv_h)                                # (B,Sq,KV,G,hd)
    scale = 1.0 / np.sqrt(q.shape[-1])
    # bf16 x bf16 -> f32 accumulate on the MXU; casting K to f32 first would
    # materialize an f32 copy of the whole KV cache per layer (measured as
    # the dominant decode HBM term in the dry-run; EXPERIMENTS.md §Perf).
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    sq, skv = scores.shape[-2], scores.shape[-1]
    if causal:
        qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 1)
        scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(probs_dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs,
                     v if v.dtype == probs_dtype else v.astype(probs_dtype),
                     preferred_element_type=jnp.float32)
    b, sq_, kvh, g, hd = out.shape
    return out.reshape(b, sq_, kvh * g, hd).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 2048,
                      kv_chunk: int = 2048, probs_dtype=jnp.float32):
    """Flash-style online-softmax attention, O(S * chunk) memory."""
    b, sq, h, hd = q.shape
    skv, kv_h = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0
    nq, nk = sq // q_chunk, skv // kv_chunk
    g = h // kv_h
    scale = 1.0 / np.sqrt(hd)

    qg = _grouped(q, kv_h).reshape(b, nq, q_chunk, kv_h, g, hd)
    kc = k.reshape(b, nk, kv_chunk, kv_h, hd)
    vc = v.reshape(b, nk, kv_chunk, kv_h, hd)

    def q_step(_, qi_qblock):
        qi, qblock = qi_qblock                     # qblock (B, Cq, KV, G, hd)
        m0 = jnp.full((b, kv_h, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_h, g, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, kv_h, g, q_chunk, hd), jnp.float32)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblock, vblock = ki_kv
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblock, kblock,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jax.lax.broadcasted_iota(
                    jnp.int32, (q_chunk, kv_chunk), 0)
                kpos = ki * kv_chunk + jax.lax.broadcasted_iota(
                    jnp.int32, (q_chunk, kv_chunk), 1)
                s = jnp.where(qpos >= kpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new stays at NEG_INF)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where((m_new > 0.5 * NEG_INF)[..., None], p, 0.0)
            alpha = jnp.where(m > 0.5 * NEG_INF, jnp.exp(m - m_new), 0.0)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(probs_dtype),
                vblock.astype(probs_dtype),
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,KV,G,Cq,hd)
        return None, out

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    # outs: (nq, B, KV, G, Cq, hd) -> (B, nq, Cq, KV, G, hd) -> (B, S, H, hd)
    outs = outs.transpose(1, 0, 4, 2, 3, 5)
    return outs.reshape(b, sq, h, hd).astype(q.dtype)


CHUNKED_THRESHOLD = 8192


def attention_block(params, x, dims: Dims, positions, *, causal=True,
                    kv_override=None, rope=True, chunk: int = 2048,
                    probs_dtype=jnp.float32):
    """Full train/prefill attention over x (B, S, d).

    ``chunk``: q/kv tile size of the flash-chunked path (perf lever;
    sequences <= CHUNKED_THRESHOLD use the dense path).
    """
    cfg = dims.cfg
    q = _project_q(params, x, positions, cfg.rope_theta, rope=rope)
    src = x if kv_override is None else kv_override
    kv_pos = positions if kv_override is None else (
        jnp.broadcast_to(jnp.arange(src.shape[1], dtype=jnp.int32)[None],
                         src.shape[:2]))
    k, v = _project_kv(params, src, kv_pos, cfg.rope_theta, rope=rope)
    if x.shape[1] > CHUNKED_THRESHOLD or src.shape[1] > CHUNKED_THRESHOLD:
        out = chunked_attention(q, k, v, causal=causal, q_chunk=chunk,
                                kv_chunk=chunk, probs_dtype=probs_dtype)
    else:
        out = full_attention(q, k, v, causal=causal, probs_dtype=probs_dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


def decode_attention_block(params, x, dims: Dims, cache_k, cache_v, lens,
                           *, rope=True):
    """One-token decode against a cache.

    x: (B, 1, d); cache_k/v: (B, S_max, KV, hd); lens: (B,) current lengths.
    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    cfg = dims.cfg
    b, smax = cache_k.shape[0], cache_k.shape[1]
    positions = lens[:, None].astype(jnp.int32)                  # (B, 1)
    q = _project_q(params, x, positions, cfg.rope_theta, rope=rope)
    k_new, v_new = _project_kv(params, x, positions, cfg.rope_theta, rope=rope)
    batch_idx = jnp.arange(b)
    cache_k = cache_k.at[batch_idx, lens].set(k_new[:, 0])
    cache_v = cache_v.at[batch_idx, lens].set(v_new[:, 0])
    valid = (jax.lax.broadcasted_iota(jnp.int32, (b, smax), 1)
             <= lens[:, None])
    out = full_attention(q, cache_k, cache_v, causal=False, kv_valid=valid)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache_k, cache_v


def decode_cross_attention_block(params, x, dims: Dims, mem_k, mem_v):
    """Cross-attention during decode: static encoder memory, no cache write."""
    q = _project_q(params, x, jnp.zeros(x.shape[:2], jnp.int32),
                   dims.cfg.rope_theta, rope=False)
    out = full_attention(q, mem_k, mem_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])
