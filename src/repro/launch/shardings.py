"""Logical axis names -> mesh PartitionSpecs (t5x/MaxText-style rules).

TP ("model"): attention heads, d_ff columns, vocab, experts, SSM heads.
FSDP (all batch axes, i.e. ("pod","data") multi-pod / ("data",) single):
the d_model ("embed"/"embed_out") axis of every large matrix -- XLA
all-gathers one scanned layer at a time, so peak weight memory per device is
O(params / (fsdp * tp) + one layer).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .mesh import batch_axes


def logical_rules(mesh) -> dict:
    fsdp = batch_axes(mesh)
    return {
        "layers": None,
        "vocab": "model",
        "embed": fsdp,
        "embed_out": fsdp,
        "heads": "model",
        "kv": "model",
        "hd": None,
        "mlp": "model",
        "experts": "model",
        "expert_mlp": None,
        "norm": None,
        "ssm_heads": "model",
        "ssm_group": None,
        "state": None,
        "conv": None,
        "conv_ch": None,
    }


def _is_axes_tuple(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, str) for a in x)


def axes_to_pspec(axes: tuple, rules: dict) -> PartitionSpec:
    return PartitionSpec(*[rules[a] for a in axes])


def param_pspecs(mesh, axes_tree):
    """Logical-axes tree (from layers.split_tree) -> PartitionSpec tree."""
    rules = logical_rules(mesh)
    return jax.tree_util.tree_map(
        lambda ax: axes_to_pspec(ax, rules), axes_tree, is_leaf=_is_axes_tuple)


def param_shardings(mesh, axes_tree):
    return jax.tree_util.tree_map(
        lambda ax: NamedSharding(mesh, axes_to_pspec(ax, logical_rules(mesh))),
        axes_tree, is_leaf=_is_axes_tuple)


def activation_pspec(mesh, *, seq_parallel: bool = False) -> PartitionSpec:
    """(B, S, d) activations: batch over all data axes.

    seq_parallel=True additionally shards the SEQUENCE dim over the model
    axis between blocks (Megatron sequence parallelism): GSPMD then lowers
    the TP boundary all-reduces into reduce-scatter + all-gather pairs --
    half the wire bytes -- and norms/elementwise run on S/tp tokens.
    """
    return PartitionSpec(batch_axes(mesh), "model" if seq_parallel else None,
                         None)


def logits_pspec(mesh) -> PartitionSpec:
    return PartitionSpec(batch_axes(mesh), None, "model")


def batch_pspec(mesh) -> PartitionSpec:
    return PartitionSpec(batch_axes(mesh), None)


def cache_pspecs(mesh, cache, *, seq_sharded: bool) -> "jax.tree":
    """PartitionSpec tree for a model.Cache.

    seq_sharded=True (long-context decode, batch < data shards): attention
    K/V caches shard their *sequence* dim over the data axes and heads over
    model; otherwise batch shards over data and heads over model.
    """
    bd = batch_axes(mesh)
    b_ax = None if seq_sharded else bd
    s_ax = bd if seq_sharded else None

    def spec_for(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        nd = leaf.ndim
        if name in ("k", "v", "mk", "mv"):
            # (layers, B, S, KV, hd)
            return PartitionSpec(None, b_ax, s_ax, "model", None)
        if name == "ssm":
            # (layers, B, H, N, P)
            return PartitionSpec(None, bd if not seq_sharded else None, "model",
                                 None, None)
        if name == "x":
            # conv state (layers, B, K-1, H*P)
            return PartitionSpec(None, b_ax, None, "model")
        if name == "bc":
            return PartitionSpec(None, b_ax, None, None)
        if nd == 1:      # lens (B,)
            return PartitionSpec(b_ax)
        return PartitionSpec(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def to_shardings(mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
