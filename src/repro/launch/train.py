"""Distributed train step: forward/backward + optimizer + SJPC stream monitor.

``make_train_step(cfg, dims, mesh, ...)`` returns (step_fn, state_specs):
step_fn is jit-able with every input/output sharding pinned down, so the
same function serves the real driver (runtime/driver.py) and the dry-run
(launch/dryrun.py lowers it with ShapeDtypeStructs).

The SJPC monitor update runs under shard_map with DEVICE-LOCAL counters
(deferred merge; DESIGN.md §7.1) -- it adds zero collectives to the step.
The runnable driver lives in examples/train_lm_sketch.py (+ runtime/driver
for fault tolerance).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import compat
from repro.models import model as M
from repro.models.config import ArchConfig, Dims
from repro.models.layers import split_tree
from repro.optim.adamw import Optimizer, make_adamw
from repro.optim.schedules import warmup_cosine
from repro.sketchstream.monitor import (SketchMonitorConfig, MonitorState,
                                        init_monitor, monitor_update_local)
from . import shardings as SH
from .mesh import batch_axes, data_shards


class TrainState(NamedTuple):
    params: Any
    opt: Any
    monitor: Any           # MonitorState | None
    step: jax.Array


MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 0.001


def make_train_step(cfg: ArchConfig, dims: Dims, optimizer: Optimizer,
                    mesh=None, *, monitor_cfg: SketchMonitorConfig | None = None,
                    monitor_params=None, remat: str = "full",
                    ssm_chunk: int = 128, attn_chunk: int = 2048,
                    compute_dtype=jnp.bfloat16, seq_parallel: bool = False,
                    probs_dtype=jnp.float32):
    """Returns step_fn(state, batch) -> (state, metrics)."""
    act_spec = (SH.activation_pspec(mesh, seq_parallel=seq_parallel)
                if mesh is not None else None)
    logits_spec = SH.logits_pspec(mesh) if mesh is not None else None
    bd = batch_axes(mesh) if mesh is not None else None

    def loss_fn(params, batch):
        logits, aux = M.forward(params, cfg, dims, batch["tokens"],
                                enc_feats=batch.get("enc_feats"),
                                compute_dtype=compute_dtype, remat=remat,
                                ssm_chunk=ssm_chunk, attn_chunk=attn_chunk,
                                act_spec=act_spec, logits_spec=logits_spec,
                                probs_dtype=probs_dtype)
        loss = M.lm_loss(logits, batch["labels"], cfg.vocab_size,
                         mask=batch.get("mask"))
        total = loss
        if cfg.num_experts:
            total = (total + MOE_LB_WEIGHT * aux["moe_lb_loss"]
                     + MOE_Z_WEIGHT * aux["moe_z_loss"])
        return total, (loss, aux)

    def update_monitor(monitor: MonitorState, tokens, step):
        if monitor_cfg is None:
            return monitor
        if mesh is None or monitor.counters.shape[0] == 1:
            # paper-faithful merged mode: counters replicated, tokens batch-
            # sharded -> GSPMD inserts the per-step all-reduce (this is the
            # baseline the deferred-merge optimization is measured against).
            c, n = monitor_update_local(monitor_cfg, monitor_params,
                                        monitor.counters[0], monitor.n[0],
                                        tokens, step)
            return MonitorState(c[None], n[None], step)

        def local(counters_blk, n_blk, tokens_blk):
            c, n = monitor_update_local(monitor_cfg, monitor_params,
                                        counters_blk[0], n_blk[0],
                                        tokens_blk, step)
            return c[None], n[None]

        c, n = compat.shard_map(
            local, mesh=mesh,
            in_specs=(PartitionSpec(bd, None, None, None),
                      PartitionSpec(bd),
                      PartitionSpec(bd, None)),
            out_specs=(PartitionSpec(bd, None, None, None),
                       PartitionSpec(bd)),
            check_vma=False,
        )(monitor.counters, monitor.n, tokens)
        return MonitorState(c, n, step)

    def step_fn(state: TrainState, batch):
        (total, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        params, opt, stats = optimizer.update(grads, state.opt, state.params)
        monitor = update_monitor(state.monitor, batch["tokens"], state.step)
        metrics = {"loss": loss, "total_loss": total, **stats}
        if cfg.num_experts:
            metrics.update({k: aux[k] for k in ("moe_lb_loss", "moe_z_loss")})
        return TrainState(params, opt, monitor, state.step + 1), metrics

    return step_fn


def make_train_state(key, cfg: ArchConfig, dims: Dims, optimizer: Optimizer,
                     *, monitor_cfg: SketchMonitorConfig | None = None):
    """Host-side init (small models / tests).  Returns (state, monitor_params,
    logical axes tree for shardings)."""
    ptree = M.init_params(key, cfg, dims)
    params, axes = split_tree(ptree)
    opt = optimizer.init(params)
    monitor = monitor_params = None
    if monitor_cfg is not None:
        monitor_params, monitor = init_monitor(monitor_cfg)
    return (TrainState(params, opt, monitor, jnp.zeros((), jnp.int32)),
            monitor_params, axes)


def state_shardings(mesh, state: TrainState, axes_tree):
    """NamedSharding tree for a TrainState (AdamW-style opt states that
    mirror params; Q8 states carry their own specs via q8sharded)."""
    pshard = SH.param_shardings(mesh, axes_tree)
    rep = NamedSharding(mesh, PartitionSpec())
    bd = batch_axes(mesh)

    # AdamW state: same tree structure as params for m/v; step scalar.
    from repro.optim.adamw import AdamWState
    if isinstance(state.opt, AdamWState):
        opt = AdamWState(step=rep,
                         m=jax.tree_util.tree_map(lambda s: s, pshard),
                         v=jax.tree_util.tree_map(lambda s: s, pshard))
    else:
        opt = jax.tree_util.tree_map(lambda _: rep, state.opt)
    mon = None
    if state.monitor is not None:
        shards = state.monitor.counters.shape[0]
        cspec = PartitionSpec(bd, None, None, None) if shards > 1 else PartitionSpec()
        nspec = PartitionSpec(bd) if shards > 1 else PartitionSpec()
        mon = MonitorState(counters=NamedSharding(mesh, cspec),
                           n=NamedSharding(mesh, nspec), step=rep)
    return TrainState(params=pshard, opt=opt, monitor=mon, step=rep)
