"""Serving steps: prefill and single-token decode with sharded KV caches.

Two cache sharding regimes (DESIGN.md §6):
  - ``decode_32k`` (batch >= data shards): batch over data axes, KV heads
    over model.
  - ``long_500k`` (batch < data shards): *sequence* over data axes --
    distributed-softmax decode; the score vector all-gather is tiny
    compared to the cache it avoids replicating.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import model as M
from repro.models.config import ArchConfig, Dims
from . import shardings as SH
from .mesh import batch_axes, data_shards


def seq_sharded_mode(mesh, batch: int) -> bool:
    return mesh is not None and batch < data_shards(mesh)


def make_prefill(cfg: ArchConfig, dims: Dims, mesh=None, *,
                 ssm_chunk: int = 128, attn_chunk: int = 2048,
                 compute_dtype=jnp.bfloat16):
    act_spec = SH.activation_pspec(mesh) if mesh is not None else None

    def prefill_fn(params, tokens, enc_feats=None):
        return M.prefill(params, cfg, dims, tokens, enc_feats=enc_feats,
                         compute_dtype=compute_dtype, ssm_chunk=ssm_chunk,
                         act_spec=act_spec, attn_chunk=attn_chunk)
    return prefill_fn


def make_decode_step(cfg: ArchConfig, dims: Dims, mesh=None, *,
                     compute_dtype=jnp.bfloat16):
    def decode_fn(params, token, cache):
        return M.decode_step(params, cfg, dims, token, cache,
                             compute_dtype=compute_dtype)
    return decode_fn


def greedy_generate(params, cfg: ArchConfig, dims: Dims, prompt, steps: int,
                    *, max_len: int = None, compute_dtype=jnp.float32,
                    ssm_chunk: int = 8, enc_feats=None):
    """Small-scale reference generation loop (examples/tests): prefill the
    prompt into a padded cache, then greedy decode ``steps`` tokens."""
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    src_len = enc_feats.shape[1] if enc_feats is not None else 0
    logits, pcache = M.prefill(params, cfg, dims, prompt, enc_feats=enc_feats,
                               compute_dtype=compute_dtype, ssm_chunk=ssm_chunk)
    cache = M.init_cache(cfg, dims, b, max_len, src_len=src_len,
                         dtype=compute_dtype)
    cache = _rebase_cache(cache, pcache, s)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = M.decode_step(params, cfg, dims, tok, cache,
                                      compute_dtype=compute_dtype)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _rebase_cache(empty: M.Cache, pcache: M.Cache, prompt_len: int) -> M.Cache:
    """Copy prefill K/V (length S) into the max_len decode cache; carry
    mamba states and cross memories through."""
    def merge(path, e, p):
        names = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]
        name = names[-1] if names else None
        if name in ("k", "v"):
            return jax.lax.dynamic_update_slice_in_dim(
                e, p.astype(e.dtype), 0, axis=2)   # (layers, B, S, KV, hd)
        return p.astype(e.dtype) if e.shape == p.shape else p

    # prefill cache groups have same tree structure per layer for k/v/mamba;
    # walk the two trees together.
    groups = jax.tree_util.tree_map_with_path(
        merge, empty.groups, pcache.groups)
    return M.Cache(groups=groups, lens=pcache.lens)


def cache_shardings(mesh, cfg: ArchConfig, dims: Dims, batch: int,
                    max_len: int, src_len: int = 0, dtype=jnp.bfloat16,
                    layout: str = "auto"):
    """(abstract cache, NamedSharding tree) for jit in/out shardings.

    layout: "auto" picks seq-sharding when batch < data shards;
    "batch"/"seq" force a regime (perf-iteration lever).
    """
    abstract = jax.eval_shape(
        lambda: M.init_cache(cfg, dims, batch, max_len, src_len=src_len,
                             dtype=dtype))
    seq = (seq_sharded_mode(mesh, batch) if layout == "auto"
           else layout == "seq")
    pspecs = SH.cache_pspecs(mesh, abstract, seq_sharded=seq)
    return abstract, SH.to_shardings(mesh, pspecs)
